//! Sharded-harness scaling: wall-clock of the Fig 12 workload grid
//! (benchmarks x {baseline, malekeh, bow, malekeh_pr}) executed at
//! increasing `--jobs`, with the bit-identity cross-check against the
//! serial run. Records the speedup table cited in CHANGES.md.
//!
//!     cargo bench --bench parallel_scaling [--quick|--full] [--sms N]
//!                                          [--max-jobs N]

use std::time::Instant;

use malekeh::config::Scheme;
use malekeh::harness::{ExpOpts, Plan, Runner};

const SCHEMES: [Scheme; 4] =
    [Scheme::BASELINE, Scheme::MALEKEH, Scheme::BOW, Scheme::MALEKEH_PR];

fn grid_plan(runner: &Runner) -> Plan {
    let mut plan = runner.plan();
    for bench in runner.opts().benchmarks() {
        for scheme in SCHEMES {
            plan.add(bench, scheme);
        }
    }
    plan
}

/// Execute the grid on a fresh runner with `jobs` workers; return
/// (seconds, fingerprint over all resulting stats).
fn timed_run(base: &ExpOpts, jobs: usize) -> (f64, u64) {
    let mut opts = base.clone();
    opts.jobs = jobs;
    let runner = Runner::new(opts);
    let plan = grid_plan(&runner);
    let t0 = Instant::now();
    runner.execute(&plan);
    let secs = t0.elapsed().as_secs_f64();
    // order-sensitive combine of the per-point digests (Stats::fingerprint
    // covers every deterministic counter)
    let mut fp = 0u64;
    for bench in runner.opts().benchmarks() {
        for scheme in SCHEMES {
            let s = runner.run(bench, scheme);
            fp = fp.rotate_left(1) ^ s.fingerprint();
        }
    }
    (secs, fp)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut base = ExpOpts::from_args(&args);
    if !args.iter().any(|a| a == "--full") {
        base.quick = true; // the grid is wide; default to the quick set
    }
    let mut max_jobs = base.effective_jobs().max(4);
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--max-jobs" {
            i += 1;
            max_jobs = args
                .get(i)
                .expect("--max-jobs requires a value (--max-jobs N)")
                .parse()
                .expect("bad value for --max-jobs (--max-jobs N)");
        }
        i += 1;
    }

    let points = base.benchmarks().len() * SCHEMES.len();
    println!(
        "== parallel harness scaling: {} sims (quick={}, sms={}) ==",
        points, base.quick, base.num_sms
    );
    println!("{:<8}{:>12}{:>12}{:>20}", "jobs", "seconds", "speedup", "fingerprint");

    let (serial_secs, serial_fp) = timed_run(&base, 1);
    println!("{:<8}{:>12.2}{:>12.2}{:>20x}", 1, serial_secs, 1.0, serial_fp);
    let mut jobs = 2;
    while jobs <= max_jobs {
        let (secs, fp) = timed_run(&base, jobs);
        assert_eq!(
            fp, serial_fp,
            "jobs={jobs} produced different stats than serial — determinism broken"
        );
        println!(
            "{:<8}{:>12.2}{:>12.2}{:>20x}",
            jobs,
            secs,
            serial_secs / secs.max(1e-9),
            fp
        );
        jobs *= 2;
    }
    println!("(fingerprints equal: sharded results bit-identical to serial)");
}
