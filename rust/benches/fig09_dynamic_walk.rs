//! Fig 9: the dynamic-STHLD FSM walking the IPC curve on a workload with
//! phase changes. Paper shape: STHLD climbs in flat regions, backs off
//! after the knee, re-converges after each phase change.
use malekeh::harness::{fig09, ExpOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let t0 = std::time::Instant::now();
    fig09(&opts).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
