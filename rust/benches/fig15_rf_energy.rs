//! Fig 15 paper: Malekeh -28.3% avg RF energy; BOW above baseline (bigger crossbar + cache).
use malekeh::harness::{fig15, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig15(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
