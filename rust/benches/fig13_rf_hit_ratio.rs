//! Fig 13 paper: Malekeh 46.4% avg hit, ~2% below BOW with 12x less storage; Malekeh_PR +28.9% over BOW.
use malekeh::harness::{fig13, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig13(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
