//! Fig 14: L1D hit ratios; lud slightly higher under Malekeh than BOW.
use malekeh::harness::{fig14, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig14(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
