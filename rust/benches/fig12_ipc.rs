//! Fig 12 paper: Malekeh +6.1% avg IPC (max +28.4% rnn_i2, worst -0.8% b+tree); Malekeh_PR beats BOW by ~3.3%.
use malekeh::harness::{fig12, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig12(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
