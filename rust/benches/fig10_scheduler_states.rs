//! Fig 10 paper: state2 = 37.6% (RFC) / 43.8% (swRFC).
use malekeh::harness::{fig10, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig10(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
