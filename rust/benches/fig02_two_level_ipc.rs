//! Fig 2 paper shape: sub-core drops ~10-13%, monolithic ~2-4%; hotspot worst (~-50% swRFC).
use malekeh::harness::{fig02, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig02(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
