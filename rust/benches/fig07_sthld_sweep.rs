//! Fig 7: hit ratio grows monotonically with STHLD; srad_v1 IPC collapses early, rnn keeps flat region.
use malekeh::harness::{fig07, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig07(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
