//! Headline claims of the abstract.
use malekeh::harness::{headline, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    headline(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
