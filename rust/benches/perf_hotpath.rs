//! §Perf: simulator throughput (L3 hot path), intra-run SM parallelism,
//! and AOT-artifact execution latency (L1/L2 path). Run after changes;
//! docs/EXPERIMENTS.md §Perf records the before/after log.
//!
//!     cargo bench --bench perf_hotpath            # full protocol (best-of-3)
//!     cargo bench --bench perf_hotpath -- --smoke # CI liveness: 1 rep, capped
//!     cargo bench --bench perf_hotpath -- --json out.json  # custom JSON path
//!
//! Protocol (docs/EXPERIMENTS.md §Perf): release build, best-of-3 wall
//! clock, report Minstr/s per workload plus the serial-vs-parallel
//! single-point speedup on the paper's `num_sms = 10` machine.
//!
//! Every run also emits a machine-readable `BENCH_PR9.json` (schema:
//! docs/EXPERIMENTS.md §Bench JSON) at the repo root: the six hot-path
//! reference points, a best-of-N Minstr/s sweep over every Table II
//! benchmark, the `--sim-threads 1/2/4` parallel point, and a
//! `golden_check` block of parity-config fingerprints CI diffs against
//! the blessed golden table. This file is the perf trajectory of record —
//! PR 10+ must beat it (target for PR 9 itself: ≥ 1.5x Minstr/s on at
//! least 4 of the 6 reference points vs the committed `BENCH_PR5.json`
//! rows in docs/EXPERIMENTS.md §Perf).

use std::fmt::Write as _;
use std::time::Instant;

use malekeh::config::{GOLDEN_PROFILE_WARPS, GpuConfig, Scheme};
use malekeh::sim::run_benchmark;
use malekeh::trace::table2;

/// The six hot-path reference points (the ≥ 1.5x PR 9 target applies to
/// these, measured against the PR 5 rows; docs/EXPERIMENTS.md §Perf).
const REFERENCE_POINTS: [(&str, Scheme); 6] = [
    ("gemm_t1", Scheme::BASELINE),
    ("gemm_t1", Scheme::MALEKEH),
    ("gemm_t1", Scheme::BOW),
    ("hotspot", Scheme::MALEKEH),
    ("kmeans", Scheme::MALEKEH),
    ("bfs", Scheme::RFC),
];

/// One measured simulator-throughput point.
struct Point {
    bench: String,
    scheme: &'static str,
    minstr_per_s: f64,
    instructions: u64,
    seconds: f64,
}

/// One `--sim-threads` entry of the SM-parallelism single point.
struct ParallelPoint {
    sim_threads: usize,
    seconds: f64,
    speedup: f64,
    minstr_per_s: f64,
    fingerprint: u64,
}

/// One parity-config fingerprint for the CI golden diff.
struct GoldenPoint {
    bench: &'static str,
    scheme: &'static str,
    fingerprint: u64,
}

fn sim_throughput(bench: &str, scheme: Scheme, reps: usize, max_cycles: u64) -> Point {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = 1;
    cfg.max_cycles = max_cycles;
    let mut best = f64::MAX;
    let mut instr = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let stats = run_benchmark(&cfg, bench, 2);
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        instr = stats.instructions;
    }
    Point {
        bench: bench.to_string(),
        scheme: scheme.name(),
        minstr_per_s: instr as f64 / best.max(1e-9) / 1e6,
        instructions: instr,
        seconds: best,
    }
}

/// §Perf intra-run SM parallelism: one `num_sms = 10` simulation stepped
/// by 1/2/4 epoch workers. Prints the speedup table recorded in
/// docs/EXPERIMENTS.md §Perf and asserts the fingerprints stay
/// bit-identical while doing so.
fn sm_parallel_point(reps: usize, smoke: bool) -> Vec<ParallelPoint> {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    cfg.num_sms = 10;
    if smoke {
        cfg.max_cycles = 50_000; // liveness only: keep CI turnaround short
    }
    println!("\n== §Perf: intra-run SM parallelism (gemm_t1/malekeh, num_sms=10) ==");
    println!(
        "{:<14}{:>12}{:>12}{:>12}{:>20}",
        "sim-threads", "seconds", "speedup", "Minstr/s", "fingerprint"
    );
    let mut out = Vec::new();
    let mut serial: Option<(f64, u64)> = None;
    for threads in [1usize, 2, 4] {
        cfg.sim_threads = threads;
        let mut best = f64::MAX;
        let mut instr = 0;
        let mut fp = 0;
        for _ in 0..reps {
            let t0 = Instant::now();
            let stats = run_benchmark(&cfg, "gemm_t1", 2);
            best = best.min(t0.elapsed().as_secs_f64());
            instr = stats.instructions;
            fp = stats.fingerprint();
        }
        let (serial_secs, serial_fp) = *serial.get_or_insert((best, fp));
        assert_eq!(
            fp, serial_fp,
            "sim-threads={threads} changed the results — determinism broken"
        );
        let speedup = serial_secs / best.max(1e-9);
        let mips = instr as f64 / best.max(1e-9) / 1e6;
        println!(
            "{:<14}{:>12.2}{:>12.2}{:>12.2}{:>20x}",
            threads, best, speedup, mips, fp
        );
        out.push(ParallelPoint {
            sim_threads: threads,
            seconds: best,
            speedup,
            minstr_per_s: mips,
            fingerprint: fp,
        });
    }
    println!("(fingerprints equal: SM-parallel results bit-identical to serial)");
    out
}

/// Fingerprints at the golden fixture's pinned configuration
/// ([`GpuConfig::golden_parity`] — the same constructor the parity suite
/// uses, so the two can never drift) for CI to machine-diff the bench run
/// against the blessed table.
fn golden_check() -> Vec<GoldenPoint> {
    let mut out = Vec::new();
    for (bench, scheme) in [
        ("kmeans", Scheme::BASELINE),
        ("kmeans", Scheme::MALEKEH),
        ("gemm_t1", Scheme::BASELINE),
        ("gemm_t1", Scheme::MALEKEH),
    ] {
        let cfg = GpuConfig::golden_parity(scheme);
        let fp = run_benchmark(&cfg, bench, GOLDEN_PROFILE_WARPS).fingerprint();
        out.push(GoldenPoint { bench, scheme: scheme.name(), fingerprint: fp });
    }
    out
}

fn push_throughput_json(out: &mut String, key: &str, pts: &[Point]) {
    let _ = writeln!(out, "  \"{key}\": [");
    for (i, p) in pts.iter().enumerate() {
        let comma = if i + 1 == pts.len() { "" } else { "," };
        let _ = writeln!(
            out,
            "    {{\"bench\": \"{}\", \"scheme\": \"{}\", \"minstr_per_s\": {:.4}, \
             \"instructions\": {}, \"seconds\": {:.6}}}{comma}",
            p.bench, p.scheme, p.minstr_per_s, p.instructions, p.seconds
        );
    }
    let _ = writeln!(out, "  ],");
}

/// Hand-rolled emitter (no serde in the offline build): the schema is
/// documented in docs/EXPERIMENTS.md §Bench JSON and is deliberately flat
/// so shell/python one-liners in CI can consume it.
fn write_bench_json(
    path: &str,
    smoke: bool,
    reps: usize,
    hot: &[Point],
    t2: &[Point],
    par: &[ParallelPoint],
    golden: &[GoldenPoint],
) {
    let mut s = String::new();
    s.push_str("{\n");
    let _ = writeln!(s, "  \"schema\": \"malekeh-bench/v1\",");
    let _ = writeln!(s, "  \"pr\": 9,");
    let _ = writeln!(s, "  \"mode\": \"{}\",", if smoke { "smoke" } else { "full" });
    let _ = writeln!(s, "  \"reps\": {reps},");
    let _ = writeln!(
        s,
        "  \"target\": {{\"min_speedup_vs_pr5\": 1.5, \"applies_to\": \"hot_path\", \"min_points\": 4}},"
    );
    push_throughput_json(&mut s, "hot_path", hot);
    push_throughput_json(&mut s, "table2", t2);
    let _ = writeln!(s, "  \"sm_parallel\": [");
    for (i, p) in par.iter().enumerate() {
        let comma = if i + 1 == par.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"sim_threads\": {}, \"seconds\": {:.6}, \"speedup\": {:.4}, \
             \"minstr_per_s\": {:.4}, \"fingerprint\": \"{:016x}\"}}{comma}",
            p.sim_threads, p.seconds, p.speedup, p.minstr_per_s, p.fingerprint
        );
    }
    let _ = writeln!(s, "  ],");
    let _ = writeln!(s, "  \"golden_check\": [");
    for (i, p) in golden.iter().enumerate() {
        let comma = if i + 1 == golden.len() { "" } else { "," };
        let _ = writeln!(
            s,
            "    {{\"bench\": \"{}\", \"scheme\": \"{}\", \"fingerprint\": \"{:016x}\"}}{comma}",
            p.bench, p.scheme, p.fingerprint
        );
    }
    let _ = writeln!(s, "  ]");
    s.push_str("}\n");
    std::fs::write(path, s).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("\nbench JSON written to {path}");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| format!("{}/BENCH_PR9.json", env!("CARGO_MANIFEST_DIR")));
    let reps = if smoke { 1 } else { 3 };

    println!("== §Perf: hot-path microbenchmarks ==");
    println!("{:<44}{:>14}{:>12}", "workload", "Minstr/s", "instrs");
    let mut hot = Vec::new();
    for (bench, scheme) in REFERENCE_POINTS {
        let p = sim_throughput(bench, scheme, reps, 0);
        println!(
            "{:<44}{:>14.2}{:>12}",
            format!("sim {bench}/{scheme}"),
            p.minstr_per_s,
            p.instructions
        );
        hot.push(p);
    }

    // Table II Minstr/s sweep (malekeh, num_sms = 1): the per-benchmark
    // perf trajectory PR 10+ diffs against. Smoke caps each run so CI
    // stays fast; the full protocol runs every benchmark to completion.
    println!("\n== §Perf: Table II Minstr/s sweep (malekeh, num_sms=1) ==");
    println!("{:<24}{:>14}{:>12}", "benchmark", "Minstr/s", "instrs");
    let t2_cap = if smoke { 40_000 } else { 0 };
    let mut t2 = Vec::new();
    for b in table2() {
        let p = sim_throughput(b.name, Scheme::MALEKEH, reps, t2_cap);
        println!("{:<24}{:>14.2}{:>12}", p.bench, p.minstr_per_s, p.instructions);
        t2.push(p);
    }

    let par = sm_parallel_point(reps, smoke);
    let golden = golden_check();
    write_bench_json(&json_path, smoke, reps, &hot, &t2, &par, &golden);

    if smoke {
        println!("\n(smoke mode: 1 rep, capped sweeps, PJRT path skipped)");
        return;
    }

    // PJRT artifact path (compile once, then measure execution)
    match malekeh::runtime::Runtime::open_default() {
        Ok(mut rt) => {
            let w = rt.manifest.profile_warps;
            let l = rt.manifest.trace_len;
            let bench = malekeh::trace::find("gemm_t1").unwrap();
            let trace = malekeh::trace::KernelTrace::generate(bench, w, 7);
            let (ids, pos, rw) = trace.access_streams(w, l);
            rt.annotate(&ids, &pos, &rw).expect("warmup"); // compile+warm
            let t0 = Instant::now();
            let reps = 5;
            for _ in 0..reps {
                rt.annotate(&ids, &pos, &rw).expect("annotate");
            }
            let per = t0.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:<44}{:>11.1} ms{:>12}",
                "pjrt reuse_annotate (8x2048)",
                per * 1e3,
                w * l
            );
            // rust engine on identical input, for the speedup column
            let t0 = Instant::now();
            for _ in 0..reps {
                for row in 0..w {
                    let s = row * l;
                    malekeh::compiler::windowed_reuse_distances(
                        &ids[s..s + l],
                        &pos[s..s + l],
                        &rw[s..s + l],
                        malekeh::compiler::WINDOW,
                        malekeh::compiler::CAP,
                    );
                }
            }
            let per_rust = t0.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:<44}{:>11.1} ms{:>12}",
                "rust reuse engine (same input)",
                per_rust * 1e3,
                w * l
            );
        }
        Err(e) => println!("pjrt path skipped (artifacts not built): {e}"),
    }
}
