//! §Perf: simulator throughput (L3 hot path), intra-run SM parallelism,
//! and AOT-artifact execution latency (L1/L2 path). Run after changes;
//! docs/EXPERIMENTS.md §Perf records the before/after log.
//!
//!     cargo bench --bench perf_hotpath            # full protocol (best-of-3)
//!     cargo bench --bench perf_hotpath -- --smoke # CI liveness: 1 rep, capped
//!
//! Protocol (docs/EXPERIMENTS.md §Perf): release build, best-of-3 wall
//! clock, report Minstr/s per workload plus the serial-vs-parallel
//! single-point speedup on the paper's `num_sms = 10` machine.

use std::time::Instant;

use malekeh::config::{GpuConfig, Scheme};
use malekeh::sim::run_benchmark;

fn sim_throughput(bench: &str, scheme: Scheme, reps: usize) -> (f64, u64) {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = 1;
    let mut best = f64::MAX;
    let mut instr = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let stats = run_benchmark(&cfg, bench, 2);
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        instr = stats.instructions;
    }
    (instr as f64 / best / 1e6, instr)
}

/// §Perf intra-run SM parallelism: one `num_sms = 10` simulation stepped
/// by 1/2/4 epoch workers. Prints the speedup table recorded in
/// docs/EXPERIMENTS.md §Perf and asserts the fingerprints stay
/// bit-identical while doing so.
fn sm_parallel_point(reps: usize, smoke: bool) {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    cfg.num_sms = 10;
    if smoke {
        cfg.max_cycles = 50_000; // liveness only: keep CI turnaround short
    }
    println!("\n== §Perf: intra-run SM parallelism (gemm_t1/malekeh, num_sms=10) ==");
    println!(
        "{:<14}{:>12}{:>12}{:>12}{:>20}",
        "sim-threads", "seconds", "speedup", "Minstr/s", "fingerprint"
    );
    let mut serial: Option<(f64, u64)> = None;
    for threads in [1usize, 2, 4] {
        cfg.sim_threads = threads;
        let mut best = f64::MAX;
        let mut instr = 0;
        let mut fp = 0;
        for _ in 0..reps {
            let t0 = Instant::now();
            let stats = run_benchmark(&cfg, "gemm_t1", 2);
            best = best.min(t0.elapsed().as_secs_f64());
            instr = stats.instructions;
            fp = stats.fingerprint();
        }
        let (serial_secs, serial_fp) = *serial.get_or_insert((best, fp));
        assert_eq!(
            fp, serial_fp,
            "sim-threads={threads} changed the results — determinism broken"
        );
        println!(
            "{:<14}{:>12.2}{:>12.2}{:>12.2}{:>20x}",
            threads,
            best,
            serial_secs / best.max(1e-9),
            instr as f64 / best.max(1e-9) / 1e6,
            fp
        );
    }
    println!("(fingerprints equal: SM-parallel results bit-identical to serial)");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let reps = if smoke { 1 } else { 3 };

    println!("== §Perf: hot-path microbenchmarks ==");
    println!("{:<44}{:>14}{:>12}", "workload", "Minstr/s", "instrs");
    for (bench, scheme) in [
        ("gemm_t1", Scheme::BASELINE),
        ("gemm_t1", Scheme::MALEKEH),
        ("gemm_t1", Scheme::BOW),
        ("hotspot", Scheme::MALEKEH),
        ("kmeans", Scheme::MALEKEH),
        ("bfs", Scheme::RFC),
    ] {
        let (mips, instr) = sim_throughput(bench, scheme, reps);
        println!(
            "{:<44}{:>14.2}{:>12}",
            format!("sim {bench}/{scheme}"),
            mips,
            instr
        );
    }

    sm_parallel_point(reps, smoke);

    if smoke {
        println!("\n(smoke mode: 1 rep, capped parallel point, PJRT path skipped)");
        return;
    }

    // PJRT artifact path (compile once, then measure execution)
    match malekeh::runtime::Runtime::open_default() {
        Ok(mut rt) => {
            let w = rt.manifest.profile_warps;
            let l = rt.manifest.trace_len;
            let bench = malekeh::trace::find("gemm_t1").unwrap();
            let trace = malekeh::trace::KernelTrace::generate(bench, w, 7);
            let (ids, pos, rw) = trace.access_streams(w, l);
            rt.annotate(&ids, &pos, &rw).expect("warmup"); // compile+warm
            let t0 = Instant::now();
            let reps = 5;
            for _ in 0..reps {
                rt.annotate(&ids, &pos, &rw).expect("annotate");
            }
            let per = t0.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:<44}{:>11.1} ms{:>12}",
                "pjrt reuse_annotate (8x2048)",
                per * 1e3,
                w * l
            );
            // rust engine on identical input, for the speedup column
            let t0 = Instant::now();
            for _ in 0..reps {
                for row in 0..w {
                    let s = row * l;
                    malekeh::compiler::windowed_reuse_distances(
                        &ids[s..s + l],
                        &pos[s..s + l],
                        &rw[s..s + l],
                        malekeh::compiler::WINDOW,
                        malekeh::compiler::CAP,
                    );
                }
            }
            let per_rust = t0.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:<44}{:>11.1} ms{:>12}",
                "rust reuse engine (same input)",
                per_rust * 1e3,
                w * l
            );
        }
        Err(e) => println!("pjrt path skipped (artifacts not built): {e}"),
    }
}
