//! §Perf: simulator throughput (L3 hot path) and AOT-artifact execution
//! latency (L1/L2 path). Run after changes; EXPERIMENTS.md §Perf records
//! the before/after log.

use std::time::Instant;

use malekeh::config::{GpuConfig, Scheme};
use malekeh::sim::run_benchmark;

fn sim_throughput(bench: &str, scheme: Scheme, reps: usize) -> (f64, u64) {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = 1;
    let mut best = f64::MAX;
    let mut instr = 0;
    for _ in 0..reps {
        let t0 = Instant::now();
        let stats = run_benchmark(&cfg, bench, 2);
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        instr = stats.instructions;
    }
    (instr as f64 / best / 1e6, instr)
}

fn main() {
    println!("== §Perf: hot-path microbenchmarks ==");
    println!("{:<44}{:>14}{:>12}", "workload", "Minstr/s", "instrs");
    for (bench, scheme) in [
        ("gemm_t1", Scheme::Baseline),
        ("gemm_t1", Scheme::Malekeh),
        ("gemm_t1", Scheme::Bow),
        ("hotspot", Scheme::Malekeh),
        ("kmeans", Scheme::Malekeh),
        ("bfs", Scheme::Rfc),
    ] {
        let (mips, instr) = sim_throughput(bench, scheme, 3);
        println!(
            "{:<44}{:>14.2}{:>12}",
            format!("sim {bench}/{scheme}"),
            mips,
            instr
        );
    }

    // PJRT artifact path (compile once, then measure execution)
    match malekeh::runtime::Runtime::open_default() {
        Ok(mut rt) => {
            let w = rt.manifest.profile_warps;
            let l = rt.manifest.trace_len;
            let bench = malekeh::trace::find("gemm_t1").unwrap();
            let trace = malekeh::trace::KernelTrace::generate(bench, w, 7);
            let (ids, pos, rw) = trace.access_streams(w, l);
            rt.annotate(&ids, &pos, &rw).expect("warmup"); // compile+warm
            let t0 = Instant::now();
            let reps = 5;
            for _ in 0..reps {
                rt.annotate(&ids, &pos, &rw).expect("annotate");
            }
            let per = t0.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:<44}{:>11.1} ms{:>12}",
                "pjrt reuse_annotate (8x2048)",
                per * 1e3,
                w * l
            );
            // rust engine on identical input, for the speedup column
            let t0 = Instant::now();
            for _ in 0..reps {
                for row in 0..w {
                    let s = row * l;
                    malekeh::compiler::windowed_reuse_distances(
                        &ids[s..s + l],
                        &pos[s..s + l],
                        &rw[s..s + l],
                        malekeh::compiler::WINDOW,
                        malekeh::compiler::CAP,
                    );
                }
            }
            let per_rust = t0.elapsed().as_secs_f64() / reps as f64;
            println!(
                "{:<44}{:>11.1} ms{:>12}",
                "rust reuse engine (same input)",
                per_rust * 1e3,
                w * l
            );
        }
        Err(e) => println!("pjrt path skipped (artifacts not built): {e}"),
    }
}
