//! Fig 17 paper: traditional GTO+LRU collapses the hit ratio to 7.9% avg.
//! The scheme columns come from the policy registry's fig17 sweep set
//! (traditional LRU, FIFO, the Belady oracle) plus `malekeh` as reference.
use malekeh::harness::{fig17, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig17(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
