//! Fig 16 paper: Malekeh writes far fewer values into the cache than BOW, and most are reused.
use malekeh::harness::{fig16, ExpOpts, Runner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    fig16(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
