//! Ablations for the paper's inline design claims (DESIGN.md §5):
//! A. CT entries sweet spot at 8 (§III-C)
//! B. RTHLD = 12 empirically best (§III-A)
//! C. scaling OCUs 2->8 is the expensive alternative (§I: +7.1% IPC)
//! D. one filtered write port ~ unbounded (§III-B, §IV-A2)
//! E. replacement policy sweep over the registry (LRU/FIFO/Belady vs
//!    the paper's reuse-guided chooser)
use malekeh::harness::{
    ablation_ct_entries, ablation_ocu_scaling, ablation_replacement, ablation_rthld,
    ablation_write_port, ExpOpts, Runner,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = ExpOpts::from_args(&args);
    if !args.iter().any(|a| a == "--full") {
        opts.quick = true; // sweeps are wide; default to the quick set
    }
    let runner = Runner::new(opts);
    let t0 = std::time::Instant::now();
    ablation_ct_entries(&runner).print();
    ablation_rthld(&runner).print();
    ablation_ocu_scaling(&runner).print();
    ablation_write_port(&runner).print();
    ablation_replacement(&runner).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
