//! Fig 1: reuse-distance distribution of register values (Rodinia vs
//! Deepbench). Paper shape: Deepbench shifted right, >40% of its reuses at
//! distance >10; Rodinia dominated by distances <=3.
use malekeh::harness::{fig01, ExpOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExpOpts::from_args(&args);
    let t0 = std::time::Instant::now();
    fig01(&opts).print();
    println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
