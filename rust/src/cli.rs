//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `malekeh <command> [positional] [--flag] [--key value] [-s k=v]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    /// Subcommand name.
    pub command: String,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
    /// `-s key=value` config overrides, in order.
    pub overrides: Vec<(String, String)>,
}

impl Cli {
    /// Parse an argv slice (without the program name).
    pub fn parse(args: &[String]) -> Result<Cli, String> {
        let mut cli = Cli::default();
        let mut it = args.iter().peekable();
        cli.command = it.next().cloned().unwrap_or_default();
        while let Some(a) = it.next() {
            if a == "-s" || a == "--set" {
                let kv = it
                    .next()
                    .ok_or_else(|| format!("{a} requires key=value"))?;
                let eq = kv
                    .find('=')
                    .ok_or_else(|| format!("bad override {kv:?}, want key=value"))?;
                cli.overrides
                    .push((kv[..eq].to_string(), kv[eq + 1..].to_string()));
            } else if let Some(name) = a.strip_prefix("--") {
                // value-taking option if the next token is not itself an
                // option: only `--...` and the exact override flag `-s`
                // start options, so values like `-shard.mtrace` or `-5`
                // pass through
                match it.peek() {
                    Some(v) if !v.starts_with("--") && v.as_str() != "-s" => {
                        cli.options
                            .insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => cli.flags.push(name.to_string()),
                }
            } else {
                cli.positional.push(a.clone());
            }
        }
        Ok(cli)
    }

    /// Flag present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option value or default.
    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.options.get(name).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Parsed numeric option.
    pub fn opt_num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("bad value for --{name}: {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Cli {
        let args: Vec<String> = s.split_whitespace().map(String::from).collect();
        Cli::parse(&args).unwrap()
    }

    #[test]
    fn parses_command_and_positional() {
        let c = p("simulate hotspot");
        assert_eq!(c.command, "simulate");
        assert_eq!(c.positional, vec!["hotspot"]);
    }

    #[test]
    fn parses_options_flags_overrides() {
        let c = p("simulate hotspot --scheme malekeh --verbose -s rthld=7 -s num_sms=2");
        assert_eq!(c.opt_or("scheme", "baseline"), "malekeh");
        assert!(c.has_flag("verbose"));
        assert_eq!(
            c.overrides,
            vec![("rthld".into(), "7".into()), ("num_sms".into(), "2".into())]
        );
    }

    #[test]
    fn option_followed_by_flag_is_flag() {
        let c = p("fig 12 --quick --sms 3");
        assert!(c.has_flag("quick"));
        assert_eq!(c.opt_num::<usize>("sms", 0).unwrap(), 3);
    }

    #[test]
    fn option_value_starting_with_dash_s_is_a_value() {
        // regression: `--out -shard.mtrace` used to be mis-parsed as the
        // bare flag `out` plus a stray positional
        let c = p("trace record hotspot --out -shard.mtrace --seed 3");
        assert_eq!(c.opt_or("out", ""), "-shard.mtrace");
        assert_eq!(c.opt_num::<u64>("seed", 0).unwrap(), 3);
        assert!(c.flags.is_empty());
        assert_eq!(c.positional, vec!["record", "hotspot"]);
        // the exact override flag still terminates an option
        let c = p("x --verbose -s rthld=7");
        assert!(c.has_flag("verbose"));
        assert_eq!(c.overrides, vec![("rthld".into(), "7".into())]);
    }

    #[test]
    fn bad_override_rejected() {
        let args: Vec<String> = vec!["x".into(), "-s".into(), "noequals".into()];
        assert!(Cli::parse(&args).is_err());
    }

    #[test]
    fn opt_num_errors_on_garbage() {
        let c = p("x --sms abc");
        assert!(c.opt_num::<usize>("sms", 1).is_err());
    }
}
