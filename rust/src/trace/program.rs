//! Per-warp program builder: the small "virtual SASS assembler" the
//! workload generators use.
//!
//! It tracks a register pool so def-use chains are real (reuse distances
//! arise from program structure, not sampled distributions) and provides an
//! address unit for line-granular memory streams.

use crate::isa::{Instruction, OpClass, MAX_SRC, NUM_REGS};
use crate::util::Rng;

/// Builder for one warp's instruction stream.
pub struct ProgramBuilder {
    instrs: Vec<Instruction>,
    /// Next temporary register to hand out (round-robin above the reserved
    /// range so long programs recycle names like a real allocator).
    next_tmp: usize,
    /// First register id handed out as a temporary; ids below are reserved
    /// for named values (accumulators, fragments, constants).
    tmp_base: usize,
    /// Size of the temporary window (wraps; models register pressure).
    tmp_window: usize,
    /// Deterministic per-warp randomness (divergence, address jitter).
    pub rng: Rng,
}

impl ProgramBuilder {
    /// `reserved` low registers are excluded from the temp pool;
    /// `tmp_window` controls register pressure (smaller = more recycling =
    /// shorter reuse distances).
    pub fn new(reserved: usize, tmp_window: usize, seed: u64) -> Self {
        assert!(reserved + tmp_window <= NUM_REGS, "register pool overflow");
        assert!(tmp_window >= 4, "need a few temporaries");
        ProgramBuilder {
            instrs: Vec::new(),
            next_tmp: 0,
            tmp_base: reserved,
            tmp_window,
            rng: Rng::new(seed),
        }
    }

    /// Allocate the next temporary register (round-robin window).
    pub fn tmp(&mut self) -> u8 {
        let r = self.tmp_base + (self.next_tmp % self.tmp_window);
        self.next_tmp += 1;
        r as u8
    }

    /// Number of instructions emitted so far.
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    /// True if nothing emitted yet.
    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Emit a raw instruction.
    pub fn push(&mut self, i: Instruction) {
        self.instrs.push(i);
    }

    /// ALU op: dst = f(srcs).
    pub fn alu(&mut self, srcs: &[u8], dst: u8) {
        self.push(Instruction::new(OpClass::Alu, srcs, &[dst]));
    }

    /// SFU op (rsqrt/exp/...): dst = f(src).
    pub fn sfu(&mut self, src: u8, dst: u8) {
        self.push(Instruction::new(OpClass::Sfu, &[src], &[dst]));
    }

    /// Global load with a *data-dependent* address register (pointer
    /// chase): the address operand is a real RF read.
    pub fn ldg(&mut self, addr_reg: u8, dst: u8, line: u32) {
        self.push(Instruction::mem(OpClass::LdGlobal, &[addr_reg], &[dst], line));
    }

    /// Global load with uniform addressing: on Turing, base+offset
    /// addresses live in the uniform register file, which is read by the
    /// dedicated uniform datapath — NOT through the RF banks / operand
    /// collectors. No source operand is modelled.
    pub fn ldg_u(&mut self, dst: u8, line: u32) {
        self.push(Instruction::mem(OpClass::LdGlobal, &[], &[dst], line));
    }

    /// Global store, uniform-addressed: only the data value is an RF read.
    pub fn stg_u(&mut self, src: u8, line: u32) {
        self.push(Instruction::mem(OpClass::StGlobal, &[src], &[], line));
    }

    /// Shared-memory load, uniform-addressed.
    pub fn lds_u(&mut self, dst: u8) {
        self.push(Instruction::mem(OpClass::LdShared, &[], &[dst], 0));
    }

    /// Tensor-core MMA: dsts = srcs-matmul-accumulate. Up to 6 srcs, 2 dsts.
    pub fn mma(&mut self, srcs: &[u8], dsts: &[u8]) {
        assert!(srcs.len() <= MAX_SRC);
        self.push(Instruction::new(OpClass::Mma, srcs, dsts));
    }

    /// Control instruction (branch/barrier): no RF operands collected.
    pub fn ctrl(&mut self) {
        self.push(Instruction::new(OpClass::Ctrl, &[], &[]));
    }

    /// Dependent ALU chain of `n` ops starting from `seed_reg`; returns the
    /// final register. Models the short-latency chains that make workloads
    /// like hotspot scheduler-sensitive.
    pub fn chain(&mut self, seed_reg: u8, n: usize) -> u8 {
        let mut cur = seed_reg;
        for _ in 0..n {
            let d = self.tmp();
            self.alu(&[cur, seed_reg], d);
            cur = d;
        }
        cur
    }

    /// Finish the stream with the Exit marker and return it.
    pub fn finish(mut self) -> Vec<Instruction> {
        self.push(Instruction::new(OpClass::Exit, &[], &[]));
        self.instrs
    }
}

/// Line-granular address stream helper. Addresses are 128B-line ids in a
/// flat space; generators use region bases to control sharing across warps
/// (shared region -> L1 temporal hits; private streams -> misses).
#[derive(Debug, Clone)]
pub struct AddrGen {
    /// Base line of this warp's private streaming region.
    pub private_base: u32,
    /// Base line of the region shared by all warps of the kernel.
    pub shared_base: u32,
    cursor: u32,
}

/// Kernel ids addressable without the shared regions wrapping out of the
/// upper half of the 32-bit line space (`0x8000_0000 + id * 0x10_0000`).
pub const MAX_KERNEL_ID: u32 = 0x7FF;

impl AddrGen {
    /// Regions are spaced far apart so they never alias. Panics when
    /// `kernel_id` exceeds [`MAX_KERNEL_ID`] — beyond that the shared base
    /// would wrap into the warp-private range (callers with external input,
    /// like the CLI, must validate first).
    pub fn new(warp_global_id: u32, kernel_id: u32) -> Self {
        assert!(
            kernel_id <= MAX_KERNEL_ID,
            "kernel_id {kernel_id} exceeds the addressable maximum {MAX_KERNEL_ID}"
        );
        AddrGen {
            private_base: 0x0100_0000 + warp_global_id * 0x4_0000,
            shared_base: 0x8000_0000 + kernel_id * 0x10_0000,
            cursor: 0,
        }
    }

    /// Next line of the private streaming sequence (stride in lines).
    pub fn stream(&mut self, stride: u32) -> u32 {
        let l = self.private_base + self.cursor;
        self.cursor = self.cursor.wrapping_add(stride);
        l
    }

    /// A line in the shared region (e.g. model weights, LUTs): index is
    /// wrapped into `extent` lines so the footprint is controllable.
    pub fn shared(&self, index: u32, extent: u32) -> u32 {
        self.shared_base + (index % extent.max(1))
    }

    /// Pseudo-random (data-dependent) line in a `extent`-line region:
    /// models indirect accesses (BFS, particlefilter).
    pub fn indirect(&self, rng: &mut Rng, extent: u32) -> u32 {
        self.shared_base + 0x8_0000 + (rng.next_u32() % extent.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    #[test]
    fn tmp_wraps_in_window() {
        let mut b = ProgramBuilder::new(16, 8, 1);
        let first = b.tmp();
        assert_eq!(first, 16);
        for _ in 0..7 {
            b.tmp();
        }
        assert_eq!(b.tmp(), 16, "should wrap after window temps");
    }

    #[test]
    #[should_panic(expected = "register pool overflow")]
    fn pool_overflow_panics() {
        ProgramBuilder::new(250, 10, 0);
    }

    #[test]
    fn chain_is_dependent() {
        let mut b = ProgramBuilder::new(8, 16, 2);
        let out = b.chain(3, 4);
        let prog = b.finish();
        assert_eq!(prog.len(), 5); // 4 ALU + Exit
        // each op consumes the previous op's dest
        for w in prog.windows(2) {
            if w[1].op == OpClass::Alu {
                assert!(w[1].sources().contains(&w[0].dests()[0]));
            }
        }
        assert_eq!(prog[3].dests()[0], out);
        assert_eq!(prog.last().unwrap().op, OpClass::Exit);
    }

    #[test]
    fn addr_regions_do_not_alias() {
        let mut a = AddrGen::new(0, 0);
        let mut b = AddrGen::new(1, 0);
        let sa: Vec<u32> = (0..100).map(|_| a.stream(1)).collect();
        let sb: Vec<u32> = (0..100).map(|_| b.stream(1)).collect();
        assert!(sa.iter().all(|x| !sb.contains(x)));
        // shared region identical across warps
        assert_eq!(a.shared(5, 64), b.shared(5, 64));
        assert!(a.shared(5, 64) > sa[99]);
    }

    #[test]
    fn shared_wraps_extent() {
        let a = AddrGen::new(0, 3);
        assert_eq!(a.shared(64, 64), a.shared(0, 64));
        assert_ne!(a.shared(1, 64), a.shared(0, 64));
    }

    #[test]
    fn max_kernel_id_stays_in_shared_half() {
        let a = AddrGen::new(0, MAX_KERNEL_ID);
        assert!(a.shared_base >= 0x8000_0000);
    }

    #[test]
    #[should_panic(expected = "exceeds the addressable maximum")]
    fn kernel_id_overflow_panics() {
        AddrGen::new(0, MAX_KERNEL_ID + 1);
    }
}
