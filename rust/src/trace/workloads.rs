//! Table II workload generators: synthetic stand-ins for the Rodinia and
//! Deepbench traces the paper replays through Accel-sim (see DESIGN.md §2
//! for the substitution argument).
//!
//! Each generator builds a real program — def-use chains, accumulators,
//! streamed fragments, shared lookup tables, divergence — so reuse
//! distances, bank pressure and memory behaviour *emerge* from structure
//! instead of being sampled from target distributions. Constants are tuned
//! so the suite reproduces the paper's aggregate characteristics:
//! Deepbench reuse distances long (>10 for ~40%+ of reuses, Fig 1),
//! conv ~65% tensor-core instructions, hotspot short-latency-sensitive,
//! lud/particlefilter memory-bound, b+tree low-reuse pointer chasing.

use super::program::{AddrGen, ProgramBuilder};
use crate::isa::Instruction;

/// Benchmark suite (Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suite {
    /// General-purpose computing (Rodinia).
    Rodinia,
    /// Deep-learning workloads with tensor cores (Deepbench).
    Deepbench,
    /// Synthetic drivers used by specific figures (not in Table II).
    Synthetic,
    /// Generated-kernel corpus beyond Table II: irregular control flow,
    /// pointer chasing, WAW churn ([`super::corpus`], `fig corpus`).
    Corpus,
}

/// Context handed to a generator for one warp.
pub struct WarpCtx {
    /// Global warp id across the whole GPU.
    pub warp_id: u32,
    /// Total warps in the launch.
    pub nwarps: u32,
    /// Per-benchmark kernel id (address-space separation).
    pub kernel_id: u32,
}

/// One benchmark: name + suite + per-warp generator.
pub struct Benchmark {
    /// Chart label (matches the paper's figures).
    pub name: &'static str,
    /// Which suite it belongs to.
    pub suite: Suite,
    /// Per-warp program generator.
    pub gen: fn(&WarpCtx, u64) -> Vec<Instruction>,
}

// =============================== bodies ====================================

/// Register-tiled tensor-core GEMM inner loop (the register allocation of
/// the L1 Pallas kernel `mma_gemm`, see DESIGN.md §Hardware-Adaptation).
///
/// `tm x tn` MMA grid per iteration: A fragments are reused across a row of
/// MMAs (near), B fragments across a column (near-ish), accumulators are
/// reused across iterations at distance ~ body length (far for big tiles —
/// the long Deepbench reuse distances of Fig 1). `shared_b` puts B in a
/// kernel-shared region (inference weight reuse -> L1 hits).
fn gemm_body(
    b: &mut ProgramBuilder,
    ag: &mut AddrGen,
    iters: usize,
    tm: usize,
    tn: usize,
    shared_b: bool,
    store_epilogue: bool,
) {
    // register plan: [2 .. 2+2*tm) A frags, then B frags, accs (addresses
    // are uniform-register based, as in Turing GEMM SASS)
    let a0 = 2usize;
    let b0 = a0 + 2 * tm;
    let acc0 = b0 + 2 * tn;
    let shared_extent = 2048;
    for it in 0..iters {
        // stream A fragments (private, far reuse: next use is this iter only)
        for i in 0..tm {
            let line = ag.stream(1);
            b.ldg_u((a0 + 2 * i) as u8, line);
            b.ldg_u((a0 + 2 * i + 1) as u8, line + 1);
        }
        // B fragments: shared weights (inference) or streamed (training)
        for j in 0..tn {
            let line = if shared_b {
                ag.shared((it * tn + j) as u32, shared_extent)
            } else {
                ag.stream(1)
            };
            b.ldg_u((b0 + 2 * j) as u8, line);
            b.ldg_u((b0 + 2 * j + 1) as u8, line + 1);
        }
        // tm x tn MMA grid
        for i in 0..tm {
            for j in 0..tn {
                let acc = (acc0 + 2 * (i * tn + j)) as u8;
                b.mma(
                    &[
                        (a0 + 2 * i) as u8,
                        (a0 + 2 * i + 1) as u8,
                        (b0 + 2 * j) as u8,
                        (b0 + 2 * j + 1) as u8,
                        acc,
                        acc + 1,
                    ],
                    &[acc, acc + 1],
                );
            }
        }
    }
    if store_epilogue {
        for i in 0..(tm * tn) {
            let acc = (acc0 + 2 * i) as u8;
            let t = b.tmp();
            b.alu(&[acc, acc + 1], t);
            let line = ag.stream(1);
            b.stg_u(t, line);
        }
    }
}

/// Stencil body (hotspot/srad/pathfinder): load a neighbourhood, run a
/// short dependent chain, store. Short chains + load dependence make these
/// kernels need many live warps to hide latency — the two-level-scheduler
/// pain case of Fig 2.
fn stencil_body(
    b: &mut ProgramBuilder,
    ag: &mut AddrGen,
    iters: usize,
    points: usize,
    chain_len: usize,
    sfu_every: usize,
    shared_frac_pct: usize,
) {
    for it in 0..iters {
        let mut loaded = Vec::with_capacity(points);
        for p in 0..points {
            let d = b.tmp();
            // neighbourhoods overlap between warps -> temporal L1 hits
            let line = if (p * 100 / points.max(1)) < shared_frac_pct {
                ag.shared((it * points + p) as u32, 4096)
            } else {
                ag.stream(1)
            };
            b.ldg_u(d, line);
            loaded.push(d);
        }
        // combine neighbours pairwise (near reuse of loaded values)
        let mut acc = loaded[0];
        for &v in &loaded[1..] {
            let d = b.tmp();
            b.alu(&[acc, v], d);
            acc = d;
        }
        let end = b.chain(acc, chain_len);
        let out = if sfu_every > 0 && it % sfu_every == 0 {
            let d = b.tmp();
            b.sfu(end, d);
            d
        } else {
            end
        };
        let line = ag.stream(1);
        b.stg_u(out, line);
    }
}

/// Irregular/graph body (bfs, b+tree): dependent (pointer-chasing) loads,
/// divergence, fresh registers — the low-reuse end of the spectrum.
///
/// Two independent chases are interleaved, as a latency-aware compiler
/// schedules them: producer->consumer distances are 2+ instructions, which
/// is what defeats short sliding windows on irregular code (§VI-B2).
fn irregular_body(
    b: &mut ProgramBuilder,
    ag: &mut AddrGen,
    iters: usize,
    chase_depth: usize,
    diverge_pct: usize,
    extent: u32,
) {
    for _ in 0..iters {
        let mut p0 = b.tmp();
        let mut p1 = b.tmp();
        let (a0, a1) = (ag.indirect(&mut b.rng, extent), ag.indirect(&mut b.rng, extent));
        b.ldg_u(p0, a0);
        b.ldg_u(p1, a1);
        for _ in 0..chase_depth {
            let n0 = b.tmp();
            let n1 = b.tmp();
            // addresses depend on the previous loads (true pointer chase),
            // the two strands interleaved
            let (a0, a1) =
                (ag.indirect(&mut b.rng, extent), ag.indirect(&mut b.rng, extent));
            b.ldg(p0, n0, a0);
            b.ldg(p1, n1, a1);
            p0 = n0;
            p1 = n1;
        }
        if b.rng.below(100) < diverge_pct {
            // divergent path: control + a couple of unrelated ops the
            // interleaved-execution model slots in (§III-A's source of
            // nondeterministic reuse distances)
            b.ctrl();
            let t0 = b.tmp();
            let t1 = b.tmp();
            b.alu(&[p0], t0);
            b.alu(&[t0], t1);
        }
        let t = b.tmp();
        b.alu(&[p0, p1], t);
    }
}

/// Compute-dense body with a hot operand set (lavamd, kmeans): an outer
/// value is reused by every inner step — near reuse the CCU feasts on.
fn hot_operand_body(
    b: &mut ProgramBuilder,
    ag: &mut AddrGen,
    outer: usize,
    inner: usize,
    sfu_every: usize,
    shared_inner: bool,
) {
    let hot0 = 2u8; // the particle / point registers
    let hot1 = 3u8;
    for o in 0..outer {
        let line = ag.stream(1);
        b.ldg_u(hot0, line);
        b.ldg_u(hot1, line + 1);
        let mut acc = b.tmp();
        b.alu(&[hot0, hot1], acc);
        for i in 0..inner {
            let other = b.tmp();
            if shared_inner {
                // centroid / neighbour list shared across warps
                b.ldg_u(other, ag.shared((o * inner + i) as u32, 512));
            } else {
                b.ldg_u(other, ag.stream(1));
            }
            let d0 = b.tmp();
            b.alu(&[hot0, other], d0); // hot regs: near reuse every iter
            let d1 = b.tmp();
            b.alu(&[hot1, d0], d1);
            let d2 = b.tmp();
            b.alu(&[acc, d1], d2);
            acc = d2;
            if sfu_every > 0 && i % sfu_every == sfu_every - 1 {
                let s = b.tmp();
                b.sfu(acc, s);
                acc = s;
            }
        }
        let line = ag.stream(1);
        b.stg_u(acc, line);
    }
}

/// Streaming elementwise body (backprop/dwt2d/nn flavours): load, a few
/// ops, store; memory-bandwidth-leaning, moderate reuse.
///
/// Software-pipelined over 3 elements the way nvcc schedules streaming
/// loops: all loads hoisted, then the three compute chains interleaved, so
/// def-use distances spread over ~3x the chain length (the reuse-distance
/// tail of Fig 1 that sliding windows cannot capture).
fn elementwise_body(
    b: &mut ProgramBuilder,
    ag: &mut AddrGen,
    iters: usize,
    ops: usize,
    sfu_every: usize,
    use_lds: bool,
) {
    const UNROLL: usize = 3;
    let mut it = 0usize;
    while it < iters {
        let lanes = UNROLL.min(iters - it);
        let mut xs = [0u8; UNROLL];
        let mut ys = [0u8; UNROLL];
        // hoisted loads for all lanes
        for l in 0..lanes {
            xs[l] = b.tmp();
            b.ldg_u(xs[l], ag.stream(1));
            ys[l] = b.tmp();
            if use_lds {
                b.lds_u(ys[l]);
            } else {
                b.ldg_u(ys[l], ag.stream(1));
            }
        }
        // interleaved compute chains
        let mut accs = xs;
        for k in 0..ops {
            for l in 0..lanes {
                let d = b.tmp();
                b.alu(&[accs[l], if k % 2 == 0 { ys[l] } else { xs[l] }], d);
                accs[l] = d;
            }
        }
        for l in 0..lanes {
            let mut out = accs[l];
            if sfu_every > 0 && (it + l) % sfu_every == 0 {
                let s = b.tmp();
                b.sfu(out, s);
                out = s;
            }
            b.stg_u(out, ag.stream(1));
        }
        it += lanes;
    }
}

// ============================ benchmark table ===============================

macro_rules! bench {
    ($name:expr, $suite:expr, $gen:expr) => {
        Benchmark { name: $name, suite: $suite, gen: $gen }
    };
}

pub(crate) fn seed_for(ctx: &WarpCtx, seed: u64) -> u64 {
    seed ^ (ctx.warp_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((ctx.kernel_id as u64) << 32)
}

// Per-benchmark generators. Iteration counts give ~1.2k-3k instrs per warp.

fn gen_bplustree(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // pointer chasing through a large tree, heavy divergence, low reuse —
    // the paper's worst case for Malekeh (-0.8% IPC)
    irregular_body(&mut b, &mut ag, 260, 3, 45, 1 << 15);
    b.finish()
}

fn gen_backprop(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    elementwise_body(&mut b, &mut ag, 300, 5, 6, true);
    b.finish()
}

fn gen_bfs(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    irregular_body(&mut b, &mut ag, 300, 2, 35, 1 << 14);
    b.finish()
}

fn gen_dwt2d(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // wavelet lifting: stencil-ish with longer arithmetic
    stencil_body(&mut b, &mut ag, 180, 4, 6, 0, 30);
    b.finish()
}

fn gen_gaussian(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 32, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // row elimination: pivot row shared across warps, multiplier reused
    hot_operand_body(&mut b, &mut ag, 70, 10, 0, true);
    b.finish()
}

fn gen_hotspot(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // 5-point stencil, very short chains: thrives on many ready warps,
    // collapses under a two-level scheduler (Fig 2: up to -50.9%)
    stencil_body(&mut b, &mut ag, 230, 5, 2, 0, 55);
    b.finish()
}

fn gen_kmeans(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // point registers hot across the centroid loop; centroids shared
    hot_operand_body(&mut b, &mut ag, 60, 12, 0, true);
    b.finish()
}

fn gen_lavamd(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // n-body: particle state hot through the neighbour loop, rsqrt SFU
    hot_operand_body(&mut b, &mut ag, 40, 18, 4, false);
    b.finish()
}

fn gen_lud(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 36, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // triangular solve: streaming rows, moderate reuse, memory-pipe bound
    // (paper: higher RF hit ratio does NOT translate to IPC here)
    elementwise_body(&mut b, &mut ag, 260, 3, 0, false);
    stencil_body(&mut b, &mut ag, 60, 3, 3, 0, 25);
    b.finish()
}

fn gen_nn(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 32, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // tiny distance kernel, almost pure streaming: memory bound
    elementwise_body(&mut b, &mut ag, 330, 2, 0, false);
    b.finish()
}

fn gen_particlefilter_float(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // memory pipeline is the bottleneck (paper: hit ratio doesn't help IPC)
    irregular_body(&mut b, &mut ag, 180, 1, 20, 1 << 13);
    elementwise_body(&mut b, &mut ag, 140, 4, 5, false);
    b.finish()
}

fn gen_particlefilter_naive(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // the naive variant: more indirect traffic, frequent warp switches ->
    // many CCU flushes (paper: 53.5% lower hit ratio than BOW)
    irregular_body(&mut b, &mut ag, 320, 2, 50, 1 << 15);
    b.finish()
}

fn gen_pathfinder(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 36, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // DP row sweep with shared-memory row buffer
    elementwise_body(&mut b, &mut ag, 150, 4, 0, true);
    stencil_body(&mut b, &mut ag, 90, 3, 2, 0, 60);
    b.finish()
}

fn gen_srad_v1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // diffusion stencil + exp(): the STHLD-sensitive app of Fig 7
    stencil_body(&mut b, &mut ag, 200, 4, 3, 2, 45);
    b.finish()
}

// ---- Deepbench: training (t) / inference (i) variants ----

fn gen_conv_t1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(64, 64, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // implicit-GEMM conv, big tiles: ~65% MMA instructions, long reuse
    gemm_body(&mut b, &mut ag, 46, 4, 4, false, true);
    b.finish()
}

fn gen_conv_i1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(48, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // inference: weights shared -> L1 hits; smaller tiles
    gemm_body(&mut b, &mut ag, 62, 3, 3, true, true);
    b.finish()
}

fn gen_gemm_t1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(64, 64, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    gemm_body(&mut b, &mut ag, 52, 4, 4, false, true);
    b.finish()
}

fn gen_gemm_i1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(48, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    gemm_body(&mut b, &mut ag, 80, 2, 4, true, true);
    b.finish()
}

fn gen_rnn_t1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(40, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // GRU step: small GEMM + elementwise gates (sigmoid SFU)
    for _ in 0..9 {
        gemm_body(&mut b, &mut ag, 6, 2, 2, false, false);
        elementwise_body(&mut b, &mut ag, 10, 3, 2, false);
    }
    b.finish()
}

fn gen_rnn_t2(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(56, 56, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // LSTM step, bigger hidden: more MMA per gate — the paper's best
    // energy result (-47.3%)
    for _ in 0..7 {
        gemm_body(&mut b, &mut ag, 7, 3, 3, false, false);
        elementwise_body(&mut b, &mut ag, 8, 4, 2, false);
    }
    b.finish()
}

fn gen_rnn_i1(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(40, 48, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    for _ in 0..10 {
        gemm_body(&mut b, &mut ag, 6, 2, 2, true, false);
        elementwise_body(&mut b, &mut ag, 9, 3, 3, false);
    }
    b.finish()
}

fn gen_rnn_i2(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(40, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    // small-batch inference: shared weights, tight accumulator reuse —
    // the paper's best IPC gain (+28.4%)
    for _ in 0..12 {
        gemm_body(&mut b, &mut ag, 7, 2, 2, true, false);
        elementwise_body(&mut b, &mut ag, 6, 2, 3, false);
    }
    b.finish()
}

// ---- synthetic drivers for specific figures ----

fn gen_phased(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    // Fig 9 driver: alternates a reuse-rich phase (wide flat STHLD region)
    // with a latency-critical phase (narrow flat region)
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    for _ in 0..3 {
        hot_operand_body(&mut b, &mut ag, 24, 12, 0, true);
        stencil_body(&mut b, &mut ag, 80, 5, 2, 0, 55);
    }
    b.finish()
}

/// Table II benchmark registry (plus the synthetic Fig-9 driver at the end).
pub const BENCHMARKS: &[Benchmark] = &[
    bench!("b+tree", Suite::Rodinia, gen_bplustree),
    bench!("backprop", Suite::Rodinia, gen_backprop),
    bench!("bfs", Suite::Rodinia, gen_bfs),
    bench!("dwt2d", Suite::Rodinia, gen_dwt2d),
    bench!("gaussian", Suite::Rodinia, gen_gaussian),
    bench!("hotspot", Suite::Rodinia, gen_hotspot),
    bench!("kmeans", Suite::Rodinia, gen_kmeans),
    bench!("lavamd", Suite::Rodinia, gen_lavamd),
    bench!("lud", Suite::Rodinia, gen_lud),
    bench!("nn", Suite::Rodinia, gen_nn),
    bench!("particlefilter_float", Suite::Rodinia, gen_particlefilter_float),
    bench!("particlefilter_naive", Suite::Rodinia, gen_particlefilter_naive),
    bench!("pathfinder", Suite::Rodinia, gen_pathfinder),
    bench!("srad_v1", Suite::Rodinia, gen_srad_v1),
    bench!("conv_t1", Suite::Deepbench, gen_conv_t1),
    bench!("conv_i1", Suite::Deepbench, gen_conv_i1),
    bench!("gemm_t1", Suite::Deepbench, gen_gemm_t1),
    bench!("gemm_i1", Suite::Deepbench, gen_gemm_i1),
    bench!("rnn_t1", Suite::Deepbench, gen_rnn_t1),
    bench!("rnn_t2", Suite::Deepbench, gen_rnn_t2),
    bench!("rnn_i1", Suite::Deepbench, gen_rnn_i1),
    bench!("rnn_i2", Suite::Deepbench, gen_rnn_i2),
    bench!("synthetic_phases", Suite::Synthetic, gen_phased),
    bench!("matmul_tiled", Suite::Corpus, super::corpus::gen_matmul_tiled),
    bench!("quicksort", Suite::Corpus, super::corpus::gen_quicksort),
    bench!("pointer_chase", Suite::Corpus, super::corpus::gen_pointer_chase),
    bench!("box_blur", Suite::Corpus, super::corpus::gen_box_blur),
    bench!("prime_sieve", Suite::Corpus, super::corpus::gen_prime_sieve),
    bench!("hazard_stress", Suite::Corpus, super::corpus::gen_hazard_stress),
];

/// Look a benchmark up by chart name.
pub fn find(name: &str) -> Option<&'static Benchmark> {
    BENCHMARKS.iter().find(|b| b.name == name)
}

/// The Table II set (the paper's evaluation grid — Rodinia + Deepbench
/// only; synthetic figure drivers and the generated corpus stay out so
/// the paper-facing figures keep their shape).
pub fn table2() -> impl Iterator<Item = &'static Benchmark> {
    BENCHMARKS
        .iter()
        .filter(|b| matches!(b.suite, Suite::Rodinia | Suite::Deepbench))
}

/// The generated-kernel corpus ([`Suite::Corpus`]), in registry order.
pub fn corpus() -> impl Iterator<Item = &'static Benchmark> {
    BENCHMARKS.iter().filter(|b| b.suite == Suite::Corpus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    fn ctx(warp: u32) -> WarpCtx {
        WarpCtx { warp_id: warp, nwarps: 32, kernel_id: 0 }
    }

    #[test]
    fn registry_covers_table2() {
        assert_eq!(table2().filter(|b| b.suite == Suite::Rodinia).count(), 14);
        assert_eq!(table2().filter(|b| b.suite == Suite::Deepbench).count(), 8);
        // the corpus rides alongside but never leaks into Table II
        assert_eq!(table2().count(), 22);
        assert_eq!(corpus().count(), 6);
        assert!(corpus().all(|b| b.suite == Suite::Corpus));
        assert!(find("hotspot").is_some());
        assert!(find("rnn_i2").is_some());
        assert!(find("nope").is_none());
    }

    #[test]
    fn all_benchmarks_generate_and_terminate() {
        for b in BENCHMARKS {
            let prog = (b.gen)(&ctx(3), 42);
            assert!(prog.len() > 400, "{} too short: {}", b.name, prog.len());
            assert!(prog.len() < 20_000, "{} too long: {}", b.name, prog.len());
            assert_eq!(prog.last().unwrap().op, OpClass::Exit, "{}", b.name);
            // Exit only at the end
            assert!(
                prog[..prog.len() - 1].iter().all(|i| i.op != OpClass::Exit),
                "{}",
                b.name
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        for b in [find("hotspot").unwrap(), find("gemm_t1").unwrap()] {
            let a = (b.gen)(&ctx(5), 7);
            let c = (b.gen)(&ctx(5), 7);
            assert_eq!(a, c);
        }
    }

    #[test]
    fn warps_differ_in_addresses_not_structure() {
        let b = find("nn").unwrap();
        let w0 = (b.gen)(&ctx(0), 7);
        let w1 = (b.gen)(&ctx(1), 7);
        assert_eq!(w0.len(), w1.len());
        // same opcode skeleton
        assert!(w0
            .iter()
            .zip(w1.iter())
            .all(|(a, b)| a.op == b.op));
        // but disjoint private address streams
        let a0: Vec<u32> = w0.iter().filter(|i| i.op == OpClass::LdGlobal).map(|i| i.line_addr).collect();
        let a1: Vec<u32> = w1.iter().filter(|i| i.op == OpClass::LdGlobal).map(|i| i.line_addr).collect();
        assert!(a0.iter().any(|x| !a1.contains(x)));
    }

    #[test]
    fn deepbench_is_mma_heavy_rodinia_is_not() {
        let frac = |name: &str| {
            let p = (find(name).unwrap().gen)(&ctx(0), 1);
            let mma = p.iter().filter(|i| i.op == OpClass::Mma).count();
            mma as f64 / p.len() as f64
        };
        assert!(frac("conv_t1") > 0.45, "conv_t1 mma frac {}", frac("conv_t1"));
        assert!(frac("gemm_t1") > 0.4);
        assert_eq!(frac("hotspot"), 0.0);
        assert_eq!(frac("bfs"), 0.0);
    }

    #[test]
    fn mma_instructions_have_tensor_core_shape() {
        let p = (find("gemm_t1").unwrap().gen)(&ctx(0), 1);
        for i in p.iter().filter(|i| i.op == OpClass::Mma) {
            assert_eq!(i.nsrc, 6);
            assert_eq!(i.ndst, 2);
        }
    }
}
