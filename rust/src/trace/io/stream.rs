//! Streaming trace ingestion: bounded-memory windows over `.mtrace`
//! files of either version.
//!
//! [`TraceStream`] auto-detects the container (binary v2 magic vs
//! textual v1) and yields [`TraceWindow`]s — contiguous instruction runs
//! of a single warp, in warp-major order. For **v2** files the stream is
//! genuinely bounded: at most one chunk (≤
//! [`super::format2::CHUNK_INSTR_CAP`] instructions) is resident at a
//! time, so a multi-GB trace replays in constant memory. For **v1**
//! files the stream is a compatibility veneer — the textual parser is
//! line-oriented and whole-file, so the trace is parsed in memory first
//! and then re-windowed; the memory bound is a v2-only guarantee
//! (documented in `docs/TRACES.md`).
//!
//! On top of the raw window iterator this module provides the two
//! consumers the rest of the crate needs:
//!
//! - [`read_limited`]: decode a trace but **retain only the first
//!   `max_warps` warps** — what `sim::run_workload` uses so replaying a
//!   2048-warp recording on a 1-SM config never materialises the other
//!   2016 warps (v2 path). The full file is still validated end to end
//!   (structure, EXIT invariants, content digest).
//! - [`content_fingerprint_path`]: the decoded-content fingerprint of a
//!   trace file, identical to
//!   [`KernelTrace::content_fingerprint`][crate::trace::KernelTrace::content_fingerprint]
//!   of the parsed trace, computed while buffering one warp at a time.
//!   This is what makes a `trace convert` output hit the same store
//!   record as its source (`serve::store`).

use std::collections::VecDeque;
use std::fs::File;
use std::io::BufReader;
use std::path::Path;

use super::format::TraceHeader;
use super::format2::{self, V2Reader, VERSION2};
use super::{reader, TraceIoError};
use crate::isa::Instruction;
use crate::trace::{fold_instruction, KernelTrace};
use crate::util::Fnv1a;

/// Window size used when re-windowing a v1 trace (matches the v2
/// writer's chunk size so both paths hand the consumer similar slices).
pub const V1_WINDOW_INSTRS: usize = format2::WRITER_CHUNK_INSTRS;

/// One streamed slice of a trace: a contiguous instruction run belonging
/// to `warp`. A warp may span several consecutive windows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceWindow {
    /// Warp index the instructions belong to (0-based, monotonic across
    /// the stream).
    pub warp: usize,
    /// The decoded instructions of this window, in program order.
    pub instrs: Vec<Instruction>,
}

enum Source {
    V2(V2Reader<BufReader<File>>),
    V1(VecDeque<(usize, Vec<Instruction>)>),
}

/// Incremental reader over a `.mtrace` file of either version (see the
/// module docs for the per-version memory contract).
pub struct TraceStream {
    header: TraceHeader,
    version: u32,
    src: Source,
}

impl TraceStream {
    /// Open `path`, probe the magic, and position the stream after the
    /// header.
    pub fn open(path: &Path) -> Result<Self, TraceIoError> {
        if format2::sniff_path_version(path)? == VERSION2 {
            let f = File::open(path).map_err(TraceIoError::from_io)?;
            let rd = V2Reader::new(BufReader::new(f))?;
            let header = rd.header().clone();
            return Ok(TraceStream { header, version: VERSION2, src: Source::V2(rd) });
        }
        let t = reader::read_path(path)?;
        let header = TraceHeader {
            name: t.name,
            kernel_id: t.kernel_id,
            nwarps: t.warps.len(),
        };
        let mut q = VecDeque::new();
        for (wi, warp) in t.warps.into_iter().enumerate() {
            for piece in warp.chunks(V1_WINDOW_INSTRS) {
                q.push_back((wi, piece.to_vec()));
            }
        }
        Ok(TraceStream { header, version: 1, src: Source::V1(q) })
    }

    /// Header of the underlying trace (name, kernel id, warp count).
    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    /// Container version this stream is reading (1 or [`VERSION2`]).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Next window, or `None` once the file validated to the end.
    pub fn next_window(&mut self) -> Result<Option<TraceWindow>, TraceIoError> {
        match &mut self.src {
            Source::V2(rd) => {
                let mut instrs = Vec::new();
                Ok(rd
                    .next_chunk(&mut instrs)?
                    .map(|warp| TraceWindow { warp, instrs }))
            }
            Source::V1(q) => Ok(q.pop_front().map(|(warp, instrs)| TraceWindow { warp, instrs })),
        }
    }

    /// Drain the stream into a full [`KernelTrace`] (the in-memory
    /// convenience path; equivalent to `io::read_path`).
    pub fn into_trace(mut self) -> Result<KernelTrace, TraceIoError> {
        let mut warps: Vec<Vec<Instruction>> = Vec::new();
        while let Some(win) = self.next_window()? {
            if win.warp == warps.len() {
                warps.push(win.instrs);
            } else {
                warps[win.warp].extend(win.instrs);
            }
        }
        Ok(KernelTrace {
            name: self.header.name,
            kernel_id: self.header.kernel_id,
            warps,
        })
    }
}

/// Result of [`read_limited`]: the retained prefix of the trace plus the
/// whole-file facts the simulator entry point needs to stay bit-identical
/// with the unlimited path.
pub struct LimitedLoad {
    /// The trace with at most `max_warps` leading warps retained.
    pub trace: KernelTrace,
    /// Warp count of the **whole file** (before truncation).
    pub total_warps: usize,
    /// Whether any instruction **anywhere in the file** (including
    /// dropped warps) carries a near/far annotation bit. The replay path
    /// keys the compiler pass off this whole-file flag, exactly like the
    /// in-memory path keys off `KernelTrace::has_annotations`.
    pub annotated: bool,
}

/// Stream-decode `path`, retaining only the first `max_warps` warps.
/// The entire file is still validated (and, for v2, digest-checked);
/// only retention is truncated.
pub fn read_limited(path: &Path, max_warps: usize) -> Result<LimitedLoad, TraceIoError> {
    let mut s = TraceStream::open(path)?;
    let header = s.header().clone();
    let mut warps: Vec<Vec<Instruction>> = Vec::new();
    let mut annotated = false;
    while let Some(win) = s.next_window()? {
        annotated = annotated
            || win
                .instrs
                .iter()
                .any(|i| i.src_near != 0 || i.dst_near != 0);
        if win.warp >= max_warps {
            continue;
        }
        if win.warp == warps.len() {
            warps.push(win.instrs);
        } else {
            warps[win.warp].extend(win.instrs);
        }
    }
    Ok(LimitedLoad {
        trace: KernelTrace {
            name: header.name,
            kernel_id: header.kernel_id,
            warps,
        },
        total_warps: header.nwarps,
        annotated,
    })
}

/// Decoded-content fingerprint of a trace file, buffering one warp at a
/// time. Bit-identical to calling
/// [`KernelTrace::content_fingerprint`][crate::trace::KernelTrace::content_fingerprint]
/// on the fully parsed trace, for either container version — so the same
/// logical trace hashes the same whether it sits in a v1 or v2 file.
pub fn content_fingerprint_path(path: &Path) -> Result<u64, TraceIoError> {
    let mut s = TraceStream::open(path)?;
    let mut h = Fnv1a::new();
    h.bytes(s.header.name.as_bytes());
    h.word(u64::from(s.header.kernel_id));
    h.word(s.header.nwarps as u64);
    let mut warp_buf: Vec<Instruction> = Vec::new();
    let mut cur_warp: Option<usize> = None;
    while let Some(win) = s.next_window()? {
        if cur_warp != Some(win.warp) {
            if cur_warp.is_some() {
                fold_warp(&mut h, &mut warp_buf);
            }
            cur_warp = Some(win.warp);
        }
        warp_buf.extend(win.instrs);
    }
    if cur_warp.is_some() {
        fold_warp(&mut h, &mut warp_buf);
    }
    Ok(h.finish())
}

fn fold_warp(h: &mut Fnv1a, warp: &mut Vec<Instruction>) {
    h.word(warp.len() as u64);
    for i in warp.iter() {
        fold_instruction(h, i);
    }
    warp.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler;
    use crate::trace::find;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("malekeh_stream_{}_{name}", std::process::id()))
    }

    fn sample(nwarps: usize) -> KernelTrace {
        KernelTrace::generate(find("kmeans").unwrap(), nwarps, 0xC0FFEE)
    }

    #[test]
    fn v2_stream_reassembles_the_trace() {
        let t = sample(6);
        let p = tmp("v2.mtrace");
        format2::write_v2_path(&p, &t).unwrap();
        let s = TraceStream::open(&p).unwrap();
        assert_eq!(s.version(), VERSION2);
        assert_eq!(s.header().nwarps, 6);
        let back = s.into_trace().unwrap();
        assert_eq!(back.warps, t.warps);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_stream_is_a_faithful_veneer() {
        let t = sample(3);
        let p = tmp("v1.mtrace");
        super::super::write_path(&p, &t).unwrap();
        let mut s = TraceStream::open(&p).unwrap();
        assert_eq!(s.version(), 1);
        let mut seen_warps = Vec::new();
        let mut back: Vec<Vec<Instruction>> = vec![Vec::new(); 3];
        while let Some(win) = s.next_window().unwrap() {
            assert!(win.instrs.len() <= V1_WINDOW_INSTRS);
            seen_warps.push(win.warp);
            back[win.warp].extend(win.instrs);
        }
        let mut sorted = seen_warps.clone();
        sorted.sort_unstable();
        assert_eq!(seen_warps, sorted, "windows must be warp-major");
        assert_eq!(back, t.warps);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn read_limited_truncates_but_validates_and_flags_the_whole_file() {
        let mut t = sample(8);
        // annotate ONLY the last warp: a limited load of 2 warps must
        // still report the file as annotated
        let last = t.warps.len() - 1;
        t.warps[last][0].set_dst_near(0, true);
        let p = tmp("limited.mtrace");
        format2::write_v2_path(&p, &t).unwrap();
        let l = read_limited(&p, 2).unwrap();
        assert_eq!(l.trace.warps.len(), 2);
        assert_eq!(l.total_warps, 8);
        assert!(l.annotated, "annotation in a dropped warp was missed");
        assert_eq!(l.trace.warps[..], t.warps[..2]);
        // corrupting a dropped warp must still fail the load
        let mut bytes = std::fs::read(&p).unwrap();
        let n = bytes.len();
        bytes[n - 20] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_limited(&p, 2).is_err(), "corruption past the limit ignored");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streamed_fingerprint_matches_in_memory_for_both_versions() {
        let mut t = sample(4);
        compiler::profile_and_annotate(&mut t, 2, 12);
        let expect = t.content_fingerprint();
        let p1 = tmp("fp_v1.mtrace");
        let p2 = tmp("fp_v2.mtrace");
        super::super::write_path(&p1, &t).unwrap();
        format2::write_v2_path(&p2, &t).unwrap();
        assert_eq!(content_fingerprint_path(&p1).unwrap(), expect);
        assert_eq!(content_fingerprint_path(&p2).unwrap(), expect);
        std::fs::remove_file(&p1).ok();
        std::fs::remove_file(&p2).ok();
    }
}
