//! Binary `.mtrace` **v2**: length-prefixed chunked records with varint
//! packing and per-chunk delta/RLE compression.
//!
//! The textual v1 grammar ([`super::format`]) is convenient to diff but
//! parses line-by-line into a fully in-memory [`KernelTrace`] — it does
//! not survive multi-GB traces. v2 is the scalable sibling: the same IR,
//! serialised as a sequence of bounded, length-prefixed **chunks** that a
//! streaming reader ([`V2Reader`], wrapped by
//! [`super::stream::TraceStream`]) can validate and hand to the simulator
//! one window at a time, holding at most one chunk of decode state.
//!
//! # Grammar (byte level)
//!
//! ```text
//! file    := magic header chunk* trailer
//! magic   := "mtrace v2\n"                      (10 ASCII bytes)
//! header  := name_len:uv name:bytes kernel_id:uv nwarps:uv
//! chunk   := 0xC1 warp:uv count:uv enc:u8 payload_len:uv payload
//! trailer := 0xC0 total_instructions:uv digest:u64le
//! ```
//!
//! `uv` is a canonical little-endian base-128 varint (LEB128): at most 10
//! bytes, non-minimal encodings rejected. Chunks appear warp-major: all
//! chunks of warp 0, then warp 1, ... — warp indices step by exactly one
//! and every warp owns at least one chunk. `enc` selects the payload
//! record encoding:
//!
//! - `0` (**raw**): per instruction — one shape byte
//!   `op(3 bits) | nsrc<<3 | ndst<<6`, then `nsrc` source and `ndst`
//!   destination register bytes, the near/far masks (2 bytes), and, for
//!   memory ops only, the absolute line address as `uv`.
//! - `1` (**packed**): run-length groups `run:uv record`, where the
//!   record is the raw shape but its line address is replaced by a
//!   zigzag-varint **delta** against the previous memory address in the
//!   chunk (reset to 0 at each chunk start). A run of `n` repeats the
//!   record `n` times, re-applying the delta each time — so a constant
//!   -stride load/store stream collapses to a single group.
//!
//! The trailer's `digest` is a streaming FNV-1a over the **decoded**
//! content (name, kernel id, warp count, then per warp its index followed
//! by every instruction field) — encoding-independent, so any byte
//! corruption that survives the structural checks still fails the digest
//! (the fuzz battery in `rust/tests/trace_v2_fuzz.rs` leans on this).
//! Every declared length is capped before allocation, so a hostile file
//! can never make the parser balloon: names ≤ [`NAME_CAP`], warps ≤
//! [`WARP_CAP`], chunk records ≤ [`CHUNK_INSTR_CAP`], chunk payloads ≤
//! [`CHUNK_PAYLOAD_CAP`]. Full grammar prose lives in `docs/TRACES.md`.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use super::format::{self, TraceHeader};
use super::TraceIoError;
use crate::isa::{Instruction, OpClass, MAX_DST, MAX_SRC};
use crate::trace::{fold_instruction, KernelTrace};
use crate::util::Fnv1a;

/// First bytes of every v2 file (the textual v1 magic line never starts
/// with these ten bytes, so a prefix probe fully disambiguates).
pub const MAGIC2: &[u8; 10] = b"mtrace v2\n";
/// Format version written and accepted by this module.
pub const VERSION2: u32 = 2;
/// Longest accepted kernel name, in bytes.
pub const NAME_CAP: usize = 255;
/// Most warps a v2 header may declare.
pub const WARP_CAP: usize = 1 << 20;
/// Most instruction records one chunk may declare.
pub const CHUNK_INSTR_CAP: usize = 1 << 16;
/// Largest accepted chunk payload, in bytes (a full-size chunk of
/// worst-case records stays well under this).
pub const CHUNK_PAYLOAD_CAP: usize = 4 << 20;
/// Instructions per chunk emitted by [`write_v2`] — the reader-side
/// memory bound is `CHUNK_INSTR_CAP`, this is just the writer's choice.
pub const WRITER_CHUNK_INSTRS: usize = 4096;

const TAG_CHUNK: u8 = 0xC1;
const TAG_END: u8 = 0xC0;
const ENC_RAW: u8 = 0;
const ENC_PACKED: u8 = 1;

fn verr(off: u64, msg: impl std::fmt::Display) -> TraceIoError {
    TraceIoError::at(0, format!("v2 offset {off}: {msg}"))
}

// ---------------------------------------------------------------- varints

fn push_uv(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

// --------------------------------------------------------- payload decode

/// Cursor over one chunk payload (already bounded by
/// [`CHUNK_PAYLOAD_CAP`], so everything here is slice arithmetic).
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
    /// File offset of `buf[0]`, for error anchoring.
    base: u64,
}

impl Cur<'_> {
    fn off(&self) -> u64 {
        self.base + self.pos as u64
    }

    fn byte(&mut self, what: &str) -> Result<u8, TraceIoError> {
        let b = *self
            .buf
            .get(self.pos)
            .ok_or_else(|| verr(self.off(), format!("chunk payload truncated in {what}")))?;
        self.pos += 1;
        Ok(b)
    }

    fn uv(&mut self, what: &str) -> Result<u64, TraceIoError> {
        let start = self.off();
        let mut val = 0u64;
        for k in 0..10u32 {
            let b = self.byte(what)?;
            if k == 9 && b > 1 {
                return Err(verr(start, format!("varint overflows u64 in {what}")));
            }
            val |= u64::from(b & 0x7F) << (7 * k);
            if b & 0x80 == 0 {
                if k > 0 && b == 0 {
                    return Err(verr(start, format!("non-canonical varint in {what}")));
                }
                return Ok(val);
            }
        }
        Err(verr(start, format!("varint longer than 10 bytes in {what}")))
    }
}

/// Decode the encoding-invariant record prefix (shape byte, registers,
/// near masks); the caller supplies the line address per the chunk
/// encoding. Returns the instruction (address still 0) and whether it is
/// a memory op (= carries an address field).
fn decode_record(c: &mut Cur) -> Result<(Instruction, bool), TraceIoError> {
    let at = c.off();
    let b0 = c.byte("record shape byte")?;
    let op = OpClass::ALL[usize::from(b0 & 0x07)];
    let nsrc = usize::from((b0 >> 3) & 0x07);
    let ndst = usize::from(b0 >> 6);
    if nsrc > MAX_SRC {
        return Err(verr(at, format!("{nsrc} sources exceed the ISA bound {MAX_SRC}")));
    }
    if ndst > MAX_DST {
        return Err(verr(
            at,
            format!("{ndst} destinations exceed the ISA bound {MAX_DST}"),
        ));
    }
    let mut srcs = [0u8; MAX_SRC];
    for s in srcs.iter_mut().take(nsrc) {
        *s = c.byte("source register")?;
    }
    let mut dsts = [0u8; MAX_DST];
    for d in dsts.iter_mut().take(ndst) {
        *d = c.byte("destination register")?;
    }
    let src_near = c.byte("source near mask")?;
    let dst_near = c.byte("destination near mask")?;
    if u32::from(src_near) >= (1u32 << nsrc) {
        return Err(verr(
            at,
            format!("near mask {src_near} names sources beyond the {nsrc} declared"),
        ));
    }
    if u32::from(dst_near) >= (1u32 << ndst) {
        return Err(verr(
            at,
            format!("near mask {dst_near} names destinations beyond the {ndst} declared"),
        ));
    }
    let mut i = Instruction::new(op, &srcs[..nsrc], &dsts[..ndst]);
    i.src_near = src_near;
    i.dst_near = dst_near;
    Ok((i, op.is_mem()))
}

/// Decode one chunk payload into `out` (appended); `count` records must
/// consume the payload exactly.
fn decode_payload(
    enc: u8,
    payload: &[u8],
    base_off: u64,
    count: usize,
    out: &mut Vec<Instruction>,
) -> Result<(), TraceIoError> {
    let mut c = Cur { buf: payload, pos: 0, base: base_off };
    match enc {
        ENC_RAW => {
            for _ in 0..count {
                let (mut i, mem) = decode_record(&mut c)?;
                if mem {
                    let at = c.off();
                    let a = c.uv("line address")?;
                    if a > u64::from(u32::MAX) {
                        return Err(verr(at, "line address exceeds u32"));
                    }
                    i.line_addr = a as u32;
                }
                out.push(i);
            }
        }
        ENC_PACKED => {
            let mut prev: i64 = 0;
            let mut remaining = count;
            while remaining > 0 {
                let at = c.off();
                let run = c.uv("run length")?;
                if run == 0 || run > remaining as u64 {
                    return Err(verr(
                        at,
                        format!("run length {run} invalid with {remaining} records left"),
                    ));
                }
                let (proto, mem) = decode_record(&mut c)?;
                let delta = if mem { unzigzag(c.uv("address delta")?) } else { 0 };
                for _ in 0..run {
                    let mut i = proto;
                    if mem {
                        let a = prev + delta;
                        if !(0..=i64::from(u32::MAX)).contains(&a) {
                            return Err(verr(at, "delta walks the line address out of u32"));
                        }
                        i.line_addr = a as u32;
                        prev = a;
                    }
                    out.push(i);
                }
                remaining -= run as usize;
            }
        }
        other => {
            return Err(verr(base_off, format!("unknown chunk encoding {other}")));
        }
    }
    if c.pos != payload.len() {
        return Err(verr(
            c.off(),
            format!("{} unconsumed payload bytes after the declared records", payload.len() - c.pos),
        ));
    }
    Ok(())
}

// --------------------------------------------------------------- encoding

fn push_record(out: &mut Vec<u8>, i: &Instruction) {
    out.push((i.op as u8) | (i.nsrc << 3) | (i.ndst << 6));
    out.extend_from_slice(&i.srcs[..usize::from(i.nsrc)]);
    out.extend_from_slice(&i.dsts[..usize::from(i.ndst)]);
    out.push(i.src_near);
    out.push(i.dst_near);
}

/// Do two instructions encode to the same record modulo the address?
fn same_shape(a: &Instruction, b: &Instruction) -> bool {
    a.op == b.op
        && a.nsrc == b.nsrc
        && a.ndst == b.ndst
        && a.srcs == b.srcs
        && a.dsts == b.dsts
        && a.src_near == b.src_near
        && a.dst_near == b.dst_near
}

/// Packed-encode one chunk: delta addresses + RLE over identical
/// (record, delta) groups. Constant-stride streams collapse to one group.
fn encode_packed(chunk: &[Instruction], out: &mut Vec<u8>) {
    let mut prev: i64 = 0;
    let mut k = 0usize;
    while k < chunk.len() {
        let first = &chunk[k];
        let mem = first.op.is_mem();
        let d0 = if mem { i64::from(first.line_addr) - prev } else { 0 };
        let mut p = if mem { i64::from(first.line_addr) } else { prev };
        let mut run = 1usize;
        while k + run < chunk.len() {
            let c = &chunk[k + run];
            if !same_shape(c, first) {
                break;
            }
            if mem {
                if i64::from(c.line_addr) - p != d0 {
                    break;
                }
                p = i64::from(c.line_addr);
            }
            run += 1;
        }
        push_uv(out, run as u64);
        push_record(out, first);
        if mem {
            push_uv(out, zigzag(d0));
        }
        prev = p;
        k += run;
    }
}

// ---------------------------------------------------------------- reader

/// Incremental v2 parser: hands out one decoded chunk at a time, holding
/// only bounded state (one payload buffer), and finishes with the
/// whole-file checks — warp coverage, EXIT invariants, instruction total,
/// content digest, and no trailing bytes.
pub struct V2Reader<R: Read> {
    r: R,
    off: u64,
    header: TraceHeader,
    digest: Fnv1a,
    /// Warp currently receiving chunks (None before the first chunk).
    cur_warp: Option<usize>,
    warps_closed: usize,
    cur_exits: usize,
    cur_ends_exit: bool,
    total: u64,
    finished: bool,
    payload: Vec<u8>,
}

impl<R: Read> V2Reader<R> {
    /// Parse the magic and header; the stream is then positioned at the
    /// first chunk.
    pub fn new(r: R) -> Result<Self, TraceIoError> {
        let mut rd = V2Reader {
            r,
            off: 0,
            header: TraceHeader { name: String::new(), kernel_id: 0, nwarps: 0 },
            digest: Fnv1a::new(),
            cur_warp: None,
            warps_closed: 0,
            cur_exits: 0,
            cur_ends_exit: false,
            total: 0,
            finished: false,
            payload: Vec::new(),
        };
        let mut magic = [0u8; MAGIC2.len()];
        rd.fill(&mut magic, "magic")?;
        if magic != *MAGIC2 {
            return Err(verr(0, "not an mtrace v2 file (bad magic)"));
        }
        let at = rd.off;
        let name_len = rd.uv("name length")? as usize;
        if name_len == 0 || name_len > NAME_CAP {
            return Err(verr(at, format!("kernel name length {name_len} outside 1..={NAME_CAP}")));
        }
        let mut name = vec![0u8; name_len];
        rd.fill(&mut name, "kernel name")?;
        let name = String::from_utf8(name)
            .map_err(|_| verr(at, "kernel name is not valid UTF-8"))?;
        format::validate_name(&name).map_err(|m| verr(at, m))?;
        let at = rd.off;
        let kernel_id = rd.uv("kernel id")?;
        if kernel_id > u64::from(u32::MAX) {
            return Err(verr(at, "kernel id exceeds u32"));
        }
        let at = rd.off;
        let nwarps = rd.uv("warp count")? as usize;
        if nwarps > WARP_CAP {
            return Err(verr(at, format!("{nwarps} warps exceed the cap {WARP_CAP}")));
        }
        rd.header = TraceHeader { name, kernel_id: kernel_id as u32, nwarps };
        rd.digest.bytes(rd.header.name.as_bytes());
        rd.digest.word(kernel_id);
        rd.digest.word(nwarps as u64);
        Ok(rd)
    }

    /// Header decoded from the front of the file.
    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    fn fill(&mut self, buf: &mut [u8], what: &str) -> Result<(), TraceIoError> {
        let at = self.off;
        self.r
            .read_exact(buf)
            .map_err(|e| verr(at, format!("truncated in {what}: {e}")))?;
        self.off += buf.len() as u64;
        Ok(())
    }

    fn byte(&mut self, what: &str) -> Result<u8, TraceIoError> {
        let mut b = [0u8; 1];
        self.fill(&mut b, what)?;
        Ok(b[0])
    }

    fn uv(&mut self, what: &str) -> Result<u64, TraceIoError> {
        let start = self.off;
        let mut val = 0u64;
        for k in 0..10u32 {
            let b = self.byte(what)?;
            if k == 9 && b > 1 {
                return Err(verr(start, format!("varint overflows u64 in {what}")));
            }
            val |= u64::from(b & 0x7F) << (7 * k);
            if b & 0x80 == 0 {
                if k > 0 && b == 0 {
                    return Err(verr(start, format!("non-canonical varint in {what}")));
                }
                return Ok(val);
            }
        }
        Err(verr(start, format!("varint longer than 10 bytes in {what}")))
    }

    fn close_warp(&mut self) -> Result<(), TraceIoError> {
        if let Some(w) = self.cur_warp {
            if self.cur_exits != 1 || !self.cur_ends_exit {
                return Err(verr(
                    self.off,
                    format!("warp {w} must end with exactly one EXIT marker"),
                ));
            }
            self.warps_closed += 1;
        }
        Ok(())
    }

    fn open_warp(&mut self, w: usize) {
        self.cur_warp = Some(w);
        self.cur_exits = 0;
        self.cur_ends_exit = false;
        self.digest.word(w as u64);
    }

    /// Decode the next chunk into `out` (cleared first) and return its
    /// warp index, or `None` once the trailer validated cleanly. After
    /// `None`, further calls keep returning `None`.
    pub fn next_chunk(&mut self, out: &mut Vec<Instruction>) -> Result<Option<usize>, TraceIoError> {
        out.clear();
        if self.finished {
            return Ok(None);
        }
        let at = self.off;
        match self.byte("chunk tag")? {
            TAG_END => {
                self.close_warp()?;
                if self.warps_closed != self.header.nwarps {
                    return Err(verr(
                        at,
                        format!(
                            "header declares {} warps but {} were encoded",
                            self.header.nwarps, self.warps_closed
                        ),
                    ));
                }
                let declared = self.uv("instruction total")?;
                if declared != self.total {
                    return Err(verr(
                        at,
                        format!("trailer declares {declared} instructions, decoded {}", self.total),
                    ));
                }
                let mut d = [0u8; 8];
                self.fill(&mut d, "content digest")?;
                if u64::from_le_bytes(d) != self.digest.finish() {
                    return Err(verr(at, "content digest mismatch (corrupt trace)"));
                }
                let mut probe = [0u8; 1];
                match self.r.read(&mut probe) {
                    Ok(0) => {}
                    Ok(_) => return Err(verr(self.off, "trailing bytes after the trailer")),
                    Err(e) => return Err(verr(self.off, e)),
                }
                self.finished = true;
                Ok(None)
            }
            TAG_CHUNK => {
                let w = self.uv("chunk warp index")? as usize;
                match self.cur_warp {
                    None => {
                        if w != 0 {
                            return Err(verr(at, format!("first chunk must be warp 0, got {w}")));
                        }
                        if self.header.nwarps == 0 {
                            return Err(verr(at, "chunk present but header declares 0 warps"));
                        }
                        self.open_warp(0);
                    }
                    Some(cw) if w == cw => {}
                    Some(cw) if w == cw + 1 => {
                        self.close_warp()?;
                        if w >= self.header.nwarps {
                            return Err(verr(
                                at,
                                format!("warp {w} beyond the {} declared", self.header.nwarps),
                            ));
                        }
                        self.open_warp(w);
                    }
                    Some(cw) => {
                        return Err(verr(
                            at,
                            format!("chunks must be warp-sequential (got {w} after {cw})"),
                        ));
                    }
                }
                let count = self.uv("chunk record count")? as usize;
                if count == 0 || count > CHUNK_INSTR_CAP {
                    return Err(verr(
                        at,
                        format!("chunk record count {count} outside 1..={CHUNK_INSTR_CAP}"),
                    ));
                }
                let enc = self.byte("chunk encoding")?;
                let plen = self.uv("chunk payload length")? as usize;
                if plen == 0 || plen > CHUNK_PAYLOAD_CAP {
                    return Err(verr(
                        at,
                        format!("chunk payload length {plen} outside 1..={CHUNK_PAYLOAD_CAP}"),
                    ));
                }
                self.payload.resize(plen, 0);
                let payload_off = self.off;
                let mut payload = std::mem::take(&mut self.payload);
                let res = self.fill(&mut payload, "chunk payload");
                self.payload = payload;
                res?;
                out.reserve(count);
                decode_payload(enc, &self.payload, payload_off, count, out)?;
                for i in out.iter() {
                    fold_instruction(&mut self.digest, i);
                    if i.op == OpClass::Exit {
                        self.cur_exits += 1;
                    }
                    self.cur_ends_exit = i.op == OpClass::Exit;
                }
                self.total += count as u64;
                Ok(Some(w))
            }
            other => Err(verr(at, format!("unknown section tag 0x{other:02X}"))),
        }
    }
}

// ------------------------------------------------------------ entry points

/// Read a whole v2 stream into a [`KernelTrace`] (in-memory counterpart
/// of the chunked path; `super::stream::TraceStream` is the bounded one).
pub fn read_v2<R: Read>(r: R) -> Result<KernelTrace, TraceIoError> {
    let mut rd = V2Reader::new(r)?;
    let mut warps: Vec<Vec<Instruction>> = Vec::new();
    let mut buf = Vec::new();
    while let Some(w) = rd.next_chunk(&mut buf)? {
        if w == warps.len() {
            warps.push(Vec::new());
        }
        warps[w].extend_from_slice(&buf);
    }
    let h = rd.header().clone();
    Ok(KernelTrace { name: h.name, kernel_id: h.kernel_id, warps })
}

/// Read a v2 trace from an in-memory byte buffer (tests, fuzzing).
pub fn read_v2_slice(bytes: &[u8]) -> Result<KernelTrace, TraceIoError> {
    read_v2(bytes)
}

/// Serialise `trace` as v2 to any writer. Deterministic: same trace, same
/// bytes. Mirrors the reader's validation (name, EXIT invariants, no
/// address on non-memory ops) so it can never emit a file [`read_v2`]
/// rejects.
pub fn write_v2<W: Write>(mut w: W, trace: &KernelTrace) -> Result<(), TraceIoError> {
    format::validate_name(&trace.name).map_err(|m| TraceIoError::at(0, m))?;
    if trace.name.len() > NAME_CAP {
        return Err(TraceIoError::at(0, format!("kernel name longer than {NAME_CAP} bytes")));
    }
    if trace.warps.len() > WARP_CAP {
        return Err(TraceIoError::at(0, format!("more than {WARP_CAP} warps")));
    }
    for (i, warp) in trace.warps.iter().enumerate() {
        let exits = warp.iter().filter(|x| x.op == OpClass::Exit).count();
        if exits != 1 || warp.last().map(|x| x.op) != Some(OpClass::Exit) {
            return Err(TraceIoError::at(
                0,
                format!("warp {i} must end with exactly one EXIT marker"),
            ));
        }
        if warp.iter().any(|x| x.line_addr != 0 && !x.op.is_mem()) {
            return Err(TraceIoError::at(
                0,
                format!("warp {i}: non-memory instruction carries a line address"),
            ));
        }
    }
    let mut digest = Fnv1a::new();
    digest.bytes(trace.name.as_bytes());
    digest.word(u64::from(trace.kernel_id));
    digest.word(trace.warps.len() as u64);
    w.write_all(MAGIC2).map_err(TraceIoError::from_io)?;
    let mut head = Vec::new();
    push_uv(&mut head, trace.name.len() as u64);
    head.extend_from_slice(trace.name.as_bytes());
    push_uv(&mut head, u64::from(trace.kernel_id));
    push_uv(&mut head, trace.warps.len() as u64);
    w.write_all(&head).map_err(TraceIoError::from_io)?;
    let mut total = 0u64;
    let mut hdr = Vec::new();
    let mut payload = Vec::new();
    for (wi, warp) in trace.warps.iter().enumerate() {
        digest.word(wi as u64);
        for instr in warp {
            fold_instruction(&mut digest, instr);
        }
        for chunk in warp.chunks(WRITER_CHUNK_INSTRS) {
            payload.clear();
            encode_packed(chunk, &mut payload);
            hdr.clear();
            hdr.push(TAG_CHUNK);
            push_uv(&mut hdr, wi as u64);
            push_uv(&mut hdr, chunk.len() as u64);
            hdr.push(ENC_PACKED);
            push_uv(&mut hdr, payload.len() as u64);
            w.write_all(&hdr).map_err(TraceIoError::from_io)?;
            w.write_all(&payload).map_err(TraceIoError::from_io)?;
            total += chunk.len() as u64;
        }
    }
    let mut tail = vec![TAG_END];
    push_uv(&mut tail, total);
    tail.extend_from_slice(&digest.finish().to_le_bytes());
    w.write_all(&tail).map_err(TraceIoError::from_io)
}

/// Serialise as v2 to a file path (parent directory must exist).
pub fn write_v2_path(path: &Path, trace: &KernelTrace) -> Result<(), TraceIoError> {
    let f = File::create(path).map_err(TraceIoError::from_io)?;
    let mut w = BufWriter::new(f);
    write_v2(&mut w, trace)?;
    w.flush().map_err(TraceIoError::from_io)
}

/// Serialise as v2 into an in-memory buffer (tests, round trips).
pub fn write_v2_bytes(trace: &KernelTrace) -> Result<Vec<u8>, TraceIoError> {
    let mut buf = Vec::new();
    write_v2(&mut buf, trace)?;
    Ok(buf)
}

/// Probe the first bytes of `path` and classify the container format:
/// [`VERSION2`] when the binary v2 magic matches, else 1 (presumed
/// textual — the v1 reader surfaces the real error for garbage input).
pub fn sniff_path_version(path: &Path) -> Result<u32, TraceIoError> {
    let mut f = File::open(path).map_err(TraceIoError::from_io)?;
    let mut probe = [0u8; MAGIC2.len()];
    let mut n = 0usize;
    while n < probe.len() {
        match f.read(&mut probe[n..]) {
            Ok(0) => break,
            Ok(k) => n += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(TraceIoError::from_io(e)),
        }
    }
    Ok(if n == probe.len() && probe == *MAGIC2 { VERSION2 } else { 1 })
}

/// Probe an in-memory buffer the same way [`sniff_path_version`] probes a
/// file.
pub fn is_v2_bytes(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC2.len() && bytes[..MAGIC2.len()] == MAGIC2[..]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::find;

    fn tiny() -> KernelTrace {
        let mut ld = Instruction::mem(OpClass::LdGlobal, &[], &[2], 0x40);
        ld.set_dst_near(0, true);
        KernelTrace {
            name: "tiny".into(),
            kernel_id: 1,
            warps: vec![vec![
                ld,
                Instruction::new(OpClass::Alu, &[2], &[3]),
                Instruction::new(OpClass::Exit, &[], &[]),
            ]],
        }
    }

    #[test]
    fn varints_roundtrip_canonically() {
        for v in [0u64, 1, 127, 128, 300, 1 << 20, u64::from(u32::MAX), u64::MAX] {
            let mut buf = Vec::new();
            push_uv(&mut buf, v);
            let mut c = Cur { buf: &buf, pos: 0, base: 0 };
            assert_eq!(c.uv("t").unwrap(), v);
            assert_eq!(c.pos, buf.len(), "value {v} not fully consumed");
        }
        // non-canonical: 0 written with a continuation group
        let mut c = Cur { buf: &[0x80, 0x00], pos: 0, base: 0 };
        assert!(c.uv("t").is_err());
        // overflow: 11 continuation bytes
        let mut c = Cur { buf: &[0x80; 11], pos: 0, base: 0 };
        assert!(c.uv("t").is_err());
    }

    #[test]
    fn zigzag_roundtrips() {
        for v in [0i64, 1, -1, 63, -64, i64::from(u32::MAX), -i64::from(u32::MAX)] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn tiny_roundtrips_bit_identically() {
        let t = tiny();
        let bytes = write_v2_bytes(&t).unwrap();
        assert!(is_v2_bytes(&bytes));
        let back = read_v2_slice(&bytes).unwrap();
        assert_eq!(back.name, t.name);
        assert_eq!(back.kernel_id, t.kernel_id);
        assert_eq!(back.warps, t.warps);
        // writer is deterministic
        assert_eq!(bytes, write_v2_bytes(&t).unwrap());
    }

    #[test]
    fn generated_benchmarks_roundtrip() {
        for name in ["kmeans", "gemm_t1", "b+tree"] {
            let mut t = KernelTrace::generate(find(name).unwrap(), 4, 0xC0FFEE);
            crate::compiler::annotate_precise(&mut t, 12);
            let back = read_v2_slice(&write_v2_bytes(&t).unwrap())
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(back.warps, t.warps, "{name}: IR not preserved");
        }
    }

    #[test]
    fn zero_warp_trace_roundtrips() {
        let t = KernelTrace { name: "empty".into(), kernel_id: 0, warps: vec![] };
        let back = read_v2_slice(&write_v2_bytes(&t).unwrap()).unwrap();
        assert_eq!(back.warps.len(), 0);
    }

    #[test]
    fn packed_encoding_compresses_streaming_sequences() {
        // a constant-stride store stream from one register is one RLE group
        let mut warp: Vec<Instruction> = (0..1000)
            .map(|k| Instruction::mem(OpClass::StGlobal, &[7], &[], 0x1000 + k))
            .collect();
        warp.push(Instruction::new(OpClass::Exit, &[], &[]));
        let t = KernelTrace { name: "stream".into(), kernel_id: 0, warps: vec![warp] };
        let v2 = write_v2_bytes(&t).unwrap();
        // raw would need >= 5 bytes per store; RLE collapses the run
        assert!(v2.len() < 200, "packed stream took {} bytes", v2.len());
        assert_eq!(read_v2_slice(&v2).unwrap().warps, t.warps);
    }

    #[test]
    fn every_strict_prefix_is_rejected() {
        let bytes = write_v2_bytes(&tiny()).unwrap();
        for cut in 0..bytes.len() {
            assert!(
                read_v2_slice(&bytes[..cut]).is_err(),
                "prefix of {cut}/{} bytes parsed",
                bytes.len()
            );
        }
    }

    #[test]
    fn trailing_bytes_and_digest_corruption_are_rejected() {
        let t = tiny();
        let mut bytes = write_v2_bytes(&t).unwrap();
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(read_v2_slice(&extra).is_err(), "trailing byte accepted");
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF; // digest byte
        assert!(read_v2_slice(&bytes).is_err(), "digest corruption accepted");
    }

    #[test]
    fn writer_rejects_what_reader_rejects() {
        let mut t = tiny();
        t.name = "has space".into();
        assert!(write_v2_bytes(&t).is_err());
        let mut t = tiny();
        t.warps[0].pop(); // drop the EXIT
        assert!(write_v2_bytes(&t).is_err());
        let mut t = tiny();
        t.warps[0][1].line_addr = 7; // address on an ALU op
        assert!(write_v2_bytes(&t).is_err());
    }
}
