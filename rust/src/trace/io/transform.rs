//! Composable trace transforms for scenario scaling: shrink, slice, or
//! rename a recorded trace before replaying it.
//!
//! Transforms are pure (`&KernelTrace -> KernelTrace`) and compose left to
//! right with [`apply_all`], so a recorded production trace can be scaled
//! down for quick sweeps (subsample warps), focused on a phase (slice an
//! instruction window), or rebased onto a different register allocation
//! (remap ids) without regenerating anything.

use crate::isa::{Instruction, OpClass, NUM_REGS};
use crate::trace::KernelTrace;

/// One scenario-scaling transform.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Transform {
    /// Keep warps `0, k, 2k, ...` — one in `keep_one_in` (values < 1 are
    /// treated as 1, i.e. keep everything).
    WarpSubsample {
        /// Subsampling factor.
        keep_one_in: usize,
    },
    /// Keep the dynamic instruction window `[start, start+len)` of every
    /// warp (counted over the stream *without* its `EXIT` marker, which is
    /// re-appended afterwards so the result stays simulable).
    InstructionWindow {
        /// First dynamic instruction kept.
        start: usize,
        /// Window length in instructions.
        len: usize,
    },
    /// Remap architectural register ids; ids not named by a pair keep
    /// their value. Near/far bits travel with the operand slot, so the
    /// annotation survives the rename.
    RegisterRemap {
        /// `(from, to)` id pairs.
        pairs: Vec<(u8, u8)>,
    },
}

impl Transform {
    /// Apply this transform, producing a new trace.
    pub fn apply(&self, trace: &KernelTrace) -> KernelTrace {
        let warps = match self {
            Transform::WarpSubsample { keep_one_in } => {
                let k = (*keep_one_in).max(1);
                trace.warps.iter().step_by(k).cloned().collect()
            }
            Transform::InstructionWindow { start, len } => trace
                .warps
                .iter()
                .map(|w| {
                    let body = match w.last() {
                        Some(i) if i.op == OpClass::Exit => &w[..w.len() - 1],
                        _ => &w[..],
                    };
                    let lo = (*start).min(body.len());
                    let hi = start.saturating_add(*len).min(body.len());
                    let mut out = body[lo..hi].to_vec();
                    out.push(Instruction::new(OpClass::Exit, &[], &[]));
                    out
                })
                .collect(),
            Transform::RegisterRemap { pairs } => {
                let mut map: [u8; NUM_REGS] = std::array::from_fn(|i| i as u8);
                for &(from, to) in pairs {
                    map[from as usize] = to;
                }
                trace
                    .warps
                    .iter()
                    .map(|w| {
                        w.iter()
                            .map(|instr| {
                                let mut i = *instr;
                                let (ns, nd) = (i.nsrc as usize, i.ndst as usize);
                                for r in i.srcs.iter_mut().take(ns) {
                                    *r = map[*r as usize];
                                }
                                for r in i.dsts.iter_mut().take(nd) {
                                    *r = map[*r as usize];
                                }
                                i
                            })
                            .collect()
                    })
                    .collect()
            }
        };
        KernelTrace { name: trace.name.clone(), kernel_id: trace.kernel_id, warps }
    }
}

/// Apply a sequence of transforms left to right.
pub fn apply_all(trace: &KernelTrace, transforms: &[Transform]) -> KernelTrace {
    let mut t = trace.clone();
    for tr in transforms {
        t = tr.apply(&t);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::find;

    fn sample() -> KernelTrace {
        KernelTrace::generate(find("hotspot").unwrap(), 8, 7)
    }

    #[test]
    fn subsample_keeps_every_kth_warp() {
        let t = sample();
        let s = Transform::WarpSubsample { keep_one_in: 4 }.apply(&t);
        assert_eq!(s.warps.len(), 2);
        assert_eq!(s.warps[0], t.warps[0]);
        assert_eq!(s.warps[1], t.warps[4]);
        // factor 0/1 keep everything
        assert_eq!(
            Transform::WarpSubsample { keep_one_in: 0 }.apply(&t).warps.len(),
            8
        );
    }

    #[test]
    fn window_slices_and_reterminates() {
        let t = sample();
        let s = Transform::InstructionWindow { start: 5, len: 10 }.apply(&t);
        for (w, orig) in s.warps.iter().zip(t.warps.iter()) {
            assert_eq!(w.len(), 11); // 10 instructions + EXIT
            assert_eq!(w.last().unwrap().op, OpClass::Exit);
            assert_eq!(&w[..10], &orig[5..15]);
        }
        // windows past the end degrade to a bare EXIT
        let s = Transform::InstructionWindow { start: usize::MAX, len: 10 }.apply(&t);
        assert!(s.warps.iter().all(|w| w.len() == 1));
    }

    #[test]
    fn remap_renames_only_named_ids() {
        let t = sample();
        let s = Transform::RegisterRemap { pairs: vec![(2, 200)] }.apply(&t);
        for (w, orig) in s.warps.iter().zip(t.warps.iter()) {
            for (a, b) in w.iter().zip(orig.iter()) {
                assert_eq!(a.op, b.op);
                assert_eq!(a.src_near, b.src_near, "near bits travel");
                for (x, y) in a.sources().iter().zip(b.sources().iter()) {
                    assert_eq!(*x, if *y == 2 { 200 } else { *y });
                }
            }
        }
        assert!(s
            .warps
            .iter()
            .flatten()
            .all(|i| !i.sources().contains(&2) && !i.dests().contains(&2)));
    }

    #[test]
    fn apply_all_composes_left_to_right() {
        let t = sample();
        let out = apply_all(
            &t,
            &[
                Transform::WarpSubsample { keep_one_in: 2 },
                Transform::InstructionWindow { start: 0, len: 20 },
            ],
        );
        assert_eq!(out.warps.len(), 4);
        assert!(out.warps.iter().all(|w| w.len() == 21));
    }
}
