//! `.mtrace` serialiser: write any [`KernelTrace`] (generated, annotated,
//! or transformed) so it can be re-ingested by [`super::reader`].
//!
//! Output is fully deterministic — no timestamps or environment state —
//! so recorded traces are stable across runs and safe to diff in CI.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::format::{self, TraceHeader};
use super::TraceIoError;
use crate::isa::OpClass;
use crate::trace::KernelTrace;

/// Serialise to a file path (parent directory must exist).
pub fn write_path(path: &Path, trace: &KernelTrace) -> Result<(), TraceIoError> {
    let f = File::create(path).map_err(TraceIoError::from_io)?;
    let mut w = BufWriter::new(f);
    write(&mut w, trace)?;
    w.flush().map_err(TraceIoError::from_io)
}

/// Serialise to an in-memory string (tests, round-trip checks).
pub fn write_string(trace: &KernelTrace) -> Result<String, TraceIoError> {
    let mut buf: Vec<u8> = Vec::new();
    write(&mut buf, trace)?;
    Ok(String::from_utf8(buf).expect("mtrace output is ASCII"))
}

/// Serialise to any writer.
pub fn write<W: Write>(mut w: W, trace: &KernelTrace) -> Result<(), TraceIoError> {
    format::validate_name(&trace.name).map_err(|m| TraceIoError::at(0, m))?;
    for (i, warp) in trace.warps.iter().enumerate() {
        // mirror the reader's validation so the writer can never emit a
        // file its own reader rejects
        let exits = warp.iter().filter(|x| x.op == OpClass::Exit).count();
        if exits != 1 || warp.last().map(|x| x.op) != Some(OpClass::Exit) {
            return Err(TraceIoError::at(
                0,
                format!("warp {i} must end with exactly one EXIT marker"),
            ));
        }
    }
    let header = TraceHeader {
        name: trace.name.clone(),
        kernel_id: trace.kernel_id,
        nwarps: trace.warps.len(),
    };
    writeln!(w, "{}", format::format_magic()).map_err(TraceIoError::from_io)?;
    writeln!(w, "{}", format::format_header(&header)).map_err(TraceIoError::from_io)?;
    for (wi, warp) in trace.warps.iter().enumerate() {
        writeln!(w, "warp {wi}").map_err(TraceIoError::from_io)?;
        for instr in warp {
            writeln!(w, "{}", format::format_instruction(instr))
                .map_err(TraceIoError::from_io)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::reader::read_str;
    use super::*;
    use crate::isa::Instruction;

    fn tiny() -> KernelTrace {
        let mut ld = Instruction::mem(OpClass::LdGlobal, &[], &[2], 0x40);
        ld.set_dst_near(0, true);
        KernelTrace {
            name: "tiny".into(),
            kernel_id: 1,
            warps: vec![vec![
                ld,
                Instruction::new(OpClass::Alu, &[2], &[3]),
                Instruction::new(OpClass::Exit, &[], &[]),
            ]],
        }
    }

    #[test]
    fn write_then_read_is_identity() {
        let t = tiny();
        let text = write_string(&t).unwrap();
        let back = read_str(&text).unwrap();
        assert_eq!(back.name, t.name);
        assert_eq!(back.kernel_id, t.kernel_id);
        assert_eq!(back.warps, t.warps);
    }

    #[test]
    fn rejects_bad_names_and_missing_exit() {
        let mut t = tiny();
        t.name = "has space".into();
        assert!(write_string(&t).is_err());
        let mut t = tiny();
        t.warps[0].pop(); // drop the EXIT
        assert!(write_string(&t).is_err());
        // interior EXIT: the writer must reject what its reader would
        let mut t = tiny();
        t.warps[0].insert(0, Instruction::new(OpClass::Exit, &[], &[]));
        assert!(write_string(&t).is_err());
    }

    #[test]
    fn output_is_deterministic() {
        let t = tiny();
        assert_eq!(write_string(&t).unwrap(), write_string(&t).unwrap());
    }
}
