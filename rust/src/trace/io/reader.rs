//! Streaming `.mtrace` parser producing the existing [`KernelTrace`] IR.
//!
//! The reader consumes any [`BufRead`] line by line (it never buffers the
//! whole file), validates as it goes, and finishes with whole-trace checks:
//! the warp count must match the header and every warp stream must end
//! with exactly one `EXIT` marker — the invariants the simulator's warp
//! slots rely on.

use std::fs::File;
use std::io::{BufRead, BufReader};
use std::path::Path;

use super::format::{self, TraceHeader};
use super::TraceIoError;
use crate::isa::{Instruction, OpClass};
use crate::trace::KernelTrace;

/// Read a trace from a file path, auto-detecting the container version:
/// files starting with the binary v2 magic go through
/// [`super::format2::read_v2`], everything else through the textual v1
/// parser. This is the single funnel behind `simulate --trace`,
/// `trace info|convert`, [`Workload::load`](crate::trace::Workload::load)
/// and harness trace points, so all of them accept either version.
pub fn read_path(path: &Path) -> Result<KernelTrace, TraceIoError> {
    use super::format2;
    if format2::sniff_path_version(path)? == format2::VERSION2 {
        let f = File::open(path).map_err(TraceIoError::from_io)?;
        return format2::read_v2(BufReader::new(f));
    }
    let f = File::open(path).map_err(TraceIoError::from_io)?;
    read(BufReader::new(f))
}

/// Read a trace from an in-memory string (tests, round-trip checks).
pub fn read_str(s: &str) -> Result<KernelTrace, TraceIoError> {
    read(s.as_bytes())
}

/// Read a trace from any buffered reader.
pub fn read<R: BufRead>(r: R) -> Result<KernelTrace, TraceIoError> {
    let mut magic_seen = false;
    let mut header: Option<TraceHeader> = None;
    let mut warps: Vec<Vec<Instruction>> = Vec::new();
    for (n, line) in r.lines().enumerate() {
        let lineno = n + 1;
        let line = line.map_err(TraceIoError::from_io)?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        if !magic_seen {
            format::parse_magic(t).map_err(|m| TraceIoError::at(lineno, m))?;
            magic_seen = true;
            continue;
        }
        match t.split_whitespace().next() {
            Some("kernel") => {
                if header.is_some() {
                    return Err(TraceIoError::at(lineno, "duplicate kernel header"));
                }
                if !warps.is_empty() {
                    return Err(TraceIoError::at(
                        lineno,
                        "kernel header must precede warp sections",
                    ));
                }
                header = Some(
                    format::parse_header(t)
                        .map_err(|m| TraceIoError::at(lineno, m))?,
                );
            }
            Some("warp") => {
                if header.is_none() {
                    return Err(TraceIoError::at(
                        lineno,
                        "warp section before the kernel header",
                    ));
                }
                let id: usize = t
                    .split_whitespace()
                    .nth(1)
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| {
                        TraceIoError::at(lineno, format!("bad warp marker {t:?}"))
                    })?;
                if id != warps.len() {
                    return Err(TraceIoError::at(
                        lineno,
                        format!("warp sections must be sequential (got {id}, expected {})", warps.len()),
                    ));
                }
                warps.push(Vec::new());
            }
            _ => {
                let instr = format::parse_instruction(t)
                    .map_err(|m| TraceIoError::at(lineno, m))?;
                match warps.last_mut() {
                    Some(w) => w.push(instr),
                    None => {
                        return Err(TraceIoError::at(
                            lineno,
                            "instruction outside a warp section",
                        ))
                    }
                }
            }
        }
    }
    if !magic_seen {
        return Err(TraceIoError::at(0, "empty trace (missing mtrace magic line)"));
    }
    let header = header
        .ok_or_else(|| TraceIoError::at(0, "trace has no kernel header"))?;
    if warps.len() != header.nwarps {
        return Err(TraceIoError::at(
            0,
            format!(
                "header declares {} warps but {} sections follow",
                header.nwarps,
                warps.len()
            ),
        ));
    }
    for (w, stream) in warps.iter().enumerate() {
        let exits = stream.iter().filter(|i| i.op == OpClass::Exit).count();
        let ends_with_exit = stream.last().map(|i| i.op) == Some(OpClass::Exit);
        if exits != 1 || !ends_with_exit {
            return Err(TraceIoError::at(
                0,
                format!("warp {w} must end with exactly one EXIT marker"),
            ));
        }
    }
    Ok(KernelTrace { name: header.name, kernel_id: header.kernel_id, warps })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_a_minimal_trace() {
        let text = "\
mtrace v1
# a comment
kernel tiny id=2 warps=2
warp 0
LDG d2 @0x100

ALU d3 s2 n1/0
EXIT
warp 1
EXIT
";
        let t = read_str(text).unwrap();
        assert_eq!(t.name, "tiny");
        assert_eq!(t.kernel_id, 2);
        assert_eq!(t.warps.len(), 2);
        assert_eq!(t.warps[0].len(), 3);
        assert_eq!(t.warps[0][0].line_addr, 0x100);
        assert!(t.warps[0][1].src_is_near(0));
        assert_eq!(t.warps[1].len(), 1);
    }

    #[test]
    fn rejects_structural_errors() {
        let cases: [(&str, &str); 7] = [
            ("", "empty input"),
            ("mtrace v1\n", "no header"),
            ("mtrace v1\nkernel k id=0 warps=1\n", "missing warp section"),
            (
                "mtrace v1\nkernel k id=0 warps=1\nwarp 0\nALU d1\n",
                "warp without EXIT",
            ),
            (
                "mtrace v1\nkernel k id=0 warps=1\nwarp 1\nEXIT\n",
                "non-sequential warp id",
            ),
            (
                "mtrace v1\nkernel k id=0 warps=2\nwarp 0\nEXIT\n",
                "warp count mismatch",
            ),
            (
                "mtrace v1\nkernel k id=0 warps=1\nALU d1\nwarp 0\nEXIT\n",
                "instruction outside warp",
            ),
        ];
        for (text, why) in cases {
            assert!(read_str(text).is_err(), "{why}");
        }
    }

    #[test]
    fn interior_exit_rejected() {
        let text = "\
mtrace v1
kernel k id=0 warps=1
warp 0
EXIT
ALU d1
EXIT
";
        assert!(read_str(text).is_err());
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let text = "mtrace v1\nkernel k id=0 warps=1\nwarp 0\nBOGUS d1\nEXIT\n";
        let e = read_str(text).unwrap_err();
        assert_eq!(e.line, 4);
        assert!(e.to_string().contains("BOGUS"));
    }
}
