//! Trace I/O subsystem: record, ingest, and replay external kernel traces.
//!
//! The paper evaluates Malekeh by replaying real Rodinia/Deepbench SASS
//! traces through Accel-sim; this module is the equivalent ingestion path
//! for this reproduction. It defines a textual, Accel-sim-inspired
//! `.mtrace` format (see `docs/TRACES.md` for the grammar) that carries
//! everything the simulator consumes — opclass, source/destination
//! registers, the compiler's near/far annotation bits, and line-granular
//! memory addresses — so a written trace replays **bit-identically** to
//! the in-memory [`KernelTrace`](crate::trace::KernelTrace) it came from
//! (enforced by `rust/tests/trace_roundtrip.rs`).
//!
//! Since PR 8 the subsystem speaks **two containers** for the same IR:
//! the textual v1 grammar above, and a binary, chunked, varint-packed
//! **v2** ([`format2`]) with a streaming reader ([`stream::TraceStream`])
//! whose memory use is bounded by one chunk rather than the whole file.
//! [`read_path`] auto-detects the container by magic, so every consumer
//! (`simulate --trace`, `trace record|info|convert`, transforms, harness
//! trace points, store fingerprinting) accepts either version.
//!
//! Layout:
//! - [`format`] — v1 line grammar: magic/header/instruction serialisation;
//! - [`format2`] — v2 binary grammar: chunked varint records, delta/RLE
//!   payload compression, content digest;
//! - [`reader`] — parser front door; auto-detects v1 vs v2 by magic;
//! - [`stream`] — bounded-memory windowed ingestion over either version;
//! - [`writer`] — v1 serialiser for any generated (or transformed) trace;
//! - [`transform`] — composable scenario-scaling transforms (warp
//!   subsample, instruction window, register remap).

pub mod format;
pub mod format2;
pub mod reader;
pub mod stream;
pub mod transform;
pub mod writer;

pub use format::{TraceHeader, MAGIC, VERSION};
pub use format2::{
    read_v2, read_v2_slice, sniff_path_version, write_v2, write_v2_bytes, write_v2_path, MAGIC2,
    VERSION2,
};
pub use reader::{read, read_path, read_str};
pub use stream::{content_fingerprint_path, read_limited, LimitedLoad, TraceStream, TraceWindow};
pub use transform::{apply_all, Transform};
pub use writer::{write, write_path, write_string};

/// Error from reading or writing `.mtrace` data: an I/O failure, or a
/// parse/validation error anchored to a 1-based input line (`line == 0`
/// when the error is not line-specific, e.g. file-open failures or
/// whole-trace validation).
#[derive(Debug)]
pub struct TraceIoError {
    /// 1-based line number of the offending input (0 = not line-specific).
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl TraceIoError {
    /// Error anchored to input line `line`.
    pub(crate) fn at(line: usize, msg: impl Into<String>) -> Self {
        TraceIoError { line, msg: msg.into() }
    }

    /// Error carrying an underlying I/O failure.
    pub(crate) fn from_io(e: std::io::Error) -> Self {
        TraceIoError { line: 0, msg: e.to_string() }
    }
}

impl std::fmt::Display for TraceIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.line == 0 {
            write!(f, "{}", self.msg)
        } else {
            write!(f, "line {}: {}", self.line, self.msg)
        }
    }
}

impl std::error::Error for TraceIoError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_line_when_present() {
        let e = TraceIoError::at(7, "bad tag");
        assert_eq!(e.to_string(), "line 7: bad tag");
        let e = TraceIoError::at(0, "open failed");
        assert_eq!(e.to_string(), "open failed");
    }
}
