//! `.mtrace` line grammar: magic line, kernel header, and one-instruction-
//! per-line serialisation.
//!
//! The format is textual and line-oriented (like Accel-sim's SASS traces):
//!
//! ```text
//! mtrace v1
//! # comments and blank lines are ignored
//! kernel <name> id=<kernel_id> warps=<nwarps>
//! warp 0
//! <TAG> [d<r>,<r>] [s<r>,...] [n<srcmask>/<dstmask>] [@0x<line_addr>]
//! ...
//! EXIT
//! warp 1
//! ...
//! ```
//!
//! Instruction fields after the opclass tag may appear in any order; the
//! writer always emits `d`, `s`, `n`, `@`. `d`/`s` carry comma-separated
//! decimal register ids, `n` carries the compiler's near/far bitmasks
//! (decimal, bit *i* = operand *i* is near-reuse), `@` the 128B-line
//! memory address in hex. Fields whose value is empty/zero are omitted,
//! so `EXIT` and `CTRL` lines are just the tag. The full grammar with a
//! worked example lives in `docs/TRACES.md`.

use crate::isa::{Instruction, OpClass, MAX_DST, MAX_SRC};

/// First token of the first non-comment line of every `.mtrace` file.
pub const MAGIC: &str = "mtrace";
/// Format version this build writes and accepts.
pub const VERSION: u32 = 1;

/// Kernel metadata carried by the `kernel` header line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceHeader {
    /// Kernel / benchmark chart name (non-empty, no whitespace).
    pub name: String,
    /// Kernel id (multi-kernel files keep separate address spaces).
    pub kernel_id: u32,
    /// Number of `warp` sections that follow.
    pub nwarps: usize,
}

/// Render the magic line (`mtrace v1`).
pub fn format_magic() -> String {
    format!("{MAGIC} v{VERSION}")
}

/// Parse and version-check the magic line.
pub fn parse_magic(line: &str) -> Result<u32, String> {
    let mut toks = line.split_whitespace();
    if toks.next() != Some(MAGIC) {
        return Err(format!("not an mtrace file (first line {line:?})"));
    }
    let v: u32 = toks
        .next()
        .and_then(|t| t.strip_prefix('v'))
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| format!("bad version token in {line:?} (want v{VERSION})"))?;
    if v != VERSION {
        return Err(format!("unsupported mtrace version v{v} (this build reads v{VERSION})"));
    }
    Ok(v)
}

/// Kernel names must survive whitespace-tokenised parsing.
pub fn validate_name(name: &str) -> Result<(), String> {
    if name.is_empty() || name.chars().any(|c| c.is_whitespace()) {
        return Err(format!(
            "kernel name {name:?} must be non-empty and contain no whitespace"
        ));
    }
    Ok(())
}

/// Render the kernel header line.
pub fn format_header(h: &TraceHeader) -> String {
    format!("kernel {} id={} warps={}", h.name, h.kernel_id, h.nwarps)
}

/// Parse a `kernel <name> key=value...` header line.
pub fn parse_header(line: &str) -> Result<TraceHeader, String> {
    let mut toks = line.split_whitespace();
    if toks.next() != Some("kernel") {
        return Err(format!("expected kernel header, got {line:?}"));
    }
    let name = toks
        .next()
        .ok_or_else(|| "kernel header missing a name".to_string())?
        .to_string();
    validate_name(&name)?;
    let mut kernel_id: Option<u32> = None;
    let mut nwarps: Option<usize> = None;
    for t in toks {
        let (k, v) = t
            .split_once('=')
            .ok_or_else(|| format!("bad header field {t:?} (want key=value)"))?;
        match k {
            "id" => {
                kernel_id =
                    Some(v.parse().map_err(|_| format!("bad kernel id {v:?}"))?)
            }
            "warps" => {
                nwarps =
                    Some(v.parse().map_err(|_| format!("bad warp count {v:?}"))?)
            }
            other => return Err(format!("unknown header field {other:?}")),
        }
    }
    Ok(TraceHeader {
        name,
        kernel_id: kernel_id.ok_or("kernel header missing id=")?,
        nwarps: nwarps.ok_or("kernel header missing warps=")?,
    })
}

/// Serialise one instruction to its `.mtrace` line.
pub fn format_instruction(i: &Instruction) -> String {
    let mut s = String::from(i.op.tag());
    if i.ndst > 0 {
        s.push_str(" d");
        push_reg_list(&mut s, i.dests());
    }
    if i.nsrc > 0 {
        s.push_str(" s");
        push_reg_list(&mut s, i.sources());
    }
    if i.src_near != 0 || i.dst_near != 0 {
        s.push_str(&format!(" n{}/{}", i.src_near, i.dst_near));
    }
    if i.line_addr != 0 {
        s.push_str(&format!(" @0x{:x}", i.line_addr));
    }
    s
}

fn push_reg_list(s: &mut String, regs: &[u8]) {
    for (k, r) in regs.iter().enumerate() {
        if k > 0 {
            s.push(',');
        }
        s.push_str(&r.to_string());
    }
}

fn parse_reg_list(s: &str, what: &str) -> Result<Vec<u8>, String> {
    if s.is_empty() {
        return Err(format!("empty {what} register list"));
    }
    s.split(',')
        .map(|r| {
            r.parse::<u8>()
                .map_err(|_| format!("bad {what} register id {r:?} (want 0..=255)"))
        })
        .collect()
}

/// Parse one instruction line (already stripped of comments/whitespace).
pub fn parse_instruction(line: &str) -> Result<Instruction, String> {
    let mut toks = line.split_whitespace();
    let tag = toks.next().ok_or("empty instruction line")?;
    let op = OpClass::from_tag(tag)
        .ok_or_else(|| format!("unknown opclass tag {tag:?}"))?;
    let mut srcs: Option<Vec<u8>> = None;
    let mut dsts: Option<Vec<u8>> = None;
    let mut near: Option<(u8, u8)> = None;
    let mut addr: Option<u32> = None;
    for t in toks {
        if let Some(rest) = t.strip_prefix('d') {
            if dsts.replace(parse_reg_list(rest, "destination")?).is_some() {
                return Err("duplicate destination field".into());
            }
        } else if let Some(rest) = t.strip_prefix('s') {
            if srcs.replace(parse_reg_list(rest, "source")?).is_some() {
                return Err("duplicate source field".into());
            }
        } else if let Some(rest) = t.strip_prefix('n') {
            let (a, b) = rest
                .split_once('/')
                .ok_or_else(|| format!("bad near field {t:?} (want n<src>/<dst>)"))?;
            let sn = a
                .parse()
                .map_err(|_| format!("bad source near mask {a:?}"))?;
            let dn = b
                .parse()
                .map_err(|_| format!("bad destination near mask {b:?}"))?;
            if near.replace((sn, dn)).is_some() {
                return Err("duplicate near field".into());
            }
        } else if let Some(rest) = t.strip_prefix('@') {
            let hex = rest.strip_prefix("0x").or_else(|| rest.strip_prefix("0X"));
            let a = u32::from_str_radix(hex.unwrap_or(rest), 16)
                .map_err(|_| format!("bad line address {rest:?}"))?;
            if addr.replace(a).is_some() {
                return Err("duplicate address field".into());
            }
        } else {
            return Err(format!("unknown instruction field {t:?}"));
        }
    }
    let srcs = srcs.unwrap_or_default();
    let dsts = dsts.unwrap_or_default();
    let (src_near, dst_near) = near.unwrap_or((0, 0));
    let line_addr = addr.unwrap_or(0);
    if srcs.len() > MAX_SRC {
        return Err(format!("{} sources exceed the ISA bound {MAX_SRC}", srcs.len()));
    }
    if dsts.len() > MAX_DST {
        return Err(format!(
            "{} destinations exceed the ISA bound {MAX_DST}",
            dsts.len()
        ));
    }
    if u32::from(src_near) >= (1u32 << srcs.len()) {
        return Err(format!(
            "near mask {src_near} names sources beyond the {} declared",
            srcs.len()
        ));
    }
    if u32::from(dst_near) >= (1u32 << dsts.len()) {
        return Err(format!(
            "near mask {dst_near} names destinations beyond the {} declared",
            dsts.len()
        ));
    }
    if line_addr != 0 && !op.is_mem() {
        return Err(format!("{tag} cannot carry a memory address"));
    }
    let mut i = Instruction::new(op, &srcs, &dsts);
    i.src_near = src_near;
    i.dst_near = dst_near;
    i.line_addr = line_addr;
    Ok(i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magic_roundtrip() {
        assert_eq!(parse_magic(&format_magic()).unwrap(), VERSION);
        assert!(parse_magic("mtrace v999").is_err());
        assert!(parse_magic("nottrace v1").is_err());
        assert!(parse_magic("mtrace").is_err());
    }

    #[test]
    fn header_roundtrip() {
        let h = TraceHeader { name: "b+tree".into(), kernel_id: 3, nwarps: 64 };
        assert_eq!(parse_header(&format_header(&h)).unwrap(), h);
        assert!(parse_header("kernel").is_err());
        assert!(parse_header("kernel x id=1").is_err(), "missing warps=");
        assert!(parse_header("kernel x warps=4").is_err(), "missing id=");
        assert!(parse_header("kernel x id=1 warps=4 bogus=2").is_err());
    }

    #[test]
    fn instruction_roundtrip_all_fields() {
        let mut i = Instruction::mem(OpClass::LdGlobal, &[7], &[9], 0xBEEF);
        i.set_src_near(0, true);
        i.set_dst_near(0, true);
        let line = format_instruction(&i);
        assert_eq!(line, "LDG d9 s7 n1/1 @0xbeef");
        assert_eq!(parse_instruction(&line).unwrap(), i);
    }

    #[test]
    fn instruction_roundtrip_minimal() {
        let exit = Instruction::new(OpClass::Exit, &[], &[]);
        assert_eq!(format_instruction(&exit), "EXIT");
        assert_eq!(parse_instruction("EXIT").unwrap(), exit);
        let mma = Instruction::new(OpClass::Mma, &[2, 3, 4, 5, 10, 11], &[10, 11]);
        assert_eq!(
            parse_instruction(&format_instruction(&mma)).unwrap(),
            mma
        );
    }

    #[test]
    fn instruction_fields_any_order() {
        let a = parse_instruction("LDG d9 s7 @0x10").unwrap();
        let b = parse_instruction("LDG @0x10 s7 d9").unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn instruction_rejects_malformed() {
        assert!(parse_instruction("BOGUS d1").is_err(), "unknown tag");
        assert!(parse_instruction("ALU d1,2,3").is_err(), "too many dsts");
        assert!(
            parse_instruction("ALU s1,2,3,4,5,6,7").is_err(),
            "too many srcs"
        );
        assert!(parse_instruction("ALU d1 s2 n4/0").is_err(), "near mask oob");
        assert!(parse_instruction("ALU d1 @0x4").is_err(), "addr on non-mem");
        assert!(parse_instruction("ALU d999").is_err(), "register oob");
        assert!(parse_instruction("ALU x7").is_err(), "unknown field");
        assert!(parse_instruction("LDG d1 @zz").is_err(), "bad hex");
        assert!(
            parse_instruction("LDG d1 d2 @0x4").is_err(),
            "duplicate field must not silently last-win"
        );
        assert!(parse_instruction("LDG d1 @0x4 @0x8").is_err(), "dup addr");
    }
}
