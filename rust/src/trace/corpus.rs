//! Generated-kernel corpus: six structurally diverse kernels beyond the
//! GEMM-shaped Table II set, modeled on the small hand-written assembly
//! suites RISC-V simulators ship for differential testing.
//!
//! The paper's evaluation (and Table II) leans heavily on regular,
//! tile-structured kernels — exactly where compiler-approximated reuse
//! distances are most accurate. This corpus deliberately stresses the
//! other end: data-dependent control flow, pointer chasing, write-after-
//! write churn and store-dominated streams, where LTRF-style interval
//! prefetch and Malekeh's sliding window can mispredict. The six kernels
//! register as [`Suite::Corpus`][super::Suite::Corpus] in
//! [`BENCHMARKS`][super::BENCHMARKS] and sweep against all registered
//! policies via `malekeh fig corpus` (docs/EXPERIMENTS.md §Corpus sweep);
//! `rust/tests/policy_parity.rs` pins their fingerprints into the golden
//! grid and asserts the generators stay mutually distinct.
//!
//! Same generation contract as `workloads.rs`: every warp's program is a
//! pure function of `(WarpCtx, seed)`, 400..20 000 instructions, one
//! trailing `EXIT`.

use super::program::{AddrGen, ProgramBuilder};
use super::workloads::{seed_for, WarpCtx};
use crate::isa::Instruction;

/// FMA-based register-tiled matrix multiply (no tensor cores — contrast
/// with `gemm_t1`'s MMA tiles): a 4x4 accumulator grid where every ALU op
/// reads two freshly loaded fragments plus its accumulator, so accumulator
/// reuse is near while fragment reuse dies each iteration.
pub fn gen_matmul_tiled(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    const TM: usize = 4;
    const TN: usize = 4;
    let mut b = ProgramBuilder::new(28, 32, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    let a0 = 2u8;
    let b0 = a0 + TM as u8;
    let acc0 = b0 + TN as u8;
    for it in 0..60usize {
        for i in 0..TM {
            b.ldg_u(a0 + i as u8, ag.stream(1));
        }
        // B tile comes from the kernel-shared weight region
        for j in 0..TN {
            b.ldg_u(b0 + j as u8, ag.shared((it * TN + j) as u32, 1024));
        }
        for i in 0..TM {
            for j in 0..TN {
                let acc = acc0 + (i * TN + j) as u8;
                b.alu(&[a0 + i as u8, b0 + j as u8, acc], acc);
            }
        }
    }
    for k in 0..(TM * TN) {
        b.stg_u(acc0 + k as u8, ag.stream(1));
    }
    b.finish()
}

/// Quicksort partition passes: a hot pivot register compared against a
/// streamed run, with a data-dependent (≈50/50) divergent branch per
/// element deciding between a swap-store and a bound update.
pub fn gen_quicksort(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    let pivot = 2u8;
    let lo = 3u8;
    let hi = 4u8;
    for _ in 0..12usize {
        b.ldg_u(pivot, ag.indirect(&mut b.rng, 1 << 14));
        b.ldg_u(lo, ag.stream(1));
        b.ldg_u(hi, ag.stream(1));
        for _ in 0..24usize {
            let x = b.tmp();
            b.ldg_u(x, ag.stream(1));
            let c = b.tmp();
            b.alu(&[x, pivot], c);
            if b.rng.below(100) < 50 {
                // taken arm: swap the element into place
                b.ctrl();
                let d = b.tmp();
                b.alu(&[c, lo], d);
                b.stg_u(x, ag.indirect(&mut b.rng, 1 << 14));
                b.alu(&[lo, d], lo);
            } else {
                b.alu(&[c, hi], hi);
            }
        }
        let t = b.tmp();
        b.alu(&[lo, hi], t);
    }
    b.finish()
}

/// Single-strand pointer chase: every load's address register is the
/// previous load's destination, so there is no instruction-level overlap
/// and near-zero register reuse — the worst case for any RF cache.
pub fn gen_pointer_chase(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 32, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    for _ in 0..110usize {
        let mut p = b.tmp();
        b.ldg_u(p, ag.indirect(&mut b.rng, 1 << 16));
        for _ in 0..10usize {
            let n = b.tmp();
            b.ldg(p, n, ag.indirect(&mut b.rng, 1 << 16));
            p = n;
        }
        let t = b.tmp();
        b.alu(&[p], t);
    }
    b.finish()
}

/// 3x3 box filter: nine taps per pixel (one column re-read from a shared
/// halo region), pairwise reduction tree, normalise, store — a wide
/// fan-in of short-lived values with overlap between adjacent pixels.
pub fn gen_box_blur(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 40, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    for px in 0..88usize {
        let mut taps = Vec::with_capacity(9);
        for k in 0..9usize {
            let d = b.tmp();
            if k % 3 == 0 {
                b.ldg_u(d, ag.shared((px * 9 + k) as u32, 2048));
            } else {
                b.ldg_u(d, ag.stream(1));
            }
            taps.push(d);
        }
        let mut acc = taps[0];
        for &v in &taps[1..] {
            let d = b.tmp();
            b.alu(&[acc, v], d);
            acc = d;
        }
        let out = b.tmp();
        b.alu(&[acc], out);
        b.stg_u(out, ag.stream(1));
    }
    b.finish()
}

/// Sieve of Eratosthenes marking passes: a hot prime register drives a
/// long run of next-multiple/store pairs at a per-prime stride — the
/// store-dominated end of the spectrum (~45% stores), where the CCU's
/// write traffic, not read reuse, is what a policy pays for.
pub fn gen_prime_sieve(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 32, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    let prime = 2u8;
    for pi in 0..16u32 {
        b.ldg_u(prime, ag.shared(pi, 64));
        let sq = b.tmp();
        b.alu(&[prime, prime], sq);
        let mut cur = sq;
        for m in 0..38usize {
            let nxt = b.tmp();
            b.alu(&[cur, prime], nxt);
            b.stg_u(nxt, ag.stream(3 + 2 * (pi % 7)));
            cur = nxt;
            if m % 13 == 12 {
                b.ctrl();
            }
        }
    }
    b.finish()
}

/// Hazard stress: bursts of back-to-back writes to a rotating set of hot
/// registers with no intervening reads (WAW churn the allocator must
/// coalesce), interleaved with ≈40% divergent branches and a trailing
/// dependent chain that finally consumes the last write.
pub fn gen_hazard_stress(ctx: &WarpCtx, seed: u64) -> Vec<Instruction> {
    let mut b = ProgramBuilder::new(8, 24, seed_for(ctx, seed));
    let mut ag = AddrGen::new(ctx.warp_id, ctx.kernel_id);
    let hot = [2u8, 3, 4, 5];
    let x = 6u8;
    b.ldg_u(x, ag.stream(1));
    for it in 0..150usize {
        let d = hot[it % hot.len()];
        // WAW burst: only the last of these four writes is ever read
        for _ in 0..4usize {
            b.alu(&[x], d);
        }
        if b.rng.below(100) < 40 {
            b.ctrl();
            let t = b.tmp();
            b.alu(&[d], t);
        }
        let end = b.chain(d, 3);
        // the load then overwrites the hot register again (load/ALU WAW)
        b.ldg_u(d, ag.indirect(&mut b.rng, 1 << 12));
        b.stg_u(end, ag.stream(1));
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;
    use crate::trace::{corpus, find, Suite};

    fn ctx(warp: u32) -> WarpCtx {
        WarpCtx { warp_id: warp, nwarps: 32, kernel_id: 0 }
    }

    #[test]
    fn corpus_is_registered_and_findable() {
        let names: Vec<&str> = corpus().map(|b| b.name).collect();
        assert_eq!(
            names,
            ["matmul_tiled", "quicksort", "pointer_chase", "box_blur", "prime_sieve",
             "hazard_stress"],
        );
        for n in names {
            assert_eq!(find(n).unwrap().suite, Suite::Corpus);
        }
    }

    #[test]
    fn corpus_kernels_avoid_tensor_cores() {
        // the corpus contrasts with Deepbench: scalar FMA tiles, no MMA
        for b in corpus() {
            let p = (b.gen)(&ctx(0), 1);
            assert!(p.iter().all(|i| i.op != OpClass::Mma), "{}", b.name);
        }
    }

    #[test]
    fn pointer_chase_is_dependent_loads() {
        let p = gen_pointer_chase(&ctx(1), 7);
        let loads = p.iter().filter(|i| i.op == OpClass::LdGlobal);
        let (dep, total) = loads.fold((0usize, 0usize), |(d, t), i| {
            (d + usize::from(i.nsrc > 0), t + 1)
        });
        assert!(
            dep * 10 >= total * 8,
            "chase must be address-dependent: {dep}/{total}"
        );
    }

    #[test]
    fn prime_sieve_is_store_heavy() {
        let p = gen_prime_sieve(&ctx(0), 3);
        let stores = p.iter().filter(|i| i.op == OpClass::StGlobal).count();
        assert!(
            stores * 10 >= p.len() * 3,
            "sieve must be store-dominated: {stores}/{}",
            p.len()
        );
    }

    #[test]
    fn hazard_stress_has_waw_bursts_and_divergence() {
        let p = gen_hazard_stress(&ctx(2), 9);
        let waw = p
            .windows(2)
            .filter(|w| {
                w[0].op == OpClass::Alu
                    && w[1].op == OpClass::Alu
                    && w[0].dests() == w[1].dests()
                    && !w[1].sources().contains(&w[0].dests()[0])
            })
            .count();
        assert!(waw > 100, "expected WAW bursts, saw {waw}");
        assert!(p.iter().any(|i| i.op == OpClass::Ctrl), "no divergence");
    }

    #[test]
    fn quicksort_diverges_per_element() {
        let p = gen_quicksort(&ctx(4), 11);
        let ctrls = p.iter().filter(|i| i.op == OpClass::Ctrl).count();
        assert!(ctrls > 80, "expected heavy divergence, saw {ctrls} branches");
    }
}
