//! Kernel traces: per-warp instruction streams, generation from the
//! Table II workload registry, and the [`Workload`] source abstraction
//! (builtin generator vs. `.mtrace` file — see [`io`]).

pub mod corpus;
pub mod io;
pub mod program;
pub mod workloads;

pub use io::{Transform, TraceIoError};
pub use program::{AddrGen, ProgramBuilder, MAX_KERNEL_ID};
pub use workloads::{corpus, find, table2, Benchmark, Suite, WarpCtx, BENCHMARKS};

use std::path::PathBuf;

use crate::isa::Instruction;

/// A kernel launch: one instruction stream per warp.
#[derive(Debug, Clone)]
pub struct KernelTrace {
    /// Benchmark chart name.
    pub name: String,
    /// Kernel id (multi-kernel traces keep separate address spaces).
    pub kernel_id: u32,
    /// Per-warp streams (each ends with an `Exit` marker).
    pub warps: Vec<Vec<Instruction>>,
}

impl KernelTrace {
    /// Generate `nwarps` warp streams for `bench` with a launch `seed`
    /// (kernel id 0).
    pub fn generate(bench: &Benchmark, nwarps: usize, seed: u64) -> Self {
        Self::generate_kernel(bench, nwarps, seed, 0)
    }

    /// Generate with an explicit `kernel_id`, so the kernels of a
    /// multi-kernel trace file keep separate, non-aliasing address spaces
    /// ([`AddrGen`] bases its shared/indirect regions on it) and distinct
    /// per-warp RNG streams.
    pub fn generate_kernel(
        bench: &Benchmark,
        nwarps: usize,
        seed: u64,
        kernel_id: u32,
    ) -> Self {
        let warps = (0..nwarps)
            .map(|w| {
                let ctx = WarpCtx {
                    warp_id: w as u32,
                    nwarps: nwarps as u32,
                    kernel_id,
                };
                (bench.gen)(&ctx, seed)
            })
            .collect();
        KernelTrace { name: bench.name.to_string(), kernel_id, warps }
    }

    /// Does any instruction carry a compiler near/far annotation bit?
    /// Replay uses this to decide whether a loaded trace was recorded
    /// post-annotation (keep its bits) or raw (run the compiler pass).
    pub fn has_annotations(&self) -> bool {
        self.warps
            .iter()
            .flatten()
            .any(|i| i.src_near != 0 || i.dst_near != 0)
    }

    /// Total dynamic instructions across all warps (including Exit markers).
    pub fn total_instructions(&self) -> usize {
        self.warps.iter().map(|w| w.len()).sum()
    }

    /// Order-stable FNV-1a digest over the trace **content**: kernel name,
    /// kernel id, warp structure, and every field of every instruction
    /// (including the compiler near/far bits and memory addresses). Two
    /// traces fingerprint equal iff the simulator would consume identical
    /// streams — this is the workload half of the persistent store's
    /// content address ([`crate::serve::store`]), deliberately independent
    /// of where (or whether) the trace lives on disk.
    pub fn content_fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv1a::new();
        h.bytes(self.name.as_bytes());
        h.word(u64::from(self.kernel_id));
        h.word(self.warps.len() as u64);
        for w in &self.warps {
            h.word(w.len() as u64);
            for i in w {
                fold_instruction(&mut h, i);
            }
        }
        h.finish()
    }

    /// Flatten the first `nwarps` warps into padded `(ids, pos, rw)` access
    /// streams for the reuse-annotation path (rust `compiler::` or the AOT
    /// artifact). Each register operand of each instruction becomes one
    /// access (sources first, as reads `rw=1`; then destinations, `rw=0`);
    /// `pos` is the dynamic instruction index. Rows are truncated / padded
    /// with `-1` to `len`.
    pub fn access_streams(
        &self,
        nwarps: usize,
        len: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let n = nwarps.min(self.warps.len());
        let mut ids = vec![-1i32; nwarps * len];
        let mut pos = vec![0i32; nwarps * len];
        let mut rw = vec![0i32; nwarps * len];
        for w in 0..n {
            let mut k = 0usize;
            'outer: for (ii, instr) in self.warps[w].iter().enumerate() {
                for (is_read, &r) in instr
                    .sources()
                    .iter()
                    .map(|r| (1, r))
                    .chain(instr.dests().iter().map(|r| (0, r)))
                {
                    if k >= len {
                        break 'outer;
                    }
                    ids[w * len + k] = r as i32;
                    pos[w * len + k] = ii as i32;
                    rw[w * len + k] = is_read;
                    k += 1;
                }
            }
        }
        (ids, pos, rw)
    }
}

/// Fold every field of one instruction into an FNV-1a accumulator — the
/// shared per-instruction step behind [`KernelTrace::content_fingerprint`],
/// the v2 container's content digest ([`io::format2`]) and the streamed
/// file fingerprint ([`io::stream::content_fingerprint_path`]). Keeping
/// one definition is what guarantees those three agree bit for bit.
pub(crate) fn fold_instruction(h: &mut crate::util::Fnv1a, i: &Instruction) {
    h.word(i.op as u64);
    h.word(u64::from(i.nsrc));
    h.word(u64::from(i.ndst));
    for &r in &i.srcs[..i.nsrc as usize] {
        h.word(u64::from(r));
    }
    for &r in &i.dsts[..i.ndst as usize] {
        h.word(u64::from(r));
    }
    h.word(u64::from(i.src_near));
    h.word(u64::from(i.dst_near));
    h.word(u64::from(i.line_addr));
}

/// Where a simulation's instruction streams come from: a built-in Table II
/// generator, or an external `.mtrace` file ingested through [`io`].
///
/// This is the unit the harness plans, caches, and shards over — a
/// trace-file point behaves exactly like a builtin point (deterministic,
/// memoised, `--jobs`-independent), it just skips generation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Workload {
    /// Registry benchmark generated on demand ([`find`]).
    Builtin(String),
    /// File-backed trace recorded earlier (or captured externally).
    TraceFile(PathBuf),
}

impl Workload {
    /// Builtin workload by registry name.
    pub fn builtin(name: &str) -> Self {
        Workload::Builtin(name.to_string())
    }

    /// File-backed workload.
    pub fn trace_file(path: impl Into<PathBuf>) -> Self {
        Workload::TraceFile(path.into())
    }

    /// Display identity used in logs and error messages: the registry
    /// name, or `trace:<path>` for file-backed workloads (the prefix keeps
    /// the two namespaces from colliding).
    pub fn cache_name(&self) -> String {
        match self {
            Workload::Builtin(name) => name.clone(),
            Workload::TraceFile(path) => format!("trace:{}", path.display()),
        }
    }

    /// Memo-cache identity. Builtin workloads key by registry name (the
    /// generator is pure), but trace files key by **byte digest**, not
    /// path: keying by path silently served stale stats after a `.mtrace`
    /// file was edited in place between two runs of one process. The
    /// digest is streamed in fixed-size chunks (never `fs::read`), so
    /// keying a multi-GB v2 trace costs no memory. An unreadable file
    /// falls back to the path form — the subsequent [`Workload::load`]
    /// surfaces the real I/O error. (Byte digest, unlike the decoded
    /// [`Workload::content_fingerprint`], is deliberate here: the memo
    /// cache is per-process and cheap to miss, so distinct encodings of
    /// one trace may occupy two slots; the persistent store unifies them.)
    pub fn cache_key(&self) -> String {
        match self {
            Workload::Builtin(name) => name.clone(),
            Workload::TraceFile(path) => match hash_file_bytes(path) {
                Ok(digest) => format!("trace:{digest:016x}"),
                Err(_) => format!("trace:{}", path.display()),
            },
        }
    }

    /// Content fingerprint of the instruction streams this workload
    /// resolves to — the workload half of the persistent store's address
    /// ([`crate::serve::store::StoreKey`]). Builtin generators digest
    /// their generated content (a pure function of name x `nwarps` x
    /// `seed`, both of which the config fingerprint also pins); trace
    /// files digest their **decoded** content via
    /// [`io::content_fingerprint_path`], so renaming or moving a file
    /// never changes its identity, editing it always does, and — since
    /// the digest is over the IR rather than the container bytes — a
    /// `trace convert`ed v2 copy of a v1 recording addresses the **same**
    /// store record as its source (v2 files are hashed streaming, one
    /// warp resident at a time).
    pub fn content_fingerprint(&self, nwarps: usize, seed: u64) -> Result<u64, String> {
        match self {
            Workload::Builtin(_) => Ok(self.load(nwarps, seed)?.content_fingerprint()),
            Workload::TraceFile(path) => io::content_fingerprint_path(path)
                .map_err(|e| format!("{}: {e}", path.display())),
        }
    }

    /// Materialise the instruction streams. Builtin generators honour
    /// `nwarps` and `seed`; trace files carry their own streams and
    /// ignore both.
    pub fn load(&self, nwarps: usize, seed: u64) -> Result<KernelTrace, String> {
        match self {
            Workload::Builtin(name) => {
                let bench = find(name)
                    .ok_or_else(|| format!("unknown benchmark {name}"))?;
                Ok(KernelTrace::generate(bench, nwarps, seed))
            }
            Workload::TraceFile(path) => io::read_path(path)
                .map_err(|e| format!("{}: {e}", path.display())),
        }
    }

    /// Materialise at most `max_warps` warps, plus the whole-source facts
    /// the replay entry point needs ([`io::LimitedLoad`]). Builtin
    /// generators simply generate `max_warps` warps (raw, so `annotated`
    /// is false); v2 trace files stream-decode and never hold more than
    /// the retained warps plus one chunk in memory; v1 trace files parse
    /// fully (textual format) and are then truncated.
    pub fn load_limited(
        &self,
        max_warps: usize,
        seed: u64,
    ) -> Result<io::LimitedLoad, String> {
        match self {
            Workload::Builtin(_) => {
                let trace = self.load(max_warps, seed)?;
                Ok(io::LimitedLoad { total_warps: trace.warps.len(), annotated: false, trace })
            }
            Workload::TraceFile(path) => io::read_limited(path, max_warps)
                .map_err(|e| format!("{}: {e}", path.display())),
        }
    }
}

/// FNV-1a over a file's raw bytes, streamed in 64 KiB chunks so hashing
/// never materialises the file.
fn hash_file_bytes(path: &std::path::Path) -> std::io::Result<u64> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut h = crate::util::Fnv1a::new();
    let mut buf = [0u8; 64 * 1024];
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            return Ok(h.finish());
        }
        h.bytes(&buf[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_produces_per_warp_streams() {
        let b = find("hotspot").unwrap();
        let t = KernelTrace::generate(b, 8, 1);
        assert_eq!(t.warps.len(), 8);
        assert!(t.total_instructions() > 8 * 400);
    }

    #[test]
    fn access_streams_pad_and_position() {
        let b = find("kmeans").unwrap();
        let t = KernelTrace::generate(b, 4, 1);
        let (ids, pos, rw) = t.access_streams(2, 64);
        assert_eq!(ids.len(), 2 * 64);
        assert_eq!(rw.len(), 2 * 64);
        // row 0 first access: first instruction's first operand
        let first = &t.warps[0][0];
        let first_reg = first
            .sources()
            .first()
            .or_else(|| first.dests().first())
            .copied()
            .unwrap();
        assert_eq!(ids[0], first_reg as i32);
        assert_eq!(pos[0], 0);
        // sources flatten before destinations, so rw[0] is a read iff the
        // first instruction has any source
        assert_eq!(rw[0], if first.nsrc > 0 { 1 } else { 0 });
        // positions never decrease within a row
        for w in 0..2 {
            let row = &pos[w * 64..(w + 1) * 64];
            assert!(row.windows(2).all(|p| p[0] <= p[1]));
        }
    }

    #[test]
    fn access_streams_more_rows_than_warps() {
        let b = find("nn").unwrap();
        let t = KernelTrace::generate(b, 1, 1);
        let (ids, _, _) = t.access_streams(3, 32);
        // rows beyond available warps are fully padded
        assert!(ids[32..].iter().all(|&x| x == -1));
    }

    #[test]
    fn kernel_ids_separate_address_spaces() {
        use crate::isa::OpClass;
        let b = find("kmeans").unwrap();
        let k0 = KernelTrace::generate_kernel(b, 2, 1, 0);
        let k1 = KernelTrace::generate_kernel(b, 2, 1, 1);
        assert_eq!(k0.kernel_id, 0);
        assert_eq!(k1.kernel_id, 1);
        // kernel-shared regions (>= 0x8000_0000) must not alias between ids
        let shared = |t: &KernelTrace| -> Vec<u32> {
            t.warps[0]
                .iter()
                .filter(|i| i.op == OpClass::LdGlobal && i.line_addr >= 0x8000_0000)
                .map(|i| i.line_addr)
                .collect()
        };
        let s0 = shared(&k0);
        let s1 = shared(&k1);
        assert!(!s0.is_empty(), "kmeans must touch its shared region");
        assert!(
            s0.iter().all(|a| !s1.contains(a)),
            "kernel 0 and kernel 1 shared regions alias"
        );
    }

    #[test]
    fn has_annotations_detects_near_bits() {
        let b = find("kmeans").unwrap();
        let mut t = KernelTrace::generate(b, 2, 1);
        assert!(!t.has_annotations(), "generators emit raw traces");
        crate::compiler::annotate_precise(&mut t, 12);
        assert!(t.has_annotations());
    }

    #[test]
    fn workload_builtin_matches_generate() {
        let w = Workload::builtin("nn");
        assert_eq!(w.cache_name(), "nn");
        let t = w.load(4, 9).unwrap();
        let direct = KernelTrace::generate(find("nn").unwrap(), 4, 9);
        assert_eq!(t.warps, direct.warps);
        assert!(Workload::builtin("nope").load(1, 0).is_err());
    }

    #[test]
    fn content_fingerprint_tracks_every_instruction_field() {
        let b = find("kmeans").unwrap();
        let t = KernelTrace::generate(b, 2, 1);
        let base = t.content_fingerprint();
        assert_eq!(base, t.clone().content_fingerprint(), "pure function");

        let mut c = t.clone();
        c.kernel_id = 9;
        assert_ne!(base, c.content_fingerprint(), "kernel id must show");
        let mut c = t.clone();
        c.warps[0][0].line_addr ^= 1;
        assert_ne!(base, c.content_fingerprint(), "address must show");
        let mut c = t.clone();
        c.warps[0][0].src_near ^= 1;
        assert_ne!(base, c.content_fingerprint(), "annotation bits must show");
        let mut c = t.clone();
        c.warps[1].pop();
        assert_ne!(base, c.content_fingerprint(), "stream length must show");
        // different seed -> different generated content
        let other = KernelTrace::generate(b, 2, 2);
        assert_ne!(base, other.content_fingerprint());
    }

    #[test]
    fn workload_fingerprint_is_content_not_path_or_encoding() {
        use std::io::Write;
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("malekeh_wfp_a_{}.mtrace", std::process::id()));
        let p2 = dir.join(format!("malekeh_wfp_b_{}.mtrace", std::process::id()));
        let pv2 = dir.join(format!("malekeh_wfp_c_{}.mtrace", std::process::id()));
        let t = KernelTrace::generate(find("nn").unwrap(), 2, 3);
        io::write_path(&p1, &t).unwrap();
        std::fs::copy(&p1, &p2).unwrap();
        let f1 = Workload::trace_file(&p1).content_fingerprint(0, 0).unwrap();
        let f2 = Workload::trace_file(&p2).content_fingerprint(0, 0).unwrap();
        assert_eq!(f1, f2, "identical bytes under different paths must match");
        // the fingerprint is over the DECODED trace: a byte-level change
        // that decodes to the same instructions (a trailing comment) must
        // NOT change the identity...
        let mut f = std::fs::OpenOptions::new().append(true).open(&p2).unwrap();
        writeln!(f, "# trailing comment").unwrap();
        drop(f);
        let f2b = Workload::trace_file(&p2).content_fingerprint(0, 0).unwrap();
        assert_eq!(f1, f2b, "comment-only edits must not change the identity");
        // ...and neither must re-encoding to the v2 binary container — the
        // property the persistent store needs so `trace convert` output
        // addresses the same record
        io::write_v2_path(&pv2, &t).unwrap();
        let fv2 = Workload::trace_file(&pv2).content_fingerprint(0, 0).unwrap();
        assert_eq!(f1, fv2, "v1 and v2 encodings of one trace must match");
        // a genuine content mutation must change the identity
        let mut m = t.clone();
        m.warps[0][0].src_near ^= 1;
        io::write_path(&p2, &m).unwrap();
        let fm = Workload::trace_file(&p2).content_fingerprint(0, 0).unwrap();
        assert_ne!(f1, fm, "instruction edits must change the identity");
        // builtin fingerprints pin the generated content, and the file
        // fingerprints above equal the in-memory one
        assert_eq!(f1, t.content_fingerprint());
        let wa = Workload::builtin("nn").content_fingerprint(2, 3).unwrap();
        assert_eq!(wa, t.content_fingerprint());
        assert!(Workload::builtin("nope").content_fingerprint(1, 0).is_err());
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
        let _ = std::fs::remove_file(&pv2);
    }

    #[test]
    fn cache_key_is_per_encoding_but_load_limited_is_not() {
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("malekeh_ck_v1_{}.mtrace", std::process::id()));
        let p2 = dir.join(format!("malekeh_ck_v2_{}.mtrace", std::process::id()));
        let t = KernelTrace::generate(find("kmeans").unwrap(), 6, 5);
        io::write_path(&p1, &t).unwrap();
        io::write_v2_path(&p2, &t).unwrap();
        // memo-cache identity is the cheap byte digest: distinct per encoding
        let k1 = Workload::trace_file(&p1).cache_key();
        let k2 = Workload::trace_file(&p2).cache_key();
        assert!(k1.starts_with("trace:") && k2.starts_with("trace:"));
        assert_ne!(k1, k2, "distinct containers are distinct memo entries");
        // limited load truncates identically for both containers
        for p in [&p1, &p2] {
            let l = Workload::trace_file(p).load_limited(2, 0).unwrap();
            assert_eq!(l.total_warps, 6);
            assert!(!l.annotated);
            assert_eq!(l.trace.warps[..], t.warps[..2]);
        }
        // builtin limited load simply generates that many warps
        let l = Workload::builtin("kmeans").load_limited(3, 5).unwrap();
        assert_eq!(l.trace.warps.len(), 3);
        assert_eq!(l.total_warps, 3);
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }

    #[test]
    fn workload_cache_names_never_collide() {
        // a trace file named like a benchmark stays in its own namespace
        let w = Workload::trace_file("kmeans");
        assert_eq!(w.cache_name(), "trace:kmeans");
        assert_ne!(w.cache_name(), Workload::builtin("kmeans").cache_name());
        assert!(
            w.load(1, 0).is_err(),
            "nonexistent trace file must be a load error"
        );
    }
}
