//! Kernel traces: per-warp instruction streams + generation from the
//! Table II workload registry.

pub mod program;
pub mod workloads;

pub use program::{AddrGen, ProgramBuilder};
pub use workloads::{find, table2, Benchmark, Suite, WarpCtx, BENCHMARKS};

use crate::isa::Instruction;

/// A generated kernel launch: one instruction stream per warp.
#[derive(Debug, Clone)]
pub struct KernelTrace {
    /// Benchmark chart name.
    pub name: String,
    /// Per-warp streams (each ends with an `Exit` marker).
    pub warps: Vec<Vec<Instruction>>,
}

impl KernelTrace {
    /// Generate `nwarps` warp streams for `bench` with a launch `seed`.
    pub fn generate(bench: &Benchmark, nwarps: usize, seed: u64) -> Self {
        let warps = (0..nwarps)
            .map(|w| {
                let ctx = WarpCtx {
                    warp_id: w as u32,
                    nwarps: nwarps as u32,
                    kernel_id: 0,
                };
                (bench.gen)(&ctx, seed)
            })
            .collect();
        KernelTrace { name: bench.name.to_string(), warps }
    }

    /// Total dynamic instructions across all warps (including Exit markers).
    pub fn total_instructions(&self) -> usize {
        self.warps.iter().map(|w| w.len()).sum()
    }

    /// Flatten the first `nwarps` warps into padded `(ids, pos, rw)` access
    /// streams for the reuse-annotation path (rust `compiler::` or the AOT
    /// artifact). Each register operand of each instruction becomes one
    /// access (sources first, as reads `rw=1`; then destinations, `rw=0`);
    /// `pos` is the dynamic instruction index. Rows are truncated / padded
    /// with `-1` to `len`.
    pub fn access_streams(
        &self,
        nwarps: usize,
        len: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let n = nwarps.min(self.warps.len());
        let mut ids = vec![-1i32; nwarps * len];
        let mut pos = vec![0i32; nwarps * len];
        let mut rw = vec![0i32; nwarps * len];
        for w in 0..n {
            let mut k = 0usize;
            'outer: for (ii, instr) in self.warps[w].iter().enumerate() {
                for (is_read, &r) in instr
                    .sources()
                    .iter()
                    .map(|r| (1, r))
                    .chain(instr.dests().iter().map(|r| (0, r)))
                {
                    if k >= len {
                        break 'outer;
                    }
                    ids[w * len + k] = r as i32;
                    pos[w * len + k] = ii as i32;
                    rw[w * len + k] = is_read;
                    k += 1;
                }
            }
        }
        (ids, pos, rw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_produces_per_warp_streams() {
        let b = find("hotspot").unwrap();
        let t = KernelTrace::generate(b, 8, 1);
        assert_eq!(t.warps.len(), 8);
        assert!(t.total_instructions() > 8 * 400);
    }

    #[test]
    fn access_streams_pad_and_position() {
        let b = find("kmeans").unwrap();
        let t = KernelTrace::generate(b, 4, 1);
        let (ids, pos, rw) = t.access_streams(2, 64);
        assert_eq!(ids.len(), 2 * 64);
        assert_eq!(rw.len(), 2 * 64);
        // row 0 first access: first instruction's first operand
        let first = &t.warps[0][0];
        let first_reg = first
            .sources()
            .first()
            .or_else(|| first.dests().first())
            .copied()
            .unwrap();
        assert_eq!(ids[0], first_reg as i32);
        assert_eq!(pos[0], 0);
        // sources flatten before destinations, so rw[0] is a read iff the
        // first instruction has any source
        assert_eq!(rw[0], if first.nsrc > 0 { 1 } else { 0 });
        // positions never decrease within a row
        for w in 0..2 {
            let row = &pos[w * 64..(w + 1) * 64];
            assert!(row.windows(2).all(|p| p[0] <= p[1]));
        }
    }

    #[test]
    fn access_streams_more_rows_than_warps() {
        let b = find("nn").unwrap();
        let t = KernelTrace::generate(b, 1, 1);
        let (ids, _, _) = t.access_streams(3, 32);
        // rows beyond available warps are fully padded
        assert!(ids[32..].iter().all(|&x| x == -1));
    }
}
