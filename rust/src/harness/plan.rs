//! Sharded execution of experiment work plans.
//!
//! A figure declares the set of `(benchmark, scheme, config)` simulation
//! points it needs as a [`Plan`]; [`Runner::execute`] shards the
//! not-yet-cached points across a pool of `std::thread` workers and merges
//! the resulting [`Stats`] into the runner's cache **in plan order**, so
//! the serial table-assembly pass that follows reads exactly the values a
//! fully serial run would have produced.
//!
//! Determinism: every point carries its own fully-resolved [`GpuConfig`]
//! (including the per-point `seed` — the simulator derives all policy RNG
//! streams from it), so a point's `Stats` are a pure function of the point
//! and independent of which shard runs it or how many workers exist. The
//! `--jobs N` / `--serial` CLI switches therefore change wall-clock only:
//! output tables are bit-identical at any worker count (enforced by
//! `rust/tests/parallel_determinism.rs`).

use std::collections::HashSet;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::{ExpOpts, Runner};
use crate::config::{GpuConfig, Scheme};
use crate::sim::run_workload;
use crate::stats::Stats;
use crate::trace::Workload;

/// One independent simulation of a figure's work plan.
#[derive(Debug, Clone)]
pub struct SimPoint {
    /// Scheme under test.
    pub scheme: Scheme,
    /// Variant key distinguishing customised configs (0 = scheme default).
    pub key: u64,
    /// Fully-resolved simulator configuration for this point.
    pub cfg: GpuConfig,
    /// Where the instruction streams come from (builtin vs. trace file).
    pub workload: Workload,
}

impl SimPoint {
    /// Display label: the registry name, or `trace:<path>` for file-backed
    /// points.
    pub fn label(&self) -> String {
        self.workload.cache_name()
    }

    fn cache_key(&self) -> (String, Scheme, u64) {
        // content-based (`Workload::cache_key`), matching
        // `Runner::run_workload_cfg_key` — NOT the display label: a trace
        // file edited in place must become a fresh point, not a stale hit
        (self.workload.cache_key(), self.scheme, self.key)
    }
}

/// An ordered list of simulation points to run before assembling a table.
///
/// Points are resolved to concrete configs at `add` time (against the
/// options the plan was created with), deduplicated at execution time, and
/// merged back in declaration order.
#[derive(Debug, Clone)]
pub struct Plan {
    opts: ExpOpts,
    points: Vec<SimPoint>,
}

impl Plan {
    /// New empty plan resolving configs against `opts`.
    pub fn new(opts: &ExpOpts) -> Self {
        Plan { opts: opts.clone(), points: Vec::new() }
    }

    /// Add a point with the default config for `scheme` (key 0) — the
    /// counterpart of [`Runner::run`].
    pub fn add(&mut self, bench: &str, scheme: Scheme) {
        self.add_cfg(bench, scheme, 0, |o| o.config(scheme));
    }

    /// Add a point with a customised config — the counterpart of
    /// [`Runner::run_cfg_key`]; `key` distinguishes variants.
    pub fn add_cfg(
        &mut self,
        bench: &str,
        scheme: Scheme,
        key: u64,
        make: impl FnOnce(&ExpOpts) -> GpuConfig,
    ) {
        self.add_workload(Workload::builtin(bench), scheme, key, make);
    }

    /// Add a `.mtrace`-file point with the default config for `scheme` —
    /// the counterpart of [`Runner::run_trace`]. Trace points cache and
    /// shard like any other point.
    pub fn add_trace(&mut self, path: &Path, scheme: Scheme) {
        self.add_workload(Workload::trace_file(path), scheme, 0, |o| o.config(scheme));
    }

    /// Add a point backed by an arbitrary workload source — the
    /// counterpart of [`Runner::run_workload_cfg_key`].
    pub fn add_workload(
        &mut self,
        workload: Workload,
        scheme: Scheme,
        key: u64,
        make: impl FnOnce(&ExpOpts) -> GpuConfig,
    ) {
        let cfg = make(&self.opts);
        self.points.push(SimPoint { scheme, key, cfg, workload });
    }

    /// Declared points, in order.
    pub fn points(&self) -> &[SimPoint] {
        &self.points
    }

    /// Number of declared points (before dedup).
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// No points declared?
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

impl Runner {
    /// Worker threads that [`Runner::execute`] will use for a plan of
    /// `points` runnable simulations.
    fn shard_count(&self, points: usize) -> usize {
        self.opts().effective_jobs().min(points).max(1)
    }

    /// Run every not-yet-cached point of `plan`, sharding independent
    /// simulations across the worker pool, then publish the results into
    /// the memo cache in plan order.
    ///
    /// After this returns, [`Runner::run`] / [`Runner::run_cfg_key`] calls
    /// for the planned points are cache hits, so table assembly stays a
    /// cheap serial pass with deterministic output.
    pub fn execute(&self, plan: &Plan) {
        // A plan resolved against different options would publish stats
        // under keys this runner attributes to ITS options — refuse.
        assert!(
            plan.opts == *self.opts(),
            "plan built against different ExpOpts than this runner \
             (build it with Runner::plan): {:?} vs {:?}",
            plan.opts,
            self.opts()
        );
        // Dedup against the cache and within the plan, preserving order.
        let todo: Vec<&SimPoint> = {
            let cache = self.cache.lock().unwrap();
            let mut seen = HashSet::new();
            plan.points()
                .iter()
                .filter(|p| {
                    let k = p.cache_key();
                    !cache.contains_key(&k) && seen.insert(k)
                })
                .collect()
        };
        if todo.is_empty() {
            return;
        }
        let jobs = self.shard_count(todo.len());
        let profile_warps = self.opts().profile_warps;
        if jobs <= 1 {
            // serial escape hatch: exactly the repeated-miss path
            for p in todo {
                self.run_workload_cfg_key(&p.workload, p.scheme, p.key, |_| {
                    p.cfg.clone()
                });
            }
            return;
        }
        // Work-stealing over a shared index: shards grab the next point as
        // they free up, so one slow simulation cannot serialise the rest.
        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<(Stats, f64)>>> =
            Mutex::new((0..todo.len()).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= todo.len() {
                        break;
                    }
                    let p = todo[i];
                    // simlint: allow(wallclock) reason="progress-log timing; never enters Stats"
                    let t0 = Instant::now();
                    // persistent store first (no-op without --store), then
                    // simulate-and-publish — same tiering as the serial path
                    let stats = match self.store_lookup(&p.cfg, &p.workload) {
                        Some(stats) => stats,
                        None => {
                            let stats =
                                run_workload(&p.cfg, &p.workload, profile_warps)
                                    .unwrap_or_else(|e| panic!("[{}] {e}", p.label()));
                            self.store_publish(&p.cfg, &p.workload, &stats);
                            stats
                        }
                    };
                    results.lock().unwrap()[i] =
                        Some((stats, t0.elapsed().as_secs_f64()));
                });
            }
        });
        // Merge in fixed plan order: cache contents and progress log are
        // identical to a serial run regardless of shard completion order.
        let results = results.into_inner().unwrap();
        let mut cache = self.cache.lock().unwrap();
        for (p, slot) in todo.iter().zip(results) {
            let (stats, dt) = slot.expect("every claimed point completes");
            log_point(&p.label(), p.scheme, p.key, &stats, dt);
            cache.insert(p.cache_key(), stats);
        }
    }
}

/// One per-point progress line; shared by every execution path so serial
/// and sharded runs emit identical logs.
pub(crate) fn log_point(bench: &str, scheme: Scheme, key: u64, stats: &Stats, secs: f64) {
    eprintln!(
        "  [{bench} / {scheme} / v{key}] {} instr, {} cycles, {:.1}s",
        stats.instructions, stats.cycles, secs
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts(jobs: usize) -> ExpOpts {
        ExpOpts {
            num_sms: 1,
            seed: 7,
            profile_warps: 2,
            quick: true,
            jobs,
            sim_threads: 1,
            store_dir: None,
        }
    }

    #[test]
    fn plan_resolves_configs_at_add_time() {
        let opts = tiny_opts(1);
        let mut plan = Plan::new(&opts);
        plan.add("nn", Scheme::BASELINE);
        plan.add_cfg("nn", Scheme::MALEKEH, 9, |o| {
            let mut c = o.config(Scheme::MALEKEH);
            c.ct_entries = 16;
            c
        });
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.points()[0].cfg.num_sms, 1);
        assert_eq!(plan.points()[0].cfg.seed, 7);
        assert_eq!(plan.points()[1].key, 9);
        assert_eq!(plan.points()[1].cfg.ct_entries, 16);
    }

    #[test]
    fn execute_dedups_and_fills_cache() {
        let runner = Runner::new(tiny_opts(1));
        let mut plan = runner.plan();
        plan.add("nn", Scheme::BASELINE);
        plan.add("nn", Scheme::BASELINE); // duplicate point
        runner.execute(&plan);
        assert_eq!(runner.cached(), 1);
        // re-execution is a no-op (everything cached)
        runner.execute(&plan);
        assert_eq!(runner.cached(), 1);
    }

    #[test]
    fn parallel_execute_matches_serial() {
        let serial = Runner::new(tiny_opts(1));
        let sharded = Runner::new(tiny_opts(2));
        for r in [&serial, &sharded] {
            let mut plan = r.plan();
            plan.add("nn", Scheme::BASELINE);
            plan.add("nn", Scheme::MALEKEH);
            r.execute(&plan);
        }
        for scheme in [Scheme::BASELINE, Scheme::MALEKEH] {
            let a = serial.run("nn", scheme);
            let b = sharded.run("nn", scheme);
            assert_eq!(a.cycles, b.cycles, "{scheme}");
            assert_eq!(a.instructions, b.instructions, "{scheme}");
            assert_eq!(a.rf_cache_reads, b.rf_cache_reads, "{scheme}");
        }
    }
}
