//! Experiment harness: one runner per paper figure/table (DESIGN.md §5).
//!
//! Bench binaries (`rust/benches/fig*.rs`) and the CLI (`malekeh fig <id>`)
//! both call into these; `docs/EXPERIMENTS.md` records their output next
//! to the paper's numbers (see its §Figure-reproduction status table).
//! Experiments default to 2 SMs (the mechanism is per-SM; the paper's
//! 10-SM Table I config is available with `--full`).
//!
//! # Parallel execution
//!
//! Every figure is assembled in two phases. First it declares its
//! simulation points as a [`Plan`] and calls [`Runner::execute`], which
//! shards the independent `(benchmark, scheme, config)` simulations across
//! a worker pool (`--jobs N`, default one worker per core; `--serial`
//! forces one). Then it builds its [`Table`] serially from the warm memo
//! cache, so output is **bit-identical at any worker count** — the figure
//! suite's wall-clock drops from sum-of-simulations to slowest-shard.
//!
//! ```no_run
//! use malekeh::config::Scheme;
//! use malekeh::harness::{ExpOpts, Runner};
//!
//! let mut opts = ExpOpts::default();
//! opts.quick = true;
//! opts.jobs = 4; // 0 = one worker per available core
//! let runner = Runner::new(opts);
//!
//! // phase 1: declare the points and shard them across the pool
//! let mut plan = runner.plan();
//! for bench in runner.opts().benchmarks() {
//!     plan.add(bench, Scheme::BASELINE);
//!     plan.add(bench, Scheme::MALEKEH);
//! }
//! runner.execute(&plan);
//!
//! // phase 2: read results (all cache hits) in table order
//! for bench in runner.opts().benchmarks() {
//!     let base = runner.run(bench, Scheme::BASELINE);
//!     let mal = runner.run(bench, Scheme::MALEKEH);
//!     println!("{bench}: IPC x{:.3}", mal.ipc() / base.ipc().max(1e-9));
//! }
//! ```

pub mod plan;
pub mod table;
pub use plan::{Plan, SimPoint};
pub use table::{geomean, mean, Table};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::{GpuConfig, Scheme, SthldMode};
use crate::energy::EnergyModel;
use crate::serve::store::{Store, StoreKey};
use crate::sim::{run_benchmark, run_workload};
use crate::stats::Stats;
use crate::trace::{table2, Suite, Workload};

/// Experiment options shared by all figure runners.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpOpts {
    /// SMs to simulate (paper: 10; default 2 for bench turnaround).
    pub num_sms: usize,
    /// Launch seed.
    pub seed: u64,
    /// Warps profiled by the compiler pass (0 = oracle annotation).
    pub profile_warps: usize,
    /// Restrict to a representative benchmark subset for quick runs.
    pub quick: bool,
    /// Worker threads for plan execution (0 = one per available core;
    /// 1 = serial).
    pub jobs: usize,
    /// Worker threads *inside each simulation* (epoch-engine SM
    /// parallelism, `GpuConfig::sim_threads`). The core budget is shared
    /// with `jobs`: total threads ≈ `jobs x sim_threads`, so auto `jobs`
    /// (0) divides the available cores by this value. Results are
    /// bit-identical at any setting.
    pub sim_threads: usize,
    /// Back the in-process memo cache with a persistent content-addressed
    /// result store (`serve::Store`) rooted here. Points already in the
    /// store are served without simulating; fresh results are written
    /// back, so re-running a figure suite across process restarts is
    /// warm-cache reads. `None` (the default) keeps the memo in-memory
    /// only.
    pub store_dir: Option<PathBuf>,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            num_sms: 2,
            seed: 0xC0FFEE,
            profile_warps: 2,
            quick: false,
            jobs: 0,
            sim_threads: 1,
            store_dir: None,
        }
    }
}

/// Fetch + parse the value of `flag` at argv position `i`, panicking with
/// the flag's usage hint when the value is missing or unparseable.
fn parse_val<T: std::str::FromStr>(args: &[String], i: usize, flag: &str) -> T {
    args.get(i)
        .unwrap_or_else(|| panic!("{flag} requires a value ({flag} N)"))
        .parse()
        .unwrap_or_else(|_| panic!("bad value for {flag} ({flag} N)"))
}

impl ExpOpts {
    /// Parse bench-binary argv: `--full` (10 SMs, all benchmarks),
    /// `--quick`, `--sms N`, `--seed N`, `--jobs N`, `--serial`,
    /// `--sim-threads N` (intra-simulation SM parallelism),
    /// `--store DIR` (persistent result store).
    pub fn from_args(args: &[String]) -> ExpOpts {
        let mut o = ExpOpts::default();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--full" => {
                    o.num_sms = 10;
                    o.quick = false;
                }
                "--quick" => o.quick = true,
                "--serial" => o.jobs = 1,
                "--sms" => {
                    i += 1;
                    o.num_sms = parse_val(args, i, "--sms");
                }
                "--seed" => {
                    i += 1;
                    o.seed = parse_val(args, i, "--seed");
                }
                "--jobs" => {
                    i += 1;
                    o.jobs = parse_val(args, i, "--jobs");
                }
                "--sim-threads" => {
                    i += 1;
                    o.sim_threads = parse_val(args, i, "--sim-threads");
                }
                "--store" => {
                    i += 1;
                    o.store_dir = Some(parse_val::<PathBuf>(args, i, "--store"));
                }
                _ => {}
            }
            i += 1;
        }
        o
    }

    /// Default simulator config for `scheme` under these options.
    pub fn config(&self, scheme: Scheme) -> GpuConfig {
        let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
        c.num_sms = self.num_sms;
        c.seed = self.seed;
        c.sim_threads = self.sim_threads;
        c
    }

    /// Resolved worker count: `jobs`, or — when 0 — one per available
    /// core **divided by `sim_threads`**, so a sharded figure run and the
    /// intra-simulation SM workers share one core budget instead of
    /// oversubscribing the machine.
    pub fn effective_jobs(&self) -> usize {
        if self.jobs != 0 {
            return self.jobs;
        }
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        // sim_threads = 0 means "one per core" inside the simulator, so
        // budget it as a full machine's worth, not as 1
        let per_sim = if self.sim_threads == 0 { cores } else { self.sim_threads };
        (cores / per_sim).max(1)
    }

    /// Benchmarks to run (Table II, or a representative 8 in quick mode).
    pub fn benchmarks(&self) -> Vec<&'static str> {
        if self.quick {
            vec![
                "hotspot", "kmeans", "b+tree", "srad_v1", "nn", "gemm_t1",
                "conv_i1", "rnn_i2",
            ]
        } else {
            table2().map(|b| b.name).collect()
        }
    }
}

/// Runs one benchmark under one scheme, memoised behind a thread-safe
/// cache so a single `Runner` can be shared by the shard pool (and across
/// figures — later figures reuse earlier baselines as cache hits).
///
/// Execution model: figures call [`Runner::execute`] with a [`Plan`] to
/// shard the misses, then read via [`Runner::run`] / [`Runner::run_cfg_key`]
/// (which also compute on miss, keeping them correct stand-alone).
pub struct Runner {
    opts: ExpOpts,
    pub(crate) cache: Mutex<HashMap<(String, Scheme, u64), Stats>>,
    pub(crate) store: Option<Store>,
}

impl Runner {
    /// New runner. When `opts.store_dir` is set the memo cache is backed
    /// by the persistent store; a store that cannot be opened degrades to
    /// in-memory-only operation with a warning rather than failing the
    /// experiment.
    pub fn new(opts: ExpOpts) -> Self {
        let store = opts.store_dir.as_ref().and_then(|dir| match Store::open(dir) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("warning: --store {}: {e}; running without", dir.display());
                None
            }
        });
        Runner { opts, cache: Mutex::new(HashMap::new()), store }
    }

    /// Options in use.
    pub fn opts(&self) -> &ExpOpts {
        &self.opts
    }

    /// Cached simulation count.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// New empty [`Plan`] bound to this runner's options — the only way
    /// plans should be built for [`Runner::execute`], which rejects plans
    /// resolved against different options (their cached stats would be
    /// indistinguishable from this runner's).
    pub fn plan(&self) -> Plan {
        Plan::new(&self.opts)
    }

    /// Simulate (cached) with the default config for `scheme`.
    pub fn run(&self, bench: &str, scheme: Scheme) -> Stats {
        self.run_cfg_key(bench, scheme, 0, |o| o.config(scheme))
    }

    /// Simulate with a customised config; `key` distinguishes variants.
    ///
    /// Computes on miss (serially, in the calling thread); when the point
    /// was pre-executed by [`Runner::execute`] this is a lock-and-clone.
    pub fn run_cfg_key(
        &self,
        bench: &str,
        scheme: Scheme,
        key: u64,
        make: impl FnOnce(&ExpOpts) -> GpuConfig,
    ) -> Stats {
        self.run_workload_cfg_key(&Workload::builtin(bench), scheme, key, make)
    }

    /// Simulate (cached) a `.mtrace` file with the default config for
    /// `scheme` — the file-backed counterpart of [`Runner::run`].
    pub fn run_trace(&self, path: &Path, scheme: Scheme) -> Stats {
        self.run_workload_cfg_key(&Workload::trace_file(path), scheme, 0, |o| {
            o.config(scheme)
        })
    }

    /// Simulate (cached) an arbitrary workload source. Trace-file points
    /// are keyed by `trace:<content-fingerprint>` (never the path), so
    /// editing a trace file in place invalidates its cached stats and
    /// two paths to identical bytes share one entry.
    pub fn run_workload_cfg_key(
        &self,
        workload: &Workload,
        scheme: Scheme,
        key: u64,
        make: impl FnOnce(&ExpOpts) -> GpuConfig,
    ) -> Stats {
        let name = workload.cache_name();
        let k = (workload.cache_key(), scheme, key);
        if let Some(s) = self.cache.lock().unwrap().get(&k) {
            return s.clone();
        }
        let cfg = make(&self.opts);
        if let Some(stats) = self.store_lookup(&cfg, workload) {
            self.cache.lock().unwrap().insert(k, stats.clone());
            return stats;
        }
        // simlint: allow(wallclock) reason="progress-log timing only; never enters Stats"
        let t0 = Instant::now();
        let stats = run_workload(&cfg, workload, self.opts.profile_warps)
            .unwrap_or_else(|e| panic!("[{name}] {e}"));
        plan::log_point(&name, scheme, key, &stats, t0.elapsed().as_secs_f64());
        self.store_publish(&cfg, workload, &stats);
        self.cache.lock().unwrap().insert(k, stats.clone());
        stats
    }

    /// Consult the persistent store for a point (no-op without `--store`).
    pub(crate) fn store_lookup(&self, cfg: &GpuConfig, workload: &Workload) -> Option<Stats> {
        let store = self.store.as_ref()?;
        let key = StoreKey::for_run(cfg, workload, self.opts.profile_warps).ok()?;
        store.get(&key)
    }

    /// Write a freshly simulated point through to the persistent store.
    /// Store write failures are warnings, never experiment failures.
    pub(crate) fn store_publish(&self, cfg: &GpuConfig, workload: &Workload, stats: &Stats) {
        let Some(store) = self.store.as_ref() else { return };
        match StoreKey::for_run(cfg, workload, self.opts.profile_warps) {
            Ok(key) => {
                if let Err(e) = store.put(&key, stats) {
                    eprintln!("warning: store write failed: {e}");
                }
            }
            Err(e) => eprintln!("warning: store key for {}: {e}", workload.cache_name()),
        }
    }
}

// ============================== figures =====================================

/// Monolithic-SM variant config for the Fig 2 comparison.
fn monolithic_cfg(o: &ExpOpts, scheme: Scheme) -> GpuConfig {
    let mut c = GpuConfig::monolithic().with_scheme(scheme);
    c.num_sms = o.num_sms;
    c.seed = o.seed;
    c
}

/// Fig 1: reuse-distance distribution per suite (buckets d<=1,2,3,4-10,>10).
pub fn fig01(opts: &ExpOpts) -> Table {
    let mut t = Table::new(
        "Fig 1: reuse distance distribution of register values (fraction)",
        &["suite", "<=1", "2", "3", "4-10", ">10"],
    );
    for suite in [Suite::Rodinia, Suite::Deepbench] {
        let mut h = [0u64; 5];
        for b in table2().filter(|b| b.suite == suite) {
            let trace =
                crate::trace::KernelTrace::generate(b, 8, opts.seed ^ 0x51);
            let hb = crate::compiler::reuse_histogram(&trace);
            for i in 0..5 {
                h[i] += hb[i];
            }
        }
        let total: u64 = h.iter().sum();
        let fr: Vec<f64> = h.iter().map(|&x| x as f64 / total.max(1) as f64).collect();
        t.row_f(
            if suite == Suite::Rodinia { "Rodinia" } else { "Deepbench" },
            &fr,
            3,
        );
    }
    t
}

/// Fig 2: IPC of two-level schedulers (RFC, software RFC) normalised to the
/// one-level baseline, for sub-core and monolithic architectures.
pub fn fig02(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    let mut plan = runner.plan();
    for bench in &benches {
        plan.add(bench, Scheme::BASELINE);
        plan.add_cfg(bench, Scheme::BASELINE, 1, |o| {
            monolithic_cfg(o, Scheme::BASELINE)
        });
        for scheme in [Scheme::RFC, Scheme::SOFTWARE_RFC] {
            plan.add(bench, scheme);
            plan.add_cfg(bench, scheme, 1, |o| monolithic_cfg(o, scheme));
        }
    }
    runner.execute(&plan);

    let mut t = Table::new(
        "Fig 2: two-level scheduler IPC normalised to baseline",
        &["bench", "rfc_subcore", "swrfc_subcore", "rfc_mono", "swrfc_mono"],
    );
    let mut cols: [Vec<f64>; 4] = Default::default();
    for bench in &benches {
        let base_sub = runner.run(bench, Scheme::BASELINE).ipc();
        let base_mono = runner
            .run_cfg_key(bench, Scheme::BASELINE, 1, |o| {
                monolithic_cfg(o, Scheme::BASELINE)
            })
            .ipc();
        let mut vals = [0f64; 4];
        for (i, scheme) in [Scheme::RFC, Scheme::SOFTWARE_RFC].iter().enumerate() {
            let sub = runner.run(bench, *scheme).ipc();
            let mono = runner
                .run_cfg_key(bench, *scheme, 1, |o| monolithic_cfg(o, *scheme))
                .ipc();
            vals[i] = sub / base_sub.max(1e-9);
            vals[2 + i] = mono / base_mono.max(1e-9);
        }
        for i in 0..4 {
            cols[i].push(vals[i]);
        }
        t.row_f(bench, &vals, 3);
    }
    t.row_f(
        "GEOMEAN",
        &[
            geomean(&cols[0]),
            geomean(&cols[1]),
            geomean(&cols[2]),
            geomean(&cols[3]),
        ],
        3,
    );
    t
}

/// Static-STHLD sweep values for Fig 7.
const FIG07_STHLDS: [u32; 7] = [0, 1, 2, 4, 8, 16, 32];
/// STHLD-sensitive apps reported in Fig 7.
const FIG07_BENCHES: [&str; 3] = ["srad_v1", "gaussian", "rnn_i2"];

/// Fig 7: IPC + RF-cache hit ratio vs static STHLD for sensitive apps.
pub fn fig07(runner: &Runner) -> Table {
    let mut plan = runner.plan();
    for bench in FIG07_BENCHES {
        plan.add(bench, Scheme::BASELINE);
        for (k, s) in FIG07_STHLDS.iter().enumerate() {
            plan.add_cfg(bench, Scheme::MALEKEH, 100 + k as u64, |o| {
                let mut c = o.config(Scheme::MALEKEH);
                c.sthld = SthldMode::Static(*s);
                c
            });
        }
    }
    runner.execute(&plan);

    let mut header: Vec<String> = vec!["bench/metric".into()];
    header.extend(FIG07_STHLDS.iter().map(|s| format!("S={s}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 7: normalised IPC and hit ratio vs static STHLD",
        &hdr,
    );
    for bench in FIG07_BENCHES {
        let base = runner.run(bench, Scheme::BASELINE).ipc();
        let mut ipc_row = Vec::new();
        let mut hit_row = Vec::new();
        for (k, s) in FIG07_STHLDS.iter().enumerate() {
            let stats = runner.run_cfg_key(bench, Scheme::MALEKEH, 100 + k as u64, |o| {
                let mut c = o.config(Scheme::MALEKEH);
                c.sthld = SthldMode::Static(*s);
                c
            });
            ipc_row.push(stats.ipc() / base.max(1e-9));
            hit_row.push(stats.rf_hit_ratio());
        }
        t.row_f(&format!("{bench} IPC"), &ipc_row, 3);
        t.row_f(&format!("{bench} hit"), &hit_row, 3);
    }
    t
}

/// Fig 9: dynamic-STHLD trajectory on the phase-changing workload.
pub fn fig09(opts: &ExpOpts) -> Table {
    let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    cfg.num_sms = opts.num_sms;
    cfg.seed = opts.seed;
    cfg.sthld = SthldMode::Dynamic;
    cfg.sthld_interval = 2_000; // finer intervals to expose the walk
    let stats = run_benchmark(&cfg, "synthetic_phases", opts.profile_warps);
    let mut t = Table::new(
        "Fig 9: dynamic algorithm walk (interval -> STHLD, IPC)",
        &["interval", "sthld", "ipc"],
    );
    for (i, (s, ipc)) in stats
        .sthld_trace
        .iter()
        .zip(stats.interval_ipc.iter())
        .enumerate()
    {
        t.row(vec![format!("{i}"), format!("{s}"), format!("{ipc:.3}")]);
    }
    t
}

/// Fig 10: state distribution of two-level schedulers.
pub fn fig10(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    let mut plan = runner.plan();
    for scheme in [Scheme::RFC, Scheme::SOFTWARE_RFC] {
        for bench in &benches {
            plan.add(bench, scheme);
        }
    }
    runner.execute(&plan);

    let mut t = Table::new(
        "Fig 10: two-level scheduler state distribution (fractions)",
        &["scheme", "issued", "state2_ready_stall", "state3_empty"],
    );
    for scheme in [Scheme::RFC, Scheme::SOFTWARE_RFC] {
        let mut acc = [0f64; 3];
        for bench in &benches {
            let s = runner.run(bench, scheme);
            let (a, b, c) = s.sched_state_distribution();
            acc[0] += a;
            acc[1] += b;
            acc[2] += c;
        }
        let n = benches.len() as f64;
        t.row_f(scheme.name(), &[acc[0] / n, acc[1] / n, acc[2] / n], 3);
    }
    t
}

/// The Fig 12/13/14/15/16 scheme set.
const MAIN_SCHEMES: [Scheme; 3] = [Scheme::MALEKEH, Scheme::BOW, Scheme::MALEKEH_PR];

/// Declare + execute `benchmarks x schemes` default-config points.
fn execute_grid(runner: &Runner, benches: &[&str], schemes: &[Scheme]) {
    let mut plan = runner.plan();
    for bench in benches {
        for scheme in schemes {
            plan.add(bench, *scheme);
        }
    }
    runner.execute(&plan);
}

/// Fig 12: IPC normalised to baseline.
pub fn fig12(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    execute_grid(
        runner,
        &benches,
        &[Scheme::BASELINE, Scheme::MALEKEH, Scheme::BOW, Scheme::MALEKEH_PR],
    );

    let mut t = Table::new(
        "Fig 12: IPC normalised to the baseline",
        &["bench", "malekeh", "bow", "malekeh_pr"],
    );
    let mut cols: [Vec<f64>; 3] = Default::default();
    for bench in &benches {
        let base = runner.run(bench, Scheme::BASELINE).ipc();
        let mut vals = [0f64; 3];
        for (i, s) in MAIN_SCHEMES.iter().enumerate() {
            vals[i] = runner.run(bench, *s).ipc() / base.max(1e-9);
            cols[i].push(vals[i]);
        }
        t.row_f(bench, &vals, 3);
    }
    t.row_f(
        "GEOMEAN",
        &[geomean(&cols[0]), geomean(&cols[1]), geomean(&cols[2])],
        3,
    );
    t
}

/// Fig 13: RF cache hit ratio.
pub fn fig13(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    execute_grid(runner, &benches, &MAIN_SCHEMES);

    let mut t = Table::new(
        "Fig 13: RF cache hit ratio",
        &["bench", "malekeh", "bow", "malekeh_pr"],
    );
    let mut cols: [Vec<f64>; 3] = Default::default();
    for bench in &benches {
        let mut vals = [0f64; 3];
        for (i, s) in MAIN_SCHEMES.iter().enumerate() {
            vals[i] = runner.run(bench, *s).rf_hit_ratio();
            cols[i].push(vals[i]);
        }
        t.row_f(bench, &vals, 3);
    }
    t.row_f(
        "MEAN",
        &[mean(&cols[0]), mean(&cols[1]), mean(&cols[2])],
        3,
    );
    t
}

/// Fig 14: L1 data cache hit ratio.
pub fn fig14(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    execute_grid(
        runner,
        &benches,
        &[Scheme::BASELINE, Scheme::MALEKEH, Scheme::BOW],
    );

    let mut t = Table::new(
        "Fig 14: L1D hit ratio",
        &["bench", "baseline", "malekeh", "bow"],
    );
    for bench in &benches {
        let vals = [
            runner.run(bench, Scheme::BASELINE).l1_hit_ratio(),
            runner.run(bench, Scheme::MALEKEH).l1_hit_ratio(),
            runner.run(bench, Scheme::BOW).l1_hit_ratio(),
        ];
        t.row_f(bench, &vals, 3);
    }
    t
}

/// Fig 15: RF dynamic energy normalised to baseline.
pub fn fig15(runner: &Runner) -> Table {
    let opts = runner.opts().clone();
    let benches = opts.benchmarks();
    execute_grid(
        runner,
        &benches,
        &[Scheme::BASELINE, Scheme::MALEKEH, Scheme::BOW, Scheme::MALEKEH_PR],
    );

    let mut t = Table::new(
        "Fig 15: RF dynamic energy normalised to the baseline",
        &["bench", "malekeh", "bow", "malekeh_pr"],
    );
    let mut cols: [Vec<f64>; 3] = Default::default();
    for bench in &benches {
        let base_stats = runner.run(bench, Scheme::BASELINE);
        let base_model = EnergyModel::for_config(&opts.config(Scheme::BASELINE));
        let base_e = base_model.total(&base_stats.energy).max(1e-9);
        let mut vals = [0f64; 3];
        for (i, s) in MAIN_SCHEMES.iter().enumerate() {
            let stats = runner.run(bench, *s);
            let model = EnergyModel::for_config(&opts.config(*s));
            vals[i] = model.total(&stats.energy) / base_e;
            cols[i].push(vals[i]);
        }
        t.row_f(bench, &vals, 3);
    }
    t.row_f(
        "MEAN",
        &[mean(&cols[0]), mean(&cols[1]), mean(&cols[2])],
        3,
    );
    t
}

/// Fig 16: writes captured by the RF cache / all RF writes.
pub fn fig16(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    execute_grid(runner, &benches, &[Scheme::MALEKEH, Scheme::BOW]);

    let mut t = Table::new(
        "Fig 16: cache writes / total RF writes (and reused fraction)",
        &["bench", "malekeh", "bow", "malekeh_reused"],
    );
    for bench in &benches {
        let m = runner.run(bench, Scheme::MALEKEH);
        let b = runner.run(bench, Scheme::BOW);
        let reused = if m.rf_cache_writes == 0 {
            0.0
        } else {
            m.cache_write_reused as f64 / m.rf_cache_writes as f64
        };
        t.row_f(
            bench,
            &[m.cache_write_fraction(), b.cache_write_fraction(), reused],
            3,
        );
    }
    t
}

/// The Fig 17 / Ablation-E scheme columns: the registry's sweep set
/// ([`crate::sim::policy::PolicyMeta::fig17_sweep`]) plus `malekeh` as
/// the reference, so a newly registered comparison policy lands in both
/// tables automatically.
fn replacement_sweep_schemes() -> Vec<Scheme> {
    let mut schemes = Scheme::fig17_sweep();
    schemes.push(Scheme::MALEKEH);
    schemes
}

/// Execute and assemble a `benches x schemes` RF-hit-ratio table with a
/// MEAN row — shared by the registry-driven sweep builders.
fn hit_ratio_sweep_table(
    runner: &Runner,
    title: &str,
    benches: &[&str],
    schemes: &[Scheme],
) -> Table {
    execute_grid(runner, benches, schemes);
    let mut header: Vec<String> = vec!["bench".into()];
    header.extend(schemes.iter().map(|s| s.name().to_string()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hdr);
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); schemes.len()];
    for bench in benches {
        let vals: Vec<f64> = schemes
            .iter()
            .map(|s| runner.run(bench, *s).rf_hit_ratio())
            .collect();
        for (col, v) in cols.iter_mut().zip(&vals) {
            col.push(*v);
        }
        t.row_f(bench, &vals, 3);
    }
    let means: Vec<f64> = cols.iter().map(|c| mean(c)).collect();
    t.row_f("MEAN", &means, 3);
    t
}

/// Fig 17: Malekeh hardware under traditional scheduling policies —
/// traditional GTO+LRU as in the paper, plus the registry-only FIFO and
/// Belady-oracle replacement brackets, with `malekeh` as the reference
/// column.
pub fn fig17(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    hit_ratio_sweep_table(
        runner,
        "Fig 17: hit ratio under traditional issue (GTO) + swept replacement policies",
        &benches,
        &replacement_sweep_schemes(),
    )
}

/// Corpus sweep (`malekeh fig corpus`): the six generated corpus kernels
/// ([`crate::trace::corpus`]) against **every** registered policy —
/// Table-II-style RF-hit-ratio grid with a MEAN row. The corpus stresses
/// irregular control flow, pointer chasing and WAW churn, so this is the
/// sweep that shows where compiler-approximated reuse distances (and the
/// related-work prefetch/compression schemes) fall off the GEMM-shaped
/// Table II results. Ignores quick mode: the corpus is always all six.
pub fn fig_corpus(runner: &Runner) -> Table {
    let benches: Vec<&'static str> = crate::trace::corpus().map(|b| b.name).collect();
    hit_ratio_sweep_table(
        runner,
        "Corpus sweep: RF hit ratio, generated-kernel corpus x all registered policies",
        &benches,
        &Scheme::all(),
    )
}

/// Headline table: the abstract's claims vs this reproduction.
pub fn headline(runner: &Runner) -> Table {
    let opts = runner.opts().clone();
    let benches = opts.benchmarks();
    execute_grid(runner, &benches, &[Scheme::BASELINE, Scheme::MALEKEH]);

    let mut t = Table::new(
        "Headline: Malekeh vs baseline (paper: hit 46.4%, energy -28.3%, IPC +6.1%, storage +0.78%)",
        &["metric", "paper", "measured"],
    );
    let mut hits = Vec::new();
    let mut ipc_ratio = Vec::new();
    let mut e_ratio = Vec::new();
    let mut br_red = Vec::new();
    for bench in &benches {
        let base = runner.run(bench, Scheme::BASELINE);
        let m = runner.run(bench, Scheme::MALEKEH);
        hits.push(m.rf_hit_ratio());
        ipc_ratio.push(m.ipc() / base.ipc().max(1e-9));
        br_red.push(m.bank_read_reduction_vs(&base));
        let bm = EnergyModel::for_config(&opts.config(Scheme::BASELINE));
        let mm = EnergyModel::for_config(&opts.config(Scheme::MALEKEH));
        e_ratio.push(mm.total(&m.energy) / bm.total(&base.energy).max(1e-9));
    }
    t.row(vec![
        "RF cache hit ratio".into(),
        "0.464".into(),
        format!("{:.3}", mean(&hits)),
    ]);
    t.row(vec![
        "bank read reduction".into(),
        "0.464".into(),
        format!("{:.3}", mean(&br_red)),
    ]);
    t.row(vec![
        "IPC vs baseline".into(),
        "1.061".into(),
        format!("{:.3}", geomean(&ipc_ratio)),
    ]);
    t.row(vec![
        "RF dynamic energy vs baseline".into(),
        "0.717".into(),
        format!("{:.3}", mean(&e_ratio)),
    ]);
    // storage overhead is architectural, not simulated: 2 extra 128B
    // entries x 2 CCUs x 4 sub-cores = 2KB per SM over a 256KB RF
    let extra_kb = (8.0 - 6.0) * 128.0 * 2.0 * 4.0 / 1024.0;
    t.row(vec![
        "extra storage per SM".into(),
        "2KB (0.78%)".into(),
        format!("{extra_kb:.0}KB ({:.2}%)", extra_kb / 256.0 * 100.0),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOpts {
        ExpOpts {
            num_sms: 1,
            seed: 7,
            profile_warps: 2,
            quick: true,
            jobs: 1,
            sim_threads: 1,
            store_dir: None,
        }
    }

    #[test]
    fn fig01_fractions_sum_to_one() {
        let t = fig01(&tiny_opts());
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn opts_from_args() {
        let o = ExpOpts::from_args(&["--quick".into(), "--sms".into(), "3".into()]);
        assert!(o.quick);
        assert_eq!(o.num_sms, 3);
        let o = ExpOpts::from_args(&["--full".into()]);
        assert_eq!(o.num_sms, 10);
        let o = ExpOpts::from_args(&["--jobs".into(), "6".into()]);
        assert_eq!(o.jobs, 6);
        assert_eq!(o.effective_jobs(), 6);
        let o = ExpOpts::from_args(&["--serial".into()]);
        assert_eq!(o.jobs, 1);
        let o = ExpOpts::from_args(&["--sim-threads".into(), "4".into()]);
        assert_eq!(o.sim_threads, 4);
        assert_eq!(o.config(Scheme::BASELINE).sim_threads, 4);
    }

    #[test]
    fn auto_jobs_share_the_core_budget_with_sim_threads() {
        // jobs = 0 resolves to cores / sim_threads (at least 1): the two
        // parallelism layers must not multiply past the machine
        let wide = ExpOpts { sim_threads: usize::MAX, ..ExpOpts::default() };
        assert_eq!(wide.effective_jobs(), 1);
        let narrow = ExpOpts { sim_threads: 1, ..ExpOpts::default() };
        assert_eq!(narrow.effective_jobs(), ExpOpts::default().effective_jobs());
        // sim_threads = 0 = "one SM worker per core": a whole machine per
        // simulation, so auto jobs must not also fan out
        let auto = ExpOpts { sim_threads: 0, ..ExpOpts::default() };
        assert_eq!(auto.effective_jobs(), 1);
    }

    #[test]
    fn effective_jobs_auto_detects() {
        let o = ExpOpts::default();
        assert_eq!(o.jobs, 0);
        assert!(o.effective_jobs() >= 1);
    }

    #[test]
    fn runner_caches() {
        let r = Runner::new(tiny_opts());
        let a = r.run("nn", Scheme::BASELINE);
        let b = r.run("nn", Scheme::BASELINE);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(r.cached(), 1);
    }
}

// ============================= ablations ====================================

/// CCU cache-table sizes swept by Ablation A.
const ABLATION_CT_SIZES: [usize; 5] = [6, 8, 10, 12, 16];
const ABLATION_CT_BENCHES: [&str; 5] =
    ["kmeans", "gemm_t1", "rnn_i2", "srad_v1", "hotspot"];

/// Ablation A (§III-C): cache-table entries sweep — the paper picks 8 as
/// the knee of the hit-ratio-vs-cost curve ("beyond a given size, it
/// reaches a point of diminishing returns").
pub fn ablation_ct_entries(runner: &Runner) -> Table {
    let mut plan = runner.plan();
    for bench in ABLATION_CT_BENCHES {
        for (k, &n) in ABLATION_CT_SIZES.iter().enumerate() {
            plan.add_cfg(bench, Scheme::MALEKEH, 200 + k as u64, |o| {
                let mut c = o.config(Scheme::MALEKEH);
                c.ct_entries = n;
                c
            });
        }
    }
    runner.execute(&plan);

    let mut header: Vec<String> = vec!["bench".into()];
    header.extend(ABLATION_CT_SIZES.iter().map(|s| format!("CT={s}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Ablation: RF hit ratio vs CCU cache-table entries", &hdr);
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); ABLATION_CT_SIZES.len()];
    for bench in ABLATION_CT_BENCHES {
        let mut vals = Vec::new();
        for (k, &n) in ABLATION_CT_SIZES.iter().enumerate() {
            let s = runner.run_cfg_key(bench, Scheme::MALEKEH, 200 + k as u64, |o| {
                let mut c = o.config(Scheme::MALEKEH);
                c.ct_entries = n;
                c
            });
            vals.push(s.rf_hit_ratio());
            cols[k].push(s.rf_hit_ratio());
        }
        t.row_f(bench, &vals, 3);
    }
    let means: Vec<f64> = cols.iter().map(|c| mean(c)).collect();
    t.row_f("MEAN", &means, 3);
    t
}

/// RTHLD values swept by Ablation B.
const ABLATION_RTHLDS: [u32; 5] = [2, 6, 12, 24, 48];
const ABLATION_RTHLD_BENCHES: [&str; 3] = ["kmeans", "gemm_t1", "srad_v1"];

/// Ablation B (§III-A): RTHLD sweep — the paper found 12 empirically best.
pub fn ablation_rthld(runner: &Runner) -> Table {
    let mut plan = runner.plan();
    for bench in ABLATION_RTHLD_BENCHES {
        plan.add(bench, Scheme::BASELINE);
        for (k, &r) in ABLATION_RTHLDS.iter().enumerate() {
            plan.add_cfg(bench, Scheme::MALEKEH, 300 + k as u64, |o| {
                let mut c = o.config(Scheme::MALEKEH);
                c.rthld = r;
                c
            });
        }
    }
    runner.execute(&plan);

    let mut header: Vec<String> = vec!["bench/metric".into()];
    header.extend(ABLATION_RTHLDS.iter().map(|s| format!("R={s}")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Ablation: hit ratio and IPC vs RTHLD", &hdr);
    for bench in ABLATION_RTHLD_BENCHES {
        let base = runner.run(bench, Scheme::BASELINE).ipc();
        let mut hit = Vec::new();
        let mut ipc = Vec::new();
        for (k, &r) in ABLATION_RTHLDS.iter().enumerate() {
            let s = runner.run_cfg_key(bench, Scheme::MALEKEH, 300 + k as u64, |o| {
                let mut c = o.config(Scheme::MALEKEH);
                c.rthld = r;
                c
            });
            hit.push(s.rf_hit_ratio());
            ipc.push(s.ipc() / base.max(1e-9));
        }
        t.row_f(&format!("{bench} hit"), &hit, 3);
        t.row_f(&format!("{bench} IPC"), &ipc, 3);
    }
    t
}

/// Baseline config with 8 operand collectors (Ablation C's alternative).
fn eight_ocu_cfg(o: &ExpOpts) -> GpuConfig {
    let mut c = o.config(Scheme::BASELINE);
    c.collectors_per_sub_core = 8;
    c
}

/// Ablation C (§I): scaling baseline OCUs 2 -> 8 — the expensive
/// alternative Malekeh avoids (paper: +7.1% IPC for 1.74x area / 2.83x
/// power). Compares baseline-8-OCU IPC against Malekeh-2-CCU.
pub fn ablation_ocu_scaling(runner: &Runner) -> Table {
    let benches = runner.opts().benchmarks();
    let mut plan = runner.plan();
    for bench in &benches {
        plan.add(bench, Scheme::BASELINE);
        plan.add_cfg(bench, Scheme::BASELINE, 400, eight_ocu_cfg);
        plan.add(bench, Scheme::MALEKEH);
    }
    runner.execute(&plan);

    let mut t = Table::new(
        "Ablation: baseline with 8 OCUs vs Malekeh with 2 CCUs (IPC norm)",
        &["bench", "base_8ocu", "malekeh_2ccu"],
    );
    let mut c8 = Vec::new();
    let mut cm = Vec::new();
    for bench in &benches {
        let base2 = runner.run(bench, Scheme::BASELINE).ipc();
        let base8 = runner
            .run_cfg_key(bench, Scheme::BASELINE, 400, eight_ocu_cfg)
            .ipc();
        let mal = runner.run(bench, Scheme::MALEKEH).ipc();
        let v = [base8 / base2.max(1e-9), mal / base2.max(1e-9)];
        c8.push(v[0]);
        cm.push(v[1]);
        t.row_f(bench, &v, 3);
    }
    t.row_f("GEOMEAN", &[geomean(&c8), geomean(&cm)], 3);
    t
}

/// Malekeh with the write filter disabled (Ablation D's comparison point).
fn unfiltered_cfg(o: &ExpOpts) -> GpuConfig {
    let mut c = o.config(Scheme::MALEKEH);
    c.no_write_filter = true;
    c
}

const ABLATION_WRITE_BENCHES: [&str; 4] = ["kmeans", "gemm_t1", "rnn_i2", "conv_t1"];

const ABLATION_REPL_BENCHES: [&str; 5] =
    ["kmeans", "gemm_t1", "rnn_i2", "srad_v1", "hotspot"];

/// Ablation E: replacement policy on identical CCU hardware — every
/// registry policy in the Fig 17 sweep (traditional LRU, FIFO, the Belady
/// oracle) bracketing `malekeh`'s reuse-guided chooser. The scheme set is
/// read from the registry, so a newly registered replacement policy joins
/// the sweep without touching this builder.
pub fn ablation_replacement(runner: &Runner) -> Table {
    hit_ratio_sweep_table(
        runner,
        "Ablation: RF hit ratio vs replacement policy (registry sweep)",
        &ABLATION_REPL_BENCHES,
        &replacement_sweep_schemes(),
    )
}

/// Ablation D (§III-B / §IV-A2): CCU write-back port — filtered single
/// port vs no write path at all vs unfiltered ("we empirically verified
/// that one port provides almost the same benefit as unbounded").
pub fn ablation_write_port(runner: &Runner) -> Table {
    let mut plan = runner.plan();
    for bench in ABLATION_WRITE_BENCHES {
        plan.add(bench, Scheme::MALEKEH);
        plan.add_cfg(bench, Scheme::MALEKEH, 500, unfiltered_cfg);
    }
    runner.execute(&plan);

    let mut t = Table::new(
        "Ablation: write filter / write path (hit ratio; cache-write fraction)",
        &["bench", "filtered_hit", "unfiltered_hit", "filtered_wr", "unfiltered_wr"],
    );
    for bench in ABLATION_WRITE_BENCHES {
        let f = runner.run(bench, Scheme::MALEKEH);
        let u = runner.run_cfg_key(bench, Scheme::MALEKEH, 500, unfiltered_cfg);
        t.row_f(
            bench,
            &[
                f.rf_hit_ratio(),
                u.rf_hit_ratio(),
                f.cache_write_fraction(),
                u.cache_write_fraction(),
            ],
            3,
        );
    }
    t
}
