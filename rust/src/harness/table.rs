//! Plain-text table formatting for figure/bench output (criterion is not
//! available offline; every bench binary prints the paper-figure rows via
//! this module).

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (figure id + caption).
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of preformatted cells.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Append a label + f64 values row with `prec` decimals.
    pub fn row_f(&mut self, label: &str, values: &[f64], prec: usize) {
        let mut cells = vec![label.to_string()];
        for v in values {
            cells.push(format!("{v:.prec$}"));
        }
        self.row(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// No data rows yet?
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    let _ = write!(out, "{:<w$}", c, w = widths[0] + 2);
                } else {
                    let _ = write!(out, "{:>w$}", c, w = widths[i] + 2);
                }
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().map(|w| w + 2).sum();
        let _ = writeln!(out, "{}", "-".repeat(total.min(120)));
        for r in &self.rows {
            line(&mut out, r);
        }
        debug_assert_eq!(ncols, self.header.len());
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
        println!();
    }
}

/// Geometric mean of strictly-positive values (0 if empty).
pub fn geomean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    let s: f64 = vals.iter().map(|v| v.max(1e-12).ln()).sum();
    (s / vals.len() as f64).exp()
}

/// Arithmetic mean (0 if empty).
pub fn mean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Fig X", &["bench", "a", "b"]);
        t.row_f("hotspot", &[1.0, 2.345], 2);
        t.row_f("k", &[10.0, 0.5], 2);
        let s = t.render();
        assert!(s.contains("== Fig X =="));
        assert!(s.contains("hotspot"));
        assert!(s.contains("2.35"));
        // all lines same structure
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn geomean_and_mean() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
