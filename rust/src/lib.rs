//! Malekeh: a lightweight, compiler-assisted register file cache for GPGPU.
//!
//! Full-system reproduction of the paper (Abaie Shoushtary et al., 2023):
//! a cycle-level sub-core GPU simulator with the paper's CCU caching
//! scheme, all comparator schemes (baseline OCU, BOW, RFC, software RFC),
//! the compiler reuse-distance pass (rust + AOT-compiled JAX/Pallas), an
//! AccelWattch-style RF energy model, Table II workload generators, and a
//! bench harness that regenerates every figure of the evaluation.
//!
//! # Layer stack
//!
//! Bottom to top — each layer only calls downward:
//!
//! | Layer | Modules | Role |
//! |---|---|---|
//! | workloads | [`isa`], [`trace`] | instruction streams: Table II generators and `.mtrace` record/replay |
//! | compiler | [`compiler`], [`runtime`] | reuse-distance profiling + near/far annotation (rust engine, or the AOT Pallas artifact via PJRT) |
//! | machine | [`sim`], [`config`] | the cycle-level GPU: sub-cores, collectors/CCUs, RF banks, L1/L2/DRAM, STHLD control; every scheme-varying decision lives in the [`sim::policy`] registry |
//! | measurement | [`stats`], [`energy`] | counters, derived figure metrics, relative RF dynamic energy |
//! | experiments | [`harness`], [`cli`] | memoising sharded Runner, figure/table builders, the `malekeh` CLI |
//!
//! The module map with file-level detail lives in `docs/ARCHITECTURE.md`;
//! every tunable is catalogued in `docs/CONFIG.md`.
//!
//! # Determinism contract
//!
//! Every simulation is a pure function of `(GpuConfig, workload, seed)` —
//! and of **nothing else**. Neither parallelism layer may change results:
//!
//! - `--jobs N` shards independent experiment points across workers
//!   ([`harness::Runner::execute`]); tables are bit-identical at any
//!   worker count.
//! - `--sim-threads N` steps the SMs *inside one simulation* in parallel
//!   (the epoch engine in [`sim::gpu`]); [`stats::Stats::fingerprint`] is
//!   bit-identical at any worker count.
//!
//! Both properties are enforced by `rust/tests/parallel_determinism.rs`
//! and CI fingerprint diffs. Code in the parallel sections must therefore
//! avoid wall-clock reads, thread identity, unordered float reduction,
//! and iteration over unordered containers. Those obligations are also
//! checked *statically*: `malekeh lint` (the [`lint`] module) enforces
//! them as six token-level rules over `rust/src` — see `docs/LINTS.md`
//! for the catalog mapping each contract to the rule that pins it.
pub mod cli;
pub mod compiler;
pub mod config;
pub mod energy;
pub mod harness;
pub mod isa;
pub mod lint;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod util;
