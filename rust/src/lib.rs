//! Malekeh: a lightweight, compiler-assisted register file cache for GPGPU.
//!
//! Full-system reproduction of the paper (Abaie Shoushtary et al., 2023):
//! a cycle-level sub-core GPU simulator with the paper's CCU caching
//! scheme, all comparator schemes (baseline OCU, BOW, RFC, software RFC),
//! the compiler reuse-distance pass (rust + AOT-compiled JAX/Pallas), an
//! AccelWattch-style RF energy model, Table II workload generators, and a
//! bench harness that regenerates every figure of the evaluation.
pub mod cli;
pub mod compiler;
pub mod config;
pub mod energy;
pub mod harness;
pub mod isa;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod trace;
pub mod util;
