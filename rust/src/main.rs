//! `malekeh` — launcher for the Malekeh reproduction.
//!
//! Subcommands:
//!   simulate <bench>   run one benchmark under one scheme, print stats
//!                      (`--trace F` replays a `.mtrace` file instead)
//!   annotate <bench>   run the compiler pass; `--engine pjrt` uses the AOT
//!                      Pallas artifact through the PJRT runtime
//!   trace record       serialise a builtin workload to a `.mtrace` file
//!   trace info         inspect a `.mtrace` file
//!   fig <id>           regenerate a paper figure (1,2,7,9,10,12..17)
//!   headline           the abstract's headline comparison
//!   serve              simulation daemon over TCP (docs/SERVING.md)
//!   submit             submit one simulation to a running daemon
//!   serve-ctl          ping/stats/shutdown a running daemon
//!   store              inspect or garbage-collect a result store
//!   lint               static determinism/hot-path contract check (simlint)
//!   list               list benchmarks and schemes
//!
//! Common options: `--scheme S`, `--sms N`, `--quick`, `--full`,
//! `--jobs N` / `--serial` (experiment shard count),
//! `--store DIR` (persistent content-addressed result store),
//! `-s key=value` (any `config::GpuConfig` key).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use malekeh::cli::Cli;
use malekeh::config::{GpuConfig, Scheme};
use malekeh::energy::EnergyModel;
use malekeh::harness::{self, ExpOpts, Runner};
use malekeh::isa::OpClass;
use malekeh::serve::protocol::{JobSpec, JobState, PROTOCOL_VERSION};
use malekeh::serve::store::DEFAULT_STORE_DIR;
use malekeh::serve::{Client, Server, ServerOpts, Store, StoreKey};
use malekeh::sim::{run_trace, run_workload};
use malekeh::stats::Stats;
use malekeh::trace::{self, io as trace_io, KernelTrace, Transform, Workload, BENCHMARKS};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "simulate" => cmd_simulate(&cli),
        "annotate" => cmd_annotate(&cli),
        "trace" => cmd_trace(&cli),
        "fig" => cmd_fig(&cli),
        "headline" => cmd_headline(&cli),
        "serve" => cmd_serve(&cli),
        "submit" => cmd_submit(&cli),
        "serve-ctl" => cmd_serve_ctl(&cli),
        "store" => cmd_store(&cli),
        "lint" => cmd_lint(&cli),
        "list" => cmd_list(),
        "policies" => cmd_policies(),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try `malekeh help`")),
    }
}

fn print_help() {
    println!(
        "malekeh — compiler-assisted RF cache for GPGPU (paper reproduction)\n\
         \n\
         USAGE: malekeh <command> [args]\n\
         \n\
         COMMANDS:\n\
           simulate <bench> [--scheme S] [--sim-threads N] [--json] [-s k=v]...\n\
                                                       simulate one benchmark\n\
           simulate --trace <file> [--scheme S] [--reannotate]   replay a .mtrace\n\
           annotate <bench> [--engine rust|pjrt]       compiler reuse pass\n\
           trace record <bench> --out <file> [--sms N] [--warps N] [--seed N]\n\
                 [--kernel-id K] [--annotate] [--subsample K] [--window S:L]\n\
                 [--format v1|v2]                      v2 = binary, streamable\n\
           trace info <file>                           inspect a .mtrace file\n\
           trace convert <file> --out <file> [--to v1|v2]   re-encode a trace\n\
           fig <1|2|7|9|10|12|13|14|15|16|17|corpus> [--quick|--full]\n\
                 [--jobs N|--serial]\n\
           headline [--quick|--full] [--jobs N|--serial]   abstract's comparison\n\
           serve [--addr H:P] [--workers N] [--store DIR|--no-store]\n\
                                                       simulation daemon (TCP)\n\
           submit <bench> [--addr H:P] [--scheme S] [--sms N] [--no-wait] [-s k=v]...\n\
           submit --trace <daemon-side file> [--addr H:P]   submit a .mtrace replay\n\
           serve-ctl <ping|stats|shutdown> [--addr H:P]\n\
           store <info|gc --budget BYTES> [--store DIR]\n\
           lint [--path DIR] [--format text|json] [--baseline FILE] [--bless]\n\
                 [--rules]                             simlint (docs/LINTS.md)\n\
           list                                        benchmarks + schemes\n\
           policies                                    the scheme registry, one\n\
                                                       line per policy\n\
         \n\
         Figure simulations shard across worker threads (--jobs N, default\n\
         one per core); --serial forces the single-thread path. A single\n\
         simulation can itself step its SMs in parallel (--sim-threads N,\n\
         default 1; the core budget is shared with --jobs). Output tables\n\
         and stats fingerprints are bit-identical at any thread count.\n\
         --store DIR backs simulate/fig/headline (and the daemon) with a\n\
         persistent content-addressed result store: known points are served\n\
         from disk, fresh ones written back (docs/SERVING.md).\n\
         Recorded traces replay bit-identically to their builtin run\n\
         (docs/TRACES.md; engine details in docs/ARCHITECTURE.md)."
    );
}

fn build_config(cli: &Cli) -> Result<GpuConfig, String> {
    let scheme = Scheme::parse(cli.opt_or("scheme", "baseline"))?;
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = cli.opt_num("sms", 2usize)?;
    cfg.sim_threads = cli.opt_num("sim-threads", cfg.sim_threads)?;
    if let Some(path) = cli.options.get("config") {
        let pairs = malekeh::config::parse_kv_file(path)?;
        cfg.apply(&pairs)?;
    }
    cfg.apply(&cli.overrides)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Simulate `workload`, optionally through a persistent store: verified
/// record → serve it without simulating; miss → simulate and write back.
fn run_stored(
    cfg: &GpuConfig,
    workload: &Workload,
    profile_warps: usize,
    store: Option<&Store>,
) -> Result<Stats, String> {
    let Some(store) = store else {
        return run_workload(cfg, workload, profile_warps);
    };
    let key = StoreKey::for_run(cfg, workload, profile_warps)?;
    if let Some(stats) = store.get(&key) {
        return Ok(stats);
    }
    let stats = run_workload(cfg, workload, profile_warps)?;
    if let Err(e) = store.put(&key, &stats) {
        eprintln!("warning: store write failed: {e}");
    }
    Ok(stats)
}

fn cmd_simulate(cli: &Cli) -> Result<(), String> {
    let cfg = build_config(cli)?;
    let profile_warps = cli.opt_num("profile-warps", 2usize)?;
    let store = match cli.options.get("store") {
        Some(dir) => Some(Store::open(dir).map_err(|e| format!("--store {dir}: {e}"))?),
        None => None,
    };
    let t0 = std::time::Instant::now();
    let (label, stats): (String, Stats) = if let Some(file) = cli.options.get("trace")
    {
        let path = Path::new(file);
        // header probe only: a huge v2 trace must stay on disk here — the
        // replay itself streams through `Workload::load_limited`
        let label = trace_io::TraceStream::open(path)
            .map_err(|e| format!("{}: {e}", path.display()))?
            .header()
            .name
            .clone();
        // `simulate <bench> --trace <file>` is allowed, but the file must
        // actually be a recording of <bench> — otherwise the output would
        // masquerade as a builtin run of the named benchmark
        if let Some(bench) = cli.positional.first() {
            if *bench != label {
                return Err(format!(
                    "--trace {file} records kernel {label:?}, not {bench:?}; \
                     omit the benchmark argument to replay it as-is"
                ));
            }
        }
        // --reannotate discards recorded near/far bits and re-runs the
        // compiler pass under the current config
        if cli.has_flag("reannotate") {
            // re-annotation changes results without changing the trace
            // bytes, so the content-addressed store must stay out of it
            if store.is_some() {
                eprintln!("note: --reannotate bypasses --store");
            }
            let loaded = trace_io::read_path(path)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            (label, run_trace(&cfg, loaded, profile_warps, true))
        } else {
            let workload = Workload::trace_file(path);
            (label, run_stored(&cfg, &workload, profile_warps, store.as_ref())?)
        }
    } else {
        let bench = cli
            .positional
            .first()
            .ok_or("usage: simulate <bench> (or simulate --trace <file>)")?
            .as_str();
        let workload = Workload::builtin(bench);
        (
            bench.to_string(),
            run_stored(&cfg, &workload, profile_warps, store.as_ref())?,
        )
    };
    let dt = t0.elapsed().as_secs_f64();
    if cli.has_flag("json") {
        // one machine-readable object: all counters + the fingerprint
        println!("{}", stats.to_json());
        return Ok(());
    }
    let model = EnergyModel::for_config(&cfg);
    println!("benchmark            {label}");
    println!("scheme               {}", cfg.scheme);
    println!("cycles               {}", stats.cycles);
    println!("instructions         {}", stats.instructions);
    println!("IPC (per SM)         {:.4}", stats.ipc() / cfg.num_sms as f64);
    println!("warps retired        {}", stats.warps_retired);
    println!("RF reads             {}", stats.rf_reads);
    println!("  served by cache    {} ({:.1}%)", stats.rf_cache_reads, stats.rf_hit_ratio() * 100.0);
    println!("  served by banks    {}", stats.rf_bank_reads);
    println!("RF writes            {} (cached {})", stats.rf_writes, stats.rf_cache_writes);
    println!("bank conflict wait   {}", stats.bank_conflict_wait);
    println!("L1D hit ratio        {:.3}", stats.l1_hit_ratio());
    println!("sched issued/s2/s3   {:?}", stats.sched_state_distribution());
    println!("waiting stalls       {}", stats.waiting_stalls);
    println!("CCU flushes          {}", stats.ccu_flushes);
    println!("RF dynamic energy    {:.0} (relative units)", model.total(&stats.energy));
    println!("stats fingerprint    {:016x}", stats.fingerprint());
    println!("sim wall time        {dt:.2}s ({:.2} Minstr/s)", stats.instructions as f64 / dt / 1e6);
    Ok(())
}

// ------------------------------ trace I/O -----------------------------------

fn cmd_trace(cli: &Cli) -> Result<(), String> {
    let sub = cli
        .positional
        .first()
        .ok_or("usage: trace <record|info|convert> ...")?
        .as_str();
    match sub {
        "record" => cmd_trace_record(cli),
        "info" => cmd_trace_info(cli),
        "convert" => cmd_trace_convert(cli),
        other => Err(format!(
            "unknown trace subcommand {other:?} (record|info|convert)"
        )),
    }
}

/// Parse a `--window start:len` spec.
fn parse_window(spec: &str) -> Result<Transform, String> {
    let (a, b) = spec
        .split_once(':')
        .ok_or_else(|| format!("bad --window {spec:?} (want start:len)"))?;
    let start = a.parse().map_err(|_| format!("bad window start {a:?}"))?;
    let len = b.parse().map_err(|_| format!("bad window length {b:?}"))?;
    Ok(Transform::InstructionWindow { start, len })
}

fn cmd_trace_record(cli: &Cli) -> Result<(), String> {
    let bench_name = cli
        .positional
        .get(1)
        .ok_or("usage: trace record <bench> --out <file>")?;
    let out = cli
        .options
        .get("out")
        .ok_or("trace record requires --out <file>")?;
    let bench = trace::find(bench_name)
        .ok_or_else(|| format!("unknown bench {bench_name}"))?;
    // defaults mirror `simulate` (2 SMs x 32 warps, seed 0xC0FFEE), so a
    // raw recording replays bit-identically to the builtin run
    let sms = cli.opt_num("sms", 2usize)?;
    let warps =
        cli.opt_num("warps", sms * GpuConfig::table1_baseline().warps_per_sm)?;
    let seed = cli.opt_num("seed", 0xC0FFEEu64)?;
    let kernel_id = cli.opt_num("kernel-id", 0u32)?;
    if kernel_id > trace::MAX_KERNEL_ID {
        return Err(format!(
            "--kernel-id {kernel_id} exceeds the addressable maximum {}",
            trace::MAX_KERNEL_ID
        ));
    }
    let mut t = KernelTrace::generate_kernel(bench, warps, seed, kernel_id);
    if cli.has_flag("annotate") {
        let rthld = cli.opt_num("rthld", malekeh::compiler::RTHLD)?;
        let pw = cli.opt_num("profile-warps", 2usize)?;
        malekeh::compiler::annotate_trace(&mut t, pw, rthld);
    }
    let mut transforms: Vec<Transform> = Vec::new();
    if let Some(k) = cli.options.get("subsample") {
        let keep_one_in =
            k.parse().map_err(|_| format!("bad --subsample {k:?}"))?;
        transforms.push(Transform::WarpSubsample { keep_one_in });
    }
    if let Some(spec) = cli.options.get("window") {
        transforms.push(parse_window(spec)?);
    }
    let t = trace_io::apply_all(&t, &transforms);
    let fmt = cli.opt_or("format", "v1");
    match fmt {
        "v1" | "1" => trace_io::write_path(Path::new(out.as_str()), &t),
        "v2" | "2" => trace_io::write_v2_path(Path::new(out.as_str()), &t),
        other => return Err(format!("bad --format {other:?} (v1|v2)")),
    }
    .map_err(|e| format!("{out}: {e}"))?;
    println!(
        "recorded `{}` (kernel {}): {} warps, {} instructions -> {} ({})",
        t.name,
        t.kernel_id,
        t.warps.len(),
        t.total_instructions(),
        out,
        if matches!(fmt, "v2" | "2") { "binary v2" } else { "text v1" }
    );
    Ok(())
}

/// `trace convert <in> --out <file> [--to v1|v2]`: re-encode a trace
/// between the textual v1 and binary v2 containers with bit-identical
/// decoded semantics (same IR, same replay fingerprint, same store
/// identity). Without `--to`, converts to the *other* version of the
/// input. Conversion decodes the whole trace in memory — the streaming
/// bound applies to v2 *replay*, not to re-encoding.
fn cmd_trace_convert(cli: &Cli) -> Result<(), String> {
    let file = cli
        .positional
        .get(1)
        .ok_or("usage: trace convert <file> --out <file> [--to v1|v2]")?;
    let out = cli
        .options
        .get("out")
        .ok_or("trace convert requires --out <file>")?;
    let path = Path::new(file.as_str());
    let from = trace_io::sniff_path_version(path).map_err(|e| format!("{file}: {e}"))?;
    let to = match cli.options.get("to").map(String::as_str) {
        Some("v1" | "1") => 1,
        Some("v2" | "2") => trace_io::VERSION2,
        Some(other) => return Err(format!("bad --to {other:?} (v1|v2)")),
        None => {
            if from == trace_io::VERSION2 {
                1
            } else {
                trace_io::VERSION2
            }
        }
    };
    let t = trace_io::read_path(path).map_err(|e| format!("{file}: {e}"))?;
    let out_path = Path::new(out.as_str());
    if to == trace_io::VERSION2 {
        trace_io::write_v2_path(out_path, &t)
    } else {
        trace_io::write_path(out_path, &t)
    }
    .map_err(|e| format!("{out}: {e}"))?;
    println!(
        "converted {file} (v{from}) -> {out} (v{to}): kernel `{}`, {} warps, {} instructions",
        t.name,
        t.warps.len(),
        t.total_instructions()
    );
    Ok(())
}

fn cmd_trace_info(cli: &Cli) -> Result<(), String> {
    let file = cli.positional.get(1).ok_or("usage: trace info <file>")?;
    let version = trace_io::sniff_path_version(Path::new(file.as_str()))
        .map_err(|e| format!("{file}: {e}"))?;
    let t = trace_io::read_path(Path::new(file.as_str()))
        .map_err(|e| format!("{file}: {e}"))?;
    let total = t.total_instructions();
    let (mut operands, mut near) = (0u64, 0u64);
    let mut by_class = [0u64; OpClass::ALL.len()];
    for i in t.warps.iter().flatten() {
        by_class[i.op as usize] += 1;
        operands += i.noperands() as u64;
        near += u64::from(i.src_near.count_ones()) + u64::from(i.dst_near.count_ones());
    }
    let (min_w, max_w) = t
        .warps
        .iter()
        .fold((usize::MAX, 0usize), |(lo, hi), w| (lo.min(w.len()), hi.max(w.len())));
    println!("kernel               {}", t.name);
    println!(
        "format               v{version} ({})",
        if version == trace_io::VERSION2 { "binary, chunked" } else { "text" }
    );
    println!("kernel id            {}", t.kernel_id);
    println!("warps                {}", t.warps.len());
    println!("instructions         {total}");
    if !t.warps.is_empty() {
        println!("per-warp range       {min_w}..={max_w}");
    }
    println!("register operands    {operands}");
    println!(
        "annotated            {} ({})",
        if t.has_annotations() { "yes" } else { "no" },
        if operands == 0 {
            "no operands".to_string()
        } else {
            format!("{:.1}% near", near as f64 / operands as f64 * 100.0)
        }
    );
    print!("opclass mix         ");
    for c in OpClass::ALL {
        let n = by_class[c as usize];
        if n > 0 {
            print!(" {}:{:.1}%", c.tag(), n as f64 / total.max(1) as f64 * 100.0);
        }
    }
    println!();
    Ok(())
}

fn cmd_annotate(cli: &Cli) -> Result<(), String> {
    let bench_name = cli
        .positional
        .first()
        .ok_or("usage: annotate <bench>")?
        .as_str();
    let bench =
        malekeh::trace::find(bench_name).ok_or_else(|| format!("unknown bench {bench_name}"))?;
    let engine = cli.opt_or("engine", "rust");
    let rthld = cli.opt_num("rthld", malekeh::compiler::RTHLD)?;
    let trace = KernelTrace::generate(bench, 8, 0xC0FFEE);
    match engine {
        "rust" => {
            let profile = malekeh::compiler::profile(&trace, 8, rthld);
            let hist = malekeh::compiler::reuse_histogram(&trace);
            let total: u64 = hist.iter().sum();
            println!("benchmark         {bench_name}");
            println!("engine            rust");
            println!("accesses profiled {}", profile.accesses);
            println!("static operands   {}", profile.static_operands());
            println!(
                "reuse histogram   <=1:{:.3} 2:{:.3} 3:{:.3} 4-10:{:.3} >10:{:.3}",
                hist[0] as f64 / total as f64,
                hist[1] as f64 / total as f64,
                hist[2] as f64 / total as f64,
                hist[3] as f64 / total as f64,
                hist[4] as f64 / total as f64
            );
        }
        "pjrt" => {
            let mut rt = malekeh::runtime::Runtime::open_default()
                .map_err(|e| format!("{e:#}"))?;
            let w = rt.manifest.profile_warps;
            let l = rt.manifest.trace_len;
            let (ids, pos, rw) = trace.access_streams(w, l);
            let t0 = std::time::Instant::now();
            let (_dist, near, hist) =
                rt.annotate(&ids, &pos, &rw).map_err(|e| format!("{e:#}"))?;
            let dt = t0.elapsed();
            let near_count = near.iter().filter(|&&n| n == 1).count();
            let valid = near.iter().filter(|&&n| n >= 0).count();
            let total: i32 = hist.iter().sum();
            println!("benchmark         {bench_name}");
            println!("engine            pjrt (AOT Pallas artifact)");
            println!("near fraction     {:.3}", near_count as f64 / valid.max(1) as f64);
            println!(
                "reuse histogram   <=1:{:.3} 2:{:.3} 3:{:.3} 4-10:{:.3} >10:{:.3}",
                hist[0] as f64 / total.max(1) as f64,
                hist[1] as f64 / total.max(1) as f64,
                hist[2] as f64 / total.max(1) as f64,
                hist[3] as f64 / total.max(1) as f64,
                hist[4] as f64 / total.max(1) as f64
            );
            println!("artifact exec     {:.1} ms", dt.as_secs_f64() * 1e3);
        }
        other => return Err(format!("unknown engine {other:?} (rust|pjrt)")),
    }
    Ok(())
}

fn exp_opts(cli: &Cli) -> Result<ExpOpts, String> {
    let mut o = ExpOpts::default();
    if cli.has_flag("quick") {
        o.quick = true;
    }
    if cli.has_flag("full") {
        o.num_sms = 10;
    }
    o.num_sms = cli.opt_num("sms", o.num_sms)?;
    o.seed = cli.opt_num("seed", o.seed)?;
    if cli.has_flag("serial") {
        o.jobs = 1;
    }
    o.jobs = cli.opt_num("jobs", o.jobs)?;
    o.sim_threads = cli.opt_num("sim-threads", o.sim_threads)?;
    o.store_dir = cli.options.get("store").map(PathBuf::from);
    Ok(o)
}

fn cmd_fig(cli: &Cli) -> Result<(), String> {
    let id = cli.positional.first().ok_or("usage: fig <id>")?.as_str();
    let opts = exp_opts(cli)?;
    let runner = Runner::new(opts.clone());
    let table = match id {
        "1" => harness::fig01(&opts),
        "2" => harness::fig02(&runner),
        "7" => harness::fig07(&runner),
        "9" => harness::fig09(&opts),
        "10" => harness::fig10(&runner),
        "12" => harness::fig12(&runner),
        "13" => harness::fig13(&runner),
        "14" => harness::fig14(&runner),
        "15" => harness::fig15(&runner),
        "16" => harness::fig16(&runner),
        "17" => harness::fig17(&runner),
        "corpus" => harness::fig_corpus(&runner),
        other => return Err(format!("no figure {other}; see DESIGN.md §5")),
    };
    table.print();
    Ok(())
}

fn cmd_headline(cli: &Cli) -> Result<(), String> {
    let runner = Runner::new(exp_opts(cli)?);
    harness::headline(&runner).print();
    Ok(())
}

// ------------------------- simulation-as-a-service --------------------------

/// Daemon address shared by `serve` / `submit` / `serve-ctl`.
fn serve_addr(cli: &Cli) -> String {
    cli.opt_or("addr", "127.0.0.1:7757").to_string()
}

fn cmd_serve(cli: &Cli) -> Result<(), String> {
    let store_dir = if cli.has_flag("no-store") {
        None
    } else {
        Some(PathBuf::from(cli.opt_or("store", DEFAULT_STORE_DIR)))
    };
    let server = Server::bind(ServerOpts {
        addr: serve_addr(cli),
        workers: cli.opt_num("workers", 0usize)?,
        store_dir: store_dir.clone(),
    })?;
    eprintln!(
        "malekeh serve: {} on {} (store: {})",
        PROTOCOL_VERSION,
        server.local_addr(),
        store_dir
            .as_deref()
            .map_or("disabled".to_string(), |d| d.display().to_string()),
    );
    server.run()
}

fn cmd_submit(cli: &Cli) -> Result<(), String> {
    let mut spec = if let Some(path) = cli.options.get("trace") {
        // resolved against the DAEMON's working directory, not ours
        JobSpec::trace(path)
    } else {
        let bench = cli.positional.first().ok_or(
            "usage: submit <bench> [--addr H:P] (or submit --trace <daemon-side path>)",
        )?;
        JobSpec::bench(bench)
    };
    spec.scheme = cli.opt_or("scheme", "baseline").to_string();
    spec.sms = cli.opt_num("sms", 2usize)?;
    spec.profile_warps = cli.opt_num("profile-warps", 2usize)?;
    spec.overrides = cli.overrides.clone();
    let mut client = Client::connect(&serve_addr(cli))?;
    let (id, state) = client.submit(&spec)?;
    eprintln!("job {id} {}", state.as_str());
    if cli.has_flag("no-wait") {
        // print the id for scripting; STATUS/RESULT pick it up later
        println!("{id}");
        return Ok(());
    }
    if client.wait(id)? != JobState::Done {
        // RESULT on a failed job carries the reason as the error
        return match client.result_json(id) {
            Err(reason) => Err(reason),
            Ok(_) => Err(format!("job {id} failed")),
        };
    }
    println!("{}", client.result_json(id)?);
    Ok(())
}

fn cmd_serve_ctl(cli: &Cli) -> Result<(), String> {
    let sub = cli
        .positional
        .first()
        .ok_or("usage: serve-ctl <ping|stats|shutdown> [--addr H:P]")?
        .as_str();
    let mut client = Client::connect(&serve_addr(cli))?;
    match sub {
        "ping" => println!("{}", client.ping()?),
        "stats" => println!("{}", client.stats_json()?),
        "shutdown" => {
            client.shutdown()?;
            println!("shutdown acknowledged");
        }
        other => return Err(format!("unknown serve-ctl subcommand {other:?}")),
    }
    Ok(())
}

fn cmd_store(cli: &Cli) -> Result<(), String> {
    let sub = cli
        .positional
        .first()
        .ok_or("usage: store <info|gc> [--store DIR]")?
        .as_str();
    let dir = PathBuf::from(cli.opt_or("store", DEFAULT_STORE_DIR));
    if !dir.is_dir() {
        // inspecting or collecting a store that was never created should
        // not create it as a side effect
        println!("store {} does not exist (0 records)", dir.display());
        return Ok(());
    }
    let store = Store::open(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    match sub {
        "info" => {
            let info = store.info().map_err(|e| format!("{}: {e}", dir.display()))?;
            println!("store     {}", dir.display());
            println!("records   {}", info.records);
            println!("bytes     {}", info.bytes);
        }
        "gc" => {
            let budget: u64 = cli
                .options
                .get("budget")
                .ok_or("store gc requires --budget <bytes>")?
                .parse()
                .map_err(|_| "bad --budget (want a byte count)".to_string())?;
            let report = store.gc(budget).map_err(|e| format!("{}: {e}", dir.display()))?;
            println!(
                "deleted {} record(s), reclaimed {} bytes; {} record(s), {} bytes remain",
                report.deleted, report.reclaimed, report.after.records, report.after.bytes
            );
        }
        other => return Err(format!("unknown store subcommand {other:?} (info|gc)")),
    }
    Ok(())
}

/// `malekeh lint`: run simlint over `rust/src` (or `--path DIR`).
///
/// Without `--baseline`, any unsuppressed finding fails. With
/// `--baseline FILE` the run is compared against the committed
/// suppression budget (exact per-rule allow counts — the ratchet);
/// `--bless` rewrites the baseline from a clean run. `--format json`
/// prints the machine-readable report CI uploads as an artifact.
fn cmd_lint(cli: &Cli) -> Result<(), String> {
    if cli.has_flag("rules") {
        for (name, contract) in malekeh::lint::RULES {
            println!("{name:20} {contract}");
        }
        return Ok(());
    }
    let root = PathBuf::from(cli.opt_or("path", "rust/src"));
    if !root.is_dir() {
        return Err(format!(
            "{}: not a directory (run from the repo root, or pass --path <src dir>)",
            root.display()
        ));
    }
    let report = malekeh::lint::run_tree(&root)?;
    match cli.opt_or("format", "text") {
        "text" => print!("{}", report.to_text()),
        "json" => println!("{}", report.to_json()),
        other => return Err(format!("bad --format {other:?} (text|json)")),
    }
    if let Some(file) = cli.options.get("baseline") {
        if cli.has_flag("bless") {
            if !report.unsuppressed().is_empty() {
                return Err(
                    "refusing to bless a baseline with unsuppressed findings — fix or allow \
                     them first"
                        .to_string(),
                );
            }
            std::fs::write(file, malekeh::lint::baseline::render(&report))
                .map_err(|e| format!("{file}: {e}"))?;
            eprintln!("blessed {file}");
            return Ok(());
        }
        let text = std::fs::read_to_string(file).map_err(|e| format!("{file}: {e}"))?;
        let base = malekeh::lint::baseline::parse(&text)?;
        malekeh::lint::baseline::check(&report, &base)?;
        eprintln!("lint: clean against baseline {file}");
        return Ok(());
    }
    let bad = report.unsuppressed().len();
    if bad > 0 {
        return Err(format!("lint: {bad} unsuppressed finding(s)"));
    }
    Ok(())
}

fn cmd_list() -> Result<(), String> {
    println!("benchmarks (Table II):");
    for b in BENCHMARKS {
        println!("  {:22} {:?}", b.name, b.suite);
    }
    println!("\nschemes (details: `malekeh policies`):");
    for s in Scheme::all() {
        println!("  {}", s.name());
    }
    Ok(())
}

/// One line per registered policy. The output is machine-diffed against
/// the table in docs/CONFIG.md by CI, so an undocumented policy (or a
/// silently changed description) fails the build.
fn cmd_policies() -> Result<(), String> {
    for s in Scheme::all() {
        println!("{}", s.policy_line());
    }
    Ok(())
}
