//! `malekeh` — launcher for the Malekeh reproduction.
//!
//! Subcommands:
//!   simulate <bench>   run one benchmark under one scheme, print stats
//!   annotate <bench>   run the compiler pass; `--engine pjrt` uses the AOT
//!                      Pallas artifact through the PJRT runtime
//!   fig <id>           regenerate a paper figure (1,2,7,9,10,12..17)
//!   headline           the abstract's headline comparison
//!   list               list benchmarks and schemes
//!
//! Common options: `--scheme S`, `--sms N`, `--quick`, `--full`,
//! `--jobs N` / `--serial` (experiment shard count),
//! `-s key=value` (any `config::GpuConfig` key).

use std::process::ExitCode;

use malekeh::cli::Cli;
use malekeh::config::{GpuConfig, Scheme};
use malekeh::energy::EnergyModel;
use malekeh::harness::{self, ExpOpts, Runner};
use malekeh::sim::run_benchmark;
use malekeh::trace::{KernelTrace, BENCHMARKS};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "simulate" => cmd_simulate(&cli),
        "annotate" => cmd_annotate(&cli),
        "fig" => cmd_fig(&cli),
        "headline" => cmd_headline(&cli),
        "list" => cmd_list(),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try `malekeh help`")),
    }
}

fn print_help() {
    println!(
        "malekeh — compiler-assisted RF cache for GPGPU (paper reproduction)\n\
         \n\
         USAGE: malekeh <command> [args]\n\
         \n\
         COMMANDS:\n\
           simulate <bench> [--scheme S] [-s k=v]...   simulate one benchmark\n\
           annotate <bench> [--engine rust|pjrt]       compiler reuse pass\n\
           fig <1|2|7|9|10|12|13|14|15|16|17> [--quick|--full] [--jobs N|--serial]\n\
           headline [--quick|--full] [--jobs N|--serial]   abstract's comparison\n\
           list                                        benchmarks + schemes\n\
         \n\
         Figure simulations shard across worker threads (--jobs N, default\n\
         one per core); --serial forces the single-thread path. Output\n\
         tables are bit-identical at any worker count."
    );
}

fn build_config(cli: &Cli) -> Result<GpuConfig, String> {
    let scheme = Scheme::from_name(cli.opt_or("scheme", "baseline"))
        .ok_or_else(|| "unknown scheme (see `malekeh list`)".to_string())?;
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = cli.opt_num("sms", 2usize)?;
    if let Some(path) = cli.options.get("config") {
        let pairs = malekeh::config::parse_kv_file(path)?;
        cfg.apply(&pairs)?;
    }
    cfg.apply(&cli.overrides)?;
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_simulate(cli: &Cli) -> Result<(), String> {
    let bench = cli
        .positional
        .first()
        .ok_or("usage: simulate <bench>")?
        .as_str();
    let cfg = build_config(cli)?;
    let profile_warps = cli.opt_num("profile-warps", 2usize)?;
    let t0 = std::time::Instant::now();
    let stats = run_benchmark(&cfg, bench, profile_warps);
    let dt = t0.elapsed().as_secs_f64();
    let model = EnergyModel::for_config(&cfg);
    println!("benchmark            {bench}");
    println!("scheme               {}", cfg.scheme);
    println!("cycles               {}", stats.cycles);
    println!("instructions         {}", stats.instructions);
    println!("IPC (per SM)         {:.4}", stats.ipc() / cfg.num_sms as f64);
    println!("warps retired        {}", stats.warps_retired);
    println!("RF reads             {}", stats.rf_reads);
    println!("  served by cache    {} ({:.1}%)", stats.rf_cache_reads, stats.rf_hit_ratio() * 100.0);
    println!("  served by banks    {}", stats.rf_bank_reads);
    println!("RF writes            {} (cached {})", stats.rf_writes, stats.rf_cache_writes);
    println!("bank conflict wait   {}", stats.bank_conflict_wait);
    println!("L1D hit ratio        {:.3}", stats.l1_hit_ratio());
    println!("sched issued/s2/s3   {:?}", stats.sched_state_distribution());
    println!("waiting stalls       {}", stats.waiting_stalls);
    println!("CCU flushes          {}", stats.ccu_flushes);
    println!("RF dynamic energy    {:.0} (relative units)", model.total(&stats.energy));
    println!("sim wall time        {dt:.2}s ({:.2} Minstr/s)", stats.instructions as f64 / dt / 1e6);
    Ok(())
}

fn cmd_annotate(cli: &Cli) -> Result<(), String> {
    let bench_name = cli
        .positional
        .first()
        .ok_or("usage: annotate <bench>")?
        .as_str();
    let bench =
        malekeh::trace::find(bench_name).ok_or_else(|| format!("unknown bench {bench_name}"))?;
    let engine = cli.opt_or("engine", "rust");
    let rthld = cli.opt_num("rthld", malekeh::compiler::RTHLD)?;
    let trace = KernelTrace::generate(bench, 8, 0xC0FFEE);
    match engine {
        "rust" => {
            let profile = malekeh::compiler::profile(&trace, 8, rthld);
            let hist = malekeh::compiler::reuse_histogram(&trace);
            let total: u64 = hist.iter().sum();
            println!("benchmark         {bench_name}");
            println!("engine            rust");
            println!("accesses profiled {}", profile.accesses);
            println!("static operands   {}", profile.static_operands());
            println!(
                "reuse histogram   <=1:{:.3} 2:{:.3} 3:{:.3} 4-10:{:.3} >10:{:.3}",
                hist[0] as f64 / total as f64,
                hist[1] as f64 / total as f64,
                hist[2] as f64 / total as f64,
                hist[3] as f64 / total as f64,
                hist[4] as f64 / total as f64
            );
        }
        "pjrt" => {
            let mut rt = malekeh::runtime::Runtime::open_default()
                .map_err(|e| format!("{e:#}"))?;
            let w = rt.manifest.profile_warps;
            let l = rt.manifest.trace_len;
            let (ids, pos, rw) = trace.access_streams(w, l);
            let t0 = std::time::Instant::now();
            let (_dist, near, hist) =
                rt.annotate(&ids, &pos, &rw).map_err(|e| format!("{e:#}"))?;
            let dt = t0.elapsed();
            let near_count = near.iter().filter(|&&n| n == 1).count();
            let valid = near.iter().filter(|&&n| n >= 0).count();
            let total: i32 = hist.iter().sum();
            println!("benchmark         {bench_name}");
            println!("engine            pjrt (AOT Pallas artifact)");
            println!("near fraction     {:.3}", near_count as f64 / valid.max(1) as f64);
            println!(
                "reuse histogram   <=1:{:.3} 2:{:.3} 3:{:.3} 4-10:{:.3} >10:{:.3}",
                hist[0] as f64 / total.max(1) as f64,
                hist[1] as f64 / total.max(1) as f64,
                hist[2] as f64 / total.max(1) as f64,
                hist[3] as f64 / total.max(1) as f64,
                hist[4] as f64 / total.max(1) as f64
            );
            println!("artifact exec     {:.1} ms", dt.as_secs_f64() * 1e3);
        }
        other => return Err(format!("unknown engine {other:?} (rust|pjrt)")),
    }
    Ok(())
}

fn exp_opts(cli: &Cli) -> Result<ExpOpts, String> {
    let mut o = ExpOpts::default();
    if cli.has_flag("quick") {
        o.quick = true;
    }
    if cli.has_flag("full") {
        o.num_sms = 10;
    }
    o.num_sms = cli.opt_num("sms", o.num_sms)?;
    o.seed = cli.opt_num("seed", o.seed)?;
    if cli.has_flag("serial") {
        o.jobs = 1;
    }
    o.jobs = cli.opt_num("jobs", o.jobs)?;
    Ok(o)
}

fn cmd_fig(cli: &Cli) -> Result<(), String> {
    let id = cli.positional.first().ok_or("usage: fig <id>")?.as_str();
    let opts = exp_opts(cli)?;
    let runner = Runner::new(opts.clone());
    let table = match id {
        "1" => harness::fig01(&opts),
        "2" => harness::fig02(&runner),
        "7" => harness::fig07(&runner),
        "9" => harness::fig09(&opts),
        "10" => harness::fig10(&runner),
        "12" => harness::fig12(&runner),
        "13" => harness::fig13(&runner),
        "14" => harness::fig14(&runner),
        "15" => harness::fig15(&runner),
        "16" => harness::fig16(&runner),
        "17" => harness::fig17(&runner),
        other => return Err(format!("no figure {other}; see DESIGN.md §5")),
    };
    table.print();
    Ok(())
}

fn cmd_headline(cli: &Cli) -> Result<(), String> {
    let runner = Runner::new(exp_opts(cli)?);
    harness::headline(&runner).print();
    Ok(())
}

fn cmd_list() -> Result<(), String> {
    println!("benchmarks (Table II):");
    for b in BENCHMARKS {
        println!("  {:22} {:?}", b.name, b.suite);
    }
    println!("\nschemes:");
    for s in Scheme::ALL {
        println!("  {}", s.name());
    }
    Ok(())
}
