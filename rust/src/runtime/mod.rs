//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python -m compile.aot` and execute them from rust.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! The real bridge lives behind the `pjrt` cargo feature because it needs
//! the `xla` bindings and `anyhow`, which the offline build does not ship.
//! Without the feature an API-compatible stub `Runtime` is compiled whose
//! constructors return an error, so every caller (CLI `annotate --engine
//! pjrt`, `perf_hotpath`, the examples) degrades to its artifacts-missing
//! path instead of failing to build.

pub mod manifest;
pub use manifest::Manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Runtime, RuntimeError};

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$MALEKEH_ARTIFACTS`, else
/// `<crate>/artifacts`, else `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("MALEKEH_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let crate_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if crate_dir.join("manifest.txt").exists() {
        return crate_dir;
    }
    PathBuf::from("artifacts")
}
