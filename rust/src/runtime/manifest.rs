//! Parser for `artifacts/manifest.txt` (written by `python -m compile.aot`).
//!
//! The manifest pins the constants and shapes the artifacts were lowered
//! with, so the rust side can refuse to feed tensors of the wrong shape or
//! run with a mismatched RTHLD/WINDOW.

use std::collections::HashMap;

/// Parsed artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Compiler near/far threshold the artifacts were built with.
    pub rthld: u32,
    /// Forward-scan window (accesses).
    pub window: u32,
    /// No-reuse cap value.
    pub cap: i32,
    /// Rows of the reuse-annotation input.
    pub profile_warps: usize,
    /// Columns of the reuse-annotation input.
    pub trace_len: usize,
    /// Fig-1 histogram buckets.
    pub hist_buckets: usize,
    /// Rows of the energy-model batch.
    pub energy_rows: usize,
    /// Energy event kinds (columns).
    pub energy_events: usize,
    /// Artifact file names present.
    pub artifacts: Vec<String>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut kv: HashMap<&str, &str> = HashMap::new();
        let mut artifacts = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some(eq) = line.find('=') else { continue };
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            if key == "artifact" {
                let name = val.split("::").next().unwrap_or(val).trim();
                artifacts.push(name.to_string());
            } else {
                kv.insert(key, val);
            }
        }
        fn get<T: std::str::FromStr>(
            kv: &HashMap<&str, &str>,
            k: &str,
        ) -> Result<T, String> {
            kv.get(k)
                .ok_or_else(|| format!("manifest missing {k}"))?
                .parse::<T>()
                .map_err(|_| format!("manifest bad value for {k}"))
        }
        Ok(Manifest {
            rthld: get(&kv, "rthld")?,
            window: get(&kv, "window")?,
            cap: get(&kv, "cap")?,
            profile_warps: get(&kv, "profile_warps")?,
            trace_len: get(&kv, "trace_len")?,
            hist_buckets: get(&kv, "hist_buckets")?,
            energy_rows: get(&kv, "energy_rows")?,
            energy_events: get(&kv, "energy_events")?,
            artifacts,
        })
    }

    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &std::path::Path) -> Result<Manifest, String> {
        let p = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&p)
            .map_err(|e| format!("cannot read {}: {e}", p.display()))?;
        Self::parse(&text)
    }

    /// Check compatibility with the rust-side constants; returns the first
    /// mismatch description.
    pub fn check_compat(&self) -> Result<(), String> {
        if self.window as usize != crate::compiler::WINDOW {
            return Err(format!(
                "artifact window {} != rust WINDOW {} — rebuild artifacts",
                self.window,
                crate::compiler::WINDOW
            ));
        }
        if self.cap != crate::compiler::CAP {
            return Err(format!("artifact cap {} != rust CAP", self.cap));
        }
        if self.energy_events != crate::energy::NEVENTS {
            return Err(format!(
                "artifact energy_events {} != rust NEVENTS {}",
                self.energy_events,
                crate::energy::NEVENTS
            ));
        }
        if self.hist_buckets != crate::compiler::HIST_BUCKETS {
            return Err("hist bucket count mismatch".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
rthld=12
window=96
cap=255
profile_warps=8
trace_len=2048
hist_buckets=5
energy_rows=32
energy_events=8
artifact=reuse_annotate.hlo.txt :: ids:i32[8,2048] ...
artifact=rf_energy.hlo.txt :: counts:f32[32,8] ...
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.rthld, 12);
        assert_eq!(m.window, 96);
        assert_eq!(m.trace_len, 2048);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0], "reuse_annotate.hlo.txt");
        assert!(m.check_compat().is_ok());
    }

    #[test]
    fn missing_key_is_error() {
        assert!(Manifest::parse("rthld=12\n").is_err());
    }

    #[test]
    fn compat_detects_window_mismatch() {
        let m = Manifest::parse(&SAMPLE.replace("window=96", "window=48")).unwrap();
        assert!(m.check_compat().is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // when `make artifacts` has run, the real manifest must be compatible
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            m.check_compat().unwrap();
            assert!(m.artifacts.iter().any(|a| a.contains("reuse_annotate")));
        }
    }
}
