//! Offline stand-in for the PJRT bridge (built without the `pjrt` feature).
//!
//! Mirrors the real `Runtime` API exactly — same constructors, fields, and
//! method signatures — but every constructor returns [`RuntimeError`], so
//! callers take their "artifacts not built" fallback path at runtime while
//! still compiling without the `xla`/`anyhow` dependencies.

use std::fmt;
use std::path::Path;

use super::Manifest;

/// Error returned by every stub entry point.
#[derive(Debug, Clone)]
pub struct RuntimeError(String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

fn unavailable() -> RuntimeError {
    RuntimeError(
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (the offline image ships no `xla` bindings). Rebuild with \
         `--features pjrt` after adding the xla/anyhow dependencies to \
         Cargo.toml."
            .to_string(),
    )
}

/// API-compatible stub of the PJRT `Runtime`; never constructible.
pub struct Runtime {
    /// Parsed manifest (shapes/constants the artifacts were built with).
    pub manifest: Manifest,
}

impl Runtime {
    /// Always fails: the PJRT bridge is compiled out.
    pub fn new(_dir: &Path) -> Result<Runtime, RuntimeError> {
        Err(unavailable())
    }

    /// Always fails: the PJRT bridge is compiled out.
    pub fn open_default() -> Result<Runtime, RuntimeError> {
        Err(unavailable())
    }

    /// Unreachable (no `Runtime` value can exist); present for API parity.
    pub fn annotate(
        &mut self,
        _ids: &[i32],
        _pos: &[i32],
        _rw: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i32>), RuntimeError> {
        Err(unavailable())
    }

    /// Unreachable (no `Runtime` value can exist); present for API parity.
    pub fn rf_energy(
        &mut self,
        _counts: &[f32],
        _costs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>), RuntimeError> {
        Err(unavailable())
    }

    /// Unreachable (no `Runtime` value can exist); present for API parity.
    pub fn gemm(
        &mut self,
        _x: &[f32],
        _y: &[f32],
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> Result<Vec<f32>, RuntimeError> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructors_fail_with_explanation() {
        let err = Runtime::open_default().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"));
        assert!(Runtime::new(Path::new("/nonexistent")).is_err());
        // the alternate Display used by `format!("{e:#}")` in main.rs works
        let msg = format!("{err:#}");
        assert!(!msg.is_empty());
    }
}
