//! The real PJRT bridge (`pjrt` feature): compile + execute the AOT
//! HLO-text artifacts on the CPU PJRT client.
//!
//! Executables are compiled once per process and cached in [`Runtime`].

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::{default_artifacts_dir, Manifest};
use crate::energy::NEVENTS;

/// A compiled artifact + its client.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Parsed manifest (shapes/constants the artifacts were built with).
    pub manifest: Manifest,
    dir: PathBuf,
    reuse: Option<xla::PjRtLoadedExecutable>,
    energy: Option<xla::PjRtLoadedExecutable>,
    gemm: Option<xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over `dir` (compiles lazily per artifact).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir).map_err(|e| anyhow!(e))?;
        manifest.check_compat().map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            reuse: None,
            energy: None,
            gemm: None,
        })
    }

    /// Open the default artifacts directory.
    pub fn open_default() -> Result<Runtime> {
        Self::new(&default_artifacts_dir())
    }

    fn compile(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }

    /// Reuse-distance annotation through the `reuse_annotate` artifact
    /// (the L1 Pallas kernel + L2 binarisation/histogram).
    ///
    /// `ids`, `pos`, `rw`: row-major `[profile_warps, trace_len]` (see the
    /// manifest for the exact shape). Returns `(dist, near, hist)`.
    pub fn annotate(
        &mut self,
        ids: &[i32],
        pos: &[i32],
        rw: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<i32>)> {
        let w = self.manifest.profile_warps as i64;
        let l = self.manifest.trace_len as i64;
        let n = (w * l) as usize;
        if ids.len() != n || pos.len() != n || rw.len() != n {
            bail!(
                "annotate expects {}x{} = {} elements, got {}/{}/{}",
                w,
                l,
                n,
                ids.len(),
                pos.len(),
                rw.len()
            );
        }
        if self.reuse.is_none() {
            self.reuse = Some(self.compile("reuse_annotate.hlo.txt")?);
        }
        let exe = self.reuse.as_ref().unwrap();
        let lit_ids = xla::Literal::vec1(ids).reshape(&[w, l])?;
        let lit_pos = xla::Literal::vec1(pos).reshape(&[w, l])?;
        let lit_rw = xla::Literal::vec1(rw).reshape(&[w, l])?;
        let result = exe.execute::<xla::Literal>(&[lit_ids, lit_pos, lit_rw])?[0][0]
            .to_literal_sync()?;
        let (dist, near, hist) = result.to_tuple3()?;
        Ok((
            dist.to_vec::<i32>()?,
            near.to_vec::<i32>()?,
            hist.to_vec::<i32>()?,
        ))
    }

    /// RF dynamic-energy evaluation through the `rf_energy` artifact.
    /// `counts`: row-major `[energy_rows, NEVENTS]`; `costs`: `[NEVENTS]`.
    /// Returns `(energy, normalized_to_row0)`.
    pub fn rf_energy(&mut self, counts: &[f32], costs: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let b = self.manifest.energy_rows as i64;
        let e = self.manifest.energy_events as i64;
        if counts.len() != (b * e) as usize {
            bail!("rf_energy expects {}x{} counts, got {}", b, e, counts.len());
        }
        if costs.len() != NEVENTS {
            bail!("rf_energy expects {NEVENTS} costs, got {}", costs.len());
        }
        if self.energy.is_none() {
            self.energy = Some(self.compile("rf_energy.hlo.txt")?);
        }
        let exe = self.energy.as_ref().unwrap();
        let lit_counts = xla::Literal::vec1(counts).reshape(&[b, e])?;
        let lit_costs = xla::Literal::vec1(costs);
        let result = exe.execute::<xla::Literal>(&[lit_counts, lit_costs])?[0][0]
            .to_literal_sync()?;
        let (energy, norm) = result.to_tuple2()?;
        Ok((energy.to_vec::<f32>()?, norm.to_vec::<f32>()?))
    }

    /// Tensor-core workload GEMM through the `mma_gemm` artifact
    /// (fixed `M,K` x `K,N` from the manifest constants, f32).
    pub fn gemm(&mut self, x: &[f32], y: &[f32], m: usize, k: usize, n: usize) -> Result<Vec<f32>> {
        if x.len() != m * k || y.len() != k * n {
            bail!("gemm shape mismatch");
        }
        if self.gemm.is_none() {
            self.gemm = Some(self.compile("mma_gemm.hlo.txt")?);
        }
        let exe = self.gemm.as_ref().unwrap();
        let lx = xla::Literal::vec1(x).reshape(&[m as i64, k as i64])?;
        let ly = xla::Literal::vec1(y).reshape(&[k as i64, n as i64])?;
        let result = exe.execute::<xla::Literal>(&[lx, ly])?[0][0].to_literal_sync()?;
        let c = result.to_tuple1()?;
        Ok(c.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("artifacts not built; skipping runtime test");
            return None;
        }
        Some(Runtime::new(&dir).expect("runtime"))
    }

    #[test]
    fn annotate_artifact_matches_rust_engine() {
        let Some(mut rt) = runtime() else { return };
        let w = rt.manifest.profile_warps;
        let l = rt.manifest.trace_len;
        // real workload access streams
        let bench = crate::trace::find("rnn_i2").unwrap();
        let trace = crate::trace::KernelTrace::generate(bench, w, 123);
        let (ids, pos, rw) = trace.access_streams(w, l);
        let (dist, near, hist) = rt.annotate(&ids, &pos, &rw).expect("annotate");
        // parity with the rust engine, row by row
        for row in 0..w {
            let s = row * l;
            let want = crate::compiler::windowed_reuse_distances(
                &ids[s..s + l],
                &pos[s..s + l],
                &rw[s..s + l],
                crate::compiler::WINDOW,
                crate::compiler::CAP,
            );
            assert_eq!(&dist[s..s + l], &want[..], "row {row} dist parity");
        }
        // near bits consistent with distances
        for (d, nb) in dist.iter().zip(near.iter()) {
            match *d {
                -1 => assert_eq!(*nb, -1),
                x if x >= 0 && x <= rt.manifest.rthld as i32 => assert_eq!(*nb, 1),
                _ => assert_eq!(*nb, 0),
            }
        }
        // histogram counts live accesses only
        let live = dist.iter().filter(|&&d| d >= 0).count() as i32;
        assert_eq!(hist.iter().sum::<i32>(), live);
    }

    #[test]
    fn energy_artifact_matches_rust_model() {
        let Some(mut rt) = runtime() else { return };
        let b = rt.manifest.energy_rows;
        let e = rt.manifest.energy_events;
        let mut counts = vec![0f32; b * e];
        for (i, c) in counts.iter_mut().enumerate() {
            *c = ((i * 37) % 1000) as f32;
        }
        let costs: Vec<f32> = (0..e).map(|i| 0.1 + i as f32 * 0.05).collect();
        let (energy, norm) = rt.rf_energy(&counts, &costs).expect("rf_energy");
        assert_eq!(energy.len(), b);
        for row in 0..b {
            let want: f32 = (0..e).map(|j| counts[row * e + j] * costs[j]).sum();
            assert!(
                (energy[row] - want).abs() <= want.abs() * 1e-5 + 1e-3,
                "row {row}: {} vs {want}",
                energy[row]
            );
        }
        assert!((norm[0] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn gemm_artifact_correct() {
        let Some(mut rt) = runtime() else { return };
        let (m, k, n) = (256, 256, 256);
        // x = identity-ish pattern for an exact check
        let mut x = vec![0f32; m * k];
        for i in 0..m {
            x[i * k + i] = 2.0;
        }
        let y: Vec<f32> = (0..k * n).map(|i| (i % 17) as f32).collect();
        let c = rt.gemm(&x, &y, m, k, n).expect("gemm");
        for i in (0..m * n).step_by(9973) {
            assert!((c[i] - 2.0 * y[i]).abs() < 1e-4, "at {i}");
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.annotate(&[1, 2], &[0, 0], &[1, 1]).is_err());
        assert!(rt.rf_energy(&[1.0], &[1.0; NEVENTS]).is_err());
    }
}
