//! Decoded instruction model — the simulator's "SASS".
//!
//! Mirrors what the paper's mechanism can observe in a Turing trace: opcode
//! class, up to 6 source and 2 destination registers (tensor-core HMMA
//! shapes, §III-C), the compiler's binary reuse-distance bit per operand
//! (§III-A), and a line-granular memory address for LD/ST.
//!
//! Kept at 32 bytes so whole warp streams stay cache-resident in the
//! simulator hot loop.

/// Maximum source operands per instruction (tensor-core HMMA bound, §II).
pub const MAX_SRC: usize = 6;
/// Maximum destination operands per instruction.
pub const MAX_DST: usize = 2;
/// Architectural registers addressable per thread (CUDA bound, §III-C: tag
/// is one byte).
pub const NUM_REGS: usize = 256;

/// Functional class of an instruction; selects the execution pipe and
/// latency (see [`crate::config::EuTiming`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum OpClass {
    /// Integer / FP32 ALU op (FFMA, IADD, ...): short pipe.
    Alu = 0,
    /// Special-function op (MUFU: rsqrt, sin, ...): long pipe, low rate.
    Sfu,
    /// Global load through L1/L2/DRAM.
    LdGlobal,
    /// Global store (fire-and-forget past L1).
    StGlobal,
    /// Shared-memory load (fixed latency, no cache).
    LdShared,
    /// Tensor-core HMMA: up to 6 sources, 2 destinations.
    Mma,
    /// Control (BRA, BAR, ...): no operands collected from the RF banks.
    Ctrl,
    /// Kernel exit marker for a warp.
    Exit,
}

impl OpClass {
    /// Does this class read memory (needs LSU + memory subsystem)?
    #[inline]
    pub fn is_load(self) -> bool {
        matches!(self, OpClass::LdGlobal | OpClass::LdShared)
    }

    /// Any memory-pipe instruction (loads and stores).
    #[inline]
    pub fn is_mem(self) -> bool {
        matches!(
            self,
            OpClass::LdGlobal | OpClass::StGlobal | OpClass::LdShared
        )
    }

    /// Short human tag used by reports and the `.mtrace` format.
    pub fn tag(self) -> &'static str {
        match self {
            OpClass::Alu => "ALU",
            OpClass::Sfu => "SFU",
            OpClass::LdGlobal => "LDG",
            OpClass::StGlobal => "STG",
            OpClass::LdShared => "LDS",
            OpClass::Mma => "MMA",
            OpClass::Ctrl => "CTRL",
            OpClass::Exit => "EXIT",
        }
    }

    /// All classes, in `repr` order.
    pub const ALL: [OpClass; 8] = [
        OpClass::Alu,
        OpClass::Sfu,
        OpClass::LdGlobal,
        OpClass::StGlobal,
        OpClass::LdShared,
        OpClass::Mma,
        OpClass::Ctrl,
        OpClass::Exit,
    ];

    /// Inverse of [`OpClass::tag`] (the `.mtrace` parse direction).
    pub fn from_tag(tag: &str) -> Option<OpClass> {
        OpClass::ALL.into_iter().find(|c| c.tag() == tag)
    }
}

/// One decoded warp instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instruction {
    /// Functional class.
    pub op: OpClass,
    /// Source register ids (first `nsrc` valid).
    pub srcs: [u8; MAX_SRC],
    /// Destination register ids (first `ndst` valid).
    pub dsts: [u8; MAX_DST],
    /// Number of valid sources.
    pub nsrc: u8,
    /// Number of valid destinations.
    pub ndst: u8,
    /// Compiler near/far bit per source (bit i set = near reuse). §III-A.
    pub src_near: u8,
    /// Compiler near/far bit per destination.
    pub dst_near: u8,
    /// 128B-line-granular address for memory ops (0 otherwise).
    pub line_addr: u32,
}

impl Instruction {
    /// Build an instruction; panics if operand counts exceed the ISA bounds.
    pub fn new(op: OpClass, srcs: &[u8], dsts: &[u8]) -> Self {
        assert!(srcs.len() <= MAX_SRC, "too many sources: {}", srcs.len());
        assert!(dsts.len() <= MAX_DST, "too many destinations: {}", dsts.len());
        let mut s = [0u8; MAX_SRC];
        let mut d = [0u8; MAX_DST];
        s[..srcs.len()].copy_from_slice(srcs);
        d[..dsts.len()].copy_from_slice(dsts);
        Instruction {
            op,
            srcs: s,
            dsts: d,
            nsrc: srcs.len() as u8,
            ndst: dsts.len() as u8,
            src_near: 0,
            dst_near: 0,
            line_addr: 0,
        }
    }

    /// Memory variant with a line address.
    pub fn mem(op: OpClass, srcs: &[u8], dsts: &[u8], line_addr: u32) -> Self {
        debug_assert!(op.is_mem());
        let mut i = Self::new(op, srcs, dsts);
        i.line_addr = line_addr;
        i
    }

    /// Valid source slice.
    #[inline]
    pub fn sources(&self) -> &[u8] {
        &self.srcs[..self.nsrc as usize]
    }

    /// Valid destination slice.
    #[inline]
    pub fn dests(&self) -> &[u8] {
        &self.dsts[..self.ndst as usize]
    }

    /// Is source operand `i` marked near-reuse by the compiler?
    #[inline]
    pub fn src_is_near(&self, i: usize) -> bool {
        self.src_near & (1 << i) != 0
    }

    /// Is destination operand `i` marked near-reuse by the compiler?
    #[inline]
    pub fn dst_is_near(&self, i: usize) -> bool {
        self.dst_near & (1 << i) != 0
    }

    /// Set the near bit of source operand `i`.
    #[inline]
    pub fn set_src_near(&mut self, i: usize, near: bool) {
        if near {
            self.src_near |= 1 << i;
        } else {
            self.src_near &= !(1 << i);
        }
    }

    /// Set the near bit of destination operand `i`.
    #[inline]
    pub fn set_dst_near(&mut self, i: usize, near: bool) {
        if near {
            self.dst_near |= 1 << i;
        } else {
            self.dst_near &= !(1 << i);
        }
    }

    /// Total register operands (sources + destinations).
    #[inline]
    pub fn noperands(&self) -> usize {
        (self.nsrc + self.ndst) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instruction_is_compact() {
        // hot-loop footprint guard: whole warp streams should stay in cache
        assert!(std::mem::size_of::<Instruction>() <= 32);
    }

    #[test]
    fn new_records_operands() {
        let i = Instruction::new(OpClass::Mma, &[2, 3, 4, 5, 10, 11], &[2, 3]);
        assert_eq!(i.sources(), &[2, 3, 4, 5, 10, 11]);
        assert_eq!(i.dests(), &[2, 3]);
        assert_eq!(i.noperands(), 8);
    }

    #[test]
    #[should_panic(expected = "too many sources")]
    fn too_many_sources_panics() {
        Instruction::new(OpClass::Alu, &[1, 2, 3, 4, 5, 6, 7], &[]);
    }

    #[test]
    fn near_bits_roundtrip() {
        let mut i = Instruction::new(OpClass::Alu, &[1, 2], &[3]);
        assert!(!i.src_is_near(0));
        i.set_src_near(0, true);
        i.set_src_near(1, false);
        i.set_dst_near(0, true);
        assert!(i.src_is_near(0));
        assert!(!i.src_is_near(1));
        assert!(i.dst_is_near(0));
        i.set_src_near(0, false);
        assert!(!i.src_is_near(0));
    }

    #[test]
    fn tags_roundtrip_through_from_tag() {
        for c in OpClass::ALL {
            assert_eq!(OpClass::from_tag(c.tag()), Some(c));
        }
        assert_eq!(OpClass::from_tag("NOPE"), None);
        assert_eq!(OpClass::from_tag("alu"), None, "tags are case-sensitive");
    }

    #[test]
    fn op_class_predicates() {
        assert!(OpClass::LdGlobal.is_load());
        assert!(OpClass::LdShared.is_load());
        assert!(!OpClass::StGlobal.is_load());
        assert!(OpClass::StGlobal.is_mem());
        assert!(!OpClass::Mma.is_mem());
        assert_eq!(OpClass::Mma.tag(), "MMA");
    }

    #[test]
    fn mem_sets_address() {
        let i = Instruction::mem(OpClass::LdGlobal, &[1], &[2], 0xBEEF);
        assert_eq!(i.line_addr, 0xBEEF);
    }
}
