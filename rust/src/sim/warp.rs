//! Per-warp architectural state: program cursor + register scoreboard.

use crate::isa::{Instruction, NUM_REGS};

/// 256-bit register bitset (one bit per architectural register).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RegSet {
    bits: [u64; NUM_REGS / 64],
}

impl RegSet {
    /// Empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert register `r`.
    #[inline]
    pub fn set(&mut self, r: u8) {
        self.bits[(r >> 6) as usize] |= 1u64 << (r & 63);
    }

    /// Remove register `r`.
    #[inline]
    pub fn clear(&mut self, r: u8) {
        self.bits[(r >> 6) as usize] &= !(1u64 << (r & 63));
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, r: u8) -> bool {
        self.bits[(r >> 6) as usize] & (1u64 << (r & 63)) != 0
    }

    /// True if no bits set.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&b| b == 0)
    }

    /// Number of registers in the set.
    pub fn len(&self) -> usize {
        self.bits.iter().map(|b| b.count_ones() as usize).sum()
    }
}

/// One warp's execution state inside a sub-core.
#[derive(Debug, Clone)]
pub struct WarpState {
    /// Index into the kernel trace's warp list (global).
    pub global_id: u32,
    /// Program counter into the instruction stream.
    pub pc: usize,
    /// Registers with a pending (in-flight) write — the scoreboard.
    pub pending: RegSet,
    /// Subset of `pending` produced by loads (long-latency scoreboard; the
    /// two-level scheduler deactivates only on these, §VI-A).
    pub pending_long: RegSet,
    /// Reached the Exit marker.
    pub done: bool,
    /// Cycle of the last issued instruction (GTO greediness).
    pub last_issue: u64,
    /// Two-level scheduler: warp currently in the active set.
    pub active: bool,
    /// Cycle the warp last became active (activation/swap delay).
    pub active_since: u64,
    /// Software-RFC strand progress (instructions since activation).
    pub strand_pos: u32,
}

impl WarpState {
    /// Fresh warp at pc 0.
    pub fn new(global_id: u32) -> Self {
        WarpState {
            global_id,
            pc: 0,
            pending: RegSet::new(),
            pending_long: RegSet::new(),
            done: false,
            last_issue: 0,
            active: false,
            active_since: 0,
            strand_pos: 0,
        }
    }

    /// The warp's next instruction, if any.
    #[inline]
    pub fn next_instr<'a>(&self, stream: &'a [Instruction]) -> Option<&'a Instruction> {
        if self.done {
            None
        } else {
            stream.get(self.pc)
        }
    }

    /// Scoreboard check: can `instr` issue now? (RAW on sources, WAW on
    /// destinations.)
    #[inline]
    pub fn deps_ready(&self, instr: &Instruction) -> bool {
        for &s in instr.sources() {
            if self.pending.contains(s) {
                return false;
            }
        }
        for &d in instr.dests() {
            if self.pending.contains(d) {
                return false;
            }
        }
        true
    }

    /// Mark destinations in flight; long-latency producers (loads, SFU,
    /// tensor core) also enter the long-latency set the two-level
    /// scheduler watches.
    #[inline]
    pub fn mark_pending(&mut self, instr: &Instruction) {
        let long = instr.op.is_load()
            || matches!(instr.op, crate::isa::OpClass::Sfu | crate::isa::OpClass::Mma);
        for &d in instr.dests() {
            self.pending.set(d);
            if long {
                self.pending_long.set(d);
            }
        }
    }

    /// Clear destinations after writeback.
    #[inline]
    pub fn clear_pending(&mut self, dsts: &[u8]) {
        for &d in dsts {
            self.pending.clear(d);
            self.pending_long.clear(d);
        }
    }

    /// Is `instr` blocked specifically on an outstanding load (the
    /// long-latency condition two-level schedulers deactivate on)?
    #[inline]
    pub fn blocked_on_load(&self, instr: &Instruction) -> bool {
        instr
            .sources()
            .iter()
            .chain(instr.dests().iter())
            .any(|&r| self.pending_long.contains(r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, OpClass};

    #[test]
    fn regset_basics() {
        let mut s = RegSet::new();
        assert!(s.is_empty());
        s.set(0);
        s.set(63);
        s.set(64);
        s.set(255);
        assert_eq!(s.len(), 4);
        assert!(s.contains(63) && s.contains(64) && s.contains(255));
        assert!(!s.contains(1));
        s.clear(63);
        assert!(!s.contains(63));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn scoreboard_raw_waw() {
        let mut w = WarpState::new(0);
        let producer = Instruction::new(OpClass::Alu, &[1], &[5]);
        let raw = Instruction::new(OpClass::Alu, &[5], &[6]);
        let waw = Instruction::new(OpClass::Alu, &[2], &[5]);
        let indep = Instruction::new(OpClass::Alu, &[2], &[7]);
        assert!(w.deps_ready(&producer));
        w.mark_pending(&producer);
        assert!(!w.deps_ready(&raw), "RAW must block");
        assert!(!w.deps_ready(&waw), "WAW must block");
        assert!(w.deps_ready(&indep));
        w.clear_pending(&[5]);
        assert!(w.deps_ready(&raw));
    }

    #[test]
    fn next_instr_respects_done() {
        let stream = vec![Instruction::new(OpClass::Alu, &[1], &[2])];
        let mut w = WarpState::new(3);
        assert!(w.next_instr(&stream).is_some());
        w.done = true;
        assert!(w.next_instr(&stream).is_none());
        w.done = false;
        w.pc = 1;
        assert!(w.next_instr(&stream).is_none());
    }
}
