//! Top level: SMs, the shared memory system, the dynamic-STHLD controller,
//! and the run loop.

use std::sync::Arc;

use crate::config::{GpuConfig, SthldMode};
use crate::isa::Instruction;
use crate::sim::memory::{L1Cache, SharedMemorySystem};
use crate::sim::sthld::SthldController;
use crate::sim::subcore::SubCore;
use crate::stats::Stats;
use crate::trace::{KernelTrace, Workload};

/// One streaming multiprocessor: sub-cores + private L1D.
pub struct Sm {
    /// Sub-cores (4 on Turing).
    pub sub_cores: Vec<SubCore>,
    /// Per-SM L1 data cache.
    pub l1: L1Cache,
}

/// Default safety cap when `max_cycles == 0` (run to completion).
pub const DEFAULT_MAX_CYCLES: u64 = 20_000_000;

/// The whole-GPU simulator.
pub struct Simulator {
    cfg: GpuConfig,
    sms: Vec<Sm>,
    shared: SharedMemorySystem,
    sthld_ctl: Option<SthldController>,
    cycle: u64,
    interval_start_instr: u64,
    interval_ipc: Vec<f64>,
    sthld_trace: Vec<u32>,
}

impl Simulator {
    /// Build a simulator and distribute `trace` warps over the SMs /
    /// sub-cores (warp i of an SM goes to sub-core `i % sub_cores`, as in
    /// Turing). If the trace has fewer warps than the GPU has slots, the
    /// extra slots stay empty; extra warps are dropped.
    pub fn new(cfg: &GpuConfig, trace: &KernelTrace) -> Self {
        cfg.validate().expect("invalid config");
        let wps = cfg.warps_per_sm;
        let nsc = cfg.sub_cores_per_sm;
        let streams: Vec<Arc<Vec<Instruction>>> =
            trace.warps.iter().cloned().map(Arc::new).collect();
        let mut sms = Vec::with_capacity(cfg.num_sms);
        for s in 0..cfg.num_sms {
            let mut per_sc: Vec<Vec<Arc<Vec<Instruction>>>> = vec![Vec::new(); nsc];
            for i in 0..wps {
                let g = s * wps + i;
                if let Some(st) = streams.get(g) {
                    per_sc[i % nsc].push(st.clone());
                }
            }
            let sub_cores = per_sc
                .into_iter()
                .enumerate()
                .map(|(i, sts)| {
                    SubCore::new(cfg, sts, cfg.seed ^ ((s * nsc + i) as u64) << 8)
                })
                .collect();
            sms.push(Sm {
                sub_cores,
                l1: L1Cache::new(
                    cfg.l1_bytes,
                    cfg.line_bytes,
                    cfg.l1_ways,
                    cfg.l1_latency,
                    cfg.l1_mshrs,
                ),
            });
        }
        let sthld_ctl = match cfg.sthld {
            SthldMode::Dynamic => {
                Some(SthldController::new(cfg.sthld_max, cfg.sthld_epsilon))
            }
            SthldMode::Static(_) => None,
        };
        Simulator {
            cfg: cfg.clone(),
            sms,
            shared: SharedMemorySystem::new(
                cfg.l2_bytes,
                cfg.line_bytes,
                cfg.l2_ways,
                cfg.l2_latency,
                cfg.dram_latency,
                // memory channels scale with SM count (Table I scaling)
                cfg.dram_reqs_per_cycle * cfg.num_sms as f64,
            ),
            sthld_ctl,
            cycle: 0,
            interval_start_instr: 0,
            interval_ipc: Vec::new(),
            sthld_trace: Vec::new(),
        }
    }

    /// Everything drained?
    pub fn idle(&self) -> bool {
        self.sms
            .iter()
            .all(|sm| sm.sub_cores.iter().all(|sc| sc.idle()))
    }

    /// Total instructions committed so far.
    fn total_instructions(&self) -> u64 {
        self.sms
            .iter()
            .map(|sm| {
                sm.sub_cores
                    .iter()
                    .map(|sc| sc.stats.instructions)
                    .sum::<u64>()
            })
            .sum()
    }

    /// Current STHLD (from the dynamic controller or the static config).
    pub fn current_sthld(&self) -> u32 {
        match (&self.sthld_ctl, self.cfg.sthld) {
            (Some(c), _) => c.sthld(),
            (None, SthldMode::Static(v)) => v,
            (None, SthldMode::Dynamic) => 0,
        }
    }

    /// Advance one cycle (plus an event-driven fast-forward over stretches
    /// where every sub-core is stalled empty and only in-flight EU/memory
    /// events can change state — see EXPERIMENTS.md §Perf).
    pub fn step(&mut self) {
        let now = self.cycle;
        for sm in &mut self.sms {
            for sc in &mut sm.sub_cores {
                sc.step(now, &mut sm.l1, &mut self.shared);
            }
        }
        self.cycle += 1;
        // fast-forward: all sub-cores quiescent until the next event
        let mut wake = u64::MAX;
        let mut quiet = true;
        'probe: for sm in &self.sms {
            for sc in &sm.sub_cores {
                match sc.next_wakeup() {
                    None => {
                        quiet = false;
                        break 'probe;
                    }
                    Some(c) => wake = wake.min(c),
                }
            }
        }
        if quiet && wake != u64::MAX && wake > self.cycle {
            // stop at the dynamic-STHLD interval boundary
            let boundary =
                (self.cycle / self.cfg.sthld_interval + 1) * self.cfg.sthld_interval;
            let target = wake.min(boundary);
            let skip = target.saturating_sub(self.cycle);
            if skip > 0 {
                for sm in &mut self.sms {
                    for sc in &mut sm.sub_cores {
                        sc.bulk_stall(skip);
                    }
                }
                self.cycle += skip;
            }
        }
        // dynamic-STHLD interval boundary
        if self.cycle % self.cfg.sthld_interval == 0 {
            let instr = self.total_instructions();
            let ipc = (instr - self.interval_start_instr) as f64
                / self.cfg.sthld_interval as f64;
            self.interval_start_instr = instr;
            self.interval_ipc.push(ipc);
            let sthld = if let Some(ctl) = &mut self.sthld_ctl {
                ctl.interval_end(ipc)
            } else {
                self.current_sthld()
            };
            self.sthld_trace.push(sthld);
            for sm in &mut self.sms {
                for sc in &mut sm.sub_cores {
                    sc.sthld = sthld;
                }
            }
        }
    }

    /// Run until every warp retires (or the cycle cap). Returns merged
    /// statistics.
    pub fn run(&mut self) -> Stats {
        let cap = if self.cfg.max_cycles == 0 {
            DEFAULT_MAX_CYCLES
        } else {
            self.cfg.max_cycles
        };
        while self.cycle < cap && !self.idle() {
            self.step();
        }
        self.collect_stats()
    }

    /// Merge all counters into one `Stats`.
    pub fn collect_stats(&self) -> Stats {
        let mut total = Stats::new();
        total.cycles = self.cycle;
        for sm in &self.sms {
            for sc in &sm.sub_cores {
                total.merge(&sc.stats);
            }
        }
        // L1/L2 counters live in the cache models
        total.l1_accesses = self.sms.iter().map(|sm| sm.l1.accesses).sum();
        total.l1_hits = self.sms.iter().map(|sm| sm.l1.hits).sum();
        total.l2_accesses = self.shared.accesses;
        total.l2_hits = self.shared.hits;
        total.interval_ipc = self.interval_ipc.clone();
        total.sthld_trace = self.sthld_trace.clone();
        // per-SM IPC convention: instructions summed over the GPU but the
        // figures normalise to baseline, so raw totals are fine
        total
    }
}

/// Annotate (when needed) + simulate an already-materialised trace.
///
/// The compiler pass runs when `force_annotate` is set or the trace
/// carries no near/far bits (a raw recording); a trace recorded
/// post-annotation keeps its bits verbatim. `profile_warps == 0` selects
/// the precise oracle pass.
pub fn run_trace(
    cfg: &GpuConfig,
    mut trace: KernelTrace,
    profile_warps: usize,
    force_annotate: bool,
) -> Stats {
    if force_annotate || !trace.has_annotations() {
        crate::compiler::annotate_trace(&mut trace, profile_warps, cfg.rthld);
    }
    Simulator::new(cfg, &trace).run()
}

/// Load + annotate + simulate one [`Workload`] under `cfg`. Builtin
/// workloads are always annotated fresh; `.mtrace`-file workloads keep
/// any recorded annotation bits (and get the same compiler pass as the
/// builtin path when the file carries none — which is what makes a raw
/// recording replay bit-identically to its generator run).
pub fn run_workload(
    cfg: &GpuConfig,
    workload: &Workload,
    profile_warps: usize,
) -> Result<Stats, String> {
    let nwarps = cfg.num_sms * cfg.warps_per_sm;
    let trace = workload.load(nwarps, cfg.seed)?;
    if trace.warps.len() > nwarps {
        // the simulator drops warps beyond the GPU's slots — loud, because
        // a truncated replay can never match the recording's source run
        eprintln!(
            "warning: {} carries {} warps but the config has {nwarps} slots; \
             extra warps are dropped (raise --sms or subsample the trace)",
            workload.cache_name(),
            trace.warps.len()
        );
    }
    let force = matches!(workload, Workload::Builtin(_));
    Ok(run_trace(cfg, trace, profile_warps, force))
}

/// Convenience: generate + annotate + simulate one benchmark under `cfg`.
/// `profile_warps` = 0 uses the precise oracle annotation.
pub fn run_benchmark(cfg: &GpuConfig, bench_name: &str, profile_warps: usize) -> Stats {
    run_workload(cfg, &Workload::builtin(bench_name), profile_warps)
        .unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    fn small_cfg(scheme: Scheme) -> GpuConfig {
        let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
        c.num_sms = 1;
        c
    }

    #[test]
    fn baseline_full_sm_completes() {
        let stats = run_benchmark(&small_cfg(Scheme::Baseline), "backprop", 2);
        assert_eq!(stats.warps_retired, 32);
        assert!(stats.ipc() > 0.1, "ipc {}", stats.ipc());
        assert!(stats.l1_accesses > 0);
    }

    #[test]
    fn malekeh_reduces_bank_reads_vs_baseline() {
        let base = run_benchmark(&small_cfg(Scheme::Baseline), "kmeans", 2);
        let mal = run_benchmark(&small_cfg(Scheme::Malekeh), "kmeans", 2);
        assert!(mal.rf_hit_ratio() > 0.1, "hit ratio {}", mal.rf_hit_ratio());
        assert!(
            mal.rf_bank_reads < base.rf_bank_reads,
            "malekeh {} vs baseline {}",
            mal.rf_bank_reads,
            base.rf_bank_reads
        );
        // identical workload => identical read demand
        assert_eq!(mal.rf_reads, base.rf_reads);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_benchmark(&small_cfg(Scheme::Malekeh), "hotspot", 2);
        let b = run_benchmark(&small_cfg(Scheme::Malekeh), "hotspot", 2);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
        assert_eq!(a.rf_cache_reads, b.rf_cache_reads);
    }

    #[test]
    fn dynamic_sthld_records_intervals() {
        let mut cfg = small_cfg(Scheme::Malekeh);
        cfg.sthld_interval = 2000; // force several intervals
        let stats = run_benchmark(&cfg, "srad_v1", 2);
        assert!(stats.interval_ipc.len() > 2);
        assert_eq!(stats.interval_ipc.len(), stats.sthld_trace.len());
    }

    #[test]
    fn monolithic_config_runs() {
        let mut cfg = GpuConfig::monolithic().with_scheme(Scheme::Rfc);
        cfg.num_sms = 1;
        let stats = run_benchmark(&cfg, "hotspot", 2);
        assert_eq!(stats.warps_retired, 32);
    }

    #[test]
    fn trace_smaller_than_gpu_is_ok() {
        let cfg = small_cfg(Scheme::Baseline);
        let bench = crate::trace::find("nn").unwrap();
        let trace = KernelTrace::generate(bench, 8, 1); // 8 warps, 32 slots
        let stats = Simulator::new(&cfg, &trace).run();
        assert_eq!(stats.warps_retired, 8);
    }
}
