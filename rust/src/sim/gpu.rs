//! Top level: SMs, the shared memory system, the dynamic-STHLD controller,
//! and the epoch-based run loop.
//!
//! # Epoch scheduler (deterministic intra-run SM parallelism)
//!
//! SMs are independent except for two things: the shared L2/DRAM system
//! and the GPU-wide dynamic-STHLD controller. The run loop exploits
//! exactly that decoupling. Instead of stepping every SM in lock-step each
//! cycle, each SM **advances independently** up to its next
//! *synchronization boundary* — the earlier of
//!
//! 1. the dynamic-STHLD interval boundary (`sthld_interval`, where the
//!    controller samples GPU-wide IPC and broadcasts a new threshold), and
//! 2. its first **L2-bound event**: an L1 miss that needs the shared L2,
//!    which is queued on the SM's [`MemPort`] instead of being served
//!    immediately.
//!
//! When every SM has reached a boundary, a **serial L2 phase** services
//! the merged request queues in the fixed `(cycle, sm_id, seq)` order and
//! posts the fill latencies back; blocked SMs then resume. Because each
//! SM's trajectory between boundaries is a pure function of its own state,
//! and the serial phase's order is a pure function of the request set, the
//! whole simulation is **bit-identical at any `sim_threads` worker
//! count** — `--sim-threads 1` and `--sim-threads N` produce the same
//! [`Stats::fingerprint`] (enforced by `rust/tests/parallel_determinism.rs`
//! and a CI diff). The parallel driver fans the per-SM phases out over a
//! persistent `std::thread::scope` worker pool.
//!
//! Drained SMs stop stepping; their stall-empty tail up to the global end
//! cycle is accounted in bulk at the end of the run, matching what
//! lock-step stepping would have recorded. See `docs/ARCHITECTURE.md` for
//! the full walk-through and `docs/EXPERIMENTS.md` §Perf for measured
//! scaling.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

use crate::config::{GpuConfig, SthldMode};
use crate::isa::Instruction;
use crate::sim::memory::{L1Cache, L2Request, L2Response, MemPort, SharedMemorySystem};
use crate::sim::sthld::SthldController;
use crate::sim::subcore::SubCore;
use crate::stats::Stats;
use crate::trace::{KernelTrace, Workload};

/// One streaming multiprocessor: sub-cores + private L1D + its epoch
/// frontier (SMs advance independently between synchronization
/// boundaries).
pub struct Sm {
    /// Sub-cores (4 on Turing).
    pub sub_cores: Vec<SubCore>,
    /// Per-SM L1 data cache.
    pub l1: L1Cache,
    /// Local cycle frontier.
    cycle: u64,
    /// Epoch-local queue of L2-bound requests.
    port: MemPort,
    /// Cycle at which this SM fully drained (`None` while live).
    drained_at: Option<u64>,
}

impl Sm {
    /// Everything in this SM drained?
    pub fn idle(&self) -> bool {
        self.sub_cores.iter().all(|sc| sc.idle())
    }

    /// Instructions committed by this SM so far.
    fn committed_instructions(&self) -> u64 {
        self.sub_cores.iter().map(|sc| sc.stats.instructions).sum()
    }

    /// Advance to `target`, stopping early at this SM's next
    /// synchronization boundary: the first cycle that queues an L2-bound
    /// request, or the drain point. Pure in this SM's state — the property
    /// the parallel driver's determinism rests on.
    fn advance(&mut self, target: u64) {
        while self.cycle < target {
            if self.idle() {
                if self.drained_at.is_none() {
                    self.drained_at = Some(self.cycle);
                }
                return;
            }
            let now = self.cycle;
            for sc in &mut self.sub_cores {
                sc.step(now, &mut self.l1, &mut self.port);
            }
            self.cycle += 1;
            if !self.port.is_empty() {
                return; // L2-bound: wait for the serial service phase
            }
            // event-driven fast-forward over stretches where every
            // sub-core is quiescent — stalled empty, or stalled ready
            // without consulting its policy — and only in-flight
            // EU/memory events or a policy time gate can change state
            // (see docs/EXPERIMENTS.md §Perf). `now` is the cycle just
            // stepped: a gate boundary at `now + 1` must veto the skip,
            // so the probe's horizon is anchored before the increment.
            let mut wake = u64::MAX;
            let mut quiet = true;
            for sc in &self.sub_cores {
                match sc.next_wakeup(now) {
                    None => {
                        quiet = false;
                        break;
                    }
                    Some(c) => wake = wake.min(c),
                }
            }
            if quiet && wake != u64::MAX && wake > self.cycle {
                let skip = wake.min(target).saturating_sub(self.cycle);
                if skip > 0 {
                    for sc in &mut self.sub_cores {
                        sc.bulk_stall(skip);
                    }
                    self.cycle += skip;
                }
            }
        }
    }

    /// Cycle at which this SM drained (meaningful once idle; falls back to
    /// the frontier for an SM that drained exactly at an epoch target).
    fn drained_cycle(&self) -> u64 {
        self.drained_at.unwrap_or(self.cycle)
    }

    /// Account the stall-empty tail between this SM's drain cycle and the
    /// global end of the run — a lock-step engine keeps stepping drained
    /// SMs until the slowest one finishes, and the counters must match.
    fn finish_at(&mut self, end: u64) {
        let from = self.drained_cycle();
        if self.idle() && end > from {
            for sc in &mut self.sub_cores {
                sc.bulk_stall(end - from);
            }
        }
        self.cycle = self.cycle.max(end);
    }

    /// Broadcast a new STHLD from the GPU-level controller.
    fn set_sthld(&mut self, v: u32) {
        for sc in &mut self.sub_cores {
            sc.sthld = v;
        }
    }
}

/// Default safety cap when `max_cycles == 0` (run to completion).
pub const DEFAULT_MAX_CYCLES: u64 = 20_000_000;

/// Shared coordination state for the persistent epoch worker pool.
struct WorkerCtl {
    /// Two waits per epoch: phase start (after `target` is published) and
    /// phase end (before the main thread's serial L2 phase).
    barrier: Barrier,
    /// Epoch target cycle, published before the start barrier.
    target: AtomicU64,
    /// Run finished: workers exit at the next start barrier.
    done: AtomicBool,
}

/// The whole-GPU simulator.
pub struct Simulator {
    cfg: GpuConfig,
    sms: Vec<Sm>,
    shared: SharedMemorySystem,
    sthld_ctl: Option<SthldController>,
    cycle: u64,
    interval_start_instr: u64,
    interval_ipc: Vec<f64>,
    sthld_trace: Vec<u32>,
}

impl Simulator {
    /// Build a simulator and distribute `trace` warps over the SMs /
    /// sub-cores (warp i of an SM goes to sub-core `i % sub_cores`, as in
    /// Turing). If the trace has fewer warps than the GPU has slots, the
    /// extra slots stay empty; extra warps are dropped.
    pub fn new(cfg: &GpuConfig, trace: &KernelTrace) -> Self {
        cfg.validate().expect("invalid config");
        let wps = cfg.warps_per_sm;
        let nsc = cfg.sub_cores_per_sm;
        let streams: Vec<Arc<Vec<Instruction>>> =
            trace.warps.iter().cloned().map(Arc::new).collect();
        let mut sms = Vec::with_capacity(cfg.num_sms);
        for s in 0..cfg.num_sms {
            let mut per_sc: Vec<Vec<Arc<Vec<Instruction>>>> = vec![Vec::new(); nsc];
            for i in 0..wps {
                let g = s * wps + i;
                if let Some(st) = streams.get(g) {
                    per_sc[i % nsc].push(st.clone());
                }
            }
            let sub_cores = per_sc
                .into_iter()
                .enumerate()
                .map(|(i, sts)| {
                    SubCore::new(cfg, sts, cfg.seed ^ ((s * nsc + i) as u64) << 8)
                })
                .collect();
            sms.push(Sm {
                sub_cores,
                l1: L1Cache::new(
                    cfg.l1_bytes,
                    cfg.line_bytes,
                    cfg.l1_ways,
                    cfg.l1_latency,
                    cfg.l1_mshrs,
                ),
                cycle: 0,
                port: MemPort::new(s as u32),
                drained_at: None,
            });
        }
        let sthld_ctl = match cfg.sthld {
            SthldMode::Dynamic => {
                Some(SthldController::new(cfg.sthld_max, cfg.sthld_epsilon))
            }
            SthldMode::Static(_) => None,
        };
        Simulator {
            cfg: cfg.clone(),
            sms,
            shared: SharedMemorySystem::new(
                cfg.l2_bytes,
                cfg.line_bytes,
                cfg.l2_ways,
                cfg.l2_latency,
                cfg.dram_latency,
                // memory channels scale with SM count (Table I scaling)
                cfg.dram_reqs_per_cycle * cfg.num_sms as f64,
            ),
            sthld_ctl,
            cycle: 0,
            interval_start_instr: 0,
            interval_ipc: Vec::new(),
            sthld_trace: Vec::new(),
        }
    }

    /// Everything drained?
    pub fn idle(&self) -> bool {
        self.sms.iter().all(|sm| sm.idle())
    }

    /// Current STHLD (from the dynamic controller or the static config).
    pub fn current_sthld(&self) -> u32 {
        match (&self.sthld_ctl, self.cfg.sthld) {
            (Some(c), _) => c.sthld(),
            (None, SthldMode::Static(v)) => v,
            (None, SthldMode::Dynamic) => 0,
        }
    }

    /// Worker threads stepping SMs inside this run: `sim_threads` (0 =
    /// one per available core), clamped to `[1, num_sms]`.
    fn effective_sim_threads(&self) -> usize {
        let t = if self.cfg.sim_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.cfg.sim_threads
        };
        t.clamp(1, self.cfg.num_sms)
    }

    /// Run until every warp retires (or the cycle cap). Returns merged
    /// statistics — bit-identical at any `sim_threads` value.
    pub fn run(&mut self) -> Stats {
        let cap = if self.cfg.max_cycles == 0 {
            DEFAULT_MAX_CYCLES
        } else {
            self.cfg.max_cycles
        };
        let threads = self.effective_sim_threads();
        let sms: Vec<Mutex<Sm>> = std::mem::take(&mut self.sms)
            .into_iter()
            .map(Mutex::new)
            .collect();
        let end = if threads <= 1 {
            self.epoch_loop(&sms, cap, |target| {
                for sm in &sms {
                    sm.lock().unwrap().advance(target);
                }
            })
        } else {
            let ctl = WorkerCtl {
                barrier: Barrier::new(threads + 1),
                target: AtomicU64::new(0),
                done: AtomicBool::new(false),
            };
            let mut end = 0;
            std::thread::scope(|scope| {
                for w in 0..threads {
                    let ctl = &ctl;
                    let sms = &sms;
                    scope.spawn(move || loop {
                        ctl.barrier.wait();
                        if ctl.done.load(Ordering::SeqCst) {
                            break;
                        }
                        let target = ctl.target.load(Ordering::SeqCst);
                        // static round-robin SM assignment; which worker
                        // runs an SM cannot affect its (pure) trajectory
                        for i in (w..sms.len()).step_by(threads) {
                            sms[i].lock().unwrap().advance(target);
                        }
                        ctl.barrier.wait();
                    });
                }
                end = self.epoch_loop(&sms, cap, |target| {
                    ctl.target.store(target, Ordering::SeqCst);
                    ctl.barrier.wait(); // release workers into the epoch
                    ctl.barrier.wait(); // all SMs at a boundary
                });
                ctl.done.store(true, Ordering::SeqCst);
                ctl.barrier.wait(); // release workers to exit
            });
            end
        };
        self.sms = sms.into_iter().map(|m| m.into_inner().unwrap()).collect();
        self.cycle = end;
        for sm in &mut self.sms {
            sm.finish_at(end);
        }
        self.collect_stats()
    }

    /// Drive the epoch scheduler to completion. `advance_all` must bring
    /// every SM to `target`, its next L2-bound event, or its drain point
    /// (serially or via the worker pool — the results are identical).
    /// Returns the global end cycle.
    fn epoch_loop(&mut self, sms: &[Mutex<Sm>], cap: u64, advance_all: impl FnMut(u64)) -> u64 {
        let mut advance_all = advance_all;
        let interval = self.cfg.sthld_interval.max(1);
        let mut target = interval.min(cap);
        // request/response buffers live for the whole run: the serial L2
        // phase stops allocating once their capacity has warmed up
        let mut reqs: Vec<L2Request> = Vec::new();
        let mut resps: Vec<L2Response> = Vec::new();
        loop {
            advance_all(target);
            // ---- serial L2 phase ----
            reqs.clear();
            for sm in sms {
                sm.lock().unwrap().port.drain_into(&mut reqs);
            }
            if !reqs.is_empty() {
                resps.clear();
                self.shared.service_into(&mut reqs, &mut resps);
                for r in &resps {
                    sms[r.sm_id as usize]
                        .lock()
                        .unwrap()
                        .l1
                        .resolve_fill(r.line, r.cycle, r.extra);
                }
                continue; // blocked SMs resume toward `target`
            }
            // no L2 traffic pending: every SM is at `target` or drained
            if sms.iter().all(|sm| sm.lock().unwrap().idle()) {
                let end = sms
                    .iter()
                    .map(|sm| sm.lock().unwrap().drained_cycle())
                    .max()
                    .unwrap_or(0);
                if end == target && target % interval == 0 {
                    // the slowest SM drained exactly on the boundary: a
                    // lock-step run would still have sampled this interval
                    self.interval_end(sms);
                }
                return end;
            }
            if target % interval == 0 {
                self.interval_end(sms);
            }
            if target >= cap {
                return cap;
            }
            target = ((target / interval + 1) * interval).min(cap);
        }
    }

    /// Dynamic-STHLD interval boundary: sample GPU-wide IPC, step the
    /// controller, broadcast the new threshold.
    fn interval_end(&mut self, sms: &[Mutex<Sm>]) {
        let instr: u64 = sms.iter().map(|sm| sm.lock().unwrap().committed_instructions()).sum();
        let ipc = (instr - self.interval_start_instr) as f64
            / self.cfg.sthld_interval.max(1) as f64;
        self.interval_start_instr = instr;
        self.interval_ipc.push(ipc);
        let sthld = if let Some(ctl) = &mut self.sthld_ctl {
            ctl.interval_end(ipc)
        } else {
            self.current_sthld()
        };
        self.sthld_trace.push(sthld);
        for sm in sms {
            sm.lock().unwrap().set_sthld(sthld);
        }
    }

    /// Merge all counters into one `Stats`.
    pub fn collect_stats(&self) -> Stats {
        let mut total = Stats::new();
        total.cycles = self.cycle;
        for sm in &self.sms {
            for sc in &sm.sub_cores {
                total.merge(&sc.stats);
            }
        }
        // L1/L2 counters live in the cache models
        total.l1_accesses = self.sms.iter().map(|sm| sm.l1.accesses).sum();
        total.l1_hits = self.sms.iter().map(|sm| sm.l1.hits).sum();
        total.l2_accesses = self.shared.accesses;
        total.l2_hits = self.shared.hits;
        // interval traces are GPU-wide series sampled by the controller at
        // interval boundaries — this is their single owner; `Stats::merge`
        // asserts per-SM inputs never carry any (see stats::Stats::merge)
        total.interval_ipc = self.interval_ipc.clone();
        total.sthld_trace = self.sthld_trace.clone();
        // per-SM IPC convention: instructions summed over the GPU but the
        // figures normalise to baseline, so raw totals are fine
        total
    }
}

/// Annotate (when needed) + simulate an already-materialised trace.
///
/// The compiler pass runs when `force_annotate` is set or the trace
/// carries no near/far bits (a raw recording); a trace recorded
/// post-annotation keeps its bits verbatim. `profile_warps == 0` selects
/// the precise oracle pass.
pub fn run_trace(
    cfg: &GpuConfig,
    mut trace: KernelTrace,
    profile_warps: usize,
    force_annotate: bool,
) -> Stats {
    if force_annotate || !trace.has_annotations() {
        crate::compiler::annotate_trace(&mut trace, profile_warps, cfg.rthld);
    }
    Simulator::new(cfg, &trace).run()
}

/// Load + annotate + simulate one [`Workload`] under `cfg`. Builtin
/// workloads are always annotated fresh; `.mtrace`-file workloads keep
/// any recorded annotation bits (and get the same compiler pass as the
/// builtin path when the file carries none — which is what makes a raw
/// recording replay bit-identically to its generator run).
///
/// Trace files load through [`Workload::load_limited`]: only the warps
/// the config can schedule are materialised, so replaying a huge v2
/// recording on a small config streams in bounded memory instead of
/// cloning every warp only to drop it at slot assignment. The annotation
/// decision still keys off the **whole file** (`LimitedLoad::annotated`),
/// so truncation never changes whether the compiler pass runs — the
/// retained warps simulate bit-identically to the unlimited path.
pub fn run_workload(
    cfg: &GpuConfig,
    workload: &Workload,
    profile_warps: usize,
) -> Result<Stats, String> {
    let nwarps = cfg.num_sms * cfg.warps_per_sm;
    let loaded = workload.load_limited(nwarps, cfg.seed)?;
    if loaded.total_warps > nwarps {
        // the simulator drops warps beyond the GPU's slots — loud, because
        // a truncated replay can never match the recording's source run
        eprintln!(
            "warning: {} carries {} warps but the config has {nwarps} slots; \
             extra warps are dropped (raise --sms or subsample the trace)",
            workload.cache_name(),
            loaded.total_warps
        );
    }
    let mut trace = loaded.trace;
    if matches!(workload, Workload::Builtin(_)) || !loaded.annotated {
        crate::compiler::annotate_trace(&mut trace, profile_warps, cfg.rthld);
    }
    Ok(Simulator::new(cfg, &trace).run())
}

/// Convenience: generate + annotate + simulate one benchmark under `cfg`.
/// `profile_warps` = 0 uses the precise oracle annotation.
pub fn run_benchmark(cfg: &GpuConfig, bench_name: &str, profile_warps: usize) -> Stats {
    run_workload(cfg, &Workload::builtin(bench_name), profile_warps)
        .unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    fn small_cfg(scheme: Scheme) -> GpuConfig {
        let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
        c.num_sms = 1;
        c
    }

    #[test]
    fn baseline_full_sm_completes() {
        let stats = run_benchmark(&small_cfg(Scheme::BASELINE), "backprop", 2);
        assert_eq!(stats.warps_retired, 32);
        assert!(stats.ipc() > 0.1, "ipc {}", stats.ipc());
        assert!(stats.l1_accesses > 0);
    }

    #[test]
    fn malekeh_reduces_bank_reads_vs_baseline() {
        let base = run_benchmark(&small_cfg(Scheme::BASELINE), "kmeans", 2);
        let mal = run_benchmark(&small_cfg(Scheme::MALEKEH), "kmeans", 2);
        assert!(mal.rf_hit_ratio() > 0.1, "hit ratio {}", mal.rf_hit_ratio());
        assert!(
            mal.rf_bank_reads < base.rf_bank_reads,
            "malekeh {} vs baseline {}",
            mal.rf_bank_reads,
            base.rf_bank_reads
        );
        // identical workload => identical read demand
        assert_eq!(mal.rf_reads, base.rf_reads);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_benchmark(&small_cfg(Scheme::MALEKEH), "hotspot", 2);
        let b = run_benchmark(&small_cfg(Scheme::MALEKEH), "hotspot", 2);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.instructions, b.instructions);
        assert_eq!(a.rf_cache_reads, b.rf_cache_reads);
    }

    #[test]
    fn dynamic_sthld_records_intervals() {
        let mut cfg = small_cfg(Scheme::MALEKEH);
        cfg.sthld_interval = 2000; // force several intervals
        let stats = run_benchmark(&cfg, "srad_v1", 2);
        assert!(stats.interval_ipc.len() > 2);
        assert_eq!(stats.interval_ipc.len(), stats.sthld_trace.len());
    }

    #[test]
    fn interval_traces_cover_every_sm() {
        // regression for the old `Stats::merge` trace handling (it claimed
        // to concatenate but kept only the first non-empty trace): the
        // GPU-level controller owns the interval series and samples
        // GPU-wide IPC, so over a run capped at an interval boundary the
        // trace must account for every SM's instructions exactly.
        let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
        cfg.num_sms = 2;
        cfg.sthld_interval = 500;
        cfg.max_cycles = 3_000; // boundary-aligned cap: every interval sampled
        let stats = run_benchmark(&cfg, "kmeans", 2);
        assert_eq!(
            stats.cycles, 3_000,
            "run must still be busy at the cap for the accounting identity"
        );
        assert_eq!(stats.interval_ipc.len(), 6);
        assert_eq!(stats.sthld_trace.len(), 6);
        let traced: f64 = stats.interval_ipc.iter().sum::<f64>() * 500.0;
        let total = stats.instructions as f64;
        assert!(
            (traced - total).abs() < 1e-6 * total.max(1.0),
            "interval trace dropped instructions: traced {traced}, committed {total}"
        );
        // both SMs actually contributed (a 1-SM run of the same workload
        // commits strictly fewer instructions in the same window)
        let mut one = cfg.clone();
        one.num_sms = 1;
        let s1 = run_benchmark(&one, "kmeans", 2);
        assert!(
            stats.instructions > s1.instructions,
            "2-SM run must out-commit 1 SM ({} vs {})",
            stats.instructions,
            s1.instructions
        );
    }

    #[test]
    fn monolithic_config_runs() {
        let mut cfg = GpuConfig::monolithic().with_scheme(Scheme::RFC);
        cfg.num_sms = 1;
        let stats = run_benchmark(&cfg, "hotspot", 2);
        assert_eq!(stats.warps_retired, 32);
    }

    #[test]
    fn trace_smaller_than_gpu_is_ok() {
        let cfg = small_cfg(Scheme::BASELINE);
        let bench = crate::trace::find("nn").unwrap();
        let trace = KernelTrace::generate(bench, 8, 1); // 8 warps, 32 slots
        let stats = Simulator::new(&cfg, &trace).run();
        assert_eq!(stats.warps_retired, 8);
    }

    #[test]
    fn sim_threads_do_not_change_results() {
        // the full Table II sweep lives in rust/tests/parallel_determinism;
        // this is the fast in-tree smoke check
        let mut serial = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
        serial.num_sms = 2;
        serial.max_cycles = 30_000;
        let mut par = serial.clone();
        par.sim_threads = 2;
        let a = run_benchmark(&serial, "kmeans", 2);
        let b = run_benchmark(&par, "kmeans", 2);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn drained_sm_accounts_stall_tail() {
        // 8 warps on a 2-SM GPU: SM1 is empty and must accumulate the
        // stall-empty tail a lock-step engine would have recorded
        let mut cfg = small_cfg(Scheme::BASELINE);
        cfg.num_sms = 2;
        let bench = crate::trace::find("nn").unwrap();
        let trace = KernelTrace::generate(bench, 8, 1);
        let stats = Simulator::new(&cfg, &trace).run();
        assert_eq!(stats.warps_retired, 8);
        assert!(
            stats.sched_stall_empty >= stats.cycles,
            "empty SM must log stall-empty cycles ({} < {})",
            stats.sched_stall_empty,
            stats.cycles
        );
    }
}
