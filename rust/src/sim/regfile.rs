//! RF banks + arbiter (paper §II, Fig 3).
//!
//! Single-ported banks: one read *or* write per cycle; writes have
//! priority. Conflicting reads wait in a per-bank FIFO; the arbiter grants
//! the oldest request whose destination collector port is free (one operand
//! delivered per collector per cycle — the crossbar/OCU port constraint).

use std::collections::VecDeque;

/// One queued operand-read request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadReq {
    /// Target collector unit.
    pub collector: u8,
    /// Source-operand slot in the collector's OCT.
    pub slot: u8,
    /// Requesting warp (local sub-core index).
    pub warp: u8,
    /// Architectural register.
    pub reg: u8,
    /// Cycle the request entered the queue (conflict-wait accounting).
    pub enqueued: u64,
}

/// One pending bank write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteReq {
    /// Register being written.
    pub reg: u8,
    /// Producing warp.
    pub warp: u8,
}

/// A granted read, reported back to the sub-core for delivery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grant {
    /// The request served.
    pub req: ReadReq,
    /// Cycles it waited in the queue.
    pub waited: u64,
}

/// The sub-core's RF bank array.
#[derive(Debug)]
pub struct RegFileBanks {
    read_q: Vec<VecDeque<ReadReq>>,
    write_q: Vec<VecDeque<WriteReq>>,
    nbanks: usize,
}

impl RegFileBanks {
    /// `nbanks` single-ported banks.
    pub fn new(nbanks: usize) -> Self {
        assert!(nbanks > 0);
        RegFileBanks {
            read_q: (0..nbanks).map(|_| VecDeque::new()).collect(),
            write_q: (0..nbanks).map(|_| VecDeque::new()).collect(),
            nbanks,
        }
    }

    // simlint: hot
    /// Bank index for a register of a warp (Turing-style interleave: the
    /// warp offset spreads the same register of different warps).
    #[inline]
    pub fn bank_of(&self, reg: u8, warp: u8) -> usize {
        (reg as usize + warp as usize) % self.nbanks
    }

    // simlint: hot
    /// Queue a read request.
    pub fn push_read(&mut self, req: ReadReq) {
        let b = self.bank_of(req.reg, req.warp);
        self.read_q[b].push_back(req);
    }

    // simlint: hot
    /// Queue a write request.
    pub fn push_write(&mut self, w: WriteReq) {
        let b = self.bank_of(w.reg, w.warp);
        self.write_q[b].push_back(w);
    }

    // simlint: hot
    /// Total queued reads (for idle detection).
    pub fn pending_reads(&self) -> usize {
        self.read_q.iter().map(|q| q.len()).sum()
    }

    // simlint: hot
    /// Total queued writes.
    pub fn pending_writes(&self) -> usize {
        self.write_q.iter().map(|q| q.len()).sum()
    }

    // simlint: hot
    /// One arbitration cycle. `port_used[collector]` counts operands
    /// already delivered to each collector this cycle (updated in place);
    /// `ports_per_collector` is the crossbar output width per collector.
    /// Granted reads are appended to the caller-owned `grants` buffer (the
    /// sub-core reuses one across all cycles, so arbitration never
    /// allocates); returns the number of writes drained.
    ///
    /// Per bank: a pending write consumes the port (write priority, §II);
    /// otherwise the oldest read whose collector port is free is granted.
    /// A blocked head-of-line read blocks the bank (FIFO, as in the paper).
    pub fn arbitrate(
        &mut self,
        now: u64,
        port_used: &mut [u8],
        ports_per_collector: u8,
        grants: &mut Vec<Grant>,
    ) -> u64 {
        let mut writes = 0u64;
        for b in 0..self.nbanks {
            if let Some(_w) = self.write_q[b].pop_front() {
                writes += 1;
                continue; // port consumed by the write
            }
            if let Some(front) = self.read_q[b].front().copied() {
                let p = front.collector as usize % port_used.len().max(1);
                if port_used[p] < ports_per_collector {
                    port_used[p] += 1;
                    self.read_q[b].pop_front();
                    grants.push(Grant {
                        req: front,
                        waited: now.saturating_sub(front.enqueued),
                    });
                }
            }
        }
        writes
    }

    /// Drop all queued reads for a collector (used when a CCU is flushed /
    /// reallocated mid-collection — not expected in normal operation).
    pub fn cancel_reads_for(&mut self, collector: u8) {
        for q in &mut self.read_q {
            q.retain(|r| r.collector != collector);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rr(collector: u8, reg: u8, warp: u8, t: u64) -> ReadReq {
        ReadReq { collector, slot: 0, warp, reg, enqueued: t }
    }

    /// Collecting wrapper over the out-param API for test ergonomics.
    fn arb(
        rf: &mut RegFileBanks,
        now: u64,
        port_used: &mut [u8],
        ports: u8,
    ) -> (Vec<Grant>, u64) {
        let mut grants = Vec::new();
        let writes = rf.arbitrate(now, port_used, ports, &mut grants);
        (grants, writes)
    }

    #[test]
    fn bank_mapping_interleaves_by_warp() {
        let rf = RegFileBanks::new(2);
        assert_ne!(rf.bank_of(4, 0), rf.bank_of(4, 1));
        assert_eq!(rf.bank_of(4, 0), rf.bank_of(6, 0));
    }

    #[test]
    fn conflicting_reads_serialize() {
        let mut rf = RegFileBanks::new(2);
        // same bank (reg 2 & 4, warp 0 -> bank 0)
        rf.push_read(rr(0, 2, 0, 0));
        rf.push_read(rr(1, 4, 0, 0));
        let (g1, _) = arb(&mut rf, 1, &mut [0u8; 4], 1);
        assert_eq!(g1.len(), 1);
        assert_eq!(g1[0].req.reg, 2, "FIFO order");
        let (g2, _) = arb(&mut rf, 2, &mut [0u8; 4], 1);
        assert_eq!(g2.len(), 1);
        assert_eq!(g2[0].req.reg, 4);
        assert_eq!(g2[0].waited, 2);
    }

    #[test]
    fn different_banks_served_in_parallel() {
        let mut rf = RegFileBanks::new(2);
        rf.push_read(rr(0, 2, 0, 0)); // bank 0
        rf.push_read(rr(1, 3, 0, 0)); // bank 1
        let (g, _) = arb(&mut rf, 0, &mut [0u8; 4], 1);
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn writes_preempt_reads() {
        let mut rf = RegFileBanks::new(1);
        rf.push_read(rr(0, 1, 0, 0));
        rf.push_write(WriteReq { reg: 3, warp: 0 });
        let (g, w) = arb(&mut rf, 0, &mut [0u8; 4], 1);
        assert!(g.is_empty(), "write must take the port");
        assert_eq!(w, 1);
        let (g, w) = arb(&mut rf, 1, &mut [0u8; 4], 1);
        assert_eq!(g.len(), 1);
        assert_eq!(w, 0);
    }

    #[test]
    fn collector_port_limit_blocks_bank() {
        let mut rf = RegFileBanks::new(2);
        rf.push_read(rr(0, 2, 0, 0)); // bank 0 -> collector 0
        rf.push_read(rr(0, 3, 0, 0)); // bank 1 -> collector 0 too
        let mut used = [0u8; 4];
        let (g, _) = arb(&mut rf, 0, &mut used, 1);
        assert_eq!(g.len(), 1, "one operand per collector per cycle");
        assert_eq!(rf.pending_reads(), 1);
    }

    #[test]
    fn cancel_reads_for_collector() {
        let mut rf = RegFileBanks::new(2);
        rf.push_read(rr(0, 2, 0, 0));
        rf.push_read(rr(1, 3, 0, 0));
        rf.cancel_reads_for(0);
        assert_eq!(rf.pending_reads(), 1);
    }
}
