//! One sub-core: issue scheduler, collector array, RF banks, execution
//! pipes — the cycle-level pipeline of Fig 3/4.
//!
//! Per-cycle phase order: writeback -> dispatch -> operand collection
//! (bank arbitration) -> issue. Writeback first so a value produced at
//! cycle t can be reused by an allocation in the same cycle (the paper's
//! waiting mechanism exists exactly to create these reuse windows).
//!
//! Every scheme-varying decision — issue gating, warp ordering, collector
//! routing, operand capture, replacement, writeback capture — is delegated
//! to the sub-core's [`CachePolicy`] (built from the scheme registry, see
//! [`crate::sim::policy`]); this file contains no scheme dispatch, only
//! the scheme-independent machine.
//!
//! Memory: global loads go through the per-SM L1 directly; an L1 miss that
//! needs the shared L2 is *deferred* — the request is queued on the SM's
//! [`MemPort`], the collector stays occupied, and the dispatch retries
//! after the GPU-level serial L2 phase posts the fill latency (one cycle
//! of miss-replay latency). This is what lets whole SMs advance in
//! parallel between L2 events while staying bit-identical at any
//! `sim_threads` count (see `docs/ARCHITECTURE.md`).

use std::sync::Arc;

use crate::config::{GpuConfig, SthldMode};
use crate::energy::EventKind;
use crate::isa::{Instruction, OpClass};
use crate::sim::collector::{CacheTable, CollectorArray, MAX_CT};
use crate::sim::exec::{DispatchReq, ExecUnits, Pipe, WbEvent, NPIPES};
use crate::sim::memory::{L1Cache, L1Fetch, MemPort};
use crate::sim::policy::{CachePolicy, CollectorChoice, PolicyCtx};
use crate::sim::regfile::{Grant, ReadReq, RegFileBanks, WriteReq};
use crate::sim::warp::WarpState;
use crate::stats::{SchedState, Stats};
use crate::util::Rng;

/// Build a [`PolicyCtx`] from a sub-core's fields. Expanded at the call
/// site so the borrows stay field-granular (a method returning the ctx
/// would borrow all of `self`, conflicting with the `policy` receiver).
macro_rules! policy_ctx {
    ($s:expr) => {
        PolicyCtx {
            collectors: &mut $s.collectors,
            rfc: &mut $s.rfc,
            warps: &$s.warps,
            streams: &$s.streams,
            rng: &mut $s.rng,
            stats: &mut $s.stats,
            wait_counter: &mut $s.wait_counter,
            sthld: $s.sthld,
        }
    };
}

/// One sub-core of an SM.
pub struct SubCore {
    /// The scheme's decision set (one instance per sub-core).
    policy: Box<dyn CachePolicy>,
    /// Two-level (active/pending) scheduling in effect (registry meta).
    two_level: bool,
    collector_ports: u8,

    /// Warp state, indexed by local warp id.
    pub warps: Vec<WarpState>,
    streams: Vec<Arc<Vec<Instruction>>>,
    /// Collector bank in SoA layout (2 shared units, or one per warp for
    /// private schemes): hot scheduling scalars in flat arrays + packed
    /// occupancy/ready bitmasks, cold payloads in a side-table.
    pub collectors: CollectorArray,
    /// RFC per-warp caches (empty unless the policy is two-level).
    rfc: Vec<CacheTable>,
    banks: RegFileBanks,
    eu: ExecUnits,
    rng: Rng,

    last_issued: Option<u8>,
    /// Round-robin cursor over pending warps (two-level swap-in order).
    swap_cursor: usize,
    /// Malekeh waiting-mechanism counter (per-core, §IV-B2).
    wait_counter: u32,
    /// Current STHLD (static or set by the GPU-level dynamic controller).
    pub sthld: u32,

    /// Scheduler state of the most recent cycle (fast-forward guard).
    pub last_state: SchedState,
    /// Did the most recent `issue` pass consult the policy's
    /// `select_collector`? A consulted policy may have mutated private
    /// state (wait counters, reservoirs), so a StallReady cycle that
    /// consulted can never be fast-forwarded.
    policy_consulted: bool,
    /// Did the most recent `update_active_set` change any warp's active
    /// flag? A changing active set has not reached its fixed point, so
    /// the next cycles are not repeats of this one.
    active_set_changed: bool,
    /// Local counters, merged by the SM at the end of the run.
    pub stats: Stats,
    /// Live (not yet exited) warps.
    pub live_warps: usize,

    // scratch buffers (no allocation in the hot loop): each is cleared
    // and refilled every cycle, so capacity stabilises after warm-up
    wb_buf: Vec<WbEvent>,
    order_buf: Vec<u8>,
    port_used: Vec<u8>,
    grant_buf: Vec<Grant>,
    rfc_flush_buf: Vec<u8>,
    dispatch_buf: Vec<DispatchReq>,
}

impl SubCore {
    /// Build a sub-core for local warps `warp streams`.
    pub fn new(cfg: &GpuConfig, streams: Vec<Arc<Vec<Instruction>>>, seed: u64) -> Self {
        let nwarps = streams.len();
        let ncol = cfg.effective_collectors().min(nwarps.max(1));
        let two_level = cfg.scheme.two_level();
        let mut warps: Vec<WarpState> =
            (0..nwarps).map(|i| WarpState::new(i as u32)).collect();
        if two_level {
            for w in warps.iter_mut().take(cfg.active_warps_per_sub_core) {
                w.active = true;
            }
        }
        let rfc = if two_level {
            (0..nwarps).map(|_| CacheTable::new(cfg.rfc_entries)).collect()
        } else {
            Vec::new()
        };
        let sthld = match cfg.sthld {
            SthldMode::Static(v) => v,
            SthldMode::Dynamic => 0,
        };
        // the policy is built before the collector bank: only window-based
        // schemes (BOW) pay for the per-unit instruction windows
        let policy = cfg.scheme.build_policy(cfg);
        let mut collectors = CollectorArray::new(ncol, cfg.ct_entries);
        if policy.uses_window() {
            collectors.enable_windows();
        }
        SubCore {
            policy,
            two_level,
            collector_ports: cfg.collector_ports.max(1) as u8,
            live_warps: nwarps,
            warps,
            streams,
            collectors,
            rfc,
            banks: RegFileBanks::new(cfg.banks_per_sub_core),
            eu: ExecUnits::new(cfg),
            rng: Rng::new(seed),
            last_issued: None,
            last_state: SchedState::StallEmpty,
            policy_consulted: true,
            active_set_changed: false,
            swap_cursor: 0,
            wait_counter: 0,
            sthld,
            stats: Stats::new(),
            wb_buf: Vec::with_capacity(8),
            order_buf: Vec::with_capacity(64),
            port_used: vec![0u8; ncol],
            grant_buf: Vec::with_capacity(8),
            rfc_flush_buf: Vec::with_capacity(MAX_CT),
            dispatch_buf: Vec::with_capacity(NPIPES),
        }
    }

    /// All warps retired and the machine fully drained.
    pub fn idle(&self) -> bool {
        self.live_warps == 0
            && !self.eu.busy()
            && self.banks.pending_reads() == 0
            && self.banks.pending_writes() == 0
            && self.collectors.occ_mask() == 0
    }

    // simlint: hot
    /// One cycle. L2-bound loads queue on `port` and defer their dispatch
    /// (the SM treats a non-empty port as its synchronization boundary).
    pub fn step(&mut self, now: u64, l1: &mut L1Cache, port: &mut MemPort) {
        self.writeback(now);
        self.dispatch(now, l1, port);
        self.collect_operands(now);
        self.issue(now);
        // leakage proxy for the collector storage
        self.stats
            .energy
            .add(EventKind::LeakProxy, self.collectors.len() as u64);
    }

    // ------------------------------------------------------------ writeback

    // simlint: hot
    /// Stable insertion sort of one cycle's (small) writeback batch by
    /// `(collector, far-destination-last)` — byte-identical ordering to
    /// the stable `sort_by_key` it replaces, but never allocating the
    /// merge buffer std's stable sort needs for longer runs.
    fn sort_wb_batch(buf: &mut [WbEvent]) {
        fn key(e: &WbEvent) -> (u8, bool) {
            (e.collector, e.dst_near == 0)
        }
        for i in 1..buf.len() {
            let mut j = i;
            while j > 0 && key(&buf[j - 1]) > key(&buf[j]) {
                buf.swap(j - 1, j);
                j -= 1;
            }
        }
    }

    // simlint: hot
    fn writeback(&mut self, now: u64) {
        let mut buf = std::mem::take(&mut self.wb_buf);
        buf.clear();
        self.eu.drain_due(now, &mut buf);
        // Single CCU write port (§IV-A2): if several writebacks target the
        // same collector this cycle, the one with a near destination wins.
        // Sort so near-destination events come first per collector.
        Self::sort_wb_batch(&mut buf);
        let mut last_col_served: Option<u8> = None;
        for ev in &buf {
            let warp = ev.warp;
            for k in 0..ev.ndst as usize {
                let reg = ev.dsts[k];
                let near = ev.dst_near & (1 << k) != 0;
                // RF banks are always written (§IV-A2)
                self.banks.push_write(WriteReq { reg, warp });
                self.stats.rf_writes += 1;
                self.stats.energy.add(EventKind::BankWrite, 1);

                // collector-cache capture: the policy decides what enters
                // the cache and with which class
                let port_free = last_col_served != Some(ev.collector);
                let captured = self
                    .policy
                    .capture_writeback(&mut policy_ctx!(self), ev, reg, near, port_free);
                if captured {
                    self.stats.rf_cache_writes += 1;
                    self.stats.energy.add(EventKind::CcuWrite, 1);
                    last_col_served = Some(ev.collector);
                }
            }
            // scoreboard release
            self.warps[warp as usize].clear_pending(&ev.dsts[..ev.ndst as usize]);
        }
        self.wb_buf = buf;
    }

    // ------------------------------------------------------------- dispatch

    // simlint: hot
    fn dispatch(&mut self, now: u64, l1: &mut L1Cache, port: &mut MemPort) {
        // per pipe, oldest ready collector first. A pipe's dispatch only
        // advances that pipe's own accept cursor and never changes another
        // pipe's candidate set (a collector's pipe class is fixed by its
        // opcode), so acceptance can be hoisted, the four per-pipe scans
        // fused into ONE pass over the ready bitmask, and the picks pushed
        // through the EU in a single batched call.
        let rdy = self.collectors.ready_mask();
        if rdy == 0 {
            return;
        }
        let mut accept = [false; NPIPES];
        for (p, a) in accept.iter_mut().enumerate() {
            let pipe = match p {
                0 => Pipe::Alu,
                1 => Pipe::Sfu,
                2 => Pipe::Mma,
                _ => Pipe::Lsu,
            };
            *a = self.eu.can_accept(pipe, now);
        }
        // fused scan: ascending collector index, strict `<` on issue_cycle
        // — the same oldest-first / lowest-index tie-break the per-pipe
        // scans produced
        let mut best: [Option<(usize, u64)>; NPIPES] = [None; NPIPES];
        let mut m = rdy;
        while m != 0 {
            let ci = m.trailing_zeros() as usize;
            m &= m - 1;
            let p = self.collectors.pipe_code(ci) as usize;
            if p >= NPIPES || !accept[p] {
                continue;
            }
            let t = self.collectors.issue_cycle(ci);
            if best[p].map_or(true, |(_, bt)| t < bt) {
                best[p] = Some((ci, t));
            }
        }
        let mut reqs = std::mem::take(&mut self.dispatch_buf);
        reqs.clear();
        for slot in best.iter() {
            let Some((ci, _)) = *slot else { continue };
            let instr = *self.collectors.instr(ci);
            let warp = self
                .collectors
                .owner(ci)
                .expect("occupied collector has an owner");
            let mem_done = match instr.op {
                OpClass::LdGlobal => {
                    match l1.load_or_defer(instr.line_addr as u64, now, port) {
                        L1Fetch::Hit(done) => {
                            self.stats.l1_accesses += 1;
                            self.stats.l1_hits += 1;
                            done
                        }
                        L1Fetch::Miss(done) => {
                            self.stats.l1_accesses += 1;
                            done
                        }
                        // L2-bound: leave the collector occupied and retry
                        // after the serial L2 phase posts the latency
                        L1Fetch::Deferred => continue,
                    }
                }
                OpClass::StGlobal => l1.store(instr.line_addr as u64, now),
                _ => 0,
            };
            reqs.push(DispatchReq {
                instr,
                warp,
                collector: ci as u8,
                boc_seq: self.collectors.cur_seq(ci),
                mem_done,
            });
        }
        self.eu.dispatch_batch(&reqs, now);
        let caching = self.policy.caching();
        for r in &reqs {
            self.collectors.dispatched(r.collector as usize, caching);
        }
        self.dispatch_buf = reqs;
    }

    // --------------------------------------------------- operand collection

    // simlint: hot
    fn collect_operands(&mut self, now: u64) {
        self.port_used.iter_mut().for_each(|p| *p = 0);
        self.grant_buf.clear();
        let _writes = self.banks.arbitrate(
            now,
            &mut self.port_used,
            self.collector_ports,
            &mut self.grant_buf,
        );
        for g in &self.grant_buf {
            let r = g.req;
            self.policy
                .operand_arrived(&mut self.collectors, r.collector as usize, r.slot, r.reg);
            self.stats.rf_bank_reads += 1;
            self.stats.bank_conflict_wait += g.waited;
            self.stats.energy.add(EventKind::BankRead, 1);
            self.stats.energy.add(EventKind::XbarTransfer, 1);
            self.stats.energy.add(EventKind::ArbiterOp, 1);
            // NOTE: RFC is write-allocate only (Gebhart 2011): values enter
            // the cache at writeback, never on read fills.
        }
    }

    // ---------------------------------------------------------------- issue

    // simlint: hot
    /// Build the warp priority order for this cycle into `order_buf`: the
    /// greedy warp first, then the policy's priority order.
    fn build_order(&mut self) {
        self.order_buf.clear();
        let greedy = self.last_issued.filter(|&w| !self.warps[w as usize].done);
        if let Some(g) = greedy {
            self.order_buf.push(g);
        }
        self.policy.build_order(&mut self.order_buf, greedy, &self.warps, &self.collectors);
    }

    // simlint: hot
    /// Scoreboard-level readiness of warp `w`.
    fn warp_ready(&self, w: usize) -> bool {
        let warp = &self.warps[w];
        match warp.next_instr(&self.streams[w]) {
            Some(i) => warp.deps_ready(i),
            None => false,
        }
    }

    // simlint: hot
    fn any_ready(&self) -> bool {
        (0..self.warps.len()).any(|w| self.warp_ready(w))
    }

    // simlint: hot
    /// Two-level scheduler bookkeeping: swap active warps out when the
    /// policy says so — long-latency stalls (hardware RFC) or strand
    /// boundaries (software RFC / LTRF), §VI-A. Short-latency stalls leave
    /// the warp active — with only 2 active warps this is exactly what
    /// produces the state-2 cycles of Fig 10.
    fn update_active_set(&mut self, now: u64) {
        if !self.two_level {
            return;
        }
        let n = self.warps.len();
        for w in 0..n {
            if !self.warps[w].active {
                continue;
            }
            let done = self.warps[w].done;
            // minimum residency: a freshly activated warp cannot be
            // swapped out before its swap-in completes
            if !done && now < self.warps[w].active_since + self.policy.activation_delay() {
                continue;
            }
            let should_swap = if done {
                true
            } else {
                let instr = match self.warps[w].next_instr(&self.streams[w]) {
                    Some(i) => *i,
                    None => continue,
                };
                let stalled = !self.warps[w].deps_ready(&instr);
                stalled && self.policy.should_swap_out(&self.warps[w], &instr, now)
            };
            if !should_swap {
                continue;
            }
            // replacement: round-robin over pending warps, with NO
            // readiness oracle — the hardware cannot see pending warps'
            // scoreboards at swap time, which is precisely why two-level
            // schedulers fail to bring ready warps in soon enough (§VI-A)
            let repl = (1..=n)
                .map(|k| (self.swap_cursor + k) % n)
                .find(|&p| !self.warps[p].active && !self.warps[p].done);
            if let Some(p) = repl {
                self.swap_cursor = p;
                self.active_set_changed = true;
                self.warps[w].active = false;
                if !self.rfc.is_empty() {
                    // RFC is write-back (energy is its whole point): on
                    // deactivation every dirty entry must be written to the
                    // MRF banks, stealing read bandwidth — the hidden cost
                    // that makes two-level swaps expensive on 2-bank
                    // sub-cores (§VI-A). The register list goes through the
                    // sub-core's reusable scratch buffer, not a fresh Vec.
                    self.rfc[w].valid_regs_into(&mut self.rfc_flush_buf);
                    for &reg in &self.rfc_flush_buf {
                        self.banks.push_write(WriteReq { reg, warp: w as u8 });
                        self.stats.energy.add(EventKind::BankWrite, 1);
                    }
                    self.rfc[w].flush();
                }
                self.warps[p].active = true;
                self.warps[p].active_since = now;
                self.warps[p].strand_pos = 0;
            } else if done {
                self.active_set_changed = true;
                self.warps[w].active = false;
            }
        }
    }

    // simlint: hot
    fn issue(&mut self, now: u64) {
        self.policy_consulted = false;
        self.active_set_changed = false;
        self.update_active_set(now);
        self.build_order();
        let order = std::mem::take(&mut self.order_buf);
        let mut issued = false;
        let mut waiting_stall = false;

        'warps: for &w in &order {
            let wi = w as usize;
            // two-level residency + activation delay (always true for
            // one-level policies)
            if !self.policy.issue_gate(&self.warps[wi], now) {
                continue;
            }
            if self.warps[wi].done || !self.warp_ready(wi) {
                continue;
            }
            let instr = self.streams[wi][self.warps[wi].pc];

            // control / exit: no collector, no RF traffic
            match instr.op {
                OpClass::Exit => {
                    self.warps[wi].done = true;
                    self.warps[wi].pc += 1;
                    self.live_warps -= 1;
                    self.stats.warps_retired += 1;
                    // the exit marker consumes the slot but is not counted
                    issued = true;
                    self.last_issued = Some(w);
                    break 'warps;
                }
                OpClass::Ctrl => {
                    self.warps[wi].pc += 1;
                    self.warps[wi].strand_pos += 1;
                    self.stats.instructions += 1;
                    issued = true;
                    self.last_issued = Some(w);
                    break 'warps;
                }
                _ => {}
            }

            // collector selection (and issue gating) per policy
            self.policy_consulted = true;
            let choice = self.policy.select_collector(&mut policy_ctx!(self), w);
            let ci = match choice {
                CollectorChoice::Unit(ci) => ci,
                CollectorChoice::SkipWarp => continue,
                CollectorChoice::StallCycle { waiting } => {
                    // §IV-B2 box 7 (waiting) or collector-full: stall the
                    // slot for this cycle
                    waiting_stall = waiting;
                    break 'warps;
                }
            };

            // allocate + generate bank reads
            let res = self.policy.allocate(&mut policy_ctx!(self), ci, w, &instr, now);
            self.stats.rf_reads += (res.hits + res.misses.len() as u32) as u64;
            self.stats.rf_cache_reads += res.hits as u64;
            self.stats.cache_write_reused += res.wb_reuse as u64;
            if res.hits > 0 {
                self.stats.energy.add(EventKind::CcuRead, res.hits as u64);
            }
            if res.flushed {
                self.stats.ccu_flushes += 1;
            }
            self.stats
                .energy
                .add(EventKind::OctOp, instr.nsrc as u64); // tag checks
            for &(slot, reg) in res.misses.iter() {
                self.banks.push_read(ReadReq {
                    collector: ci as u8,
                    slot,
                    warp: w,
                    reg,
                    enqueued: now,
                });
            }
            // scoreboard + cursors
            self.warps[wi].mark_pending(&instr);
            self.warps[wi].pc += 1;
            self.warps[wi].last_issue = now;
            self.warps[wi].strand_pos += 1;
            self.stats.instructions += 1;
            self.last_issued = Some(w);
            self.wait_counter = 0;
            issued = true;
            break 'warps;
        }
        self.order_buf = order;

        // scheduler state accounting (Fig 10 classification)
        let state = if issued {
            SchedState::Issued
        } else if waiting_stall || self.any_ready() {
            // a waiting-mechanism stall implies a ready warp existed
            if waiting_stall {
                self.stats.waiting_stalls += 1;
            }
            SchedState::StallReady
        } else {
            SchedState::StallEmpty
        };
        self.stats.record_sched(state);
        self.last_state = state;
    }

    // simlint: hot
    /// Fast-forward probe: if nothing can happen before the next event
    /// cycle, return that cycle. `None` = must simulate cycle-by-cycle
    /// (work is queued, a warp issued, or the next cycle is not a repeat
    /// of this one).
    ///
    /// Two quiescent shapes fast-forward:
    /// - **StallEmpty** (no warp ready): the EU event heap is the only
    ///   future driver — skip to its next event.
    /// - **StallReady** (ready warps, none can issue): only safe when the
    ///   policy was *not* consulted this cycle (a gated two-level stall —
    ///   consulting could mutate policy-private state) and the active set
    ///   reached its fixed point. Then the cycle repeats verbatim until an
    ///   EU writeback lands or a policy time gate (activation delay, idle
    ///   timeout) opens — [`CachePolicy::quiescent_horizon`] bounds the
    ///   skip; its conservative default (`now`) disables it per policy.
    ///
    /// Both shapes additionally require idle banks and an empty ready
    /// bitmask, so writeback/dispatch/collection phases are provably
    /// no-ops across the skipped range.
    pub fn next_wakeup(&self, now: u64) -> Option<u64> {
        if self.last_state == SchedState::Issued {
            return None; // the machine is making progress
        }
        if self.banks.pending_reads() > 0 || self.banks.pending_writes() > 0 {
            return None; // bank traffic drains next cycle
        }
        if self.collectors.ready_mask() != 0 {
            return None; // a dispatch is pending
        }
        if self.last_state == SchedState::StallReady {
            if self.policy_consulted || self.active_set_changed {
                return None;
            }
            let horizon = self.policy.quiescent_horizon(&self.warps, now);
            let wake = self.eu.next_event_cycle().unwrap_or(u64::MAX).min(horizon);
            return if wake == u64::MAX { None } else { Some(wake) };
        }
        // StallEmpty
        if self.live_warps == 0 && !self.eu.busy() {
            return Some(u64::MAX); // fully drained
        }
        // the EU event heap is the only future driver
        self.eu.next_event_cycle()
    }

    // simlint: hot
    /// Account `n` skipped quiescent cycles (fast-forward bookkeeping must
    /// match what `step` would have recorded: the scheduler state repeats,
    /// so the skipped cycles replay `last_state`).
    pub fn bulk_stall(&mut self, n: u64) {
        if self.last_state == SchedState::StallReady {
            self.stats.sched_stall_ready += n;
        } else {
            self.stats.sched_stall_empty += n;
        }
        self.stats
            .energy
            .add(EventKind::LeakProxy, n * self.collectors.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, Scheme};
    use crate::sim::memory::{L2Request, L2Response, SharedMemorySystem};
    use crate::trace::{find, KernelTrace};

    fn mem_sys(cfg: &GpuConfig) -> (L1Cache, SharedMemorySystem) {
        (
            L1Cache::new(cfg.l1_bytes, cfg.line_bytes, cfg.l1_ways, cfg.l1_latency, cfg.l1_mshrs),
            SharedMemorySystem::new(
                cfg.l2_bytes,
                cfg.line_bytes,
                cfg.l2_ways,
                cfg.l2_latency,
                cfg.dram_latency,
                cfg.dram_reqs_per_cycle,
            ),
        )
    }

    /// One-SM epoch driver: step, then (as the GPU-level scheduler would
    /// after the SM blocks) service any queued L2 requests and post the
    /// fills so deferred dispatches retry next cycle. Owns the run-long
    /// port and request/response buffers, exactly like the real epoch
    /// loop — no per-cycle allocation.
    struct EpochDriver {
        port: MemPort,
        reqs: Vec<L2Request>,
        resps: Vec<L2Response>,
    }

    impl EpochDriver {
        fn new() -> Self {
            EpochDriver { port: MemPort::new(0), reqs: Vec::new(), resps: Vec::new() }
        }

        fn step(
            &mut self,
            sc: &mut SubCore,
            l1: &mut L1Cache,
            l2: &mut SharedMemorySystem,
            t: u64,
        ) {
            sc.step(t, l1, &mut self.port);
            self.reqs.clear();
            self.port.drain_into(&mut self.reqs);
            if !self.reqs.is_empty() {
                self.resps.clear();
                l2.service_into(&mut self.reqs, &mut self.resps);
                for r in &self.resps {
                    l1.resolve_fill(r.line, r.cycle, r.extra);
                }
            }
        }
    }

    fn run_subcore(cfg: &GpuConfig, bench: &str, nwarps: usize, max: u64) -> SubCore {
        let trace = KernelTrace::generate(find(bench).unwrap(), nwarps, 7);
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(cfg);
        let mut drv = EpochDriver::new();
        let mut t = 0;
        while !sc.idle() && t < max {
            drv.step(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        sc.stats.cycles = t;
        sc.stats.l1_accesses = l1.accesses;
        sc.stats.l1_hits = l1.hits;
        sc
    }

    #[test]
    fn baseline_runs_to_completion() {
        let cfg = GpuConfig::table1_baseline();
        let sc = run_subcore(&cfg, "hotspot", 8, 2_000_000);
        assert!(sc.idle(), "must drain");
        assert_eq!(sc.stats.warps_retired, 8);
        assert!(sc.stats.instructions > 8 * 400);
        assert!(sc.stats.ipc() > 0.05, "ipc {}", sc.stats.ipc());
        assert_eq!(sc.stats.rf_cache_reads, 0, "baseline has no cache");
        assert_eq!(sc.stats.rf_bank_reads, sc.stats.rf_reads);
    }

    #[test]
    fn malekeh_serves_reads_from_cache() {
        let cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
        let mut trace = KernelTrace::generate(find("kmeans").unwrap(), 8, 7);
        crate::compiler::profile_and_annotate(&mut trace, 2, cfg.rthld);
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(&cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(&cfg);
        let mut drv = EpochDriver::new();
        let mut t = 0;
        while !sc.idle() && t < 2_000_000 {
            drv.step(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        assert!(sc.idle());
        assert!(
            sc.stats.rf_cache_reads > 0,
            "kmeans has hot operands; CCU must hit"
        );
        assert_eq!(
            sc.stats.rf_reads,
            sc.stats.rf_cache_reads + sc.stats.rf_bank_reads,
            "every read is served by cache or banks"
        );
    }

    #[test]
    fn exit_retires_all_warps_all_schemes() {
        for scheme in Scheme::all() {
            let cfg = GpuConfig::table1_baseline().with_scheme(scheme);
            let sc = run_subcore(&cfg, "backprop", 8, 3_000_000);
            assert!(sc.idle(), "{scheme}: not drained");
            assert_eq!(sc.stats.warps_retired, 8, "{scheme}");
        }
    }

    #[test]
    fn two_level_has_state2_stalls() {
        let cfg = GpuConfig::table1_baseline().with_scheme(Scheme::RFC);
        let sc = run_subcore(&cfg, "hotspot", 8, 2_000_000);
        let (_, s2, _) = sc.stats.sched_state_distribution();
        assert!(
            s2 > 0.02,
            "two-level scheduler must show ready-but-stalled cycles, got {s2}"
        );
    }

    #[test]
    fn waiting_mechanism_counts_stalls() {
        let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
        cfg.sthld = SthldMode::Static(8);
        let mut trace = KernelTrace::generate(find("kmeans").unwrap(), 8, 7);
        crate::compiler::profile_and_annotate(&mut trace, 2, cfg.rthld);
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(&cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(&cfg);
        let mut drv = EpochDriver::new();
        let mut t = 0;
        while !sc.idle() && t < 2_000_000 {
            drv.step(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        assert!(sc.stats.waiting_stalls > 0, "sthld=8 should cause waits");
    }

    #[test]
    fn instruction_count_matches_stream_content() {
        let cfg = GpuConfig::table1_baseline();
        let trace = KernelTrace::generate(find("nn").unwrap(), 4, 7);
        let expect: u64 = trace
            .warps
            .iter()
            .map(|w| w.iter().filter(|i| i.op != OpClass::Exit).count() as u64)
            .sum();
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(&cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(&cfg);
        let mut drv = EpochDriver::new();
        let mut t = 0;
        while !sc.idle() && t < 2_000_000 {
            drv.step(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        assert_eq!(sc.stats.instructions, expect);
    }
}
