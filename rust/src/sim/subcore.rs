//! One sub-core: issue scheduler, collector array, RF banks, execution
//! pipes — the cycle-level pipeline of Fig 3/4 and the policies of §IV.
//!
//! Per-cycle phase order: writeback -> dispatch -> operand collection
//! (bank arbitration) -> issue. Writeback first so a value produced at
//! cycle t can be reused by an allocation in the same cycle (the paper's
//! waiting mechanism exists exactly to create these reuse windows).
//!
//! Memory: global loads go through the per-SM L1 directly; an L1 miss that
//! needs the shared L2 is *deferred* — the request is queued on the SM's
//! [`MemPort`], the collector stays occupied, and the dispatch retries
//! after the GPU-level serial L2 phase posts the fill latency (one cycle
//! of miss-replay latency). This is what lets whole SMs advance in
//! parallel between L2 events while staying bit-identical at any
//! `sim_threads` count (see `docs/ARCHITECTURE.md`).

use std::sync::Arc;

use crate::config::{GpuConfig, Scheme, SthldMode};
use crate::energy::EventKind;
use crate::isa::{Instruction, OpClass};
use crate::sim::collector::{AllocResult, CacheTable, Collector};
use crate::sim::exec::{pipe_of, ExecUnits, Pipe, WbEvent, NPIPES};
use crate::sim::memory::{L1Cache, L1Fetch, MemPort};
use crate::sim::regfile::{ReadReq, RegFileBanks, WriteReq};
use crate::sim::warp::WarpState;
use crate::stats::{SchedState, Stats};
use crate::util::Rng;

/// One sub-core of an SM.
pub struct SubCore {
    scheme: Scheme,
    traditional: bool,
    no_write_filter: bool,
    bow_window: usize,
    two_level: bool,
    collector_ports: u8,
    swrfc_strand_len: u32,

    /// Warp state, indexed by local warp id.
    pub warps: Vec<WarpState>,
    streams: Vec<Arc<Vec<Instruction>>>,
    /// Collector units (2 shared, or one per warp for private schemes).
    pub collectors: Vec<Collector>,
    /// RFC per-warp caches (empty unless scheme is RFC/SoftwareRfc).
    rfc: Vec<CacheTable>,
    banks: RegFileBanks,
    eu: ExecUnits,
    rng: Rng,

    last_issued: Option<u8>,
    /// Round-robin cursor over pending warps (two-level swap-in order).
    swap_cursor: usize,
    /// Malekeh waiting-mechanism counter (per-core, §IV-B2).
    wait_counter: u32,
    /// Current STHLD (static or set by the GPU-level dynamic controller).
    pub sthld: u32,

    /// Scheduler state of the most recent cycle (fast-forward guard).
    pub last_state: SchedState,
    /// Local counters, merged by the SM at the end of the run.
    pub stats: Stats,
    /// Live (not yet exited) warps.
    pub live_warps: usize,

    // scratch buffers (no allocation in the hot loop)
    wb_buf: Vec<WbEvent>,
    order_buf: Vec<u8>,
    port_used: Vec<u8>,
}

impl SubCore {
    /// Build a sub-core for local warps `warp streams`.
    pub fn new(cfg: &GpuConfig, streams: Vec<Arc<Vec<Instruction>>>, seed: u64) -> Self {
        let nwarps = streams.len();
        let ncol = cfg.effective_collectors().min(nwarps.max(1));
        let two_level = cfg.scheme.two_level();
        let mut warps: Vec<WarpState> =
            (0..nwarps).map(|i| WarpState::new(i as u32)).collect();
        if two_level {
            for w in warps.iter_mut().take(cfg.active_warps_per_sub_core) {
                w.active = true;
            }
        }
        let rfc = if two_level {
            (0..nwarps).map(|_| CacheTable::new(cfg.rfc_entries)).collect()
        } else {
            Vec::new()
        };
        let sthld = match cfg.sthld {
            SthldMode::Static(v) => v,
            SthldMode::Dynamic => 0,
        };
        SubCore {
            scheme: cfg.scheme,
            traditional: cfg.traditional_replacement,
            no_write_filter: cfg.no_write_filter,
            bow_window: cfg.bow_window,
            two_level,
            collector_ports: cfg.collector_ports.max(1) as u8,
            swrfc_strand_len: cfg.swrfc_strand_len as u32,
            live_warps: nwarps,
            warps,
            streams,
            collectors: (0..ncol).map(|_| Collector::new(cfg.ct_entries)).collect(),
            rfc,
            banks: RegFileBanks::new(cfg.banks_per_sub_core),
            eu: ExecUnits::new(cfg),
            rng: Rng::new(seed),
            last_issued: None,
            last_state: SchedState::StallEmpty,
            swap_cursor: 0,
            wait_counter: 0,
            sthld,
            stats: Stats::new(),
            wb_buf: Vec::with_capacity(8),
            order_buf: Vec::with_capacity(64),
            port_used: vec![0u8; ncol],
        }
    }

    /// All warps retired and the machine fully drained.
    pub fn idle(&self) -> bool {
        self.live_warps == 0
            && !self.eu.busy()
            && self.banks.pending_reads() == 0
            && self.banks.pending_writes() == 0
            && self.collectors.iter().all(|c| !c.occupied)
    }

    fn caching(&self) -> bool {
        matches!(
            self.scheme,
            Scheme::Malekeh | Scheme::MalekehPr | Scheme::MalekehTraditional | Scheme::Bow
        )
    }

    /// One cycle. L2-bound loads queue on `port` and defer their dispatch
    /// (the SM treats a non-empty port as its synchronization boundary).
    pub fn step(&mut self, now: u64, l1: &mut L1Cache, port: &mut MemPort) {
        self.writeback(now);
        self.dispatch(now, l1, port);
        self.collect_operands(now);
        self.issue(now);
        // leakage proxy for the collector storage
        self.stats
            .energy
            .add(EventKind::LeakProxy, self.collectors.len() as u64);
    }

    // ------------------------------------------------------------ writeback

    fn writeback(&mut self, now: u64) {
        let mut buf = std::mem::take(&mut self.wb_buf);
        buf.clear();
        self.eu.drain_due(now, &mut buf);
        // Single CCU write port (§IV-A2): if several writebacks target the
        // same collector this cycle, the one with a near destination wins.
        // Sort so near-destination events come first per collector.
        buf.sort_by_key(|e| (e.collector, e.dst_near == 0));
        let mut last_col_served: Option<u8> = None;
        for ev in &buf {
            let warp = ev.warp;
            for k in 0..ev.ndst as usize {
                let reg = ev.dsts[k];
                let near = ev.dst_near & (1 << k) != 0;
                // RF banks are always written (§IV-A2)
                self.banks.push_write(WriteReq { reg, warp });
                self.stats.rf_writes += 1;
                self.stats.energy.add(EventKind::BankWrite, 1);

                // collector-cache capture
                let port_free = last_col_served != Some(ev.collector);
                let captured = match self.scheme {
                    Scheme::Malekeh | Scheme::MalekehPr | Scheme::MalekehTraditional => {
                        let ci = ev.collector as usize;
                        if port_free && ci < self.collectors.len() {
                            self.stats.energy.add(EventKind::OctOp, 1);
                            self.collectors[ci].ccu_writeback(
                                warp,
                                reg,
                                near,
                                &mut self.rng,
                                self.traditional,
                                self.no_write_filter,
                            )
                        } else {
                            false
                        }
                    }
                    Scheme::Bow => {
                        let ci = ev.collector as usize;
                        if ci < self.collectors.len() {
                            // BOW writes every in-window destination
                            self.collectors[ci].boc_writeback(ev.boc_seq, reg)
                        } else {
                            false
                        }
                    }
                    Scheme::Rfc => {
                        // hardware RFC: fill if the warp is still active
                        if self.warps[warp as usize].active {
                            self.rfc[warp as usize]
                                .allocate(reg, true, false, &mut self.rng, true)
                                .is_some()
                        } else {
                            false
                        }
                    }
                    Scheme::SoftwareRfc => {
                        // compiler-managed: only near-marked results are
                        // placed in the cache
                        if near && self.warps[warp as usize].active {
                            self.rfc[warp as usize]
                                .allocate(reg, true, false, &mut self.rng, true)
                                .is_some()
                        } else {
                            false
                        }
                    }
                    Scheme::Baseline => false,
                };
                if captured {
                    self.stats.rf_cache_writes += 1;
                    self.stats.energy.add(EventKind::CcuWrite, 1);
                    last_col_served = Some(ev.collector);
                }
            }
            // scoreboard release
            self.warps[warp as usize].clear_pending(&ev.dsts[..ev.ndst as usize]);
        }
        self.wb_buf = buf;
    }

    // ------------------------------------------------------------- dispatch

    fn dispatch(&mut self, now: u64, l1: &mut L1Cache, port: &mut MemPort) {
        // per pipe, oldest ready collector first
        for pipe_idx in 0..NPIPES {
            let pipe = match pipe_idx {
                0 => Pipe::Alu,
                1 => Pipe::Sfu,
                2 => Pipe::Mma,
                _ => Pipe::Lsu,
            };
            if !self.eu.can_accept(pipe, now) {
                continue;
            }
            let mut best: Option<(usize, u64)> = None;
            for (i, c) in self.collectors.iter().enumerate() {
                if c.ready() && pipe_of(c.instr.op) == Some(pipe) {
                    if best.map_or(true, |(_, t)| c.issue_cycle < t) {
                        best = Some((i, c.issue_cycle));
                    }
                }
            }
            let Some((ci, _)) = best else { continue };
            let instr = self.collectors[ci].instr;
            let warp = self.collectors[ci]
                .owner
                .expect("occupied collector has an owner");
            let mem_done = match instr.op {
                OpClass::LdGlobal => {
                    match l1.load_or_defer(instr.line_addr as u64, now, port) {
                        L1Fetch::Hit(done) => {
                            self.stats.l1_accesses += 1;
                            self.stats.l1_hits += 1;
                            done
                        }
                        L1Fetch::Miss(done) => {
                            self.stats.l1_accesses += 1;
                            done
                        }
                        // L2-bound: leave the collector occupied and retry
                        // after the serial L2 phase posts the latency
                        L1Fetch::Deferred => continue,
                    }
                }
                OpClass::StGlobal => l1.store(instr.line_addr as u64, now),
                _ => 0,
            };
            let seq = self.collectors[ci].cur_seq;
            let caching = self.caching();
            self.eu.dispatch(&instr, warp, ci as u8, seq, now, mem_done);
            self.collectors[ci].dispatched(caching);
        }
    }

    // --------------------------------------------------- operand collection

    fn collect_operands(&mut self, now: u64) {
        self.port_used.iter_mut().for_each(|p| *p = 0);
        let (grants, _writes) =
            self.banks.arbitrate(now, &mut self.port_used, self.collector_ports);
        let bow = self.scheme == Scheme::Bow;
        for g in &grants {
            let r = g.req;
            self.collectors[r.collector as usize].bank_operand_arrived(r.slot, r.reg, bow);
            self.stats.rf_bank_reads += 1;
            self.stats.bank_conflict_wait += g.waited;
            self.stats.energy.add(EventKind::BankRead, 1);
            self.stats.energy.add(EventKind::XbarTransfer, 1);
            self.stats.energy.add(EventKind::ArbiterOp, 1);
            // NOTE: RFC is write-allocate only (Gebhart 2011): values enter
            // the cache at writeback, never on read fills.
        }
    }

    // ---------------------------------------------------------------- issue

    /// Build the warp priority order for this cycle into `order_buf`.
    fn build_order(&mut self) {
        self.order_buf.clear();
        let n = self.warps.len() as u8;
        let greedy = self.last_issued.filter(|&w| !self.warps[w as usize].done);
        if let Some(g) = greedy {
            self.order_buf.push(g);
        }
        match self.scheme {
            Scheme::Malekeh => {
                // §IV-B1: warps with data in a CCU first (by age), then rest
                for w in 0..n {
                    if Some(w) == greedy {
                        continue;
                    }
                    let owns = self
                        .collectors
                        .iter()
                        .any(|c| c.owner == Some(w) && c.ct.has_values());
                    if owns {
                        self.order_buf.push(w);
                    }
                }
                for w in 0..n {
                    if Some(w) == greedy || self.order_buf.contains(&w) {
                        continue;
                    }
                    self.order_buf.push(w);
                }
            }
            _ => {
                // GTO: greedy then oldest (ascending id = age order)
                for w in 0..n {
                    if Some(w) != greedy {
                        self.order_buf.push(w);
                    }
                }
            }
        }
    }

    /// Scoreboard-level readiness of warp `w`.
    fn warp_ready(&self, w: usize) -> bool {
        let warp = &self.warps[w];
        match warp.next_instr(&self.streams[w]) {
            Some(i) => warp.deps_ready(i),
            None => false,
        }
    }

    fn any_ready(&self) -> bool {
        (0..self.warps.len()).any(|w| self.warp_ready(w))
    }

    /// Two-level scheduler bookkeeping: swap active warps out on
    /// long-latency stalls (hardware RFC) or strand boundaries (software
    /// RFC / LTRF), §VI-A. Short-latency stalls leave the warp active —
    /// with only 2 active warps this is exactly what produces the state-2
    /// cycles of Fig 10.
    fn update_active_set(&mut self, now: u64) {
        if !self.two_level {
            return;
        }
        let n = self.warps.len();
        for w in 0..n {
            if !self.warps[w].active {
                continue;
            }
            let done = self.warps[w].done;
            // minimum residency: a freshly activated warp cannot be
            // swapped out before its swap-in completes
            if !done && now < self.warps[w].active_since + self.activation_delay() {
                continue;
            }
            let should_swap = if done {
                true
            } else {
                let instr = match self.warps[w].next_instr(&self.streams[w]) {
                    Some(i) => *i,
                    None => continue,
                };
                let stalled = !self.warps[w].deps_ready(&instr);
                match self.scheme {
                    // hardware RFC: deactivate only on long-latency stalls
                    Scheme::Rfc => stalled && self.warps[w].blocked_on_load(&instr),
                    // software RFC / LTRF: swaps happen only at
                    // compiler-placed strand ends; a warp stuck mid-strand
                    // is released only after a long stall (the strand
                    // timeout) — short ALU-dependence stalls keep it
                    // resident and idle, the state-2 cost of Fig 10
                    _ => {
                        stalled
                            && (self.warps[w].strand_pos >= self.swrfc_strand_len
                                || now.saturating_sub(self.warps[w].last_issue) > 64)
                    }
                }
            };
            if !should_swap {
                continue;
            }
            // replacement: round-robin over pending warps, with NO
            // readiness oracle — the hardware cannot see pending warps'
            // scoreboards at swap time, which is precisely why two-level
            // schedulers fail to bring ready warps in soon enough (§VI-A)
            let repl = (1..=n)
                .map(|k| (self.swap_cursor + k) % n)
                .find(|&p| !self.warps[p].active && !self.warps[p].done);
            if let Some(p) = repl {
                self.swap_cursor = p;
                self.warps[w].active = false;
                if !self.rfc.is_empty() {
                    // RFC is write-back (energy is its whole point): on
                    // deactivation every dirty entry must be written to the
                    // MRF banks, stealing read bandwidth — the hidden cost
                    // that makes two-level swaps expensive on 2-bank
                    // sub-cores (§VI-A)
                    for reg in self.rfc[w].valid_regs() {
                        self.banks.push_write(WriteReq { reg, warp: w as u8 });
                        self.stats.energy.add(EventKind::BankWrite, 1);
                    }
                    self.rfc[w].flush();
                }
                self.warps[p].active = true;
                self.warps[p].active_since = now;
                self.warps[p].strand_pos = 0;
            } else if done {
                self.warps[w].active = false;
            }
        }
    }

    /// Activation (swap-in) latency of the two-level scheduler: the newly
    /// activated warp's RF-cache working set must be moved in — RFC
    /// allocates cache lines, software RFC/LTRF issue the strand's
    /// prefetch moves (which is why its swaps are costlier).
    fn activation_delay(&self) -> u64 {
        match self.scheme {
            Scheme::SoftwareRfc => 4,
            _ => 4,
        }
    }

    fn issue(&mut self, now: u64) {
        self.update_active_set(now);
        self.build_order();
        let order = std::mem::take(&mut self.order_buf);
        let mut issued = false;
        let mut waiting_stall = false;

        'warps: for &w in &order {
            let wi = w as usize;
            if self.two_level
                && (!self.warps[wi].active
                    || now < self.warps[wi].active_since + self.activation_delay())
            {
                continue;
            }
            if self.warps[wi].done || !self.warp_ready(wi) {
                continue;
            }
            let instr = self.streams[wi][self.warps[wi].pc];

            // control / exit: no collector, no RF traffic
            match instr.op {
                OpClass::Exit => {
                    self.warps[wi].done = true;
                    self.warps[wi].pc += 1;
                    self.live_warps -= 1;
                    self.stats.warps_retired += 1;
                    // the exit marker consumes the slot but is not counted
                    issued = true;
                    self.last_issued = Some(w);
                    break 'warps;
                }
                OpClass::Ctrl => {
                    self.warps[wi].pc += 1;
                    self.warps[wi].strand_pos += 1;
                    self.stats.instructions += 1;
                    issued = true;
                    self.last_issued = Some(w);
                    break 'warps;
                }
                _ => {}
            }

            // collector selection per scheme
            let chosen: Option<usize> = match self.scheme {
                Scheme::MalekehPr | Scheme::Bow => {
                    let ci = wi % self.collectors.len();
                    if self.collectors[ci].occupied {
                        None // private unit busy: this warp cannot issue
                    } else {
                        Some(ci)
                    }
                }
                Scheme::Malekeh => {
                    match self.choose_ccu(w) {
                        CcuChoice::Unit(ci) => Some(ci),
                        CcuChoice::Skip => None,
                        CcuChoice::WaitStall => {
                            waiting_stall = true;
                            break 'warps; // §IV-B2 box 7: stall the slot
                        }
                    }
                }
                Scheme::MalekehTraditional => {
                    // Fig 17 ablation: CCU hardware but *traditional*
                    // allocation — any free unit, randomly, like the
                    // baseline OCU allocator. This causes the "excessive
                    // flushes when GTO schedules a new warp" of §VI-C.
                    let mut seen = 0usize;
                    let mut pick = None;
                    for (i, c) in self.collectors.iter().enumerate() {
                        if !c.occupied {
                            seen += 1;
                            if self.rng.below(seen) == 0 {
                                pick = Some(i);
                            }
                        }
                    }
                    if pick.is_none() {
                        self.stats.collector_full_stalls += 1;
                        break 'warps;
                    }
                    pick
                }
                _ => {
                    // baseline / RFC: any free unit, random pick
                    // (reservoir sample: no allocation on the hot path)
                    let mut seen = 0usize;
                    let mut pick = None;
                    for (i, c) in self.collectors.iter().enumerate() {
                        if !c.occupied {
                            seen += 1;
                            if self.rng.below(seen) == 0 {
                                pick = Some(i);
                            }
                        }
                    }
                    if pick.is_none() {
                        self.stats.collector_full_stalls += 1;
                        break 'warps; // nothing can issue this cycle
                    }
                    pick
                }
            };
            let Some(ci) = chosen else { continue };

            // allocate + generate bank reads
            let res = self.allocate(ci, w, &instr, now);
            self.stats.rf_reads += (res.hits + res.misses.len() as u32) as u64;
            self.stats.rf_cache_reads += res.hits as u64;
            self.stats.cache_write_reused += res.wb_reuse as u64;
            if res.hits > 0 {
                self.stats.energy.add(EventKind::CcuRead, res.hits as u64);
            }
            if res.flushed {
                self.stats.ccu_flushes += 1;
            }
            self.stats
                .energy
                .add(EventKind::OctOp, instr.nsrc as u64); // tag checks
            for (slot, reg) in &res.misses {
                self.banks.push_read(ReadReq {
                    collector: ci as u8,
                    slot: *slot,
                    warp: w,
                    reg: *reg,
                    enqueued: now,
                });
            }
            // scoreboard + cursors
            self.warps[wi].mark_pending(&instr);
            self.warps[wi].pc += 1;
            self.warps[wi].last_issue = now;
            self.warps[wi].strand_pos += 1;
            self.stats.instructions += 1;
            self.last_issued = Some(w);
            self.wait_counter = 0;
            issued = true;
            break 'warps;
        }
        self.order_buf = order;

        // scheduler state accounting (Fig 10 classification)
        let state = if issued {
            SchedState::Issued
        } else if waiting_stall || self.any_ready() {
            // a waiting-mechanism stall implies a ready warp existed
            if waiting_stall {
                self.stats.waiting_stalls += 1;
            }
            SchedState::StallReady
        } else {
            SchedState::StallEmpty
        };
        self.stats.record_sched(state);
        self.last_state = state;
    }

    /// Fast-forward probe: if nothing can happen before the next writeback
    /// event, return that event's cycle. `None` = must simulate
    /// cycle-by-cycle (work is queued or a warp is ready).
    pub fn next_wakeup(&self) -> Option<u64> {
        if self.last_state != SchedState::StallEmpty {
            return None; // a warp was ready (or waiting-stalled)
        }
        if self.banks.pending_reads() > 0 || self.banks.pending_writes() > 0 {
            return None; // bank traffic drains next cycle
        }
        if self.collectors.iter().any(|c| c.ready()) {
            return None; // a dispatch is pending
        }
        if self.live_warps == 0 && !self.eu.busy() {
            return Some(u64::MAX); // fully drained
        }
        // the EU event heap is the only future driver
        self.eu.next_event_cycle()
    }

    /// Account `n` skipped all-stall cycles (fast-forward bookkeeping must
    /// match what `step` would have recorded).
    pub fn bulk_stall(&mut self, n: u64) {
        self.stats.sched_stall_empty += n;
        self.stats
            .energy
            .add(EventKind::LeakProxy, n * self.collectors.len() as u64);
    }

    /// Allocate instruction to collector `ci` per scheme; RFC schemes check
    /// the per-warp cache and shrink the miss list.
    fn allocate(&mut self, ci: usize, w: u8, instr: &Instruction, now: u64) -> AllocResult {
        match self.scheme {
            Scheme::Malekeh | Scheme::MalekehPr | Scheme::MalekehTraditional => self
                .collectors[ci]
                .alloc_ccu(w, instr, now, &mut self.rng, self.traditional),
            Scheme::Bow => self.collectors[ci].alloc_boc(w, instr, now, self.bow_window),
            Scheme::Baseline => self.collectors[ci].alloc_ocu(w, instr, now),
            Scheme::Rfc | Scheme::SoftwareRfc => {
                let mut res = self.collectors[ci].alloc_ocu(w, instr, now);
                if self.warps[w as usize].active {
                    let sw = self.scheme == Scheme::SoftwareRfc;
                    let cache = &mut self.rfc[w as usize];
                    let mut still_miss = Vec::with_capacity(res.misses.len());
                    for (slot, reg) in res.misses.drain(..) {
                        let allowed = !sw || instr.src_is_near(slot as usize);
                        if allowed && cache.lookup(reg).is_some() {
                            cache.touch(cache.lookup(reg).unwrap());
                            self.collectors[ci].deliver(slot);
                            res.hits += 1;
                        } else {
                            still_miss.push((slot, reg));
                        }
                    }
                    res.misses = still_miss;
                }
                res
            }
        }
    }

    /// Malekeh CCU allocation policy (§IV-B2, Fig 6).
    fn choose_ccu(&mut self, w: u8) -> CcuChoice {
        // a warp can own at most one CCU (coherence-free invariant)
        if let Some(ci) = self
            .collectors
            .iter()
            .position(|c| c.owner == Some(w))
        {
            return if self.collectors[ci].occupied {
                CcuChoice::Skip // box 4: no other CCU may be allocated
            } else {
                CcuChoice::Unit(ci) // box 3: reuse the owned unit
            };
        }
        // reservoir-sample the free and the far/empty-free sets in one
        // pass (no allocation on the hot path)
        let mut nfree = 0usize;
        let mut free_pick = None;
        let mut nfar = 0usize;
        let mut far_pick = None;
        for (i, c) in self.collectors.iter().enumerate() {
            if c.occupied {
                continue;
            }
            nfree += 1;
            if self.rng.below(nfree) == 0 {
                free_pick = Some(i);
            }
            if !c.ct.has_near_value() {
                nfar += 1;
                if self.rng.below(nfar) == 0 {
                    far_pick = Some(i);
                }
            }
        }
        if nfree == 0 {
            self.stats.collector_full_stalls += 1;
            return CcuChoice::Skip; // box 6
        }
        if let Some(i) = far_pick {
            return CcuChoice::Unit(i); // box 5: random far/empty unit
        }
        // all free units hold near values: waiting mechanism (boxes 7-9)
        if self.wait_counter < self.sthld {
            self.wait_counter += 1;
            CcuChoice::WaitStall
        } else {
            self.wait_counter = 0;
            CcuChoice::Unit(free_pick.expect("nfree > 0"))
        }
    }
}

enum CcuChoice {
    Unit(usize),
    Skip,
    WaitStall,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;
    use crate::sim::memory::SharedMemorySystem;
    use crate::trace::{find, KernelTrace};

    fn mem_sys(cfg: &GpuConfig) -> (L1Cache, SharedMemorySystem) {
        (
            L1Cache::new(cfg.l1_bytes, cfg.line_bytes, cfg.l1_ways, cfg.l1_latency, cfg.l1_mshrs),
            SharedMemorySystem::new(
                cfg.l2_bytes,
                cfg.line_bytes,
                cfg.l2_ways,
                cfg.l2_latency,
                cfg.dram_latency,
                cfg.dram_reqs_per_cycle,
            ),
        )
    }

    /// One-SM epoch driver: step, then (as the GPU-level scheduler would
    /// after the SM blocks) service any queued L2 requests and post the
    /// fills so deferred dispatches retry next cycle.
    fn step_epoch(sc: &mut SubCore, l1: &mut L1Cache, l2: &mut SharedMemorySystem, t: u64) {
        let mut port = MemPort::new(0);
        sc.step(t, l1, &mut port);
        let mut reqs = Vec::new();
        port.drain_into(&mut reqs);
        if !reqs.is_empty() {
            for r in l2.service(&mut reqs) {
                l1.resolve_fill(r.line, r.cycle, r.extra);
            }
        }
    }

    fn run_subcore(cfg: &GpuConfig, bench: &str, nwarps: usize, max: u64) -> SubCore {
        let trace = KernelTrace::generate(find(bench).unwrap(), nwarps, 7);
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(cfg);
        let mut t = 0;
        while !sc.idle() && t < max {
            step_epoch(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        sc.stats.cycles = t;
        sc.stats.l1_accesses = l1.accesses;
        sc.stats.l1_hits = l1.hits;
        sc
    }

    #[test]
    fn baseline_runs_to_completion() {
        let cfg = GpuConfig::table1_baseline();
        let sc = run_subcore(&cfg, "hotspot", 8, 2_000_000);
        assert!(sc.idle(), "must drain");
        assert_eq!(sc.stats.warps_retired, 8);
        assert!(sc.stats.instructions > 8 * 400);
        assert!(sc.stats.ipc() > 0.05, "ipc {}", sc.stats.ipc());
        assert_eq!(sc.stats.rf_cache_reads, 0, "baseline has no cache");
        assert_eq!(sc.stats.rf_bank_reads, sc.stats.rf_reads);
    }

    #[test]
    fn malekeh_serves_reads_from_cache() {
        let cfg = GpuConfig::table1_baseline().with_scheme(Scheme::Malekeh);
        let mut trace = KernelTrace::generate(find("kmeans").unwrap(), 8, 7);
        crate::compiler::profile_and_annotate(&mut trace, 2, cfg.rthld);
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(&cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(&cfg);
        let mut t = 0;
        while !sc.idle() && t < 2_000_000 {
            step_epoch(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        assert!(sc.idle());
        assert!(
            sc.stats.rf_cache_reads > 0,
            "kmeans has hot operands; CCU must hit"
        );
        assert_eq!(
            sc.stats.rf_reads,
            sc.stats.rf_cache_reads + sc.stats.rf_bank_reads,
            "every read is served by cache or banks"
        );
    }

    #[test]
    fn exit_retires_all_warps_all_schemes() {
        for scheme in Scheme::ALL {
            let cfg = GpuConfig::table1_baseline().with_scheme(scheme);
            let sc = run_subcore(&cfg, "backprop", 8, 3_000_000);
            assert!(sc.idle(), "{scheme}: not drained");
            assert_eq!(sc.stats.warps_retired, 8, "{scheme}");
        }
    }

    #[test]
    fn two_level_has_state2_stalls() {
        let cfg = GpuConfig::table1_baseline().with_scheme(Scheme::Rfc);
        let sc = run_subcore(&cfg, "hotspot", 8, 2_000_000);
        let (_, s2, _) = sc.stats.sched_state_distribution();
        assert!(
            s2 > 0.02,
            "two-level scheduler must show ready-but-stalled cycles, got {s2}"
        );
    }

    #[test]
    fn waiting_mechanism_counts_stalls() {
        let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::Malekeh);
        cfg.sthld = SthldMode::Static(8);
        let mut trace = KernelTrace::generate(find("kmeans").unwrap(), 8, 7);
        crate::compiler::profile_and_annotate(&mut trace, 2, cfg.rthld);
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(&cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(&cfg);
        let mut t = 0;
        while !sc.idle() && t < 2_000_000 {
            step_epoch(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        assert!(sc.stats.waiting_stalls > 0, "sthld=8 should cause waits");
    }

    #[test]
    fn instruction_count_matches_stream_content() {
        let cfg = GpuConfig::table1_baseline();
        let trace = KernelTrace::generate(find("nn").unwrap(), 4, 7);
        let expect: u64 = trace
            .warps
            .iter()
            .map(|w| w.iter().filter(|i| i.op != OpClass::Exit).count() as u64)
            .sum();
        let streams: Vec<_> = trace.warps.into_iter().map(Arc::new).collect();
        let mut sc = SubCore::new(&cfg, streams, 3);
        let (mut l1, mut l2) = mem_sys(&cfg);
        let mut t = 0;
        while !sc.idle() && t < 2_000_000 {
            step_epoch(&mut sc, &mut l1, &mut l2, t);
            t += 1;
        }
        assert_eq!(sc.stats.instructions, expect);
    }
}
