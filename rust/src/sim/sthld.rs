//! Dynamic STHLD controller (§IV-B3, Figs 8-9).
//!
//! Every `sthld_interval` cycles the GPU-level controller compares the
//! interval's IPC with the previous one; a relative delta below epsilon
//! (0.02) is Small (S), otherwise Large (L). A 6-state FSM walks STHLD
//! toward the knee of the IPC-vs-STHLD curve and re-converges when the
//! application phase changes.
//!
//! Fig 8's drawing is not fully legible in the paper, so the FSM below is
//! the reconstruction of the *described* behaviour (§IV-B3): climb the
//! flat region while IPC is stable; on a Large change take one speculative
//! increase; if that loses IPC, back off until stable; hold at the knee
//! until the next phase change. The asterisk transitions (taken
//! regardless of S/L) are Init->Climb and Approach->Hold.

/// FSM states (numbered as in Fig 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SthldState {
    /// 1: first interval after reset.
    Init,
    /// 2: walking the flat region upward.
    Climb,
    /// 3: speculative increase after a Large change.
    Speculate,
    /// 4: backing out of the steep region.
    Backoff,
    /// 5: one stabilising interval before holding.
    Approach,
    /// 6: at the knee; hold until a Large change.
    Hold,
}

/// Dynamic STHLD controller.
#[derive(Debug, Clone)]
pub struct SthldController {
    state: SthldState,
    sthld: u32,
    max: u32,
    epsilon: f64,
    prev_ipc: Option<f64>,
    /// Best IPC seen recently (slowly decayed): catches *compounding*
    /// slow decay while climbing, where every per-interval delta is Small
    /// but the cumulative loss is not.
    anchor: f64,
}

impl SthldController {
    /// Start at STHLD = 0 (no waiting) in Init.
    pub fn new(max: u32, epsilon: f64) -> Self {
        SthldController {
            state: SthldState::Init,
            sthld: 0,
            max,
            epsilon,
            prev_ipc: None,
            anchor: 0.0,
        }
    }

    /// Current threshold.
    pub fn sthld(&self) -> u32 {
        self.sthld
    }

    /// Current state (observability / tests).
    pub fn state(&self) -> SthldState {
        self.state
    }

    fn bump(&mut self, delta: i32) {
        let v = self.sthld as i64 + delta as i64;
        self.sthld = v.clamp(0, self.max as i64) as u32;
    }

    /// Feed the IPC of the interval that just ended; returns the STHLD to
    /// use for the next interval.
    pub fn interval_end(&mut self, ipc: f64) -> u32 {
        let prev = match self.prev_ipc.replace(ipc) {
            Some(p) => p,
            None => {
                // first interval: asterisk transition Init -> Climb
                self.state = SthldState::Climb;
                self.bump(1);
                return self.sthld;
            }
        };
        let rel = if prev > 0.0 { (ipc - prev).abs() / prev } else { 0.0 };
        let large = rel >= self.epsilon;
        let dropped = ipc < prev;
        self.anchor = (self.anchor * 0.995).max(ipc);
        let below_anchor = ipc < self.anchor * (1.0 - self.epsilon);
        use SthldState::*;
        match self.state {
            Init => {
                self.state = Climb;
                self.bump(1);
            }
            Climb => {
                if below_anchor {
                    // cumulative decay vs the best-seen IPC: we climbed
                    // past the knee without a single Large step
                    self.state = Backoff;
                    self.bump(-1);
                } else if large {
                    // phase change or knee: speculative move up (§IV-B3)
                    self.state = Speculate;
                    self.bump(1);
                } else {
                    // flat region: free hit-ratio, keep climbing
                    self.bump(1);
                }
            }
            Speculate => {
                if large && dropped {
                    // speculation was into the steep region: undo + back off
                    self.state = Backoff;
                    self.bump(-2);
                } else {
                    // wider flat region (Fig 9d): resume climbing
                    self.state = Climb;
                    self.bump(1);
                }
            }
            Backoff => {
                // descending the steep wall produces large deltas in BOTH
                // directions (IPC recovers as STHLD drops); keep backing
                // off until the deltas are small again (flat region).
                if large || below_anchor {
                    self.bump(-1);
                } else {
                    // stabilised: one more settling interval
                    self.state = Approach;
                }
            }
            Approach => {
                // asterisk transition: settle at the knee
                self.state = Hold;
            }
            Hold => {
                if large {
                    self.state = Speculate;
                    self.bump(1);
                }
            }
        }
        self.sthld
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic IPC curve with a knee: flat (small noise) until `knee`,
    /// dropping steeply after.
    fn curve(sthld: u32, knee: u32) -> f64 {
        if sthld <= knee {
            1.0 - 0.001 * sthld as f64
        } else {
            1.0 - 0.15 * (sthld - knee) as f64
        }
    }

    #[test]
    fn starts_at_zero_and_climbs() {
        let mut c = SthldController::new(64, 0.02);
        assert_eq!(c.sthld(), 0);
        c.interval_end(1.0);
        assert_eq!(c.state(), SthldState::Climb);
        assert_eq!(c.sthld(), 1);
        c.interval_end(1.0); // small delta -> keep climbing
        assert_eq!(c.sthld(), 2);
    }

    #[test]
    fn converges_near_knee() {
        let knee = 6u32;
        let mut c = SthldController::new(64, 0.02);
        let mut s = c.sthld();
        for _ in 0..40 {
            s = c.interval_end(curve(s, knee));
        }
        assert!(
            c.state() == SthldState::Hold || c.state() == SthldState::Approach,
            "should settle, got {:?}",
            c.state()
        );
        let settled = c.sthld();
        assert!(
            settled >= knee.saturating_sub(2) && settled <= knee + 2,
            "settled {settled} too far from knee {knee}"
        );
    }

    #[test]
    fn phase_change_reconverges() {
        let mut c = SthldController::new(64, 0.02);
        let mut s = c.sthld();
        for _ in 0..40 {
            s = c.interval_end(curve(s, 8));
        }
        let first = c.sthld();
        // narrower flat region (Fig 9c): knee moves down to 3
        for _ in 0..60 {
            s = c.interval_end(curve(s, 3));
        }
        let second = c.sthld();
        assert!(second < first, "knee shrank: {first} -> {second}");
        assert!(second <= 5, "should re-approach the new knee, got {second}");
    }

    #[test]
    fn wider_flat_region_climbs_higher() {
        let mut c = SthldController::new(64, 0.02);
        let mut s = c.sthld();
        for _ in 0..30 {
            s = c.interval_end(curve(s, 3));
        }
        let low = c.sthld();
        // phase change: one interval with a big IPC jump (new phase), then
        // the wider curve (knee at 20) — Fig 9d
        s = c.interval_end(0.5);
        for _ in 0..40 {
            s = c.interval_end(curve(s, 20));
        }
        assert!(c.sthld() > low, "wider flat region should raise STHLD");
    }

    #[test]
    fn sthld_clamped_to_max() {
        let mut c = SthldController::new(4, 0.02);
        for _ in 0..50 {
            c.interval_end(1.0); // perfectly flat: climb forever
        }
        assert!(c.sthld() <= 4);
    }

    #[test]
    fn backoff_descends_until_deltas_stabilise() {
        // The Backoff state carries no direction memory (the field that
        // once claimed to was write-only and has been removed): it keeps
        // stepping STHLD down while per-interval deltas stay Large or IPC
        // sits below the decayed anchor, then settles via Approach.
        let mut c = SthldController::new(64, 0.02);
        // climb the flat region to 4 (first call is the Init transition)
        for _ in 0..4 {
            c.interval_end(1.0);
        }
        assert_eq!(c.state(), SthldState::Climb);
        assert_eq!(c.sthld(), 4);
        // a Large upward change moves Climb -> Speculate (one step up)...
        c.interval_end(1.5);
        assert_eq!(c.state(), SthldState::Speculate);
        assert_eq!(c.sthld(), 5);
        // ...and a Large *drop* while speculating enters Backoff (-2)
        c.interval_end(1.0);
        assert_eq!(c.state(), SthldState::Backoff);
        assert_eq!(c.sthld(), 3, "speculation undone plus one step");
        // Large deltas (either direction) keep it descending
        c.interval_end(0.75);
        assert_eq!(c.state(), SthldState::Backoff);
        assert_eq!(c.sthld(), 2);
        c.interval_end(1.5); // large recovery jump: still backing off
        assert_eq!(c.state(), SthldState::Backoff);
        assert_eq!(c.sthld(), 1);
        // a Small delta at the anchor stabilises: Approach, then Hold,
        // with STHLD untouched
        c.interval_end(1.5);
        assert_eq!(c.state(), SthldState::Approach);
        assert_eq!(c.sthld(), 1);
        c.interval_end(1.5);
        assert_eq!(c.state(), SthldState::Hold);
        assert_eq!(c.sthld(), 1);
    }

    #[test]
    fn hold_reacts_only_to_large() {
        let mut c = SthldController::new(64, 0.02);
        let mut s = c.sthld();
        for _ in 0..40 {
            s = c.interval_end(curve(s, 5));
        }
        assert_eq!(c.state(), SthldState::Hold);
        let at_hold = c.sthld();
        c.interval_end(curve(at_hold, 5) * 1.001); // small
        assert_eq!(c.state(), SthldState::Hold);
        c.interval_end(curve(at_hold, 5) * 0.5); // large
        assert_eq!(c.state(), SthldState::Speculate);
    }
}
