//! Collector units: baseline OCU, Malekeh CCU (§III-C), BOW BOC (§VI-B),
//! and the per-warp RFC cache tables (§VI-A).
//!
//! A single `Collector` struct covers OCU/CCU (the CCU is an OCU plus a
//! cache table and control); BOW's sliding window lives in the same struct
//! (`window`) and is only populated for the BOW scheme. This module is
//! policy-free mechanism: *which* entry gets evicted is decided by the
//! [`VictimFn`] the caller (a [`crate::sim::policy::CachePolicy`]) passes
//! in — the policy layer's `replacement` decision point.

use std::collections::VecDeque;

use crate::isa::Instruction;
use crate::util::Rng;

/// Upper bound on cache-table entries (config.ct_entries must not exceed).
pub const MAX_CT: usize = 16;

/// Victim chooser invoked when a full cache table must evict — the policy
/// layer's `replacement` decision point. Called only when no invalid entry
/// exists; must return an *unlocked* entry index, or `None` to refuse the
/// allocation. All randomness must come from the passed [`Rng`].
pub type VictimFn<'a> = &'a mut dyn FnMut(&CacheTable, &mut Rng) -> Option<usize>;

/// One cache-table entry (§III-C: tag, lock, reuse distance, LRU).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CtEntry {
    /// Register tag (one byte, §III-C).
    pub reg: u8,
    /// Entry holds a live value.
    pub valid: bool,
    /// Pinned: operand of the instruction occupying the CCU.
    pub locked: bool,
    /// Compiler reuse-distance bit of the value (true = near).
    pub near: bool,
    /// Value entered via the writeback port (Fig-16 reuse accounting).
    pub from_wb: bool,
    /// LRU priority (higher = more recent).
    pub lru: u32,
    /// Insertion tick (FIFO-style policies; stable across tag-hit
    /// updates, so an entry keeps its queue position when refreshed).
    pub inserted: u32,
}

/// Fully-associative register cache with the paper's replacement policy.
#[derive(Debug, Clone)]
pub struct CacheTable {
    entries: Vec<CtEntry>,
    tick: u32,
}

impl CacheTable {
    /// `n` entries (8 in the paper).
    pub fn new(n: usize) -> Self {
        assert!(n <= MAX_CT && n >= 1);
        CacheTable { entries: vec![CtEntry::default(); n], tick: 0 }
    }

    /// Invalidate everything (CCU reallocation to a new warp, §III-C1).
    pub fn flush(&mut self) {
        for e in &mut self.entries {
            *e = CtEntry::default();
        }
        self.tick = 0;
    }

    /// Find a valid entry holding `reg`.
    pub fn lookup(&self, reg: u8) -> Option<usize> {
        self.entries.iter().position(|e| e.valid && e.reg == reg)
    }

    /// Bump LRU recency of entry `i`.
    pub fn touch(&mut self, i: usize) {
        self.tick += 1;
        self.entries[i].lru = self.tick;
    }

    /// Any valid entry with near reuse? (the bit sent to the scheduler over
    /// port R, §III-C).
    pub fn has_near_value(&self) -> bool {
        self.entries.iter().any(|e| e.valid && e.near)
    }

    /// Any valid entries at all?
    pub fn has_values(&self) -> bool {
        self.entries.iter().any(|e| e.valid)
    }

    /// Count of valid entries.
    pub fn valid_count(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }

    /// Registers of all valid entries (RFC write-back flush).
    pub fn valid_regs(&self) -> Vec<u8> {
        self.entries.iter().filter(|e| e.valid).map(|e| e.reg).collect()
    }

    /// Unlock all entries (instruction dispatched, §III-C1).
    pub fn unlock_all(&mut self) {
        for e in &mut self.entries {
            e.locked = false;
        }
    }

    /// Entry accessor for tests / energy accounting.
    pub fn entry(&self, i: usize) -> &CtEntry {
        &self.entries[i]
    }

    /// Mutable entry accessor.
    pub fn entry_mut(&mut self, i: usize) -> &mut CtEntry {
        &mut self.entries[i]
    }

    /// Entry slice (victim choosers inspect the whole table).
    pub fn entries(&self) -> &[CtEntry] {
        &self.entries
    }

    /// Install `(reg, near, locked)`, evicting through `victim` if needed.
    ///
    /// Mechanism common to every policy: a present tag is updated in place
    /// (tags must stay unique) and invalid entries are filled first; only
    /// when the table is full does `victim` choose the replacement — the
    /// policy layer's `replacement` decision point (the paper's §IV-A1
    /// chooser is [`reuse_guided_victim`]). Returns the index, or `None`
    /// if `victim` refuses (e.g. every entry is locked).
    pub fn allocate(
        &mut self,
        reg: u8,
        near: bool,
        locked: bool,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> Option<usize> {
        // tag already present: update in place (tags must stay unique)
        if let Some(i) = self.lookup(reg) {
            if self.entries[i].locked && !locked {
                // a locked entry keeps its pin; just refresh recency/bits
                self.entries[i].near = near;
                self.touch(i);
                return Some(i);
            }
            self.tick += 1;
            let inserted = self.entries[i].inserted;
            self.entries[i] = CtEntry {
                reg,
                valid: true,
                locked,
                near,
                from_wb: false,
                lru: self.tick,
                inserted,
            };
            return Some(i);
        }
        // invalid first; the policy decides only among live entries
        let i = match self.entries.iter().position(|e| !e.valid) {
            Some(i) => i,
            None => victim(&*self, rng)?,
        };
        self.tick += 1;
        self.entries[i] = CtEntry {
            reg,
            valid: true,
            locked,
            near,
            from_wb: false,
            lru: self.tick,
            inserted: self.tick,
        };
        Some(i)
    }

    /// Least-recently-used unlocked entry (the plain-LRU building block).
    pub fn lru_victim(&self) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.locked)
            .min_by_key(|(_, e)| e.lru)
            .map(|(i, _)| i)
    }
}

/// The paper's replacement chooser (§IV-A1), after invalid-first: a random
/// unlocked entry among those with *far* reuse, otherwise LRU.
pub fn reuse_guided_victim(ct: &CacheTable, rng: &mut Rng) -> Option<usize> {
    let far: Vec<usize> = ct
        .entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| !e.locked && !e.near)
        .map(|(i, _)| i)
        .collect();
    if !far.is_empty() {
        Some(far[rng.below(far.len())])
    } else {
        ct.lru_victim()
    }
}

/// Plain LRU over unlocked entries (Fig 17's traditional replacement; no
/// RNG draws, matching the pre-refactor `traditional` path bit-exactly).
pub fn plain_lru_victim(ct: &CacheTable, _rng: &mut Rng) -> Option<usize> {
    ct.lru_victim()
}

/// One instruction's register set inside a BOW sliding window.
#[derive(Debug, Clone)]
pub struct BocInstr {
    /// Issue sequence number (matches writebacks to window slots).
    pub seq: u64,
    /// (reg, value present, is destination).
    pub regs: Vec<(u8, bool, bool)>,
}

/// Result of allocating an instruction to a collector.
#[derive(Debug, Clone, Default)]
pub struct AllocResult {
    /// Source slots that must be fetched from the banks: (slot, reg).
    pub misses: Vec<(u8, u8)>,
    /// Source operands served from the cache.
    pub hits: u32,
    /// Hits on values captured via the writeback port (Fig 16: proves
    /// cache writes are reused).
    pub wb_reuse: u32,
    /// The cache table was flushed (ownership change).
    pub flushed: bool,
}

/// A collector unit (OCU / CCU / BOC depending on scheme flags).
#[derive(Debug, Clone)]
pub struct Collector {
    /// An un-dispatched instruction occupies this unit.
    pub occupied: bool,
    /// Warp whose values live in the cache table (survives dispatch).
    pub owner: Option<u8>,
    /// The occupying instruction.
    pub instr: Instruction,
    /// Cycle the occupying instruction was issued.
    pub issue_cycle: u64,
    /// Ready bitmask over source slots.
    pub src_ready: u8,
    /// Sequence number of the occupying instruction (BOW writeback match).
    pub cur_seq: u64,
    /// Cache table (CCU variants; OCU uses it as a plain operand buffer).
    pub ct: CacheTable,
    /// BOW sliding window (empty unless scheme is BOW).
    pub window: VecDeque<BocInstr>,
    seq_counter: u64,
}

impl Collector {
    /// New collector with `ct_entries` cache-table entries.
    pub fn new(ct_entries: usize) -> Self {
        Collector {
            occupied: false,
            owner: None,
            instr: Instruction::new(crate::isa::OpClass::Ctrl, &[], &[]),
            issue_cycle: 0,
            src_ready: 0,
            cur_seq: 0,
            ct: CacheTable::new(ct_entries),
            window: VecDeque::new(),
            seq_counter: 0,
        }
    }

    /// All valid source operands ready (dispatch condition, §III-C1)?
    #[inline]
    pub fn ready(&self) -> bool {
        self.occupied && self.src_ready.count_ones() as u8 == self.instr.nsrc
    }

    /// Mark source slot ready (operand arrived over port S).
    #[inline]
    pub fn deliver(&mut self, slot: u8) {
        self.src_ready |= 1 << slot;
    }

    /// Allocate as a *baseline OCU*: no caching, every source fetched.
    pub fn alloc_ocu(&mut self, warp: u8, instr: &Instruction, now: u64) -> AllocResult {
        debug_assert!(!self.occupied);
        self.occupied = true;
        self.owner = Some(warp);
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        self.ct.flush();
        let misses = instr
            .sources()
            .iter()
            .enumerate()
            .map(|(slot, &reg)| (slot as u8, reg))
            .collect();
        AllocResult { misses, ..Default::default() }
    }

    /// Allocate as a *Malekeh CCU* (§III-C1): flush on ownership change,
    /// tag-check every source, lock hits, allocate entries for misses
    /// (evicting through the policy's `victim` chooser).
    pub fn alloc_ccu(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> AllocResult {
        debug_assert!(!self.occupied);
        let mut res = AllocResult::default();
        if self.owner != Some(warp) {
            self.ct.flush();
            res.flushed = self.owner.is_some();
            self.owner = Some(warp);
        }
        self.occupied = true;
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        for (slot, &reg) in instr.sources().iter().enumerate() {
            let near = instr.src_is_near(slot);
            if let Some(i) = self.ct.lookup(reg) {
                // hit: value already in the CCU — no bank read
                let e = self.ct.entry_mut(i);
                e.locked = true;
                e.near = near;
                if e.from_wb {
                    e.from_wb = false;
                    res.wb_reuse += 1;
                }
                self.ct.touch(i);
                self.src_ready |= 1 << slot;
                res.hits += 1;
            } else {
                let idx = self
                    .ct
                    .allocate(reg, near, true, rng, &mut *victim)
                    .expect("CT must fit all sources (ct_entries >= MAX_SRC)");
                debug_assert!(idx < MAX_CT);
                res.misses.push((slot as u8, reg));
            }
        }
        res
    }

    /// Allocate as a *BOW BOC*: check the sliding window, then append this
    /// instruction's registers to it.
    pub fn alloc_boc(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        window_len: usize,
    ) -> AllocResult {
        debug_assert!(!self.occupied);
        let mut res = AllocResult::default();
        self.occupied = true;
        self.owner = Some(warp);
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        self.seq_counter += 1;
        self.cur_seq = self.seq_counter;

        let mut new_regs: Vec<(u8, bool, bool)> = Vec::with_capacity(8);
        for (slot, &reg) in instr.sources().iter().enumerate() {
            // newest-first search over the window + regs already added for
            // this instruction (duplicate sources)
            let hit = new_regs.iter().any(|&(r, p, _)| r == reg && p)
                || self
                    .window
                    .iter()
                    .rev()
                    .any(|bi| bi.regs.iter().any(|&(r, p, _)| r == reg && p));
            if hit {
                self.src_ready |= 1 << slot;
                res.hits += 1;
                new_regs.push((reg, true, false));
            } else {
                res.misses.push((slot as u8, reg));
                new_regs.push((reg, false, false)); // present once fetched
            }
        }
        for &reg in instr.dests() {
            new_regs.push((reg, false, true)); // present at writeback
        }
        self.window.push_back(BocInstr { seq: self.cur_seq, regs: new_regs });
        while self.window.len() > window_len {
            self.window.pop_front(); // slid out: pending dsts go RF-only
        }
        res
    }

    /// Operand fetched from the banks: mark the slot ready and (BOW) mark
    /// the value present in the window.
    pub fn bank_operand_arrived(&mut self, slot: u8, reg: u8, bow: bool) {
        self.deliver(slot);
        if bow {
            if let Some(bi) = self.window.iter_mut().find(|bi| bi.seq == self.cur_seq) {
                for e in bi.regs.iter_mut() {
                    if e.0 == reg && !e.2 {
                        e.1 = true;
                    }
                }
            }
        }
    }

    /// Dispatch bookkeeping: the unit becomes free; a CCU keeps (and
    /// unlocks) its contents, an OCU drops them.
    pub fn dispatched(&mut self, caching: bool) {
        self.occupied = false;
        self.src_ready = 0;
        if caching {
            self.ct.unlock_all();
        } else {
            self.ct.flush();
        }
    }

    /// CCU destination writeback (§IV-A2): update on hit; allocate only if
    /// `near` (write filter) unless `no_write_filter`, evicting through
    /// the policy's `victim` chooser. Returns true if the cache captured
    /// the value.
    pub fn ccu_writeback(
        &mut self,
        warp: u8,
        reg: u8,
        near: bool,
        rng: &mut Rng,
        victim: VictimFn,
        no_write_filter: bool,
    ) -> bool {
        if self.owner != Some(warp) {
            return false;
        }
        if let Some(i) = self.ct.lookup(reg) {
            let e = self.ct.entry_mut(i);
            e.near = near;
            e.from_wb = true;
            self.ct.touch(i);
            return true;
        }
        if near || no_write_filter {
            if let Some(i) = self.ct.allocate(reg, near, false, rng, victim) {
                self.ct.entry_mut(i).from_wb = true;
                return true;
            }
            return false;
        }
        false
    }

    /// BOW destination writeback: if the producing instruction is still in
    /// the window, the value is captured there. Returns true if captured.
    pub fn boc_writeback(&mut self, seq: u64, reg: u8) -> bool {
        if let Some(bi) = self.window.iter_mut().find(|bi| bi.seq == seq) {
            let mut hit = false;
            for e in bi.regs.iter_mut() {
                if e.0 == reg && e.2 {
                    e.1 = true;
                    hit = true;
                }
            }
            hit
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, OpClass};

    fn rng() -> Rng {
        Rng::new(1)
    }

    // ---- CacheTable ----

    #[test]
    fn ct_lookup_and_flush() {
        let mut ct = CacheTable::new(4);
        assert!(ct.lookup(5).is_none());
        ct.allocate(5, true, false, &mut rng(), &mut reuse_guided_victim);
        assert!(ct.lookup(5).is_some());
        assert!(ct.has_near_value());
        ct.flush();
        assert!(ct.lookup(5).is_none());
        assert!(!ct.has_values());
    }

    #[test]
    fn ct_replacement_prefers_invalid_then_far() {
        let mut ct = CacheTable::new(3);
        let mut r = rng();
        ct.allocate(1, true, false, &mut r, &mut reuse_guided_victim); // near
        ct.allocate(2, false, false, &mut r, &mut reuse_guided_victim); // far
        ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim); // near
        // table full; new alloc must evict the far entry (reg 2)
        ct.allocate(4, true, false, &mut r, &mut reuse_guided_victim);
        assert!(ct.lookup(2).is_none(), "far entry must be the victim");
        assert!(ct.lookup(1).is_some() && ct.lookup(3).is_some());
    }

    #[test]
    fn ct_replacement_falls_back_to_lru_when_all_near() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, true, false, &mut r, &mut reuse_guided_victim);
        ct.allocate(2, true, false, &mut r, &mut reuse_guided_victim);
        ct.touch(ct.lookup(1).unwrap()); // reg1 most recent
        ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim);
        assert!(ct.lookup(2).is_none(), "LRU (reg 2) must be evicted");
        assert!(ct.lookup(1).is_some());
    }

    #[test]
    fn ct_locked_entries_never_evicted() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, false, true, &mut r, &mut reuse_guided_victim); // locked far
        ct.allocate(2, false, true, &mut r, &mut reuse_guided_victim); // locked far
        assert_eq!(ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim), None);
        assert!(ct.lookup(1).is_some() && ct.lookup(2).is_some());
    }

    #[test]
    fn ct_traditional_uses_plain_lru() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, false, false, &mut r, &mut plain_lru_victim); // far, older
        ct.allocate(2, true, false, &mut r, &mut plain_lru_victim); // near, newer
        // traditional LRU evicts reg 1 (oldest) even though reuse-aware
        // policy would also pick it; now make near entry the oldest:
        ct.touch(ct.lookup(1).unwrap());
        ct.allocate(3, false, false, &mut r, &mut plain_lru_victim);
        assert!(
            ct.lookup(2).is_none(),
            "plain LRU must evict the near entry when it is oldest"
        );
    }

    // ---- CCU allocation ----

    fn mma(srcs: &[u8], dsts: &[u8]) -> Instruction {
        Instruction::new(OpClass::Mma, srcs, dsts)
    }

    #[test]
    fn ccu_first_alloc_all_miss_then_reuse_hits() {
        let mut c = Collector::new(8);
        let mut r = rng();
        let i1 = mma(&[1, 2, 3], &[10]);
        let res = c.alloc_ccu(0, &i1, 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.len(), 3);
        assert!(!c.ready());
        c.bank_operand_arrived(0, 1, false);
        c.bank_operand_arrived(1, 2, false);
        c.bank_operand_arrived(2, 3, false);
        assert!(c.ready());
        c.dispatched(true);
        assert!(!c.occupied);
        // same warp reuses r2, r3
        let i2 = mma(&[2, 3, 4], &[11]);
        let res = c.alloc_ccu(0, &i2, 5, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 2);
        assert_eq!(res.misses, vec![(2, 4)]);
        assert!(!res.flushed);
    }

    #[test]
    fn ccu_flushes_on_owner_change() {
        let mut c = Collector::new(8);
        let mut r = rng();
        c.alloc_ccu(0, &mma(&[1], &[2]), 0, &mut r, &mut reuse_guided_victim);
        c.bank_operand_arrived(0, 1, false);
        c.dispatched(true);
        let res = c.alloc_ccu(3, &mma(&[1], &[2]), 1, &mut r, &mut reuse_guided_victim);
        assert!(res.flushed, "different warp must flush");
        assert_eq!(res.hits, 0);
        assert_eq!(c.owner, Some(3));
    }

    #[test]
    fn ccu_duplicate_source_served_from_ct() {
        let mut c = Collector::new(8);
        let mut r = rng();
        // r7 appears twice: second occurrence hits the entry allocated for
        // the first
        let res = c.alloc_ccu(0, &mma(&[7, 7], &[1]), 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 1);
        assert_eq!(res.misses.len(), 1);
    }

    #[test]
    fn ccu_writeback_policy() {
        let mut c = Collector::new(8);
        let mut r = rng();
        c.alloc_ccu(0, &mma(&[1], &[9]), 0, &mut r, &mut reuse_guided_victim);
        c.bank_operand_arrived(0, 1, false);
        c.dispatched(true);
        // near write allocates
        assert!(c.ccu_writeback(0, 9, true, &mut r, &mut reuse_guided_victim, false));
        assert!(c.ct.lookup(9).is_some());
        // far write misses and is filtered
        assert!(!c.ccu_writeback(0, 20, false, &mut r, &mut reuse_guided_victim, false));
        assert!(c.ct.lookup(20).is_none());
        // far write with filter disabled allocates
        assert!(c.ccu_writeback(0, 21, false, &mut r, &mut reuse_guided_victim, true));
        // wrong warp ignored
        assert!(!c.ccu_writeback(2, 22, true, &mut r, &mut reuse_guided_victim, false));
        // hit updates even when far
        assert!(c.ccu_writeback(0, 9, false, &mut r, &mut reuse_guided_victim, false));
        let e = c.ct.entry(c.ct.lookup(9).unwrap());
        assert!(!e.near);
    }

    #[test]
    fn ocu_never_hits() {
        let mut c = Collector::new(8);
        let i = mma(&[1, 2], &[3]);
        let res = c.alloc_ocu(0, &i, 0);
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.len(), 2);
        c.bank_operand_arrived(0, 1, false);
        c.bank_operand_arrived(1, 2, false);
        c.dispatched(false);
        let res = c.alloc_ocu(0, &i, 1);
        assert_eq!(res.hits, 0, "OCU has no cache");
        assert_eq!(res.misses.len(), 2);
    }

    // ---- BOW BOC ----

    #[test]
    fn boc_window_hits_and_slides() {
        let mut c = Collector::new(8);
        // i1 fetches r1, r2
        let r1 = c.alloc_boc(0, &mma(&[1, 2], &[3]), 0, 3);
        assert_eq!(r1.hits, 0);
        c.bank_operand_arrived(0, 1, true);
        c.bank_operand_arrived(1, 2, true);
        c.dispatched(true);
        // i2 reuses r1 (present), needs r4
        let r2 = c.alloc_boc(0, &mma(&[1, 4], &[5]), 1, 3);
        assert_eq!(r2.hits, 1);
        assert_eq!(r2.misses, vec![(1, 4)]);
        c.bank_operand_arrived(1, 4, true);
        c.dispatched(true);
        // fill the window beyond 3: r1's entry slides out
        c.alloc_boc(0, &mma(&[6], &[7]), 2, 3);
        c.bank_operand_arrived(0, 6, true);
        c.dispatched(true);
        c.alloc_boc(0, &mma(&[8], &[9]), 3, 3);
        c.bank_operand_arrived(0, 8, true);
        c.dispatched(true);
        assert_eq!(c.window.len(), 3);
        // r2 only appeared in i1, which has slid out (window = i3,i4,i5)
        let r5 = c.alloc_boc(0, &mma(&[2], &[10]), 4, 3);
        assert_eq!(r5.hits, 0, "r2 slid out of the window");
    }

    #[test]
    fn boc_writeback_only_within_window() {
        let mut c = Collector::new(8);
        c.alloc_boc(0, &mma(&[1], &[3]), 0, 2);
        let seq1 = c.cur_seq;
        c.bank_operand_arrived(0, 1, true);
        c.dispatched(true);
        // dst r3 still in window: captured
        assert!(c.boc_writeback(seq1, 3));
        // subsequent instr can hit r3
        let r = c.alloc_boc(0, &mma(&[3], &[4]), 1, 2);
        assert_eq!(r.hits, 1);
        c.dispatched(true);
        // slide seq1 out
        c.alloc_boc(0, &mma(&[5], &[6]), 2, 2);
        c.dispatched(true);
        assert!(!c.boc_writeback(seq1, 3), "slid out -> RF only");
    }
}
