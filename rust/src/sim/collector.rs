//! Collector units: baseline OCU, Malekeh CCU (§III-C), BOW BOC (§VI-B),
//! and the per-warp RFC cache tables (§VI-A).
//!
//! A single `Collector` struct covers OCU/CCU (the CCU is an OCU plus a
//! cache table and control); BOW's sliding window lives in the same struct
//! (`window`) and is only populated for the BOW scheme. This module is
//! policy-free mechanism: *which* entry gets evicted is decided by the
//! [`VictimFn`] the caller (a [`crate::sim::policy::CachePolicy`]) passes
//! in — the policy layer's `replacement` decision point.
//!
//! Everything here sits on the per-cycle hot path, so the storage is flat
//! and fixed-capacity: the cache table is an inline `[CtEntry; MAX_CT]`,
//! an allocation result carries its misses in an inline [`MissList`], and
//! a BOW window row is an inline register array — no per-event heap
//! traffic (see `docs/EXPERIMENTS.md` §Perf, PR 5).

use std::collections::VecDeque;

use crate::isa::{Instruction, MAX_DST, MAX_SRC};
use crate::util::Rng;

/// Upper bound on cache-table entries (config.ct_entries must not exceed).
pub const MAX_CT: usize = 16;

/// Victim chooser invoked when a full cache table must evict — the policy
/// layer's `replacement` decision point. Called only when no invalid entry
/// exists; must return an *unlocked* entry index, or `None` to refuse the
/// allocation. All randomness must come from the passed [`Rng`].
pub type VictimFn<'a> = &'a mut dyn FnMut(&CacheTable, &mut Rng) -> Option<usize>;

/// One cache-table entry (§III-C: tag, lock, reuse distance, LRU).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CtEntry {
    /// Register tag (one byte, §III-C).
    pub reg: u8,
    /// Entry holds a live value.
    pub valid: bool,
    /// Pinned: operand of the instruction occupying the CCU.
    pub locked: bool,
    /// Compiler reuse-distance bit of the value (true = near).
    pub near: bool,
    /// Value entered via the writeback port (Fig-16 reuse accounting).
    pub from_wb: bool,
    /// LRU priority (higher = more recent).
    pub lru: u32,
    /// Insertion tick (FIFO-style policies; stable across tag-hit
    /// updates, so an entry keeps its queue position when refreshed).
    pub inserted: u32,
}

/// Fully-associative register cache with the paper's replacement policy.
///
/// Storage is a flat inline array (`n <= MAX_CT`), so cloning or flushing
/// a table never touches the heap.
#[derive(Debug, Clone)]
pub struct CacheTable {
    entries: [CtEntry; MAX_CT],
    n: u8,
    tick: u32,
}

impl CacheTable {
    /// `n` entries (8 in the paper).
    pub fn new(n: usize) -> Self {
        assert!(n <= MAX_CT && n >= 1);
        CacheTable { entries: [CtEntry::default(); MAX_CT], n: n as u8, tick: 0 }
    }

    /// Invalidate everything (CCU reallocation to a new warp, §III-C1).
    pub fn flush(&mut self) {
        for e in self.live_mut() {
            *e = CtEntry::default();
        }
        self.tick = 0;
    }

    /// The live entry region (only indices `< n` are ever written).
    #[inline]
    fn live(&self) -> &[CtEntry] {
        &self.entries[..self.n as usize]
    }

    /// Mutable live entry region.
    #[inline]
    fn live_mut(&mut self) -> &mut [CtEntry] {
        &mut self.entries[..self.n as usize]
    }

    /// Find a valid entry holding `reg`.
    pub fn lookup(&self, reg: u8) -> Option<usize> {
        self.live().iter().position(|e| e.valid && e.reg == reg)
    }

    /// Bump LRU recency of entry `i`.
    pub fn touch(&mut self, i: usize) {
        self.tick += 1;
        let t = self.tick;
        self.live_mut()[i].lru = t;
    }

    /// Any valid entry with near reuse? (the bit sent to the scheduler over
    /// port R, §III-C).
    pub fn has_near_value(&self) -> bool {
        self.live().iter().any(|e| e.valid && e.near)
    }

    /// Any valid entries at all?
    pub fn has_values(&self) -> bool {
        self.live().iter().any(|e| e.valid)
    }

    /// Count of valid entries.
    pub fn valid_count(&self) -> usize {
        self.live().iter().filter(|e| e.valid).count()
    }

    /// Registers of all valid entries (allocating convenience; the hot
    /// path uses [`CacheTable::valid_regs_into`] with a caller-owned
    /// scratch buffer instead).
    pub fn valid_regs(&self) -> Vec<u8> {
        self.live().iter().filter(|e| e.valid).map(|e| e.reg).collect()
    }

    /// Registers of all valid entries, written into `out` (cleared first).
    /// The RFC write-back flush calls this every warp deactivation; a
    /// reused buffer stops growing after warm-up, so the steady state is
    /// allocation-free.
    pub fn valid_regs_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend(self.live().iter().filter(|e| e.valid).map(|e| e.reg));
    }

    /// Unlock all entries (instruction dispatched, §III-C1).
    pub fn unlock_all(&mut self) {
        for e in self.live_mut() {
            e.locked = false;
        }
    }

    /// Entry accessor for tests / energy accounting.
    pub fn entry(&self, i: usize) -> &CtEntry {
        &self.live()[i]
    }

    /// Mutable entry accessor.
    pub fn entry_mut(&mut self, i: usize) -> &mut CtEntry {
        &mut self.live_mut()[i]
    }

    /// Entry slice (victim choosers inspect the whole table).
    pub fn entries(&self) -> &[CtEntry] {
        self.live()
    }

    /// Install `(reg, near, locked)`, evicting through `victim` if needed.
    ///
    /// Mechanism common to every policy: a present tag is updated in place
    /// (tags must stay unique) and invalid entries are filled first; only
    /// when the table is full does `victim` choose the replacement — the
    /// policy layer's `replacement` decision point (the paper's §IV-A1
    /// chooser is [`reuse_guided_victim`]). Returns the index, or `None`
    /// if `victim` refuses (e.g. every entry is locked).
    pub fn allocate(
        &mut self,
        reg: u8,
        near: bool,
        locked: bool,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> Option<usize> {
        // tag already present: update in place (tags must stay unique)
        if let Some(i) = self.lookup(reg) {
            if self.live()[i].locked && !locked {
                // a locked entry keeps its pin; just refresh recency/bits
                self.live_mut()[i].near = near;
                self.touch(i);
                return Some(i);
            }
            self.tick += 1;
            let t = self.tick;
            let inserted = self.live()[i].inserted;
            self.live_mut()[i] = CtEntry {
                reg,
                valid: true,
                locked,
                near,
                from_wb: false,
                lru: t,
                inserted,
            };
            return Some(i);
        }
        // invalid first; the policy decides only among live entries
        let i = match self.live().iter().position(|e| !e.valid) {
            Some(i) => i,
            None => victim(&*self, rng)?,
        };
        self.tick += 1;
        let t = self.tick;
        self.live_mut()[i] = CtEntry {
            reg,
            valid: true,
            locked,
            near,
            from_wb: false,
            lru: t,
            inserted: t,
        };
        Some(i)
    }

    /// Least-recently-used unlocked entry (the plain-LRU building block).
    pub fn lru_victim(&self) -> Option<usize> {
        self.live()
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.locked)
            .min_by_key(|(_, e)| e.lru)
            .map(|(i, _)| i)
    }
}

/// The paper's replacement chooser (§IV-A1), after invalid-first: a random
/// unlocked entry among those with *far* reuse, otherwise LRU.
///
/// Two passes over the (≤ [`MAX_CT`]) entries instead of collecting the
/// candidate set into a `Vec`: the first counts the far unlocked entries,
/// the second resolves the drawn ordinal to its index. The RNG sees the
/// same single `below(count)` draw with the same bound and the same
/// ordinal→entry mapping as the old collecting version, so both the choice
/// and the stream position are bit-identical — with zero allocation
/// (`ct_reuse_guided_matches_collecting_reference` pins this).
pub fn reuse_guided_victim(ct: &CacheTable, rng: &mut Rng) -> Option<usize> {
    fn far(e: &CtEntry) -> bool {
        !e.locked && !e.near
    }
    let nfar = ct.entries().iter().filter(|e| far(e)).count();
    if nfar == 0 {
        return ct.lru_victim();
    }
    let k = rng.below(nfar);
    ct.entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| far(e))
        .nth(k)
        .map(|(i, _)| i)
}

/// Plain LRU over unlocked entries (Fig 17's traditional replacement; no
/// RNG draws, matching the pre-refactor `traditional` path bit-exactly).
pub fn plain_lru_victim(ct: &CacheTable, _rng: &mut Rng) -> Option<usize> {
    ct.lru_victim()
}

/// Register slots one instruction contributes to a BOW window row
/// (sources + destinations).
pub const BOC_REGS: usize = MAX_SRC + MAX_DST;

/// One instruction's register set inside a BOW sliding window. Inline
/// fixed-capacity storage: pushing a row into the window copies a few
/// dozen bytes in place, never a heap block.
#[derive(Debug, Clone, Copy)]
pub struct BocInstr {
    /// Issue sequence number (matches writebacks to window slots).
    pub seq: u64,
    /// (reg, value present, is destination); first `nregs` valid.
    regs: [(u8, bool, bool); BOC_REGS],
    nregs: u8,
}

impl BocInstr {
    /// Empty row for sequence number `seq`.
    fn new(seq: u64) -> Self {
        BocInstr { seq, regs: [(0, false, false); BOC_REGS], nregs: 0 }
    }

    /// Append one register slot; panics past `BOC_REGS` (an instruction
    /// has at most `MAX_SRC + MAX_DST` operands by ISA construction).
    fn push(&mut self, reg: u8, present: bool, is_dst: bool) {
        self.regs[self.nregs as usize] = (reg, present, is_dst);
        self.nregs += 1;
    }

    /// The valid register slots.
    #[inline]
    pub fn regs(&self) -> &[(u8, bool, bool)] {
        &self.regs[..self.nregs as usize]
    }

    /// Mutable valid register slots (writeback capture flips `present`).
    #[inline]
    pub fn regs_mut(&mut self) -> &mut [(u8, bool, bool)] {
        &mut self.regs[..self.nregs as usize]
    }
}

/// Fixed-capacity list of `(slot, reg)` source operands that missed the
/// collector cache and must be fetched from the RF banks. Inline storage
/// (an instruction has at most [`MAX_SRC`] sources), so building one per
/// issued instruction allocates nothing. Derefs to a slice for iteration
/// and comparisons.
#[derive(Debug, Clone, Copy, Default)]
pub struct MissList {
    items: [(u8, u8); MAX_SRC],
    len: u8,
}

/// Equality over the *live* entries only — `retain` compacts in place and
/// leaves stale values beyond `len`, which must never make two logically
/// equal lists compare unequal.
impl PartialEq for MissList {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for MissList {}

impl MissList {
    /// Append one missing `(slot, reg)`; panics past [`MAX_SRC`].
    #[inline]
    pub fn push(&mut self, slot: u8, reg: u8) {
        self.items[self.len as usize] = (slot, reg);
        self.len += 1;
    }

    /// Valid entries as a slice.
    #[inline]
    pub fn as_slice(&self) -> &[(u8, u8)] {
        &self.items[..self.len as usize]
    }

    /// Number of misses.
    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// No misses recorded?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Keep only the entries `keep` returns true for, preserving order —
    /// the in-place replacement for the old drain-into-a-new-`Vec`
    /// filtering in the RFC policies.
    pub fn retain(&mut self, mut keep: impl FnMut(u8, u8) -> bool) {
        let mut kept = 0u8;
        for i in 0..self.len as usize {
            let (slot, reg) = self.items[i];
            if keep(slot, reg) {
                self.items[kept as usize] = (slot, reg);
                kept += 1;
            }
        }
        self.len = kept;
    }
}

impl std::ops::Deref for MissList {
    type Target = [(u8, u8)];

    fn deref(&self) -> &[(u8, u8)] {
        self.as_slice()
    }
}

/// Result of allocating an instruction to a collector. `Copy`-sized and
/// heap-free: the hot issue loop returns one per instruction.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocResult {
    /// Source slots that must be fetched from the banks: (slot, reg).
    pub misses: MissList,
    /// Source operands served from the cache.
    pub hits: u32,
    /// Hits on values captured via the writeback port (Fig 16: proves
    /// cache writes are reused).
    pub wb_reuse: u32,
    /// The cache table was flushed (ownership change).
    pub flushed: bool,
}

/// A collector unit (OCU / CCU / BOC depending on scheme flags).
#[derive(Debug, Clone)]
pub struct Collector {
    /// An un-dispatched instruction occupies this unit.
    pub occupied: bool,
    /// Warp whose values live in the cache table (survives dispatch).
    pub owner: Option<u8>,
    /// The occupying instruction.
    pub instr: Instruction,
    /// Cycle the occupying instruction was issued.
    pub issue_cycle: u64,
    /// Ready bitmask over source slots.
    pub src_ready: u8,
    /// Sequence number of the occupying instruction (BOW writeback match).
    pub cur_seq: u64,
    /// Cache table (CCU variants; OCU uses it as a plain operand buffer).
    pub ct: CacheTable,
    /// BOW sliding window (empty unless scheme is BOW).
    pub window: VecDeque<BocInstr>,
    seq_counter: u64,
}

impl Collector {
    /// New collector with `ct_entries` cache-table entries.
    pub fn new(ct_entries: usize) -> Self {
        Collector {
            occupied: false,
            owner: None,
            instr: Instruction::new(crate::isa::OpClass::Ctrl, &[], &[]),
            issue_cycle: 0,
            src_ready: 0,
            cur_seq: 0,
            ct: CacheTable::new(ct_entries),
            window: VecDeque::new(),
            seq_counter: 0,
        }
    }

    /// All valid source operands ready (dispatch condition, §III-C1)?
    #[inline]
    pub fn ready(&self) -> bool {
        self.occupied && self.src_ready.count_ones() as u8 == self.instr.nsrc
    }

    /// Mark source slot ready (operand arrived over port S).
    #[inline]
    pub fn deliver(&mut self, slot: u8) {
        self.src_ready |= 1 << slot;
    }

    /// Allocate as a *baseline OCU*: no caching, every source fetched.
    pub fn alloc_ocu(&mut self, warp: u8, instr: &Instruction, now: u64) -> AllocResult {
        debug_assert!(!self.occupied);
        self.occupied = true;
        self.owner = Some(warp);
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        self.ct.flush();
        let mut res = AllocResult::default();
        for (slot, &reg) in instr.sources().iter().enumerate() {
            res.misses.push(slot as u8, reg);
        }
        res
    }

    /// Allocate as a *Malekeh CCU* (§III-C1): flush on ownership change,
    /// tag-check every source, lock hits, allocate entries for misses
    /// (evicting through the policy's `victim` chooser).
    pub fn alloc_ccu(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> AllocResult {
        // RNG-identical to the pre-admission code: the always-true
        // predicate reproduces the original per-source sequence exactly
        self.alloc_ccu_admit(warp, instr, now, rng, victim, &mut |_, _| true)
    }

    /// [`Collector::alloc_ccu`] with a cache-*admission* predicate
    /// (`admit(slot, reg)`): a missing source the predicate rejects is
    /// still fetched from the banks but gets **no** cache-table entry —
    /// the hook selective-caching policies (e.g. the compression scheme's
    /// compressibility signal) use to keep uncacheable values out of the
    /// table. Hits are always served regardless of the predicate (the
    /// value is already resident).
    pub fn alloc_ccu_admit(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
        admit: &mut dyn FnMut(usize, u8) -> bool,
    ) -> AllocResult {
        debug_assert!(!self.occupied);
        let mut res = AllocResult::default();
        if self.owner != Some(warp) {
            self.ct.flush();
            res.flushed = self.owner.is_some();
            self.owner = Some(warp);
        }
        self.occupied = true;
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        for (slot, &reg) in instr.sources().iter().enumerate() {
            let near = instr.src_is_near(slot);
            if let Some(i) = self.ct.lookup(reg) {
                // hit: value already in the CCU — no bank read
                let e = self.ct.entry_mut(i);
                e.locked = true;
                e.near = near;
                if e.from_wb {
                    e.from_wb = false;
                    res.wb_reuse += 1;
                }
                self.ct.touch(i);
                self.src_ready |= 1 << slot;
                res.hits += 1;
            } else if admit(slot, reg) {
                let idx = self
                    .ct
                    .allocate(reg, near, true, rng, &mut *victim)
                    .expect("CT must fit all sources (ct_entries >= MAX_SRC)");
                debug_assert!(idx < MAX_CT);
                res.misses.push(slot as u8, reg);
            } else {
                // not admitted: bank fetch only, no table entry
                res.misses.push(slot as u8, reg);
            }
        }
        res
    }

    /// Allocate as a *BOW BOC*: check the sliding window, then append this
    /// instruction's registers to it.
    pub fn alloc_boc(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        window_len: usize,
    ) -> AllocResult {
        debug_assert!(!self.occupied);
        let mut res = AllocResult::default();
        self.occupied = true;
        self.owner = Some(warp);
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        self.seq_counter += 1;
        self.cur_seq = self.seq_counter;

        // the row is built inline (fixed capacity) and copied into the
        // window ring buffer — no per-instruction heap traffic
        let mut row = BocInstr::new(self.cur_seq);
        for (slot, &reg) in instr.sources().iter().enumerate() {
            // newest-first search over the window + regs already added for
            // this instruction (duplicate sources)
            let hit = row.regs().iter().any(|&(r, p, _)| r == reg && p)
                || self
                    .window
                    .iter()
                    .rev()
                    .any(|bi| bi.regs().iter().any(|&(r, p, _)| r == reg && p));
            if hit {
                self.src_ready |= 1 << slot;
                res.hits += 1;
                row.push(reg, true, false);
            } else {
                res.misses.push(slot as u8, reg);
                row.push(reg, false, false); // present once fetched
            }
        }
        for &reg in instr.dests() {
            row.push(reg, false, true); // present at writeback
        }
        self.window.push_back(row);
        while self.window.len() > window_len {
            self.window.pop_front(); // slid out: pending dsts go RF-only
        }
        res
    }

    /// Operand fetched from the banks: mark the slot ready and (BOW) mark
    /// the value present in the window.
    pub fn bank_operand_arrived(&mut self, slot: u8, reg: u8, bow: bool) {
        self.deliver(slot);
        if bow {
            if let Some(bi) = self.window.iter_mut().find(|bi| bi.seq == self.cur_seq) {
                for e in bi.regs_mut() {
                    if e.0 == reg && !e.2 {
                        e.1 = true;
                    }
                }
            }
        }
    }

    /// Dispatch bookkeeping: the unit becomes free; a CCU keeps (and
    /// unlocks) its contents, an OCU drops them.
    pub fn dispatched(&mut self, caching: bool) {
        self.occupied = false;
        self.src_ready = 0;
        if caching {
            self.ct.unlock_all();
        } else {
            self.ct.flush();
        }
    }

    /// CCU destination writeback (§IV-A2): update on hit; allocate only if
    /// `near` (write filter) unless `no_write_filter`, evicting through
    /// the policy's `victim` chooser. Returns true if the cache captured
    /// the value.
    pub fn ccu_writeback(
        &mut self,
        warp: u8,
        reg: u8,
        near: bool,
        rng: &mut Rng,
        victim: VictimFn,
        no_write_filter: bool,
    ) -> bool {
        if self.owner != Some(warp) {
            return false;
        }
        if let Some(i) = self.ct.lookup(reg) {
            let e = self.ct.entry_mut(i);
            e.near = near;
            e.from_wb = true;
            self.ct.touch(i);
            return true;
        }
        if near || no_write_filter {
            if let Some(i) = self.ct.allocate(reg, near, false, rng, victim) {
                self.ct.entry_mut(i).from_wb = true;
                return true;
            }
            return false;
        }
        false
    }

    /// BOW destination writeback: if the producing instruction is still in
    /// the window, the value is captured there. Returns true if captured.
    pub fn boc_writeback(&mut self, seq: u64, reg: u8) -> bool {
        if let Some(bi) = self.window.iter_mut().find(|bi| bi.seq == seq) {
            let mut hit = false;
            for e in bi.regs_mut() {
                if e.0 == reg && e.2 {
                    e.1 = true;
                    hit = true;
                }
            }
            hit
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, OpClass};

    fn rng() -> Rng {
        Rng::new(1)
    }

    // ---- CacheTable ----

    #[test]
    fn ct_lookup_and_flush() {
        let mut ct = CacheTable::new(4);
        assert!(ct.lookup(5).is_none());
        ct.allocate(5, true, false, &mut rng(), &mut reuse_guided_victim);
        assert!(ct.lookup(5).is_some());
        assert!(ct.has_near_value());
        ct.flush();
        assert!(ct.lookup(5).is_none());
        assert!(!ct.has_values());
    }

    #[test]
    fn ct_replacement_prefers_invalid_then_far() {
        let mut ct = CacheTable::new(3);
        let mut r = rng();
        ct.allocate(1, true, false, &mut r, &mut reuse_guided_victim); // near
        ct.allocate(2, false, false, &mut r, &mut reuse_guided_victim); // far
        ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim); // near
        // table full; new alloc must evict the far entry (reg 2)
        ct.allocate(4, true, false, &mut r, &mut reuse_guided_victim);
        assert!(ct.lookup(2).is_none(), "far entry must be the victim");
        assert!(ct.lookup(1).is_some() && ct.lookup(3).is_some());
    }

    #[test]
    fn ct_replacement_falls_back_to_lru_when_all_near() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, true, false, &mut r, &mut reuse_guided_victim);
        ct.allocate(2, true, false, &mut r, &mut reuse_guided_victim);
        ct.touch(ct.lookup(1).unwrap()); // reg1 most recent
        ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim);
        assert!(ct.lookup(2).is_none(), "LRU (reg 2) must be evicted");
        assert!(ct.lookup(1).is_some());
    }

    #[test]
    fn ct_locked_entries_never_evicted() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, false, true, &mut r, &mut reuse_guided_victim); // locked far
        ct.allocate(2, false, true, &mut r, &mut reuse_guided_victim); // locked far
        assert_eq!(ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim), None);
        assert!(ct.lookup(1).is_some() && ct.lookup(2).is_some());
    }

    #[test]
    fn ct_traditional_uses_plain_lru() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, false, false, &mut r, &mut plain_lru_victim); // far, older
        ct.allocate(2, true, false, &mut r, &mut plain_lru_victim); // near, newer
        // traditional LRU evicts reg 1 (oldest) even though reuse-aware
        // policy would also pick it; now make near entry the oldest:
        ct.touch(ct.lookup(1).unwrap());
        ct.allocate(3, false, false, &mut r, &mut plain_lru_victim);
        assert!(
            ct.lookup(2).is_none(),
            "plain LRU must evict the near entry when it is oldest"
        );
    }

    // ---- CCU allocation ----

    fn mma(srcs: &[u8], dsts: &[u8]) -> Instruction {
        Instruction::new(OpClass::Mma, srcs, dsts)
    }

    #[test]
    fn ccu_first_alloc_all_miss_then_reuse_hits() {
        let mut c = Collector::new(8);
        let mut r = rng();
        let i1 = mma(&[1, 2, 3], &[10]);
        let res = c.alloc_ccu(0, &i1, 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.len(), 3);
        assert!(!c.ready());
        c.bank_operand_arrived(0, 1, false);
        c.bank_operand_arrived(1, 2, false);
        c.bank_operand_arrived(2, 3, false);
        assert!(c.ready());
        c.dispatched(true);
        assert!(!c.occupied);
        // same warp reuses r2, r3
        let i2 = mma(&[2, 3, 4], &[11]);
        let res = c.alloc_ccu(0, &i2, 5, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 2);
        assert_eq!(res.misses.as_slice(), &[(2, 4)]);
        assert!(!res.flushed);
    }

    #[test]
    fn ccu_flushes_on_owner_change() {
        let mut c = Collector::new(8);
        let mut r = rng();
        c.alloc_ccu(0, &mma(&[1], &[2]), 0, &mut r, &mut reuse_guided_victim);
        c.bank_operand_arrived(0, 1, false);
        c.dispatched(true);
        let res = c.alloc_ccu(3, &mma(&[1], &[2]), 1, &mut r, &mut reuse_guided_victim);
        assert!(res.flushed, "different warp must flush");
        assert_eq!(res.hits, 0);
        assert_eq!(c.owner, Some(3));
    }

    #[test]
    fn ccu_duplicate_source_served_from_ct() {
        let mut c = Collector::new(8);
        let mut r = rng();
        // r7 appears twice: second occurrence hits the entry allocated for
        // the first
        let res = c.alloc_ccu(0, &mma(&[7, 7], &[1]), 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 1);
        assert_eq!(res.misses.len(), 1);
    }

    #[test]
    fn ccu_admit_predicate_gates_table_entries_not_fetches() {
        let mut c = Collector::new(8);
        let mut r = rng();
        // admit only registers < 10: r3 gets an entry, r20 is fetch-only
        let res = c.alloc_ccu_admit(
            0,
            &mma(&[3, 20], &[1]),
            0,
            &mut r,
            &mut reuse_guided_victim,
            &mut |_, reg| reg < 10,
        );
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.as_slice(), &[(0, 3), (1, 20)], "both still fetched");
        assert!(c.ct.lookup(3).is_some(), "admitted miss gets an entry");
        assert!(c.ct.lookup(20).is_none(), "rejected miss gets none");
        c.bank_operand_arrived(0, 3, false);
        c.bank_operand_arrived(1, 20, false);
        assert!(c.ready(), "readiness is slot-based, not table-based");
        c.dispatched(true);
        // a later instruction hits the admitted value only
        let res = c.alloc_ccu_admit(
            0,
            &mma(&[3, 20], &[2]),
            1,
            &mut r,
            &mut reuse_guided_victim,
            &mut |_, reg| reg < 10,
        );
        assert_eq!(res.hits, 1);
        assert_eq!(res.misses.as_slice(), &[(1, 20)]);
    }

    #[test]
    fn ccu_admit_always_true_matches_alloc_ccu_and_rng_stream() {
        // the delegation contract: an always-admit predicate must be
        // bit-identical to alloc_ccu, including the RNG stream position
        let seed = 0xFEED;
        let mut ca = Collector::new(8);
        let mut cb = Collector::new(8);
        let mut ra = Rng::new(seed);
        let mut rb = Rng::new(seed);
        for (k, instr) in [
            mma(&[1, 2, 3], &[10]),
            mma(&[2, 3, 4], &[11]),
            mma(&[9, 9, 1], &[12]),
        ]
        .iter()
        .enumerate()
        {
            let a = ca.alloc_ccu(0, instr, k as u64, &mut ra, &mut reuse_guided_victim);
            let b = cb.alloc_ccu_admit(
                0,
                instr,
                k as u64,
                &mut rb,
                &mut reuse_guided_victim,
                &mut |_, _| true,
            );
            assert_eq!(a.hits, b.hits);
            assert_eq!(a.misses, b.misses);
            assert_eq!(a.wb_reuse, b.wb_reuse);
            for (slot, &reg) in instr.sources().iter().enumerate() {
                ca.bank_operand_arrived(slot as u8, reg, false);
                cb.bank_operand_arrived(slot as u8, reg, false);
            }
            ca.dispatched(true);
            cb.dispatched(true);
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "RNG stream position diverged");
    }

    #[test]
    fn ccu_writeback_policy() {
        let mut c = Collector::new(8);
        let mut r = rng();
        c.alloc_ccu(0, &mma(&[1], &[9]), 0, &mut r, &mut reuse_guided_victim);
        c.bank_operand_arrived(0, 1, false);
        c.dispatched(true);
        // near write allocates
        assert!(c.ccu_writeback(0, 9, true, &mut r, &mut reuse_guided_victim, false));
        assert!(c.ct.lookup(9).is_some());
        // far write misses and is filtered
        assert!(!c.ccu_writeback(0, 20, false, &mut r, &mut reuse_guided_victim, false));
        assert!(c.ct.lookup(20).is_none());
        // far write with filter disabled allocates
        assert!(c.ccu_writeback(0, 21, false, &mut r, &mut reuse_guided_victim, true));
        // wrong warp ignored
        assert!(!c.ccu_writeback(2, 22, true, &mut r, &mut reuse_guided_victim, false));
        // hit updates even when far
        assert!(c.ccu_writeback(0, 9, false, &mut r, &mut reuse_guided_victim, false));
        let e = c.ct.entry(c.ct.lookup(9).unwrap());
        assert!(!e.near);
    }

    #[test]
    fn ocu_never_hits() {
        let mut c = Collector::new(8);
        let i = mma(&[1, 2], &[3]);
        let res = c.alloc_ocu(0, &i, 0);
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.len(), 2);
        c.bank_operand_arrived(0, 1, false);
        c.bank_operand_arrived(1, 2, false);
        c.dispatched(false);
        let res = c.alloc_ocu(0, &i, 1);
        assert_eq!(res.hits, 0, "OCU has no cache");
        assert_eq!(res.misses.len(), 2);
    }

    // ---- BOW BOC ----

    #[test]
    fn boc_window_hits_and_slides() {
        let mut c = Collector::new(8);
        // i1 fetches r1, r2
        let r1 = c.alloc_boc(0, &mma(&[1, 2], &[3]), 0, 3);
        assert_eq!(r1.hits, 0);
        c.bank_operand_arrived(0, 1, true);
        c.bank_operand_arrived(1, 2, true);
        c.dispatched(true);
        // i2 reuses r1 (present), needs r4
        let r2 = c.alloc_boc(0, &mma(&[1, 4], &[5]), 1, 3);
        assert_eq!(r2.hits, 1);
        assert_eq!(r2.misses.as_slice(), &[(1, 4)]);
        c.bank_operand_arrived(1, 4, true);
        c.dispatched(true);
        // fill the window beyond 3: r1's entry slides out
        c.alloc_boc(0, &mma(&[6], &[7]), 2, 3);
        c.bank_operand_arrived(0, 6, true);
        c.dispatched(true);
        c.alloc_boc(0, &mma(&[8], &[9]), 3, 3);
        c.bank_operand_arrived(0, 8, true);
        c.dispatched(true);
        assert_eq!(c.window.len(), 3);
        // r2 only appeared in i1, which has slid out (window = i3,i4,i5)
        let r5 = c.alloc_boc(0, &mma(&[2], &[10]), 4, 3);
        assert_eq!(r5.hits, 0, "r2 slid out of the window");
    }

    // ---- zero-allocation scratch paths (PR 5) ----

    /// The pre-refactor allocating chooser, kept verbatim as the test
    /// reference for the two-pass [`reuse_guided_victim`].
    fn reuse_guided_victim_collecting(ct: &CacheTable, rng: &mut Rng) -> Option<usize> {
        let far: Vec<usize> = ct
            .entries()
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.locked && !e.near)
            .map(|(i, _)| i)
            .collect();
        if !far.is_empty() {
            Some(far[rng.below(far.len())])
        } else {
            ct.lru_victim()
        }
    }

    #[test]
    fn ct_reuse_guided_matches_collecting_reference() {
        // drive many random table states: the zero-alloc two-pass chooser
        // must pick the same victim from the same RNG state AND leave the
        // stream at the same position (bit-identity of whole runs depends
        // on both)
        let mut gen = Rng::new(99);
        for round in 0..500u64 {
            let n = gen.below(MAX_CT) + 1;
            let mut ct = CacheTable::new(n);
            let fill = gen.below(n) + 1;
            for k in 0..fill {
                // unique tags per slot; random near/locked classes
                let reg = (k * 8 + gen.below(8)) as u8;
                let near = gen.chance(0.5);
                let locked = gen.chance(0.3);
                ct.allocate(reg, near, locked, &mut Rng::new(round), &mut plain_lru_victim);
            }
            let seed = gen.next_u64();
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            assert_eq!(
                reuse_guided_victim(&ct, &mut ra),
                reuse_guided_victim_collecting(&ct, &mut rb),
                "round {round}: victims diverge"
            );
            assert_eq!(
                ra.next_u64(),
                rb.next_u64(),
                "round {round}: RNG stream position diverges"
            );
        }
    }

    #[test]
    fn ct_valid_regs_into_reuses_capacity_and_matches_alloc_path() {
        let mut ct = CacheTable::new(8);
        let mut r = rng();
        let mut buf = Vec::new();
        let mut warm_cap = 0;
        for round in 0..64u8 {
            ct.flush();
            for k in 0..(round % 8) {
                ct.allocate(
                    k.wrapping_mul(7).wrapping_add(round),
                    k % 2 == 0,
                    false,
                    &mut r,
                    &mut reuse_guided_victim,
                );
            }
            ct.valid_regs_into(&mut buf);
            assert_eq!(
                buf,
                ct.valid_regs(),
                "scratch path must return exactly what the allocating path did"
            );
            if round == 7 {
                // by now the buffer has seen the largest fill (7 entries)
                warm_cap = buf.capacity();
            }
            if round > 7 {
                assert_eq!(buf.capacity(), warm_cap, "no growth after warm-up");
            }
        }
    }

    #[test]
    fn miss_list_push_retain_deref() {
        let mut m = MissList::default();
        assert!(m.is_empty());
        for (slot, reg) in [(0u8, 10u8), (1, 11), (2, 12), (3, 13)] {
            m.push(slot, reg);
        }
        assert_eq!(m.len(), 4);
        assert_eq!(m[1], (1, 11)); // Deref indexing
        // retain keeps order and compacts in place
        m.retain(|slot, _| slot % 2 == 0);
        assert_eq!(m.as_slice(), &[(0, 10), (2, 12)]);
        // equality sees only live entries, not the stale compacted-over
        // storage beyond len
        let mut fresh = MissList::default();
        fresh.push(0, 10);
        fresh.push(2, 12);
        assert_eq!(m, fresh);
        m.retain(|_, _| false);
        assert!(m.is_empty());
        assert_eq!(m, MissList::default());
    }

    #[test]
    fn boc_window_rows_are_fixed_capacity() {
        // a full MMA (6 src + 2 dst) exactly fills one window row
        let mut c = Collector::new(8);
        let i = Instruction::new(OpClass::Mma, &[1, 2, 3, 4, 5, 6], &[7, 8]);
        c.alloc_boc(0, &i, 0, 4);
        let row = c.window.back().unwrap();
        assert_eq!(row.regs().len(), BOC_REGS);
        assert!(row.regs().iter().all(|&(_, p, _)| !p), "nothing present yet");
    }

    #[test]
    fn boc_writeback_only_within_window() {
        let mut c = Collector::new(8);
        c.alloc_boc(0, &mma(&[1], &[3]), 0, 2);
        let seq1 = c.cur_seq;
        c.bank_operand_arrived(0, 1, true);
        c.dispatched(true);
        // dst r3 still in window: captured
        assert!(c.boc_writeback(seq1, 3));
        // subsequent instr can hit r3
        let r = c.alloc_boc(0, &mma(&[3], &[4]), 1, 2);
        assert_eq!(r.hits, 1);
        c.dispatched(true);
        // slide seq1 out
        c.alloc_boc(0, &mma(&[5], &[6]), 2, 2);
        c.dispatched(true);
        assert!(!c.boc_writeback(seq1, 3), "slid out -> RF only");
    }
}
