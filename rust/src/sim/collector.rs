//! Collector units: baseline OCU, Malekeh CCU (§III-C), BOW BOC (§VI-B),
//! and the per-warp RFC cache tables (§VI-A).
//!
//! A single `Collector` struct covers OCU/CCU (the CCU is an OCU plus a
//! cache table and control); BOW's sliding window lives in the same struct
//! (`window`) and is only populated for the BOW scheme. This module is
//! policy-free mechanism: *which* entry gets evicted is decided by the
//! [`VictimFn`] the caller (a [`crate::sim::policy::CachePolicy`]) passes
//! in — the policy layer's `replacement` decision point.
//!
//! Everything here sits on the per-cycle hot path, so the storage is flat
//! and fixed-capacity: the cache table is an inline `[CtEntry; MAX_CT]`,
//! an allocation result carries its misses in an inline [`MissList`], and
//! a BOW window row is an inline register array — no per-event heap
//! traffic (see `docs/EXPERIMENTS.md` §Perf, PR 5).

use std::collections::VecDeque;

use crate::isa::{Instruction, MAX_DST, MAX_SRC};
use crate::sim::exec::pipe_of;
use crate::util::Rng;

/// Upper bound on cache-table entries (config.ct_entries must not exceed).
pub const MAX_CT: usize = 16;

/// Victim chooser invoked when a full cache table must evict — the policy
/// layer's `replacement` decision point. Called only when no invalid entry
/// exists; must return an *unlocked* entry index, or `None` to refuse the
/// allocation. All randomness must come from the passed [`Rng`].
pub type VictimFn<'a> = &'a mut dyn FnMut(&CacheTable, &mut Rng) -> Option<usize>;

/// One cache-table entry (§III-C: tag, lock, reuse distance, LRU).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CtEntry {
    /// Register tag (one byte, §III-C).
    pub reg: u8,
    /// Entry holds a live value.
    pub valid: bool,
    /// Pinned: operand of the instruction occupying the CCU.
    pub locked: bool,
    /// Compiler reuse-distance bit of the value (true = near).
    pub near: bool,
    /// Value entered via the writeback port (Fig-16 reuse accounting).
    pub from_wb: bool,
    /// LRU priority (higher = more recent).
    pub lru: u32,
    /// Insertion tick (FIFO-style policies; stable across tag-hit
    /// updates, so an entry keeps its queue position when refreshed).
    pub inserted: u32,
}

/// Fully-associative register cache with the paper's replacement policy.
///
/// Storage is a flat inline array (`n <= MAX_CT`), so cloning or flushing
/// a table never touches the heap.
#[derive(Debug, Clone)]
pub struct CacheTable {
    entries: [CtEntry; MAX_CT],
    n: u8,
    /// Count of valid entries, maintained by `allocate`/`flush` so the
    /// empty/occupancy checks on the issue hot path are O(1) instead of a
    /// table scan. Invariant: equals `live().filter(valid).count()` —
    /// which is why [`CacheTable::entry_mut`] callers must never toggle
    /// `valid` directly.
    nvalid: u8,
    tick: u32,
}

impl CacheTable {
    /// `n` entries (8 in the paper).
    pub fn new(n: usize) -> Self {
        assert!(n <= MAX_CT && n >= 1);
        CacheTable { entries: [CtEntry::default(); MAX_CT], n: n as u8, nvalid: 0, tick: 0 }
    }

    // simlint: hot
    /// Invalidate everything (CCU reallocation to a new warp, §III-C1).
    ///
    /// Early-returns on an already-empty table: `alloc_ocu` flushes on
    /// every OCU allocation and an OCU table is empty in steady state, so
    /// without this check every issued baseline instruction paid a full
    /// entry-clearing pass for nothing
    /// (`ct_flush_on_empty_table_is_a_no_op` pins the early return).
    pub fn flush(&mut self) {
        if self.nvalid == 0 {
            self.tick = 0;
            return;
        }
        for e in self.live_mut() {
            *e = CtEntry::default();
        }
        self.nvalid = 0;
        self.tick = 0;
    }

    /// The live entry region (only indices `< n` are ever written).
    #[inline]
    fn live(&self) -> &[CtEntry] {
        &self.entries[..self.n as usize]
    }

    /// Mutable live entry region.
    #[inline]
    fn live_mut(&mut self) -> &mut [CtEntry] {
        &mut self.entries[..self.n as usize]
    }

    // simlint: hot
    /// Find a valid entry holding `reg`.
    pub fn lookup(&self, reg: u8) -> Option<usize> {
        self.live().iter().position(|e| e.valid && e.reg == reg)
    }

    // simlint: hot
    /// Bump LRU recency of entry `i`.
    pub fn touch(&mut self, i: usize) {
        self.tick += 1;
        let t = self.tick;
        self.live_mut()[i].lru = t;
    }

    /// Any valid entry with near reuse? (the bit sent to the scheduler over
    /// port R, §III-C).
    pub fn has_near_value(&self) -> bool {
        self.live().iter().any(|e| e.valid && e.near)
    }

    /// Any valid entries at all? O(1): reads the maintained valid count.
    pub fn has_values(&self) -> bool {
        self.nvalid > 0
    }

    /// Count of valid entries. O(1): reads the maintained valid count.
    pub fn valid_count(&self) -> usize {
        self.nvalid as usize
    }

    /// Registers of all valid entries (allocating convenience; the hot
    /// path uses [`CacheTable::valid_regs_into`] with a caller-owned
    /// scratch buffer instead).
    pub fn valid_regs(&self) -> Vec<u8> {
        self.live().iter().filter(|e| e.valid).map(|e| e.reg).collect()
    }

    // simlint: hot
    /// Registers of all valid entries, written into `out` (cleared first).
    /// The RFC write-back flush calls this every warp deactivation; a
    /// reused buffer stops growing after warm-up, so the steady state is
    /// allocation-free.
    pub fn valid_regs_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend(self.live().iter().filter(|e| e.valid).map(|e| e.reg));
    }

    // simlint: hot
    /// Unlock all entries (instruction dispatched, §III-C1).
    pub fn unlock_all(&mut self) {
        for e in self.live_mut() {
            e.locked = false;
        }
    }

    /// Entry accessor for tests / energy accounting.
    pub fn entry(&self, i: usize) -> &CtEntry {
        &self.live()[i]
    }

    /// Mutable entry accessor. Callers may update the class/lock/LRU bits
    /// but must not toggle `valid` — validity transitions go through
    /// [`CacheTable::allocate`] / [`CacheTable::flush`], which maintain
    /// the O(1) valid count.
    pub fn entry_mut(&mut self, i: usize) -> &mut CtEntry {
        &mut self.live_mut()[i]
    }

    /// Entry slice (victim choosers inspect the whole table).
    pub fn entries(&self) -> &[CtEntry] {
        self.live()
    }

    // simlint: hot
    /// Install `(reg, near, locked)`, evicting through `victim` if needed.
    ///
    /// Mechanism common to every policy: a present tag is updated in place
    /// (tags must stay unique) and invalid entries are filled first; only
    /// when the table is full does `victim` choose the replacement — the
    /// policy layer's `replacement` decision point (the paper's §IV-A1
    /// chooser is [`reuse_guided_victim`]). Returns the index, or `None`
    /// if `victim` refuses (e.g. every entry is locked).
    pub fn allocate(
        &mut self,
        reg: u8,
        near: bool,
        locked: bool,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> Option<usize> {
        // tag already present: update in place (tags must stay unique)
        if let Some(i) = self.lookup(reg) {
            if self.live()[i].locked && !locked {
                // a locked entry keeps its pin; just refresh recency/bits
                self.live_mut()[i].near = near;
                self.touch(i);
                return Some(i);
            }
            self.tick += 1;
            let t = self.tick;
            let inserted = self.live()[i].inserted;
            self.live_mut()[i] = CtEntry {
                reg,
                valid: true,
                locked,
                near,
                from_wb: false,
                lru: t,
                inserted,
            };
            return Some(i);
        }
        // invalid first; the policy decides only among live entries
        let i = match self.live().iter().position(|e| !e.valid) {
            Some(i) => {
                self.nvalid += 1; // filling an empty slot; evictions swap in place
                i
            }
            None => victim(&*self, rng)?,
        };
        self.tick += 1;
        let t = self.tick;
        self.live_mut()[i] = CtEntry {
            reg,
            valid: true,
            locked,
            near,
            from_wb: false,
            lru: t,
            inserted: t,
        };
        Some(i)
    }

    // simlint: hot
    /// Least-recently-used unlocked entry (the plain-LRU building block).
    pub fn lru_victim(&self) -> Option<usize> {
        self.live()
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.locked)
            .min_by_key(|(_, e)| e.lru)
            .map(|(i, _)| i)
    }
}

// simlint: hot
/// The paper's replacement chooser (§IV-A1), after invalid-first: a random
/// unlocked entry among those with *far* reuse, otherwise LRU.
///
/// Two passes over the (≤ [`MAX_CT`]) entries instead of collecting the
/// candidate set into a `Vec`: the first counts the far unlocked entries,
/// the second resolves the drawn ordinal to its index. The RNG sees the
/// same single `below(count)` draw with the same bound and the same
/// ordinal→entry mapping as the old collecting version, so both the choice
/// and the stream position are bit-identical — with zero allocation
/// (`ct_reuse_guided_matches_collecting_reference` pins this).
pub fn reuse_guided_victim(ct: &CacheTable, rng: &mut Rng) -> Option<usize> {
    fn far(e: &CtEntry) -> bool {
        !e.locked && !e.near
    }
    let nfar = ct.entries().iter().filter(|e| far(e)).count();
    if nfar == 0 {
        return ct.lru_victim();
    }
    // simlint: allow(rng-discipline) reason="replacement decision point; draws the policy Rng"
    let k = rng.below(nfar);
    ct.entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| far(e))
        .nth(k)
        .map(|(i, _)| i)
}

// simlint: hot
/// Plain LRU over unlocked entries (Fig 17's traditional replacement; no
/// RNG draws, matching the pre-refactor `traditional` path bit-exactly).
pub fn plain_lru_victim(ct: &CacheTable, _rng: &mut Rng) -> Option<usize> {
    ct.lru_victim()
}

/// Register slots one instruction contributes to a BOW window row
/// (sources + destinations).
pub const BOC_REGS: usize = MAX_SRC + MAX_DST;

/// One instruction's register set inside a BOW sliding window. Inline
/// fixed-capacity storage: pushing a row into the window copies a few
/// dozen bytes in place, never a heap block.
#[derive(Debug, Clone, Copy)]
pub struct BocInstr {
    /// Issue sequence number (matches writebacks to window slots).
    pub seq: u64,
    /// (reg, value present, is destination); first `nregs` valid.
    regs: [(u8, bool, bool); BOC_REGS],
    nregs: u8,
}

impl BocInstr {
    /// Empty row for sequence number `seq`.
    fn new(seq: u64) -> Self {
        BocInstr { seq, regs: [(0, false, false); BOC_REGS], nregs: 0 }
    }

    /// Append one register slot; panics past `BOC_REGS` (an instruction
    /// has at most `MAX_SRC + MAX_DST` operands by ISA construction).
    fn push(&mut self, reg: u8, present: bool, is_dst: bool) {
        self.regs[self.nregs as usize] = (reg, present, is_dst);
        self.nregs += 1;
    }

    /// The valid register slots.
    #[inline]
    pub fn regs(&self) -> &[(u8, bool, bool)] {
        &self.regs[..self.nregs as usize]
    }

    /// Mutable valid register slots (writeback capture flips `present`).
    #[inline]
    pub fn regs_mut(&mut self) -> &mut [(u8, bool, bool)] {
        &mut self.regs[..self.nregs as usize]
    }
}

/// Fixed-capacity list of `(slot, reg)` source operands that missed the
/// collector cache and must be fetched from the RF banks. Inline storage
/// (an instruction has at most [`MAX_SRC`] sources), so building one per
/// issued instruction allocates nothing. Derefs to a slice for iteration
/// and comparisons.
#[derive(Debug, Clone, Copy, Default)]
pub struct MissList {
    items: [(u8, u8); MAX_SRC],
    len: u8,
}

/// Equality over the *live* entries only — `retain` compacts in place and
/// leaves stale values beyond `len`, which must never make two logically
/// equal lists compare unequal.
impl PartialEq for MissList {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for MissList {}

impl MissList {
    // simlint: hot
    /// Append one missing `(slot, reg)`; panics past [`MAX_SRC`].
    #[inline]
    pub fn push(&mut self, slot: u8, reg: u8) {
        self.items[self.len as usize] = (slot, reg);
        self.len += 1;
    }

    /// Valid entries as a slice.
    #[inline]
    pub fn as_slice(&self) -> &[(u8, u8)] {
        &self.items[..self.len as usize]
    }

    /// Number of misses.
    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// No misses recorded?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    // simlint: hot
    /// Keep only the entries `keep` returns true for, preserving order —
    /// the in-place replacement for the old drain-into-a-new-`Vec`
    /// filtering in the RFC policies.
    pub fn retain(&mut self, mut keep: impl FnMut(u8, u8) -> bool) {
        let mut kept = 0u8;
        for i in 0..self.len as usize {
            let (slot, reg) = self.items[i];
            if keep(slot, reg) {
                self.items[kept as usize] = (slot, reg);
                kept += 1;
            }
        }
        self.len = kept;
    }
}

impl std::ops::Deref for MissList {
    type Target = [(u8, u8)];

    fn deref(&self) -> &[(u8, u8)] {
        self.as_slice()
    }
}

/// Result of allocating an instruction to a collector. `Copy`-sized and
/// heap-free: the hot issue loop returns one per instruction.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocResult {
    /// Source slots that must be fetched from the banks: (slot, reg).
    pub misses: MissList,
    /// Source operands served from the cache.
    pub hits: u32,
    /// Hits on values captured via the writeback port (Fig 16: proves
    /// cache writes are reused).
    pub wb_reuse: u32,
    /// The cache table was flushed (ownership change).
    pub flushed: bool,
}

/// A collector unit (OCU / CCU / BOC depending on scheme flags).
///
/// This is the array-of-structs form. The simulator's hot path runs on
/// [`CollectorArray`] (the structure-of-arrays layout of the same state);
/// `Collector` is retained as the obviously-correct reference that the
/// randomized equivalence suite (`rust/tests/soa_equivalence.rs`) drives
/// in lockstep against the flat arrays, draw-for-draw on the RNG stream.
#[derive(Debug, Clone)]
pub struct Collector {
    /// An un-dispatched instruction occupies this unit.
    pub occupied: bool,
    /// Warp whose values live in the cache table (survives dispatch).
    pub owner: Option<u8>,
    /// The occupying instruction.
    pub instr: Instruction,
    /// Cycle the occupying instruction was issued.
    pub issue_cycle: u64,
    /// Ready bitmask over source slots.
    pub src_ready: u8,
    /// Sequence number of the occupying instruction (BOW writeback match).
    pub cur_seq: u64,
    /// Cache table (CCU variants; OCU uses it as a plain operand buffer).
    pub ct: CacheTable,
    /// BOW sliding window (empty unless scheme is BOW).
    pub window: VecDeque<BocInstr>,
    seq_counter: u64,
}

impl Collector {
    /// New collector with `ct_entries` cache-table entries.
    pub fn new(ct_entries: usize) -> Self {
        Collector {
            occupied: false,
            owner: None,
            instr: Instruction::new(crate::isa::OpClass::Ctrl, &[], &[]),
            issue_cycle: 0,
            src_ready: 0,
            cur_seq: 0,
            ct: CacheTable::new(ct_entries),
            window: VecDeque::new(),
            seq_counter: 0,
        }
    }

    /// All valid source operands ready (dispatch condition, §III-C1)?
    #[inline]
    pub fn ready(&self) -> bool {
        self.occupied && self.src_ready.count_ones() as u8 == self.instr.nsrc
    }

    /// Mark source slot ready (operand arrived over port S).
    #[inline]
    pub fn deliver(&mut self, slot: u8) {
        self.src_ready |= 1 << slot;
    }

    /// Allocate as a *baseline OCU*: no caching, every source fetched.
    pub fn alloc_ocu(&mut self, warp: u8, instr: &Instruction, now: u64) -> AllocResult {
        debug_assert!(!self.occupied);
        self.occupied = true;
        self.owner = Some(warp);
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        self.ct.flush();
        let mut res = AllocResult::default();
        for (slot, &reg) in instr.sources().iter().enumerate() {
            res.misses.push(slot as u8, reg);
        }
        res
    }

    /// Allocate as a *Malekeh CCU* (§III-C1): flush on ownership change,
    /// tag-check every source, lock hits, allocate entries for misses
    /// (evicting through the policy's `victim` chooser).
    pub fn alloc_ccu(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> AllocResult {
        // RNG-identical to the pre-admission code: the always-true
        // predicate reproduces the original per-source sequence exactly
        self.alloc_ccu_admit(warp, instr, now, rng, victim, &mut |_, _| true)
    }

    /// [`Collector::alloc_ccu`] with a cache-*admission* predicate
    /// (`admit(slot, reg)`): a missing source the predicate rejects is
    /// still fetched from the banks but gets **no** cache-table entry —
    /// the hook selective-caching policies (e.g. the compression scheme's
    /// compressibility signal) use to keep uncacheable values out of the
    /// table. Hits are always served regardless of the predicate (the
    /// value is already resident).
    pub fn alloc_ccu_admit(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
        admit: &mut dyn FnMut(usize, u8) -> bool,
    ) -> AllocResult {
        debug_assert!(!self.occupied);
        let mut res = AllocResult::default();
        if self.owner != Some(warp) {
            self.ct.flush();
            res.flushed = self.owner.is_some();
            self.owner = Some(warp);
        }
        self.occupied = true;
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        for (slot, &reg) in instr.sources().iter().enumerate() {
            let near = instr.src_is_near(slot);
            if let Some(i) = self.ct.lookup(reg) {
                // hit: value already in the CCU — no bank read
                let e = self.ct.entry_mut(i);
                e.locked = true;
                e.near = near;
                if e.from_wb {
                    e.from_wb = false;
                    res.wb_reuse += 1;
                }
                self.ct.touch(i);
                self.src_ready |= 1 << slot;
                res.hits += 1;
            } else if admit(slot, reg) {
                let idx = self
                    .ct
                    .allocate(reg, near, true, rng, &mut *victim)
                    .expect("CT must fit all sources (ct_entries >= MAX_SRC)");
                debug_assert!(idx < MAX_CT);
                res.misses.push(slot as u8, reg);
            } else {
                // not admitted: bank fetch only, no table entry
                res.misses.push(slot as u8, reg);
            }
        }
        res
    }

    /// Allocate as a *BOW BOC*: check the sliding window, then append this
    /// instruction's registers to it.
    pub fn alloc_boc(
        &mut self,
        warp: u8,
        instr: &Instruction,
        now: u64,
        window_len: usize,
    ) -> AllocResult {
        debug_assert!(!self.occupied);
        let mut res = AllocResult::default();
        self.occupied = true;
        self.owner = Some(warp);
        self.instr = *instr;
        self.issue_cycle = now;
        self.src_ready = 0;
        self.seq_counter += 1;
        self.cur_seq = self.seq_counter;

        // the row is built inline (fixed capacity) and copied into the
        // window ring buffer — no per-instruction heap traffic
        let mut row = BocInstr::new(self.cur_seq);
        for (slot, &reg) in instr.sources().iter().enumerate() {
            // newest-first search over the window + regs already added for
            // this instruction (duplicate sources)
            let hit = row.regs().iter().any(|&(r, p, _)| r == reg && p)
                || self
                    .window
                    .iter()
                    .rev()
                    .any(|bi| bi.regs().iter().any(|&(r, p, _)| r == reg && p));
            if hit {
                self.src_ready |= 1 << slot;
                res.hits += 1;
                row.push(reg, true, false);
            } else {
                res.misses.push(slot as u8, reg);
                row.push(reg, false, false); // present once fetched
            }
        }
        for &reg in instr.dests() {
            row.push(reg, false, true); // present at writeback
        }
        self.window.push_back(row);
        while self.window.len() > window_len {
            self.window.pop_front(); // slid out: pending dsts go RF-only
        }
        res
    }

    /// Operand fetched from the banks: mark the slot ready and (BOW) mark
    /// the value present in the window.
    pub fn bank_operand_arrived(&mut self, slot: u8, reg: u8, bow: bool) {
        self.deliver(slot);
        if bow {
            if let Some(bi) = self.window.iter_mut().find(|bi| bi.seq == self.cur_seq) {
                for e in bi.regs_mut() {
                    if e.0 == reg && !e.2 {
                        e.1 = true;
                    }
                }
            }
        }
    }

    /// Dispatch bookkeeping: the unit becomes free; a CCU keeps (and
    /// unlocks) its contents, an OCU drops them.
    pub fn dispatched(&mut self, caching: bool) {
        self.occupied = false;
        self.src_ready = 0;
        if caching {
            self.ct.unlock_all();
        } else {
            self.ct.flush();
        }
    }

    /// CCU destination writeback (§IV-A2): update on hit; allocate only if
    /// `near` (write filter) unless `no_write_filter`, evicting through
    /// the policy's `victim` chooser. Returns true if the cache captured
    /// the value.
    pub fn ccu_writeback(
        &mut self,
        warp: u8,
        reg: u8,
        near: bool,
        rng: &mut Rng,
        victim: VictimFn,
        no_write_filter: bool,
    ) -> bool {
        if self.owner != Some(warp) {
            return false;
        }
        if let Some(i) = self.ct.lookup(reg) {
            let e = self.ct.entry_mut(i);
            e.near = near;
            e.from_wb = true;
            self.ct.touch(i);
            return true;
        }
        if near || no_write_filter {
            if let Some(i) = self.ct.allocate(reg, near, false, rng, victim) {
                self.ct.entry_mut(i).from_wb = true;
                return true;
            }
            return false;
        }
        false
    }

    /// BOW destination writeback: if the producing instruction is still in
    /// the window, the value is captured there. Returns true if captured.
    pub fn boc_writeback(&mut self, seq: u64, reg: u8) -> bool {
        if let Some(bi) = self.window.iter_mut().find(|bi| bi.seq == seq) {
            let mut hit = false;
            for e in bi.regs_mut() {
                if e.0 == reg && e.2 {
                    e.1 = true;
                    hit = true;
                }
            }
            hit
        } else {
            false
        }
    }
}

// ----------------------------------------------- structure-of-arrays bank

/// Maximum collector units per sub-core the packed bitmasks support.
pub const MAX_COLLECTORS: usize = 64;

/// `owner` sentinel: the unit has never been allocated to a warp.
const NO_OWNER: u8 = u8::MAX;

/// `pipe` sentinel: the unit holds no dispatchable instruction.
const NO_PIPE: u8 = u8::MAX;

/// The sub-core's collector bank in structure-of-arrays layout — the hot
/// half of every per-cycle scan.
///
/// The per-unit scheduling scalars (`occupied`, `owner`, `src_ready`,
/// `nsrc`, pipe class, `issue_cycle`, `cur_seq`) live in parallel flat
/// arrays, with three derived facts packed into per-bank `u64` bitmasks:
///
/// - `occ`  — bit `ci` set iff unit `ci` is occupied,
/// - `rdy`  — bit `ci` set iff unit `ci` is [`Collector::ready`],
/// - `hasv`/`nearv` — mirrors of `ct.has_values()` / `ct.has_near_value()`.
///
/// `free_unit_reservoir`, the Malekeh dual reservoir, `build_order`'s
/// ownership scan, and the dispatch arbitrate loop all read only these
/// arrays/masks, so a full scan of the bank touches a handful of cache
/// lines regardless of how big the cold payloads are. The bulky state — a
/// 32-byte [`Instruction`], a [`CacheTable`], and (BOW only) the sliding
/// window — sits in a cold side-table touched only on allocate / deliver /
/// dispatch / writeback of that specific unit.
///
/// The value-bit mirrors are resynced after the closed set of table
/// mutations (`alloc_ocu`'s flush, `alloc_ccu_admit`, `ccu_writeback`,
/// `dispatched`'s OCU flush); policies never mutate a collector's table
/// directly (per-warp RFC tables are a separate [`CacheTable`] array), so
/// the mirror cannot go stale. The BOW windows are allocated only when the
/// policy declares `uses_window()` — the other schemes carry no per-unit
/// `VecDeque` at all.
///
/// Every operation here is the literal port of the corresponding
/// [`Collector`] method — same branch structure, same RNG draw sequence —
/// and `rust/tests/soa_equivalence.rs` drives both layouts in lockstep
/// over randomized operation streams to prove it draw-for-draw.
#[derive(Debug, Clone)]
pub struct CollectorArray {
    n: usize,
    occ: u64,
    rdy: u64,
    hasv: u64,
    nearv: u64,
    owner: Box<[u8]>,
    src_ready: Box<[u8]>,
    nsrc: Box<[u8]>,
    pipe: Box<[u8]>,
    issue_cycle: Box<[u64]>,
    cur_seq: Box<[u64]>,
    seq_counter: Box<[u64]>,
    // cold side-table: touched only when operating on one specific unit
    instr: Box<[Instruction]>,
    ct: Box<[CacheTable]>,
    /// BOW sliding windows; empty unless [`CollectorArray::enable_windows`]
    /// was called (only the BOW policy asks for them).
    windows: Vec<VecDeque<BocInstr>>,
}

impl CollectorArray {
    /// Bank of `n` units, each with `ct_entries` cache-table entries.
    pub fn new(n: usize, ct_entries: usize) -> Self {
        assert!(n <= MAX_COLLECTORS, "bitmasks are {MAX_COLLECTORS} bits wide");
        CollectorArray {
            n,
            occ: 0,
            rdy: 0,
            hasv: 0,
            nearv: 0,
            owner: vec![NO_OWNER; n].into_boxed_slice(),
            src_ready: vec![0; n].into_boxed_slice(),
            nsrc: vec![0; n].into_boxed_slice(),
            pipe: vec![NO_PIPE; n].into_boxed_slice(),
            issue_cycle: vec![0; n].into_boxed_slice(),
            cur_seq: vec![0; n].into_boxed_slice(),
            seq_counter: vec![0; n].into_boxed_slice(),
            instr: (0..n)
                .map(|_| Instruction::new(crate::isa::OpClass::Ctrl, &[], &[]))
                .collect(),
            ct: (0..n).map(|_| CacheTable::new(ct_entries)).collect(),
            windows: Vec::new(),
        }
    }

    /// Allocate the per-unit BOW windows (satellite: the 12 non-BOW
    /// policies never pay the `VecDeque` footprint).
    pub fn enable_windows(&mut self) {
        if self.windows.is_empty() {
            self.windows = (0..self.n).map(|_| VecDeque::new()).collect();
        }
    }

    /// Number of units.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// No units at all?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Packed occupancy bitmask.
    #[inline]
    pub fn occ_mask(&self) -> u64 {
        self.occ
    }

    /// Packed bitmask of free (unoccupied) units.
    #[inline]
    pub fn free_mask(&self) -> u64 {
        !self.occ & self.unit_mask()
    }

    /// Packed readiness bitmask (`occupied && all sources ready`).
    #[inline]
    pub fn ready_mask(&self) -> u64 {
        self.rdy
    }

    /// All-units mask (`n` low bits).
    #[inline]
    fn unit_mask(&self) -> u64 {
        if self.n == 64 {
            u64::MAX
        } else {
            (1u64 << self.n) - 1
        }
    }

    /// Is unit `ci` occupied?
    #[inline]
    pub fn occupied(&self, ci: usize) -> bool {
        debug_assert!(ci < self.n);
        self.occ & (1 << ci) != 0
    }

    /// All valid source operands of unit `ci` ready (dispatch condition)?
    #[inline]
    pub fn ready(&self, ci: usize) -> bool {
        self.rdy & (1 << ci) != 0
    }

    /// Warp whose values live in unit `ci`'s cache table.
    #[inline]
    pub fn owner(&self, ci: usize) -> Option<u8> {
        let w = self.owner[ci];
        if w == NO_OWNER {
            None
        } else {
            Some(w)
        }
    }

    /// The instruction occupying unit `ci` (cold side-table access).
    #[inline]
    pub fn instr(&self, ci: usize) -> &Instruction {
        &self.instr[ci]
    }

    /// Cycle unit `ci`'s occupying instruction was issued.
    #[inline]
    pub fn issue_cycle(&self, ci: usize) -> u64 {
        self.issue_cycle[ci]
    }

    /// BOW sequence number of unit `ci`'s occupying instruction.
    #[inline]
    pub fn cur_seq(&self, ci: usize) -> u64 {
        self.cur_seq[ci]
    }

    /// Execution-pipe class of unit `ci`'s instruction, as
    /// `Pipe as u8` ([`crate::sim::exec::Pipe`]); `u8::MAX` when empty.
    #[inline]
    pub fn pipe_code(&self, ci: usize) -> u8 {
        self.pipe[ci]
    }

    /// Unit `ci`'s cache table (read-only; mutations go through the ops
    /// below so the packed value mirrors stay coherent).
    #[inline]
    pub fn ct(&self, ci: usize) -> &CacheTable {
        &self.ct[ci]
    }

    /// Mirror of `ct(ci).has_values()` (bit read, no table access).
    #[inline]
    pub fn has_values(&self, ci: usize) -> bool {
        self.hasv & (1 << ci) != 0
    }

    /// Mirror of `ct(ci).has_near_value()` (bit read, no table access).
    #[inline]
    pub fn has_near_value(&self, ci: usize) -> bool {
        self.nearv & (1 << ci) != 0
    }

    // simlint: hot
    /// Does any unit owned by `w` hold cached values? (Malekeh §IV-B1
    /// priority scan — a bitmask walk plus one owner-byte read per
    /// value-holding unit.)
    pub fn warp_owns_values(&self, w: u8) -> bool {
        let mut m = self.hasv;
        while m != 0 {
            let ci = m.trailing_zeros() as usize;
            m &= m - 1;
            if self.owner[ci] == w {
                return true;
            }
        }
        false
    }

    /// First unit owned by warp `w` (the at-most-one-CCU invariant makes
    /// it unique); contiguous scan of the owner byte array.
    pub fn position_owned_by(&self, w: u8) -> Option<usize> {
        self.owner.iter().position(|&o| o == w)
    }

    // ----------------------------------------------------- mask upkeep

    // simlint: hot
    /// Recompute unit `ci`'s readiness bit from the hot arrays.
    #[inline]
    fn update_ready(&mut self, ci: usize) {
        let bit = 1u64 << ci;
        if self.occ & bit != 0 && self.src_ready[ci].count_ones() as u8 == self.nsrc[ci] {
            self.rdy |= bit;
        } else {
            self.rdy &= !bit;
        }
    }

    // simlint: hot
    /// Resync the value-bit mirrors of unit `ci` from its cache table
    /// (called after every table mutation; O(ct entries)).
    fn resync_values(&mut self, ci: usize) {
        let bit = 1u64 << ci;
        if self.ct[ci].has_values() {
            self.hasv |= bit;
        } else {
            self.hasv &= !bit;
        }
        if self.ct[ci].has_near_value() {
            self.nearv |= bit;
        } else {
            self.nearv &= !bit;
        }
    }

    // simlint: hot
    /// Install the hot scalars of a fresh allocation into unit `ci`.
    fn set_hot(&mut self, ci: usize, warp: u8, instr: &Instruction, now: u64) {
        debug_assert!(warp != NO_OWNER, "warp id {NO_OWNER} is the empty sentinel");
        self.occ |= 1 << ci;
        self.owner[ci] = warp;
        self.src_ready[ci] = 0;
        self.nsrc[ci] = instr.nsrc;
        self.pipe[ci] = pipe_of(instr.op).map(|p| p as u8).unwrap_or(NO_PIPE);
        self.issue_cycle[ci] = now;
        self.instr[ci] = *instr;
    }

    // ------------------------------------------------------ operations

    // simlint: hot
    /// Mark source slot of unit `ci` ready (operand arrived over port S).
    #[inline]
    pub fn deliver(&mut self, ci: usize, slot: u8) {
        self.src_ready[ci] |= 1 << slot;
        self.update_ready(ci);
    }

    // simlint: hot
    /// [`Collector::alloc_ocu`] on unit `ci`.
    pub fn alloc_ocu(&mut self, ci: usize, warp: u8, instr: &Instruction, now: u64) -> AllocResult {
        debug_assert!(!self.occupied(ci));
        self.set_hot(ci, warp, instr, now);
        self.ct[ci].flush(); // no-op pass in steady state (empty OCU table)
        self.resync_values(ci);
        let mut res = AllocResult::default();
        for (slot, &reg) in instr.sources().iter().enumerate() {
            res.misses.push(slot as u8, reg);
        }
        self.update_ready(ci);
        res
    }

    // simlint: hot
    /// [`Collector::alloc_ccu`] on unit `ci`.
    pub fn alloc_ccu(
        &mut self,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
    ) -> AllocResult {
        // RNG-identical to the pre-admission code, like Collector::alloc_ccu
        self.alloc_ccu_admit(ci, warp, instr, now, rng, victim, &mut |_, _| true)
    }

    // simlint: hot
    /// [`Collector::alloc_ccu_admit`] on unit `ci` — same flush-on-owner-
    /// change ordering, same per-source lookup/allocate sequence, same RNG
    /// draws.
    #[allow(clippy::too_many_arguments)]
    pub fn alloc_ccu_admit(
        &mut self,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
        rng: &mut Rng,
        victim: VictimFn,
        admit: &mut dyn FnMut(usize, u8) -> bool,
    ) -> AllocResult {
        debug_assert!(!self.occupied(ci));
        let mut res = AllocResult::default();
        if self.owner[ci] != warp {
            self.ct[ci].flush();
            res.flushed = self.owner[ci] != NO_OWNER;
        }
        self.set_hot(ci, warp, instr, now);
        let ct = &mut self.ct[ci];
        let mut ready_bits = 0u8;
        for (slot, &reg) in instr.sources().iter().enumerate() {
            let near = instr.src_is_near(slot);
            if let Some(i) = ct.lookup(reg) {
                // hit: value already in the CCU — no bank read
                let e = ct.entry_mut(i);
                e.locked = true;
                e.near = near;
                if e.from_wb {
                    e.from_wb = false;
                    res.wb_reuse += 1;
                }
                ct.touch(i);
                ready_bits |= 1 << slot;
                res.hits += 1;
            } else if admit(slot, reg) {
                let idx = ct
                    .allocate(reg, near, true, rng, &mut *victim)
                    .expect("CT must fit all sources (ct_entries >= MAX_SRC)");
                debug_assert!(idx < MAX_CT);
                res.misses.push(slot as u8, reg);
            } else {
                // not admitted: bank fetch only, no table entry
                res.misses.push(slot as u8, reg);
            }
        }
        self.src_ready[ci] = ready_bits;
        self.resync_values(ci);
        self.update_ready(ci);
        res
    }

    // simlint: hot
    /// [`Collector::alloc_boc`] on unit `ci`. Requires
    /// [`CollectorArray::enable_windows`].
    pub fn alloc_boc(
        &mut self,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
        window_len: usize,
    ) -> AllocResult {
        debug_assert!(!self.occupied(ci));
        assert!(
            !self.windows.is_empty(),
            "alloc_boc needs enable_windows() (BOW-only cold state)"
        );
        let mut res = AllocResult::default();
        self.set_hot(ci, warp, instr, now);
        self.seq_counter[ci] += 1;
        self.cur_seq[ci] = self.seq_counter[ci];
        let window = &mut self.windows[ci];
        let mut row = BocInstr::new(self.cur_seq[ci]);
        let mut ready_bits = 0u8;
        for (slot, &reg) in instr.sources().iter().enumerate() {
            // newest-first search over the window + regs already added for
            // this instruction (duplicate sources)
            let hit = row.regs().iter().any(|&(r, p, _)| r == reg && p)
                || window
                    .iter()
                    .rev()
                    .any(|bi| bi.regs().iter().any(|&(r, p, _)| r == reg && p));
            if hit {
                ready_bits |= 1 << slot;
                res.hits += 1;
                row.push(reg, true, false);
            } else {
                res.misses.push(slot as u8, reg);
                row.push(reg, false, false); // present once fetched
            }
        }
        for &reg in instr.dests() {
            row.push(reg, false, true); // present at writeback
        }
        window.push_back(row);
        while window.len() > window_len {
            window.pop_front(); // slid out: pending dsts go RF-only
        }
        self.src_ready[ci] = ready_bits;
        self.update_ready(ci);
        res
    }

    // simlint: hot
    /// [`Collector::bank_operand_arrived`] on unit `ci`.
    pub fn bank_operand_arrived(&mut self, ci: usize, slot: u8, reg: u8, bow: bool) {
        self.deliver(ci, slot);
        if bow {
            let seq = self.cur_seq[ci];
            if let Some(bi) = self
                .windows
                .get_mut(ci)
                .and_then(|w| w.iter_mut().find(|bi| bi.seq == seq))
            {
                for e in bi.regs_mut() {
                    if e.0 == reg && !e.2 {
                        e.1 = true;
                    }
                }
            }
        }
    }

    // simlint: hot
    /// [`Collector::dispatched`] on unit `ci`.
    pub fn dispatched(&mut self, ci: usize, caching: bool) {
        self.occ &= !(1 << ci);
        self.src_ready[ci] = 0;
        self.pipe[ci] = NO_PIPE;
        self.update_ready(ci);
        if caching {
            self.ct[ci].unlock_all(); // lock bits only: value mirrors unchanged
        } else {
            self.ct[ci].flush();
            self.resync_values(ci);
        }
    }

    // simlint: hot
    /// [`Collector::ccu_writeback`] on unit `ci`.
    #[allow(clippy::too_many_arguments)]
    pub fn ccu_writeback(
        &mut self,
        ci: usize,
        warp: u8,
        reg: u8,
        near: bool,
        rng: &mut Rng,
        victim: VictimFn,
        no_write_filter: bool,
    ) -> bool {
        if self.owner[ci] != warp || warp == NO_OWNER {
            return false;
        }
        let ct = &mut self.ct[ci];
        if let Some(i) = ct.lookup(reg) {
            let e = ct.entry_mut(i);
            e.near = near;
            e.from_wb = true;
            ct.touch(i);
            self.resync_values(ci);
            return true;
        }
        if near || no_write_filter {
            if let Some(i) = ct.allocate(reg, near, false, rng, victim) {
                ct.entry_mut(i).from_wb = true;
                self.resync_values(ci);
                return true;
            }
            return false;
        }
        false
    }

    // simlint: hot
    /// [`Collector::boc_writeback`] on unit `ci`.
    pub fn boc_writeback(&mut self, ci: usize, seq: u64, reg: u8) -> bool {
        if let Some(bi) = self
            .windows
            .get_mut(ci)
            .and_then(|w| w.iter_mut().find(|bi| bi.seq == seq))
        {
            let mut hit = false;
            for e in bi.regs_mut() {
                if e.0 == reg && e.2 {
                    e.1 = true;
                    hit = true;
                }
            }
            hit
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, OpClass};

    fn rng() -> Rng {
        Rng::new(1)
    }

    // ---- CacheTable ----

    #[test]
    fn ct_lookup_and_flush() {
        let mut ct = CacheTable::new(4);
        assert!(ct.lookup(5).is_none());
        ct.allocate(5, true, false, &mut rng(), &mut reuse_guided_victim);
        assert!(ct.lookup(5).is_some());
        assert!(ct.has_near_value());
        ct.flush();
        assert!(ct.lookup(5).is_none());
        assert!(!ct.has_values());
    }

    #[test]
    fn ct_replacement_prefers_invalid_then_far() {
        let mut ct = CacheTable::new(3);
        let mut r = rng();
        ct.allocate(1, true, false, &mut r, &mut reuse_guided_victim); // near
        ct.allocate(2, false, false, &mut r, &mut reuse_guided_victim); // far
        ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim); // near
        // table full; new alloc must evict the far entry (reg 2)
        ct.allocate(4, true, false, &mut r, &mut reuse_guided_victim);
        assert!(ct.lookup(2).is_none(), "far entry must be the victim");
        assert!(ct.lookup(1).is_some() && ct.lookup(3).is_some());
    }

    #[test]
    fn ct_replacement_falls_back_to_lru_when_all_near() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, true, false, &mut r, &mut reuse_guided_victim);
        ct.allocate(2, true, false, &mut r, &mut reuse_guided_victim);
        ct.touch(ct.lookup(1).unwrap()); // reg1 most recent
        ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim);
        assert!(ct.lookup(2).is_none(), "LRU (reg 2) must be evicted");
        assert!(ct.lookup(1).is_some());
    }

    #[test]
    fn ct_locked_entries_never_evicted() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, false, true, &mut r, &mut reuse_guided_victim); // locked far
        ct.allocate(2, false, true, &mut r, &mut reuse_guided_victim); // locked far
        assert_eq!(ct.allocate(3, true, false, &mut r, &mut reuse_guided_victim), None);
        assert!(ct.lookup(1).is_some() && ct.lookup(2).is_some());
    }

    #[test]
    fn ct_traditional_uses_plain_lru() {
        let mut ct = CacheTable::new(2);
        let mut r = rng();
        ct.allocate(1, false, false, &mut r, &mut plain_lru_victim); // far, older
        ct.allocate(2, true, false, &mut r, &mut plain_lru_victim); // near, newer
        // traditional LRU evicts reg 1 (oldest) even though reuse-aware
        // policy would also pick it; now make near entry the oldest:
        ct.touch(ct.lookup(1).unwrap());
        ct.allocate(3, false, false, &mut r, &mut plain_lru_victim);
        assert!(
            ct.lookup(2).is_none(),
            "plain LRU must evict the near entry when it is oldest"
        );
    }

    // ---- CCU allocation ----

    fn mma(srcs: &[u8], dsts: &[u8]) -> Instruction {
        Instruction::new(OpClass::Mma, srcs, dsts)
    }

    #[test]
    fn ccu_first_alloc_all_miss_then_reuse_hits() {
        let mut c = Collector::new(8);
        let mut r = rng();
        let i1 = mma(&[1, 2, 3], &[10]);
        let res = c.alloc_ccu(0, &i1, 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.len(), 3);
        assert!(!c.ready());
        c.bank_operand_arrived(0, 1, false);
        c.bank_operand_arrived(1, 2, false);
        c.bank_operand_arrived(2, 3, false);
        assert!(c.ready());
        c.dispatched(true);
        assert!(!c.occupied);
        // same warp reuses r2, r3
        let i2 = mma(&[2, 3, 4], &[11]);
        let res = c.alloc_ccu(0, &i2, 5, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 2);
        assert_eq!(res.misses.as_slice(), &[(2, 4)]);
        assert!(!res.flushed);
    }

    #[test]
    fn ccu_flushes_on_owner_change() {
        let mut c = Collector::new(8);
        let mut r = rng();
        c.alloc_ccu(0, &mma(&[1], &[2]), 0, &mut r, &mut reuse_guided_victim);
        c.bank_operand_arrived(0, 1, false);
        c.dispatched(true);
        let res = c.alloc_ccu(3, &mma(&[1], &[2]), 1, &mut r, &mut reuse_guided_victim);
        assert!(res.flushed, "different warp must flush");
        assert_eq!(res.hits, 0);
        assert_eq!(c.owner, Some(3));
    }

    #[test]
    fn ccu_duplicate_source_served_from_ct() {
        let mut c = Collector::new(8);
        let mut r = rng();
        // r7 appears twice: second occurrence hits the entry allocated for
        // the first
        let res = c.alloc_ccu(0, &mma(&[7, 7], &[1]), 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(res.hits, 1);
        assert_eq!(res.misses.len(), 1);
    }

    #[test]
    fn ccu_admit_predicate_gates_table_entries_not_fetches() {
        let mut c = Collector::new(8);
        let mut r = rng();
        // admit only registers < 10: r3 gets an entry, r20 is fetch-only
        let res = c.alloc_ccu_admit(
            0,
            &mma(&[3, 20], &[1]),
            0,
            &mut r,
            &mut reuse_guided_victim,
            &mut |_, reg| reg < 10,
        );
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.as_slice(), &[(0, 3), (1, 20)], "both still fetched");
        assert!(c.ct.lookup(3).is_some(), "admitted miss gets an entry");
        assert!(c.ct.lookup(20).is_none(), "rejected miss gets none");
        c.bank_operand_arrived(0, 3, false);
        c.bank_operand_arrived(1, 20, false);
        assert!(c.ready(), "readiness is slot-based, not table-based");
        c.dispatched(true);
        // a later instruction hits the admitted value only
        let res = c.alloc_ccu_admit(
            0,
            &mma(&[3, 20], &[2]),
            1,
            &mut r,
            &mut reuse_guided_victim,
            &mut |_, reg| reg < 10,
        );
        assert_eq!(res.hits, 1);
        assert_eq!(res.misses.as_slice(), &[(1, 20)]);
    }

    #[test]
    fn ccu_admit_always_true_matches_alloc_ccu_and_rng_stream() {
        // the delegation contract: an always-admit predicate must be
        // bit-identical to alloc_ccu, including the RNG stream position
        let seed = 0xFEED;
        let mut ca = Collector::new(8);
        let mut cb = Collector::new(8);
        let mut ra = Rng::new(seed);
        let mut rb = Rng::new(seed);
        for (k, instr) in [
            mma(&[1, 2, 3], &[10]),
            mma(&[2, 3, 4], &[11]),
            mma(&[9, 9, 1], &[12]),
        ]
        .iter()
        .enumerate()
        {
            let a = ca.alloc_ccu(0, instr, k as u64, &mut ra, &mut reuse_guided_victim);
            let b = cb.alloc_ccu_admit(
                0,
                instr,
                k as u64,
                &mut rb,
                &mut reuse_guided_victim,
                &mut |_, _| true,
            );
            assert_eq!(a.hits, b.hits);
            assert_eq!(a.misses, b.misses);
            assert_eq!(a.wb_reuse, b.wb_reuse);
            for (slot, &reg) in instr.sources().iter().enumerate() {
                ca.bank_operand_arrived(slot as u8, reg, false);
                cb.bank_operand_arrived(slot as u8, reg, false);
            }
            ca.dispatched(true);
            cb.dispatched(true);
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "RNG stream position diverged");
    }

    #[test]
    fn ccu_writeback_policy() {
        let mut c = Collector::new(8);
        let mut r = rng();
        c.alloc_ccu(0, &mma(&[1], &[9]), 0, &mut r, &mut reuse_guided_victim);
        c.bank_operand_arrived(0, 1, false);
        c.dispatched(true);
        // near write allocates
        assert!(c.ccu_writeback(0, 9, true, &mut r, &mut reuse_guided_victim, false));
        assert!(c.ct.lookup(9).is_some());
        // far write misses and is filtered
        assert!(!c.ccu_writeback(0, 20, false, &mut r, &mut reuse_guided_victim, false));
        assert!(c.ct.lookup(20).is_none());
        // far write with filter disabled allocates
        assert!(c.ccu_writeback(0, 21, false, &mut r, &mut reuse_guided_victim, true));
        // wrong warp ignored
        assert!(!c.ccu_writeback(2, 22, true, &mut r, &mut reuse_guided_victim, false));
        // hit updates even when far
        assert!(c.ccu_writeback(0, 9, false, &mut r, &mut reuse_guided_victim, false));
        let e = c.ct.entry(c.ct.lookup(9).unwrap());
        assert!(!e.near);
    }

    #[test]
    fn ocu_never_hits() {
        let mut c = Collector::new(8);
        let i = mma(&[1, 2], &[3]);
        let res = c.alloc_ocu(0, &i, 0);
        assert_eq!(res.hits, 0);
        assert_eq!(res.misses.len(), 2);
        c.bank_operand_arrived(0, 1, false);
        c.bank_operand_arrived(1, 2, false);
        c.dispatched(false);
        let res = c.alloc_ocu(0, &i, 1);
        assert_eq!(res.hits, 0, "OCU has no cache");
        assert_eq!(res.misses.len(), 2);
    }

    // ---- BOW BOC ----

    #[test]
    fn boc_window_hits_and_slides() {
        let mut c = Collector::new(8);
        // i1 fetches r1, r2
        let r1 = c.alloc_boc(0, &mma(&[1, 2], &[3]), 0, 3);
        assert_eq!(r1.hits, 0);
        c.bank_operand_arrived(0, 1, true);
        c.bank_operand_arrived(1, 2, true);
        c.dispatched(true);
        // i2 reuses r1 (present), needs r4
        let r2 = c.alloc_boc(0, &mma(&[1, 4], &[5]), 1, 3);
        assert_eq!(r2.hits, 1);
        assert_eq!(r2.misses.as_slice(), &[(1, 4)]);
        c.bank_operand_arrived(1, 4, true);
        c.dispatched(true);
        // fill the window beyond 3: r1's entry slides out
        c.alloc_boc(0, &mma(&[6], &[7]), 2, 3);
        c.bank_operand_arrived(0, 6, true);
        c.dispatched(true);
        c.alloc_boc(0, &mma(&[8], &[9]), 3, 3);
        c.bank_operand_arrived(0, 8, true);
        c.dispatched(true);
        assert_eq!(c.window.len(), 3);
        // r2 only appeared in i1, which has slid out (window = i3,i4,i5)
        let r5 = c.alloc_boc(0, &mma(&[2], &[10]), 4, 3);
        assert_eq!(r5.hits, 0, "r2 slid out of the window");
    }

    // ---- zero-allocation scratch paths (PR 5) ----

    /// The pre-refactor allocating chooser, kept verbatim as the test
    /// reference for the two-pass [`reuse_guided_victim`].
    fn reuse_guided_victim_collecting(ct: &CacheTable, rng: &mut Rng) -> Option<usize> {
        let far: Vec<usize> = ct
            .entries()
            .iter()
            .enumerate()
            .filter(|(_, e)| !e.locked && !e.near)
            .map(|(i, _)| i)
            .collect();
        if !far.is_empty() {
            Some(far[rng.below(far.len())])
        } else {
            ct.lru_victim()
        }
    }

    #[test]
    fn ct_reuse_guided_matches_collecting_reference() {
        // drive many random table states: the zero-alloc two-pass chooser
        // must pick the same victim from the same RNG state AND leave the
        // stream at the same position (bit-identity of whole runs depends
        // on both)
        let mut gen = Rng::new(99);
        for round in 0..500u64 {
            let n = gen.below(MAX_CT) + 1;
            let mut ct = CacheTable::new(n);
            let fill = gen.below(n) + 1;
            for k in 0..fill {
                // unique tags per slot; random near/locked classes
                let reg = (k * 8 + gen.below(8)) as u8;
                let near = gen.chance(0.5);
                let locked = gen.chance(0.3);
                ct.allocate(reg, near, locked, &mut Rng::new(round), &mut plain_lru_victim);
            }
            let seed = gen.next_u64();
            let mut ra = Rng::new(seed);
            let mut rb = Rng::new(seed);
            assert_eq!(
                reuse_guided_victim(&ct, &mut ra),
                reuse_guided_victim_collecting(&ct, &mut rb),
                "round {round}: victims diverge"
            );
            assert_eq!(
                ra.next_u64(),
                rb.next_u64(),
                "round {round}: RNG stream position diverges"
            );
        }
    }

    #[test]
    fn ct_valid_regs_into_reuses_capacity_and_matches_alloc_path() {
        let mut ct = CacheTable::new(8);
        let mut r = rng();
        let mut buf = Vec::new();
        let mut warm_cap = 0;
        for round in 0..64u8 {
            ct.flush();
            for k in 0..(round % 8) {
                ct.allocate(
                    k.wrapping_mul(7).wrapping_add(round),
                    k % 2 == 0,
                    false,
                    &mut r,
                    &mut reuse_guided_victim,
                );
            }
            ct.valid_regs_into(&mut buf);
            assert_eq!(
                buf,
                ct.valid_regs(),
                "scratch path must return exactly what the allocating path did"
            );
            if round == 7 {
                // by now the buffer has seen the largest fill (7 entries)
                warm_cap = buf.capacity();
            }
            if round > 7 {
                assert_eq!(buf.capacity(), warm_cap, "no growth after warm-up");
            }
        }
    }

    #[test]
    fn miss_list_push_retain_deref() {
        let mut m = MissList::default();
        assert!(m.is_empty());
        for (slot, reg) in [(0u8, 10u8), (1, 11), (2, 12), (3, 13)] {
            m.push(slot, reg);
        }
        assert_eq!(m.len(), 4);
        assert_eq!(m[1], (1, 11)); // Deref indexing
        // retain keeps order and compacts in place
        m.retain(|slot, _| slot % 2 == 0);
        assert_eq!(m.as_slice(), &[(0, 10), (2, 12)]);
        // equality sees only live entries, not the stale compacted-over
        // storage beyond len
        let mut fresh = MissList::default();
        fresh.push(0, 10);
        fresh.push(2, 12);
        assert_eq!(m, fresh);
        m.retain(|_, _| false);
        assert!(m.is_empty());
        assert_eq!(m, MissList::default());
    }

    #[test]
    fn boc_window_rows_are_fixed_capacity() {
        // a full MMA (6 src + 2 dst) exactly fills one window row
        let mut c = Collector::new(8);
        let i = Instruction::new(OpClass::Mma, &[1, 2, 3, 4, 5, 6], &[7, 8]);
        c.alloc_boc(0, &i, 0, 4);
        let row = c.window.back().unwrap();
        assert_eq!(row.regs().len(), BOC_REGS);
        assert!(row.regs().iter().all(|&(_, p, _)| !p), "nothing present yet");
    }

    #[test]
    fn boc_writeback_only_within_window() {
        let mut c = Collector::new(8);
        c.alloc_boc(0, &mma(&[1], &[3]), 0, 2);
        let seq1 = c.cur_seq;
        c.bank_operand_arrived(0, 1, true);
        c.dispatched(true);
        // dst r3 still in window: captured
        assert!(c.boc_writeback(seq1, 3));
        // subsequent instr can hit r3
        let r = c.alloc_boc(0, &mma(&[3], &[4]), 1, 2);
        assert_eq!(r.hits, 1);
        c.dispatched(true);
        // slide seq1 out
        c.alloc_boc(0, &mma(&[5], &[6]), 2, 2);
        c.dispatched(true);
        assert!(!c.boc_writeback(seq1, 3), "slid out -> RF only");
    }

    // ---- O(1) valid count + empty-flush fast path (PR 9) ----

    #[test]
    fn ct_flush_on_empty_table_is_a_no_op() {
        // alloc_ocu flushes on every allocation and an OCU table is empty
        // in steady state; the flush must early-return without touching
        // the entry array. Pin by planting a sentinel in a *dead* slot
        // (valid=false never becomes visible through the public API): a
        // full clearing pass would wipe it, the early return leaves it.
        let mut ct = CacheTable::new(4);
        let mut r = rng();
        ct.allocate(7, true, false, &mut r, &mut reuse_guided_victim);
        ct.flush(); // real flush: table had a value
        assert!(!ct.has_values());
        ct.entry_mut(2).reg = 0xAB; // sentinel in an invalid entry
        ct.flush(); // empty flush: must not run the clearing pass
        assert_eq!(ct.entry(2).reg, 0xAB, "empty flush cleared entries");
        assert!(!ct.has_values());
        assert!(ct.lookup(0xAB).is_none(), "sentinel is invalid, not live");
    }

    #[test]
    fn ct_nvalid_matches_recount_under_random_ops() {
        // the maintained count must equal a fresh scan after any mix of
        // allocate (fill / evict / tag-update) and flush
        let mut gen = Rng::new(0x9A71D);
        for round in 0..300u64 {
            let n = gen.below(MAX_CT) + 1;
            let mut ct = CacheTable::new(n);
            let mut r = Rng::new(round);
            for _ in 0..gen.below(40) {
                match gen.below(10) {
                    0 => ct.flush(),
                    1..=7 => {
                        ct.allocate(
                            gen.below(16) as u8,
                            gen.chance(0.5),
                            gen.chance(0.2),
                            &mut r,
                            &mut reuse_guided_victim,
                        );
                    }
                    _ => ct.unlock_all(),
                }
                let recount =
                    ct.entries().iter().filter(|e| e.valid).count();
                assert_eq!(
                    ct.valid_count(),
                    recount,
                    "round {round}: nvalid diverged from scan"
                );
                assert_eq!(ct.has_values(), recount > 0);
            }
        }
    }

    // ---- CollectorArray (SoA bank) smoke tests; the full draw-for-draw
    // ---- lockstep battery lives in rust/tests/soa_equivalence.rs ----

    #[test]
    fn soa_masks_track_alloc_deliver_dispatch() {
        let mut arr = CollectorArray::new(3, 8);
        assert_eq!(arr.occ_mask(), 0);
        assert_eq!(arr.free_mask(), 0b111);
        let mut r = rng();
        let i = mma(&[1, 2], &[3]);
        arr.alloc_ccu(1, 5, &i, 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(arr.occ_mask(), 0b010);
        assert_eq!(arr.free_mask(), 0b101);
        assert_eq!(arr.ready_mask(), 0, "sources outstanding");
        assert_eq!(arr.owner(1), Some(5));
        assert!(arr.owner(0).is_none());
        arr.bank_operand_arrived(1, 0, 1, false);
        arr.bank_operand_arrived(1, 1, 2, false);
        assert_eq!(arr.ready_mask(), 0b010);
        assert!(arr.ready(1));
        assert!(arr.has_values(1), "CCU misses allocated entries");
        arr.dispatched(1, true);
        assert_eq!(arr.occ_mask(), 0);
        assert_eq!(arr.ready_mask(), 0);
        assert!(arr.has_values(1), "caching dispatch keeps values");
        arr.dispatched(1, false);
        assert!(!arr.has_values(1), "OCU dispatch drops values");
    }

    #[test]
    fn soa_value_mirrors_match_table_state() {
        let mut arr = CollectorArray::new(2, 4);
        let mut r = rng();
        // near source -> both mirrors set
        let mut i = Instruction::new(OpClass::Alu, &[1], &[2]);
        i.set_src_near(0, true);
        arr.alloc_ccu(0, 1, &i, 0, &mut r, &mut reuse_guided_victim);
        assert_eq!(arr.has_values(0), arr.ct(0).has_values());
        assert_eq!(arr.has_near_value(0), arr.ct(0).has_near_value());
        assert!(arr.has_near_value(0));
        arr.bank_operand_arrived(0, 0, 1, false);
        arr.dispatched(0, true);
        // writeback hit flips the near bit far -> mirror must follow
        assert!(arr.ccu_writeback(0, 1, 1, false, &mut r, &mut reuse_guided_victim, true));
        assert_eq!(arr.has_near_value(0), arr.ct(0).has_near_value());
        assert!(!arr.has_near_value(0), "hit downgraded the only near value");
        assert!(arr.warp_owns_values(1));
        assert!(!arr.warp_owns_values(2));
        assert_eq!(arr.position_owned_by(1), Some(0));
        assert_eq!(arr.position_owned_by(9), None);
    }

    #[test]
    fn soa_boc_requires_windows_and_matches_aos() {
        let mut arr = CollectorArray::new(1, 8);
        arr.enable_windows();
        let mut c = Collector::new(8);
        for (k, i) in [mma(&[1, 2], &[3]), mma(&[1, 4], &[5]), mma(&[3], &[6])]
            .iter()
            .enumerate()
        {
            let a = arr.alloc_boc(0, 0, i, k as u64, 3);
            let b = c.alloc_boc(0, i, k as u64, 3);
            assert_eq!(a.hits, b.hits, "instr {k}");
            assert_eq!(a.misses, b.misses, "instr {k}");
            for (slot, &reg) in i.sources().iter().enumerate() {
                arr.bank_operand_arrived(0, slot as u8, reg, true);
                c.bank_operand_arrived(slot as u8, reg, true);
            }
            let (sa, sb) = (arr.cur_seq(0), c.cur_seq);
            assert_eq!(sa, sb);
            arr.dispatched(0, true);
            c.dispatched(true);
            for &d in i.dests() {
                assert_eq!(arr.boc_writeback(0, sa, d), c.boc_writeback(sb, d));
            }
        }
    }

    #[test]
    #[should_panic(expected = "enable_windows")]
    fn soa_boc_without_windows_panics() {
        let mut arr = CollectorArray::new(1, 8);
        arr.alloc_boc(0, 0, &mma(&[1], &[2]), 0, 3);
    }
}
