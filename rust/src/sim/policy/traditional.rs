//! Fig 17 ablation: the Malekeh CCU *hardware* driven by traditional
//! policies — GTO issue order, any free unit picked at random (like the
//! baseline OCU allocator), no waiting mechanism. `GpuConfig::with_scheme`
//! additionally sets plain-LRU replacement and disables the write filter,
//! which together cause the "excessive flushes when GTO schedules a new
//! warp" of §VI-C.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::AllocResult;
use crate::sim::exec::WbEvent;

use super::{free_unit_reservoir, CachePolicy, CcuKnobs, CollectorChoice, PolicyCtx};

/// Malekeh hardware under traditional GTO + LRU.
pub struct MalekehTraditionalPolicy {
    knobs: CcuKnobs,
}

impl MalekehTraditionalPolicy {
    /// Capture the ablation knobs (normally set by `with_scheme`) from the
    /// resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        MalekehTraditionalPolicy { knobs: CcuKnobs::from_config(cfg) }
    }
}

impl CachePolicy for MalekehTraditionalPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        self.knobs.entries()
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        // any free unit, randomly, like the baseline OCU allocator
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        self.knobs.allocate(ctx, ci, warp, instr, now)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        self.knobs.capture(ctx, ev, reg, near, port_free)
    }
}
