//! Hardware RFC (Gebhart 2011, §VI-A): a small per-warp register file
//! cache for the *active* warps of a two-level scheduler. Write-allocate
//! only — values enter at writeback, never on read fills — with plain
//! LRU replacement; warps deactivate on long-latency (load) stalls.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{plain_lru_victim, AllocResult};
use crate::sim::exec::WbEvent;
use crate::sim::warp::WarpState;

use super::{free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// Hardware RFC + two-level scheduler.
pub struct RfcPolicy {
    entries: usize,
}

impl RfcPolicy {
    /// Capture the cache size from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        RfcPolicy { entries: cfg.rfc_entries }
    }
}

impl CachePolicy for RfcPolicy {
    fn cache_entries_per_collector(&self) -> f64 {
        self.entries as f64
    }

    fn issue_gate(&self, warp: &WarpState, now: u64) -> bool {
        warp.active && now >= warp.active_since + self.activation_delay()
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        let mut res = ctx.collectors.alloc_ocu(ci, warp, instr, now);
        if ctx.warps[warp as usize].active {
            // filter cache hits out of the miss list in place (the list is
            // inline fixed-capacity storage — no per-instruction Vec)
            let cache = &mut ctx.rfc[warp as usize];
            let col = &mut *ctx.collectors;
            let mut hits = 0u32;
            res.misses.retain(|slot, reg| {
                if let Some(i) = cache.lookup(reg) {
                    cache.touch(i);
                    col.deliver(ci, slot);
                    hits += 1;
                    false
                } else {
                    true
                }
            });
            res.hits += hits;
        }
        res
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        _near: bool,
        _port_free: bool,
    ) -> bool {
        // hardware RFC: fill if the warp is still active
        if ctx.warps[ev.warp as usize].active {
            ctx.rfc[ev.warp as usize]
                .allocate(reg, true, false, ctx.rng, &mut plain_lru_victim)
                .is_some()
        } else {
            false
        }
    }

    /// Deactivate only on long-latency (load) stalls (§VI-A).
    fn should_swap_out(&self, warp: &WarpState, instr: &Instruction, _now: u64) -> bool {
        warp.blocked_on_load(instr)
    }

    /// The only time-dependent gate is the activation delay: a quiescent
    /// sub-core may fast-forward until the next pending activation opens
    /// its issue gate (swap-out is load-blocked, i.e. time-independent).
    fn quiescent_horizon(&self, warps: &[WarpState], now: u64) -> u64 {
        let mut h = u64::MAX;
        for w in warps {
            if !w.active || w.done {
                continue;
            }
            let gate = w.active_since + self.activation_delay();
            if gate > now {
                h = h.min(gate);
            }
        }
        h
    }
}
