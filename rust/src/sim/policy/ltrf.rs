//! LTRF-style latency-tolerant register file (Sadrosadati et al.,
//! PAPERS.md): a software/hardware cooperative scheme. The *compiler*
//! partitions each warp's code into register intervals whose working set
//! fits the per-warp cache ([`crate::compiler::register_intervals`]); the
//! *hardware* prefetches an interval's registers into the per-warp RFC
//! when the warp enters it, hiding the RF access latency behind the
//! two-level scheduler's activation delay (`ltrf_prefetch`).
//!
//! Cooperation shows up in three hooks:
//! - [`CachePolicy::allocate`] detects interval entry (the compiler's
//!   marks) and runs the hardware prefetch engine: write back the old
//!   interval's contents, then stage the new interval's source registers
//!   — each prefetch is a real bank read plus a cache fill, charged to
//!   the energy model.
//! - [`CachePolicy::operand_arrived`] is the hardware half of the fill
//!   path: operands the prefetch missed but the banks fetched anyway are
//!   recorded and installed into the warp's cache on the next allocation
//!   (the "fill on return" of the paper).
//! - [`CachePolicy::build_order`] prioritises warps deepest into their
//!   interval (largest `strand_pos`), so a staged interval is drained
//!   before the scheduler pays for staging another — deterministic
//!   selection with an ascending-id tie-break, no allocation.

use crate::compiler::register_intervals;
use crate::config::GpuConfig;
use crate::energy::EventKind;
use crate::isa::Instruction;
use crate::sim::collector::{plain_lru_victim, AllocResult, CollectorArray};
use crate::sim::exec::WbEvent;
use crate::sim::warp::WarpState;

use super::{free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// Capacity of the fill-on-return staging buffer (drained every
/// allocation, so a handful of slots suffices).
const PENDING_FILLS: usize = 8;

/// Idle cycles after which a mid-interval warp is deactivated anyway.
const INTERVAL_TIMEOUT: u64 = 64;

/// Marker: the warp has not entered any interval yet.
const NO_INTERVAL: u32 = u32::MAX;

/// Software/hardware cooperative RFC prefetch + two-level scheduler.
pub struct LtrfPolicy {
    entries: usize,
    prefetch: u64,
    /// Compiler interval table per local warp (lazily computed once from
    /// the warp's stream — a pure function, so determinism is preserved).
    intervals: Vec<Vec<u32>>,
    /// Interval each warp currently has staged.
    cur_interval: Vec<u32>,
    /// Fill-on-return staging: `(warp, reg)` operands fetched from the
    /// banks, installed into the warp's cache at the next allocation.
    pending: [(u8, u8); PENDING_FILLS],
    n_pending: u8,
}

impl LtrfPolicy {
    /// Capture cache size and prefetch latency from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        LtrfPolicy {
            entries: cfg.rfc_entries,
            prefetch: cfg.ltrf_prefetch,
            intervals: Vec::new(),
            cur_interval: Vec::new(),
            pending: [(0, 0); PENDING_FILLS],
            n_pending: 0,
        }
    }

    /// One-time sizing of the per-warp state (the hook signatures do not
    /// carry the warp count, so it is learned at the first allocation).
    fn ensure_warp_state(&mut self, nwarps: usize) {
        if self.intervals.len() < nwarps {
            self.intervals.resize_with(nwarps, Vec::new);
            self.cur_interval.resize(nwarps, NO_INTERVAL);
        }
    }
}

impl CachePolicy for LtrfPolicy {
    fn cache_entries_per_collector(&self) -> f64 {
        self.entries as f64
    }

    fn issue_gate(&self, warp: &WarpState, now: u64) -> bool {
        warp.active && now >= warp.active_since + self.activation_delay()
    }

    /// Drain staged intervals first: deepest `strand_pos` issues ahead,
    /// ascending warp id breaks ties (deterministic, allocation-free).
    fn build_order(
        &mut self,
        order: &mut Vec<u8>,
        greedy: Option<u8>,
        warps: &[WarpState],
        _collectors: &CollectorArray,
    ) {
        let n = warps.len();
        debug_assert!(n <= 128, "selection mask is 128 bits wide");
        let mut picked: u128 = 0;
        if let Some(g) = greedy {
            picked |= 1u128 << g; // already at the front of `order`
        }
        loop {
            let mut best: Option<u8> = None;
            for w in 0..n as u8 {
                if picked & (1u128 << w) != 0 {
                    continue;
                }
                match best {
                    None => best = Some(w),
                    Some(b) => {
                        if warps[w as usize].strand_pos > warps[b as usize].strand_pos {
                            best = Some(w);
                        }
                    }
                }
            }
            let Some(b) = best else { break };
            picked |= 1u128 << b;
            order.push(b);
        }
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        self.ensure_warp_state(ctx.warps.len());
        // fill on return: install operands the banks fetched since the
        // last allocation into their warps' caches
        for k in 0..self.n_pending as usize {
            let (w, reg) = self.pending[k];
            if ctx.warps[w as usize].active {
                ctx.rfc[w as usize].allocate(reg, true, false, ctx.rng, &mut plain_lru_victim);
                ctx.stats.energy.add(EventKind::CcuWrite, 1);
            }
        }
        self.n_pending = 0;

        let wi = warp as usize;
        // compiler half: the interval table is a pure function of the
        // stream, computed once per warp (one-time init, not per-event)
        if self.intervals[wi].is_empty() && !ctx.streams[wi].is_empty() {
            self.intervals[wi] = register_intervals(&ctx.streams[wi], self.entries);
        }
        let pc = ctx.warps[wi].pc;
        let table = &self.intervals[wi];
        if pc < table.len() && table[pc] != self.cur_interval[wi] {
            // hardware half: interval entry — retire the old interval's
            // contents and stage the new one's source registers
            let iv = table[pc];
            self.cur_interval[wi] = iv;
            let stream = &ctx.streams[wi];
            let cache = &mut ctx.rfc[wi];
            let dirty = cache.valid_count() as u64;
            if dirty > 0 {
                ctx.stats.energy.add(EventKind::BankWrite, dirty);
            }
            cache.flush();
            let mut j = pc;
            while j < stream.len() && table[j] == iv && cache.valid_count() < self.entries {
                for &r in stream[j].sources() {
                    if cache.valid_count() >= self.entries {
                        break;
                    }
                    if cache.lookup(r).is_none() {
                        cache.allocate(r, true, false, ctx.rng, &mut plain_lru_victim);
                        ctx.stats.energy.add(EventKind::BankRead, 1);
                        ctx.stats.energy.add(EventKind::CcuWrite, 1);
                    }
                }
                j += 1;
            }
        }

        let mut res = ctx.collectors.alloc_ocu(ci, warp, instr, now);
        if ctx.warps[wi].active {
            // staged registers hit; the rest go to the banks (and come
            // back through the fill-on-return path)
            let cache = &mut ctx.rfc[wi];
            let col = &mut *ctx.collectors;
            let mut hits = 0u32;
            res.misses.retain(|slot, reg| {
                if let Some(i) = cache.lookup(reg) {
                    cache.touch(i);
                    col.deliver(ci, slot);
                    hits += 1;
                    false
                } else {
                    true
                }
            });
            res.hits += hits;
        }
        res
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        _port_free: bool,
    ) -> bool {
        // results stay in the staged interval only when the compiler marked
        // them near (they will be read again before the interval ends)
        if near && ctx.warps[ev.warp as usize].active {
            ctx.rfc[ev.warp as usize]
                .allocate(reg, true, false, ctx.rng, &mut plain_lru_victim)
                .is_some()
        } else {
            false
        }
    }

    /// Fill on return: remember which warp's operand the banks produced;
    /// installed at the next allocation (this hook has no cache access).
    fn operand_arrived(&mut self, collectors: &mut CollectorArray, ci: usize, slot: u8, reg: u8) {
        if let Some(w) = collectors.owner(ci) {
            if (self.n_pending as usize) < PENDING_FILLS {
                self.pending[self.n_pending as usize] = (w, reg);
                self.n_pending += 1;
            }
        }
        collectors.bank_operand_arrived(ci, slot, reg, false);
    }

    fn should_swap_out(&self, warp: &WarpState, instr: &Instruction, now: u64) -> bool {
        warp.blocked_on_load(instr) || now.saturating_sub(warp.last_issue) > INTERVAL_TIMEOUT
    }

    /// Staging an interval takes the software-prefetch latency.
    fn activation_delay(&self) -> u64 {
        self.prefetch
    }

    /// Time-dependent gates: pending prefetch completions open the issue
    /// gate, and the interval timeout makes a resident stalled warp
    /// swappable at `last_issue + INTERVAL_TIMEOUT + 1` — fast-forward up
    /// to whichever boundary comes first.
    fn quiescent_horizon(&self, warps: &[WarpState], now: u64) -> u64 {
        let mut h = u64::MAX;
        for w in warps {
            if !w.active || w.done {
                continue;
            }
            let gate = w.active_since + self.activation_delay();
            if gate > now {
                h = h.min(gate);
            }
            let timeout = w.last_issue + INTERVAL_TIMEOUT + 1;
            if timeout > now {
                h = h.min(timeout);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    #[test]
    fn prefetch_latency_is_the_activation_delay() {
        let mut cfg = GpuConfig::table1_baseline();
        cfg.ltrf_prefetch = 13;
        let p = LtrfPolicy::from_config(&cfg);
        assert_eq!(p.activation_delay(), 13);
        assert!((p.cache_entries_per_collector() - cfg.rfc_entries as f64).abs() < 1e-12);
    }

    #[test]
    fn build_order_drains_deepest_interval_first() {
        let cfg = GpuConfig::table1_baseline();
        let mut p = LtrfPolicy::from_config(&cfg);
        let mut warps: Vec<WarpState> = (0..4u32).map(WarpState::new).collect();
        warps[0].strand_pos = 2;
        warps[1].strand_pos = 5;
        warps[2].strand_pos = 9;
        warps[3].strand_pos = 2;
        let empty = CollectorArray::new(0, 8);
        let mut order = Vec::new();
        p.build_order(&mut order, None, &warps, &empty);
        // descending strand_pos; the 0/3 tie resolves to the lower id
        assert_eq!(order, vec![2, 1, 0, 3]);
        // a greedy warp is already at the front and never re-pushed
        let mut order = vec![2u8];
        p.build_order(&mut order, Some(2), &warps, &empty);
        assert_eq!(order, vec![2, 1, 0, 3]);
    }

    #[test]
    fn fill_buffer_is_bounded() {
        use crate::isa::{Instruction, OpClass};
        let cfg = GpuConfig::table1_baseline();
        let mut p = LtrfPolicy::from_config(&cfg);
        let mut arr = CollectorArray::new(1, 8);
        // give unit 0 an owner so arrivals are recorded
        arr.alloc_ocu(0, 1, &Instruction::new(OpClass::Alu, &[1, 2], &[3]), 0);
        for k in 0..(PENDING_FILLS + 4) as u8 {
            p.operand_arrived(&mut arr, 0, k % 6, k);
        }
        assert_eq!(p.n_pending as usize, PENDING_FILLS, "overflow is dropped");
    }
}
