//! Pluggable RF-cache policy layer.
//!
//! Every scheme-varying decision the sub-core pipeline makes is owned by a
//! [`CachePolicy`] implementation — one self-contained file per scheme —
//! and schemes are looked up by name in the [`registry`]. The sub-core
//! ([`crate::sim::subcore`]) and collector ([`crate::sim::collector`])
//! hot paths contain **zero** scheme dispatch: they call through the trait
//! (enforced by `rust/tests/policy_parity.rs`).
//!
//! # Decision points
//!
//! | Paper concept | Trait hook |
//! |---|---|
//! | issue gate (two-level residency, §VI-A) | [`CachePolicy::issue_gate`] |
//! | STHLD waiting mechanism (§IV-B2) | [`CachePolicy::select_collector`] returning [`CollectorChoice::StallCycle`] |
//! | warp priority order (§IV-B1) | [`CachePolicy::build_order`] |
//! | collector routing (OCU/CCU/BOC/private) | [`CachePolicy::select_collector`] |
//! | operand capture + tag checks (§III-C1) | [`CachePolicy::allocate`] |
//! | replacement / victim choice (§IV-A1) | the [`VictimFn`] each policy passes to [`CacheTable::allocate`] |
//! | writeback capture + write filter (§IV-A2) | [`CachePolicy::capture_writeback`] |
//! | two-level swap-out (§VI-A) | [`CachePolicy::should_swap_out`] |
//! | quiescent fast-forward horizon (simulator perf, not paper) | [`CachePolicy::quiescent_horizon`] |
//!
//! # Adding a scheme
//!
//! Write one file implementing [`CachePolicy`], then either add it to the
//! built-in table in [`registry`] or register it at runtime with
//! [`registry::register`] (see `examples/custom_policy.rs`). The name
//! becomes usable everywhere a scheme name is accepted
//! (`simulate --scheme <name>`, `-s scheme=<name>`, the harness, …).
//!
//! # Determinism contract
//!
//! Policies draw every tie-break from the per-sub-core [`Rng`] handed to
//! them via [`PolicyCtx`] and must not read wall clock, thread identity,
//! or unordered containers — a policy's decisions must be a pure function
//! of `(sub-core state, its own state, the RNG stream)`. The golden
//! fingerprint fixture (`rust/tests/golden/fingerprints.txt`) pins each
//! built-in policy's behavior bit-exactly.
//!
//! # Allocation contract
//!
//! Every hook here runs on the per-cycle hot path, so policies must not
//! heap-allocate per event. All scratch is caller-owned: the sub-core
//! passes its reusable buffers through [`PolicyCtx`] (`order` in
//! [`CachePolicy::build_order`] is the sub-core's scratch, miss lists are
//! inline [`AllocResult`] storage), and set selection uses streaming
//! patterns — reservoir sampling ([`free_unit_reservoir`], one RNG draw
//! per candidate) or count-then-pick two-pass selection
//! ([`reuse_guided_victim`](crate::sim::collector::reuse_guided_victim),
//! one draw total) — instead of collecting candidate `Vec`s. When porting
//! an allocating chooser, keep the RNG draw sequence identical or the
//! golden fingerprints will (correctly) fail.

pub mod registry;

mod baseline;
mod belady;
mod bow;
mod compress;
mod fifo;
mod greener;
mod ltrf;
mod malekeh;
mod malekeh_pr;
mod regdem;
mod rfc;
mod software_rfc;
mod traditional;

pub use baseline::BaselinePolicy;
pub use belady::BeladyPolicy;
pub use bow::BowPolicy;
pub use compress::CompressPolicy;
pub use fifo::FifoPolicy;
pub use greener::GreenerPolicy;
pub use ltrf::LtrfPolicy;
pub use malekeh::MalekehPolicy;
pub use malekeh_pr::MalekehPrPolicy;
pub use regdem::RegdemPolicy;
pub use registry::{register, PolicyMeta, Scheme};
pub use rfc::RfcPolicy;
pub use software_rfc::SoftwareRfcPolicy;
pub use traditional::MalekehTraditionalPolicy;

use std::sync::Arc;

use crate::config::GpuConfig;
use crate::energy::EventKind;
use crate::isa::Instruction;
use crate::sim::collector::{
    plain_lru_victim, reuse_guided_victim, AllocResult, CacheTable, CollectorArray, VictimFn,
};
use crate::sim::exec::WbEvent;
use crate::sim::warp::WarpState;
use crate::stats::Stats;
use crate::util::Rng;

/// Mutable view of the sub-core state a policy decision may touch. Built
/// fresh at each hook call from disjoint sub-core fields, so policies can
/// combine collector mutation, RNG draws, and counter bumps in one call.
pub struct PolicyCtx<'a> {
    /// The collector bank in SoA layout (2 shared units, or one per warp
    /// for private schemes). Policies scan its hot arrays/bitmasks
    /// (`free_mask`, `ready_mask`, `owner`, value mirrors) without
    /// touching the cold `CacheTable`/window payloads.
    pub collectors: &'a mut CollectorArray,
    /// RFC per-warp cache tables (empty unless the policy is two-level).
    pub rfc: &'a mut [CacheTable],
    /// Warp state, indexed by local warp id.
    pub warps: &'a [WarpState],
    /// Instruction stream per local warp (oracle policies scan ahead).
    pub streams: &'a [Arc<Vec<Instruction>>],
    /// The sub-core's seeded policy RNG — the only randomness source.
    pub rng: &'a mut Rng,
    /// Run counters (policies bump their own stall/energy events).
    pub stats: &'a mut Stats,
    /// Waiting-mechanism counter (§IV-B2, per sub-core).
    pub wait_counter: &'a mut u32,
    /// Current STHLD (static, or broadcast by the dynamic controller).
    pub sthld: u32,
}

/// Outcome of [`CachePolicy::select_collector`] for one candidate warp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectorChoice {
    /// Allocate the instruction into this collector unit.
    Unit(usize),
    /// This warp cannot issue; the scheduler tries the next warp in the
    /// priority order.
    SkipWarp,
    /// Nothing issues this cycle (the slot stalls). `waiting: true` marks
    /// a waiting-mechanism stall (§IV-B2 box 7) for Fig 10 accounting.
    StallCycle {
        /// Stall caused by the STHLD waiting mechanism.
        waiting: bool,
    },
}

/// One scheme's complete decision set. One boxed instance lives in every
/// sub-core (policies may carry per-sub-core state); construction happens
/// through the [`registry`] from the resolved [`crate::config::GpuConfig`].
pub trait CachePolicy: Send {
    /// Collector cache tables survive dispatch (CCU semantics, §III-C1);
    /// `false` drops the contents like a plain OCU.
    fn caching(&self) -> bool {
        false
    }

    /// Cache entries per collector for the energy model's storage scaling.
    /// Default 0: a scheme without a cache (the baseline OCU) must report
    /// zero entries, and zero entries means the energy model charges
    /// nothing for cache events — the OCU's operand latches are pipeline
    /// plumbing, not a cache, so Fig 15's baseline point has no
    /// CCU-read/-write or cache-leakage component (`energy::tests` pins
    /// this).
    fn cache_entries_per_collector(&self) -> f64 {
        0.0
    }

    /// Append this cycle's warp priority order to `order` (the greedy warp,
    /// if any, is already at the front). Default: GTO — greedy then oldest
    /// (ascending id = age order).
    fn build_order(
        &mut self,
        order: &mut Vec<u8>,
        greedy: Option<u8>,
        warps: &[WarpState],
        _collectors: &CollectorArray,
    ) {
        for w in 0..warps.len() as u8 {
            if Some(w) != greedy {
                order.push(w);
            }
        }
    }

    /// May the issue slot consider this warp at all this cycle? Two-level
    /// policies gate on active-set residency + activation delay (§VI-A).
    fn issue_gate(&self, _warp: &WarpState, _now: u64) -> bool {
        true
    }

    /// Route a ready warp to a collector unit — and implement any issue
    /// gating (the STHLD waiting mechanism stalls the slot from here).
    fn select_collector(&mut self, ctx: &mut PolicyCtx, warp: u8) -> CollectorChoice;

    /// Allocate the issued instruction into collector `ci`: tag-check the
    /// sources against whatever cache the scheme keeps and return which
    /// slots still need RF bank reads.
    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult;

    /// The capture decision: should this written-back destination value
    /// enter the scheme's cache, and with which class? Returns true if
    /// captured. `port_free` models the single CCU write port (§IV-A2).
    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool;

    /// A bank-fetched operand arrived over port S for collector `ci`.
    /// Default: mark the slot ready; window-tracking policies (BOW) also
    /// record the value.
    fn operand_arrived(&mut self, collectors: &mut CollectorArray, ci: usize, slot: u8, reg: u8) {
        collectors.bank_operand_arrived(ci, slot, reg, false);
    }

    /// Two-level scheduler: should this *stalled* active warp be swapped
    /// out for a pending one? Only consulted for two-level policies.
    fn should_swap_out(&self, _warp: &WarpState, _instr: &Instruction, _now: u64) -> bool {
        false
    }

    /// Activation (swap-in) latency of the two-level scheduler (§VI-A).
    fn activation_delay(&self) -> u64 {
        4
    }

    /// Does this scheme keep a per-collector sliding window (BOW)? The
    /// sub-core allocates the window side-table only when true, so the
    /// other schemes carry no per-unit `VecDeque` at all.
    fn uses_window(&self) -> bool {
        false
    }

    /// Earliest future cycle at which this policy's *time-dependent* state
    /// could change an issue decision while every warp is stall-ready and
    /// the policy was not consulted (see `SubCore::next_wakeup`). The
    /// quiescent fast-forward never skips past this horizon. Policies
    /// whose gates depend on time (activation delays, idle timeouts)
    /// override it; the default of `now` means "never skip", which is
    /// always safe — including for external registry policies that predate
    /// this hook.
    fn quiescent_horizon(&self, _warps: &[WarpState], now: u64) -> u64 {
        now
    }
}

// --------------------------------------------------------- shared helpers

/// Reservoir-sample a free collector unit — the baseline OCU allocator's
/// uniform pick, one RNG draw per free unit, no allocation on the hot path.
/// Iterates the packed free bitmask (ascending bit order = ascending unit
/// index, the same candidate sequence as the old per-struct scan, so the
/// RNG draw stream is unchanged).
pub fn free_unit_reservoir(collectors: &CollectorArray, rng: &mut Rng) -> Option<usize> {
    let mut seen = 0usize;
    let mut pick = None;
    let mut free = collectors.free_mask();
    while free != 0 {
        let i = free.trailing_zeros() as usize;
        free &= free - 1;
        seen += 1;
        if rng.below(seen) == 0 {
            pick = Some(i);
        }
    }
    pick
}

/// CCU-family allocation: delegate to [`CollectorArray::alloc_ccu`] with
/// the policy's victim chooser.
pub fn ccu_allocate(
    ctx: &mut PolicyCtx,
    ci: usize,
    warp: u8,
    instr: &Instruction,
    now: u64,
    victim: VictimFn,
) -> AllocResult {
    ctx.collectors.alloc_ccu(ci, warp, instr, now, ctx.rng, victim)
}

/// CCU-family writeback capture: one write port per CCU (§IV-A2) — the
/// value enters the cache only when the port is free, costing one OCT
/// bookkeeping event; `no_write_filter` disables the near-only filter.
pub fn ccu_capture(
    ctx: &mut PolicyCtx,
    ev: &WbEvent,
    reg: u8,
    near: bool,
    port_free: bool,
    victim: VictimFn,
    no_write_filter: bool,
) -> bool {
    let ci = ev.collector as usize;
    if port_free && ci < ctx.collectors.len() {
        ctx.stats.energy.add(EventKind::OctOp, 1);
        ctx.collectors.ccu_writeback(ci, ev.warp, reg, near, ctx.rng, victim, no_write_filter)
    } else {
        false
    }
}

/// Shared knobs + plumbing of the CCU-hardware scheme family (`malekeh`,
/// `malekeh_pr`, `malekeh_traditional`): the Fig-17 ablation flags from
/// the config, the replacement chooser they select, and the common
/// allocation/capture delegation — so a knob fix lands in one place.
pub struct CcuKnobs {
    traditional: bool,
    no_write_filter: bool,
    ct_entries: usize,
}

impl CcuKnobs {
    /// Capture the ablation knobs from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        CcuKnobs {
            traditional: cfg.traditional_replacement,
            no_write_filter: cfg.no_write_filter,
            ct_entries: cfg.ct_entries,
        }
    }

    /// The replacement chooser these knobs select: the paper's
    /// reuse-guided policy (§IV-A1), or plain LRU under
    /// `traditional_replacement`.
    pub fn victim(&self) -> fn(&CacheTable, &mut Rng) -> Option<usize> {
        if self.traditional {
            plain_lru_victim
        } else {
            reuse_guided_victim
        }
    }

    /// Cache-table entries per collector (energy-model storage scaling).
    pub fn entries(&self) -> f64 {
        self.ct_entries as f64
    }

    /// CCU allocation with the selected replacement.
    pub fn allocate(
        &self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        ccu_allocate(ctx, ci, warp, instr, now, &mut self.victim())
    }

    /// CCU writeback capture with the selected replacement and filter.
    pub fn capture(
        &self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        ccu_capture(ctx, ev, reg, near, port_free, &mut self.victim(), self.no_write_filter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_unit_reservoir_is_uniform_and_deterministic() {
        use crate::isa::OpClass;
        let mut cols = CollectorArray::new(4, 8);
        let i = Instruction::new(OpClass::Alu, &[1], &[2]);
        cols.alloc_ocu(1, 0, &i, 0); // occupy unit 1
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let pa = free_unit_reservoir(&cols, &mut a);
        let pb = free_unit_reservoir(&cols, &mut b);
        assert_eq!(pa, pb, "same seed, same pick");
        assert!(matches!(pa, Some(0 | 2 | 3)), "occupied unit never picked");
        for ci in [0usize, 2, 3] {
            cols.alloc_ocu(ci, 0, &i, 0);
        }
        assert_eq!(free_unit_reservoir(&cols, &mut a), None);
    }

    #[test]
    fn default_build_order_is_gto() {
        struct P;
        impl CachePolicy for P {
            fn select_collector(&mut self, _: &mut PolicyCtx, _: u8) -> CollectorChoice {
                CollectorChoice::SkipWarp
            }
            fn allocate(
                &mut self,
                _: &mut PolicyCtx,
                _: usize,
                _: u8,
                _: &Instruction,
                _: u64,
            ) -> AllocResult {
                AllocResult::default()
            }
            fn capture_writeback(
                &mut self,
                _: &mut PolicyCtx,
                _: &WbEvent,
                _: u8,
                _: bool,
                _: bool,
            ) -> bool {
                false
            }
        }
        let warps: Vec<WarpState> = (0..4).map(|i| WarpState::new(i)).collect();
        let mut order = vec![2u8]; // greedy already pushed by the sub-core
        P.build_order(&mut order, Some(2), &warps, &CollectorArray::new(0, 8));
        assert_eq!(order, vec![2, 0, 1, 3]);
    }
}
