//! GREENER-style power-gated, sliced register file (Jatala et al.,
//! PAPERS.md): the RF is partitioned into per-warp slices and only the
//! slices of the *active* warps are powered; everything else is gated off.
//!
//! Mapping onto this simulator: the two-level scheduler's active set *is*
//! the powered slice set — an inactive warp's slice is gated, so the warp
//! cannot issue at all ([`CachePolicy::issue_gate`]) and re-powering a
//! slice costs the gate wake-up latency (`greener_wakeup`, longer than the
//! plain two-level activation delay). The per-warp RFC tables model the
//! retention latches of a powered slice: any register of an active warp
//! may hit ([`CachePolicy::allocate`]), but only near-marked results are
//! retained at writeback (gating pressure keeps the latch set small). The
//! energy model sees only the powered fraction of the cache storage:
//! [`CachePolicy::cache_entries_per_collector`] reports
//! `rfc_entries x active / warps` — the gated slices charge nothing,
//! which is the scheme's whole point.
//!
//! Aggressive gating: a warp is swapped out (slice gated) not just on load
//! stalls but after a short idle timeout, trading activation latency for
//! leakage — the GREENER trade-off the Fig 15-style rows expose.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{plain_lru_victim, AllocResult};
use crate::sim::exec::WbEvent;
use crate::sim::warp::WarpState;

use super::{free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// Idle cycles after which an active warp's slice is gated off.
const GATE_IDLE_CYCLES: u64 = 32;

/// Power-gated/sliced RF + two-level scheduler.
pub struct GreenerPolicy {
    rfc_entries: usize,
    active_warps: usize,
    warps_per_sub_core: usize,
    wakeup: u64,
}

impl GreenerPolicy {
    /// Capture slice geometry and the gate wake-up latency from the
    /// resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        GreenerPolicy {
            rfc_entries: cfg.rfc_entries,
            active_warps: cfg.active_warps_per_sub_core,
            warps_per_sub_core: cfg.warps_per_sub_core(),
            wakeup: cfg.greener_wakeup,
        }
    }
}

impl CachePolicy for GreenerPolicy {
    /// Only the powered (active) fraction of the slice storage exists as
    /// far as the energy model is concerned — gated slices leak nothing.
    fn cache_entries_per_collector(&self) -> f64 {
        self.rfc_entries as f64 * self.active_warps as f64 / self.warps_per_sub_core.max(1) as f64
    }

    /// A gated slice cannot feed the pipeline: the warp must be active and
    /// past the gate wake-up latency.
    fn issue_gate(&self, warp: &WarpState, now: u64) -> bool {
        warp.active && now >= warp.active_since + self.activation_delay()
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        let mut res = ctx.collectors.alloc_ocu(ci, warp, instr, now);
        if ctx.warps[warp as usize].active {
            // powered slice: any retained register may hit (filtered out of
            // the miss list in place — inline storage, no per-event heap)
            let cache = &mut ctx.rfc[warp as usize];
            let col = &mut *ctx.collectors;
            let mut hits = 0u32;
            res.misses.retain(|slot, reg| {
                if let Some(i) = cache.lookup(reg) {
                    cache.touch(i);
                    col.deliver(ci, slot);
                    hits += 1;
                    false
                } else {
                    true
                }
            });
            res.hits += hits;
        }
        res
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        _port_free: bool,
    ) -> bool {
        // retention latches are scarce under gating pressure: keep only
        // near-reuse results of a still-powered slice
        if near && ctx.warps[ev.warp as usize].active {
            ctx.rfc[ev.warp as usize]
                .allocate(reg, true, false, ctx.rng, &mut plain_lru_victim)
                .is_some()
        } else {
            false
        }
    }

    /// Gate the slice on load stalls *and* after a short idle timeout —
    /// GREENER gates more aggressively than a plain two-level RFC.
    fn should_swap_out(&self, warp: &WarpState, instr: &Instruction, now: u64) -> bool {
        warp.blocked_on_load(instr) || now.saturating_sub(warp.last_issue) > GATE_IDLE_CYCLES
    }

    /// Power-gate wake-up: slower than the plain scheduler swap-in.
    fn activation_delay(&self) -> u64 {
        self.wakeup
    }

    /// Time-dependent gates: pending wake-ups open the issue gate, and the
    /// idle timeout makes a resident stalled warp gateable at
    /// `last_issue + GATE_IDLE_CYCLES + 1` — fast-forward up to whichever
    /// boundary comes first.
    fn quiescent_horizon(&self, warps: &[WarpState], now: u64) -> u64 {
        let mut h = u64::MAX;
        for w in warps {
            if !w.active || w.done {
                continue;
            }
            let gate = w.active_since + self.activation_delay();
            if gate > now {
                h = h.min(gate);
            }
            let timeout = w.last_issue + GATE_IDLE_CYCLES + 1;
            if timeout > now {
                h = h.min(timeout);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    #[test]
    fn energy_model_sees_only_powered_slices() {
        let cfg = GpuConfig::table1_baseline();
        let p = GreenerPolicy::from_config(&cfg);
        // Table I: 6 entries x 2 active / 8 warps = 1.5 powered entries
        assert!((p.cache_entries_per_collector() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn gate_wakeup_is_the_activation_delay() {
        let mut cfg = GpuConfig::table1_baseline();
        cfg.greener_wakeup = 11;
        let p = GreenerPolicy::from_config(&cfg);
        assert_eq!(p.activation_delay(), 11);
        // a freshly powered slice is unusable until the wake-up elapses
        let mut w = WarpState::new(0);
        w.active = true;
        w.active_since = 100;
        assert!(!p.issue_gate(&w, 105));
        assert!(p.issue_gate(&w, 111));
        w.active = false;
        assert!(!p.issue_gate(&w, 200), "gated slice never issues");
    }

    #[test]
    fn idle_timeout_gates_the_slice() {
        let cfg = GpuConfig::table1_baseline();
        let p = GreenerPolicy::from_config(&cfg);
        let mut w = WarpState::new(0);
        w.active = true;
        w.last_issue = 10;
        let instr = Instruction::new(crate::isa::OpClass::Alu, &[1], &[2]);
        assert!(!p.should_swap_out(&w, &instr, 20), "short stall keeps power");
        assert!(
            p.should_swap_out(&w, &instr, 10 + GATE_IDLE_CYCLES + 1),
            "idle past the timeout gates the slice"
        );
    }
}
