//! String-keyed scheme registry — the single source of scheme names.
//!
//! A [`Scheme`] is an opaque, copyable handle into the registry: the
//! built-in policies occupy fixed slots (the associated constants below),
//! and [`register`] appends new policies at runtime (see
//! `examples/custom_policy.rs`). Everything that used to be duplicated
//! across the old enum — the name table, `from_name`, the
//! private-per-warp / two-level structural flags — now lives in one
//! [`PolicyMeta`] per entry, so a new scheme is one file plus one entry
//! and no string table can drift.
//!
//! Builders are cloned out of the registry and invoked with no lock
//! held, so a policy builder may freely use registry-backed [`Scheme`]
//! APIs (or even [`register`] another policy).

use std::fmt;
use std::sync::{Arc, OnceLock, RwLock};

use super::{
    BaselinePolicy, BeladyPolicy, BowPolicy, CachePolicy, CompressPolicy, FifoPolicy,
    GreenerPolicy, LtrfPolicy, MalekehPolicy, MalekehPrPolicy, MalekehTraditionalPolicy,
    RegdemPolicy, RfcPolicy, SoftwareRfcPolicy,
};
use crate::config::GpuConfig;

/// Structural description of a registered policy — everything the config
/// layer and the harness need to know without building the policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyMeta {
    /// Stable name used by the CLI, configs, and reports.
    pub name: &'static str,
    /// One-line description (`malekeh policies`, docs/CONFIG.md).
    pub summary: &'static str,
    /// One private collector per resident warp instead of a shared pool.
    pub private_per_warp: bool,
    /// Uses the two-level (active/pending) warp scheduler (§VI-A).
    pub two_level: bool,
    /// Part of the Fig 17 traditional-policy comparison sweep.
    pub fig17_sweep: bool,
}

type BuildFn = dyn Fn(&GpuConfig) -> Box<dyn CachePolicy> + Send + Sync;

struct Entry {
    meta: PolicyMeta,
    build: Arc<BuildFn>,
}

static REGISTRY: OnceLock<RwLock<Vec<Entry>>> = OnceLock::new();

fn registry() -> &'static RwLock<Vec<Entry>> {
    REGISTRY.get_or_init(|| RwLock::new(builtin_entries()))
}

/// Read the registry, shrugging off lock poisoning: entries are only ever
/// appended (never left half-written), so a panic inside a policy builder
/// must not cascade into every later `Scheme` operation — `name()` feeds
/// Display and panic messages, where a poison panic would mask the
/// original failure.
fn read_entries() -> std::sync::RwLockReadGuard<'static, Vec<Entry>> {
    registry().read().unwrap_or_else(|e| e.into_inner())
}

/// Built-in policies in figure-report order. Index == the associated
/// constants on [`Scheme`]; append only (the constants are public API).
fn builtin_entries() -> Vec<Entry> {
    fn e(
        meta: PolicyMeta,
        build: impl Fn(&GpuConfig) -> Box<dyn CachePolicy> + Send + Sync + 'static,
    ) -> Entry {
        Entry { meta, build: Arc::new(build) }
    }
    vec![
        e(
            PolicyMeta {
                name: "baseline",
                summary: "Turing OCUs, no caching (§II)",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: false,
            },
            |cfg| Box::new(BaselinePolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "malekeh",
                summary: "shared CCUs, reuse-guided replacement + waiting mechanism (§III–§IV)",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: false,
            },
            |cfg| Box::new(MalekehPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "malekeh_pr",
                summary: "Malekeh with a private CCU per warp (§VI-B)",
                private_per_warp: true,
                two_level: false,
                fig17_sweep: false,
            },
            |cfg| Box::new(MalekehPrPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "bow",
                summary: "per-warp bypassing collectors with a sliding window (§VI-B)",
                private_per_warp: true,
                two_level: false,
                fig17_sweep: false,
            },
            |cfg| Box::new(BowPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "rfc",
                summary: "per-active-warp HW register file cache, two-level scheduler (§VI-A)",
                private_per_warp: false,
                two_level: true,
                fig17_sweep: false,
            },
            |cfg| Box::new(RfcPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "software_rfc",
                summary: "compiler-managed RFC with strand swaps (§VI-A)",
                private_per_warp: false,
                two_level: true,
                fig17_sweep: false,
            },
            |cfg| Box::new(SoftwareRfcPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "malekeh_traditional",
                summary: "CCU hardware under GTO + plain LRU, no write filter (Fig 17)",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: true,
            },
            |cfg| Box::new(MalekehTraditionalPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "fifo",
                summary: "CCU hardware under GTO + FIFO replacement, no write filter",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: true,
            },
            |cfg| Box::new(FifoPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "belady",
                summary: "CCU hardware under GTO + oracle (Belady) replacement on exact reuse",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: true,
            },
            |cfg| Box::new(BeladyPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "greener",
                summary: "power-gated RF slices, only active warps powered (GREENER)",
                private_per_warp: false,
                two_level: true,
                fig17_sweep: true,
            },
            |cfg| Box::new(GreenerPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "compress",
                summary: "static compression admission, half-width cache entries (Angerd et al.)",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: true,
            },
            |cfg| Box::new(CompressPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "ltrf",
                summary: "compiler register intervals + HW prefetch into per-warp RFC (LTRF)",
                private_per_warp: false,
                two_level: true,
                fig17_sweep: true,
            },
            |cfg| Box::new(LtrfPolicy::from_config(cfg)),
        ),
        e(
            PolicyMeta {
                name: "regdem",
                summary: "cold registers demoted to shared-memory spills, no cache (RegDem)",
                private_per_warp: false,
                two_level: false,
                fig17_sweep: true,
            },
            |cfg| Box::new(RegdemPolicy::from_config(cfg)),
        ),
    ]
}

/// Register a new policy at runtime; its name becomes usable everywhere a
/// scheme name is accepted. Errors on a duplicate name.
pub fn register(
    meta: PolicyMeta,
    build: impl Fn(&GpuConfig) -> Box<dyn CachePolicy> + Send + Sync + 'static,
) -> Result<Scheme, String> {
    let mut reg = registry().write().unwrap_or_else(|e| e.into_inner());
    if reg.iter().any(|e| e.meta.name == meta.name) {
        return Err(format!("policy {:?} is already registered", meta.name));
    }
    if reg.len() > u16::MAX as usize {
        return Err("policy registry full".into());
    }
    reg.push(Entry { meta, build: Arc::new(build) });
    Ok(Scheme((reg.len() - 1) as u16))
}

/// Identifier of a registered cache policy (scheme): an opaque, copyable
/// handle that keys harness caches and configs exactly like the old enum.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Scheme(u16);

impl Scheme {
    /// Baseline Turing-style OCUs, no caching (§II).
    pub const BASELINE: Scheme = Scheme(0);
    /// Malekeh: shared CCUs with reuse-guided policies (§III, §IV).
    pub const MALEKEH: Scheme = Scheme(1);
    /// Malekeh with a private CCU per warp (§VI-B, "Malekeh_PR").
    pub const MALEKEH_PR: Scheme = Scheme(2);
    /// BOW: private per-warp bypassing operand collectors, sliding window.
    pub const BOW: Scheme = Scheme(3);
    /// RFC: per-active-warp RF cache + two-level scheduler (Gebhart 2011).
    pub const RFC: Scheme = Scheme(4);
    /// Software RFC: compiler-managed cache + two-level scheduler (strands).
    pub const SOFTWARE_RFC: Scheme = Scheme(5);
    /// Fig 17 ablation: Malekeh hardware, traditional GTO + plain LRU.
    pub const MALEKEH_TRADITIONAL: Scheme = Scheme(6);
    /// Registry-only policy: CCU hardware with FIFO replacement.
    pub const FIFO: Scheme = Scheme(7);
    /// Registry-only policy: CCU hardware with Belady oracle replacement.
    pub const BELADY: Scheme = Scheme(8);
    /// GREENER: power-gated/sliced RF, two-level active set (PAPERS.md).
    pub const GREENER: Scheme = Scheme(9);
    /// Static data-compression admission CCU (Angerd et al., PAPERS.md).
    pub const COMPRESS: Scheme = Scheme(10);
    /// LTRF: compiler register intervals + hardware prefetch (PAPERS.md).
    pub const LTRF: Scheme = Scheme(11);
    /// RegDem: cold registers demoted to shared-memory spills (PAPERS.md).
    pub const REGDEM: Scheme = Scheme(12);

    /// Every registered scheme, in registration (= figure-report) order.
    pub fn all() -> Vec<Scheme> {
        (0..read_entries().len() as u16).map(Scheme).collect()
    }

    /// The Fig 17 traditional-policy sweep set, in registration order.
    pub fn fig17_sweep() -> Vec<Scheme> {
        read_entries()
            .iter()
            .enumerate()
            .filter(|(_, e)| e.meta.fig17_sweep)
            .map(|(i, _)| Scheme(i as u16))
            .collect()
    }

    /// Look a scheme up by its registry name.
    pub fn from_name(s: &str) -> Option<Scheme> {
        read_entries().iter().position(|e| e.meta.name == s).map(|i| Scheme(i as u16))
    }

    /// Like [`Scheme::from_name`], but an unknown name errors with the
    /// list of valid ones.
    pub fn parse(s: &str) -> Result<Scheme, String> {
        Scheme::from_name(s).ok_or_else(|| {
            let names: Vec<&str> =
                read_entries().iter().map(|e| e.meta.name).collect();
            format!("unknown scheme {s:?} (valid: {})", names.join(", "))
        })
    }

    /// Structural metadata of this scheme.
    pub fn meta(self) -> PolicyMeta {
        read_entries()[self.0 as usize].meta
    }

    /// Stable name used by the CLI and reports.
    pub fn name(self) -> &'static str {
        self.meta().name
    }

    /// Does this scheme use a private collector per warp?
    pub fn private_per_warp(self) -> bool {
        self.meta().private_per_warp
    }

    /// Does this scheme use the two-level (active/pending) scheduler?
    pub fn two_level(self) -> bool {
        self.meta().two_level
    }

    /// Build this scheme's policy for one sub-core under `cfg`.
    pub fn build_policy(self, cfg: &GpuConfig) -> Box<dyn CachePolicy> {
        // clone the builder out and drop the guard before invoking it, so
        // a builder may use registry-backed Scheme APIs without queueing
        // behind a waiting writer (std RwLock may deadlock there)
        let build = Arc::clone(&read_entries()[self.0 as usize].build);
        (*build)(cfg)
    }

    /// One human/CI-diffable description line (`malekeh policies`; the
    /// table in docs/CONFIG.md is diffed against these in CI).
    pub fn policy_line(self) -> String {
        let m = self.meta();
        format!(
            "{:<20} {:<8} {:<8} {}",
            m.name,
            if m.private_per_warp { "private" } else { "shared" },
            if m.two_level { "2-level" } else { "1-level" },
            m.summary
        )
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl fmt::Debug for Scheme {
    /// Debug prints the registry name (the index is an implementation
    /// detail).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Scheme({})", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_constants_map_to_names() {
        for (s, name) in [
            (Scheme::BASELINE, "baseline"),
            (Scheme::MALEKEH, "malekeh"),
            (Scheme::MALEKEH_PR, "malekeh_pr"),
            (Scheme::BOW, "bow"),
            (Scheme::RFC, "rfc"),
            (Scheme::SOFTWARE_RFC, "software_rfc"),
            (Scheme::MALEKEH_TRADITIONAL, "malekeh_traditional"),
            (Scheme::FIFO, "fifo"),
            (Scheme::BELADY, "belady"),
            (Scheme::GREENER, "greener"),
            (Scheme::COMPRESS, "compress"),
            (Scheme::LTRF, "ltrf"),
            (Scheme::REGDEM, "regdem"),
        ] {
            assert_eq!(s.name(), name);
            assert_eq!(Scheme::from_name(name), Some(s));
        }
    }

    #[test]
    fn names_are_unique_and_roundtrip() {
        let all = Scheme::all();
        assert!(all.len() >= 9);
        let mut names: Vec<&str> = all.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate scheme name");
        for s in all {
            assert_eq!(Scheme::from_name(s.name()), Some(s));
        }
    }

    #[test]
    fn parse_lists_valid_names_on_error() {
        let err = Scheme::parse("bogus").unwrap_err();
        assert!(err.contains("baseline") && err.contains("belady"), "{err}");
        assert_eq!(Scheme::parse("malekeh").unwrap(), Scheme::MALEKEH);
    }

    #[test]
    fn structural_flags_match_the_old_enum() {
        assert!(Scheme::MALEKEH_PR.private_per_warp());
        assert!(Scheme::BOW.private_per_warp());
        assert!(!Scheme::MALEKEH.private_per_warp());
        assert!(Scheme::RFC.two_level());
        assert!(Scheme::SOFTWARE_RFC.two_level());
        assert!(!Scheme::BASELINE.two_level());
    }

    #[test]
    fn fig17_sweep_set() {
        let sweep = Scheme::fig17_sweep();
        assert_eq!(
            sweep,
            vec![
                Scheme::MALEKEH_TRADITIONAL,
                Scheme::FIFO,
                Scheme::BELADY,
                Scheme::GREENER,
                Scheme::COMPRESS,
                Scheme::LTRF,
                Scheme::REGDEM,
            ]
        );
    }

    #[test]
    fn related_work_schemes_structural_flags() {
        assert!(Scheme::GREENER.two_level());
        assert!(Scheme::LTRF.two_level());
        assert!(!Scheme::COMPRESS.two_level());
        assert!(!Scheme::REGDEM.two_level());
        for s in [Scheme::GREENER, Scheme::COMPRESS, Scheme::LTRF, Scheme::REGDEM] {
            assert!(!s.private_per_warp(), "{s} uses the shared collector pool");
            assert!(s.meta().fig17_sweep, "{s} joins the comparison sweep");
        }
    }
}
