//! Baseline Turing-style operand collectors: no caching anywhere (§II).
//!
//! Issue picks any free OCU uniformly at random, every source operand is
//! fetched from the RF banks, collector contents are dropped at dispatch,
//! and writebacks are never captured.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::AllocResult;
use crate::sim::exec::WbEvent;

use super::{free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// The no-cache reference point every figure normalises to.
pub struct BaselinePolicy;

impl BaselinePolicy {
    /// Build from config (stateless; the signature matches the registry).
    pub fn from_config(_cfg: &GpuConfig) -> Self {
        BaselinePolicy
    }
}

impl CachePolicy for BaselinePolicy {
    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        ctx.collectors.alloc_ocu(ci, warp, instr, now)
    }

    fn capture_writeback(
        &mut self,
        _ctx: &mut PolicyCtx,
        _ev: &WbEvent,
        _reg: u8,
        _near: bool,
        _port_free: bool,
    ) -> bool {
        false
    }
}
