//! Software RFC / LTRF-style (§VI-A): the compiler marks which operands
//! live in the per-warp cache (near bits) and splits code into strands;
//! the two-level scheduler swaps warps at compiler-placed strand ends (or
//! after a long stall — the strand timeout). Only near-marked values are
//! cached, on both the read-check and the writeback path.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{plain_lru_victim, AllocResult};
use crate::sim::exec::WbEvent;
use crate::sim::warp::WarpState;

use super::{free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// Cycles a mid-strand warp may sit stalled before the strand timeout
/// releases it (§VI-A; also bounds the quiescent fast-forward horizon).
const STRAND_TIMEOUT: u64 = 64;

/// Compiler-managed RFC + two-level scheduler with strands.
pub struct SoftwareRfcPolicy {
    entries: usize,
    strand_len: u32,
}

impl SoftwareRfcPolicy {
    /// Capture cache size and strand length from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        SoftwareRfcPolicy {
            entries: cfg.rfc_entries,
            strand_len: cfg.swrfc_strand_len as u32,
        }
    }
}

impl CachePolicy for SoftwareRfcPolicy {
    fn cache_entries_per_collector(&self) -> f64 {
        self.entries as f64
    }

    fn issue_gate(&self, warp: &WarpState, now: u64) -> bool {
        warp.active && now >= warp.active_since + self.activation_delay()
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        let mut res = ctx.collectors.alloc_ocu(ci, warp, instr, now);
        if ctx.warps[warp as usize].active {
            // filter cache hits out of the miss list in place (the list is
            // inline fixed-capacity storage — no per-instruction Vec)
            let cache = &mut ctx.rfc[warp as usize];
            let col = &mut *ctx.collectors;
            let mut hits = 0u32;
            res.misses.retain(|slot, reg| {
                // compiler-managed: only near-marked operands can live in
                // the cache
                let allowed = instr.src_is_near(slot as usize);
                let hit = if allowed { cache.lookup(reg) } else { None };
                if let Some(i) = hit {
                    cache.touch(i);
                    col.deliver(ci, slot);
                    hits += 1;
                    false
                } else {
                    true
                }
            });
            res.hits += hits;
        }
        res
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        _port_free: bool,
    ) -> bool {
        // compiler-managed: only near-marked results are placed in the cache
        if near && ctx.warps[ev.warp as usize].active {
            ctx.rfc[ev.warp as usize]
                .allocate(reg, true, false, ctx.rng, &mut plain_lru_victim)
                .is_some()
        } else {
            false
        }
    }

    /// Swaps happen only at compiler-placed strand ends; a warp stuck
    /// mid-strand is released only after a long stall (the strand
    /// timeout) — short ALU-dependence stalls keep it resident and idle,
    /// the state-2 cost of Fig 10.
    fn should_swap_out(&self, warp: &WarpState, _instr: &Instruction, now: u64) -> bool {
        warp.strand_pos >= self.strand_len
            || now.saturating_sub(warp.last_issue) > STRAND_TIMEOUT
    }

    /// Time-dependent gates: pending activations open the issue gate, and
    /// the strand timeout makes a resident stalled warp swappable at
    /// `last_issue + STRAND_TIMEOUT + 1` — fast-forward up to whichever
    /// boundary comes first.
    fn quiescent_horizon(&self, warps: &[WarpState], now: u64) -> u64 {
        let mut h = u64::MAX;
        for w in warps {
            if !w.active || w.done {
                continue;
            }
            let gate = w.active_since + self.activation_delay();
            if gate > now {
                h = h.min(gate);
            }
            let timeout = w.last_issue + STRAND_TIMEOUT + 1;
            if timeout > now {
                h = h.min(timeout);
            }
        }
        h
    }
}
