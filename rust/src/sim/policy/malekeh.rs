//! The paper's scheme: shared CCUs + reuse-guided policies (§III, §IV).
//!
//! - **Order** (§IV-B1): warps that own cached values issue first.
//! - **Allocation** (§IV-B2, Fig 6): a warp reuses its owned CCU; else a
//!   random far/empty free unit; else the STHLD waiting mechanism.
//! - **Replacement** (§IV-A1): invalid first, then random-far, then LRU
//!   (plain LRU when `traditional_replacement` is set — Fig 17 ablation).
//! - **Writeback** (§IV-A2): single filtered write port — only near
//!   destinations are captured unless `no_write_filter`.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{AllocResult, CollectorArray};
use crate::sim::exec::WbEvent;
use crate::sim::warp::WarpState;

use super::{CachePolicy, CcuKnobs, CollectorChoice, PolicyCtx};

/// Malekeh with shared CCUs.
pub struct MalekehPolicy {
    knobs: CcuKnobs,
}

impl MalekehPolicy {
    /// Capture the Fig-17 ablation knobs from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        MalekehPolicy { knobs: CcuKnobs::from_config(cfg) }
    }
}

impl CachePolicy for MalekehPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        self.knobs.entries()
    }

    /// §IV-B1: warps with data in a CCU first (by age), then the rest.
    fn build_order(
        &mut self,
        order: &mut Vec<u8>,
        greedy: Option<u8>,
        warps: &[WarpState],
        collectors: &CollectorArray,
    ) {
        let n = warps.len() as u8;
        for w in 0..n {
            if Some(w) == greedy {
                continue;
            }
            // bitmask walk over value-holding units + one owner-byte read
            // each — no cold CacheTable access on this scan
            if collectors.warp_owns_values(w) {
                order.push(w);
            }
        }
        for w in 0..n {
            if Some(w) == greedy || order.contains(&w) {
                continue;
            }
            order.push(w);
        }
    }

    /// CCU allocation policy (§IV-B2, Fig 6): the numbered boxes below
    /// follow the paper's flow chart.
    fn select_collector(&mut self, ctx: &mut PolicyCtx, warp: u8) -> CollectorChoice {
        // a warp can own at most one CCU (coherence-free invariant)
        if let Some(ci) = ctx.collectors.position_owned_by(warp) {
            return if ctx.collectors.occupied(ci) {
                CollectorChoice::SkipWarp // box 4: no other CCU may be allocated
            } else {
                CollectorChoice::Unit(ci) // box 3: reuse the owned unit
            };
        }
        // reservoir-sample the free and the far/empty-free sets in one
        // pass over the packed free bitmask (ascending bit order = the old
        // per-struct scan order, so the interleaved draw sequence — one
        // free draw, then conditionally one far draw, per unit — is
        // unchanged; no allocation on the hot path)
        let mut nfree = 0usize;
        let mut free_pick = None;
        let mut nfar = 0usize;
        let mut far_pick = None;
        let mut free = ctx.collectors.free_mask();
        while free != 0 {
            let i = free.trailing_zeros() as usize;
            free &= free - 1;
            nfree += 1;
            if ctx.rng.below(nfree) == 0 {
                free_pick = Some(i);
            }
            if !ctx.collectors.has_near_value(i) {
                nfar += 1;
                if ctx.rng.below(nfar) == 0 {
                    far_pick = Some(i);
                }
            }
        }
        if nfree == 0 {
            ctx.stats.collector_full_stalls += 1;
            return CollectorChoice::SkipWarp; // box 6
        }
        if let Some(i) = far_pick {
            return CollectorChoice::Unit(i); // box 5: random far/empty unit
        }
        // all free units hold near values: waiting mechanism (boxes 7-9)
        if *ctx.wait_counter < ctx.sthld {
            *ctx.wait_counter += 1;
            CollectorChoice::StallCycle { waiting: true }
        } else {
            *ctx.wait_counter = 0;
            CollectorChoice::Unit(free_pick.expect("nfree > 0"))
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        self.knobs.allocate(ctx, ci, warp, instr, now)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        self.knobs.capture(ctx, ev, reg, near, port_free)
    }
}
