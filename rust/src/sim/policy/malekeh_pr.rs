//! Malekeh_PR (§VI-B): the Malekeh caching policies on a *private* CCU
//! per warp — no ownership flushes, but also no pooling, so a busy unit
//! blocks its warp. GTO issue order (the CCU-priority order is pointless
//! when every warp always owns a unit).

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::AllocResult;
use crate::sim::exec::WbEvent;

use super::{CachePolicy, CcuKnobs, CollectorChoice, PolicyCtx};

/// Malekeh with a private CCU per warp.
pub struct MalekehPrPolicy {
    knobs: CcuKnobs,
}

impl MalekehPrPolicy {
    /// Capture the ablation knobs from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        MalekehPrPolicy { knobs: CcuKnobs::from_config(cfg) }
    }
}

impl CachePolicy for MalekehPrPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        self.knobs.entries()
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, warp: u8) -> CollectorChoice {
        let ci = warp as usize % ctx.collectors.len();
        if ctx.collectors.occupied(ci) {
            CollectorChoice::SkipWarp // private unit busy: this warp cannot issue
        } else {
            CollectorChoice::Unit(ci)
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        self.knobs.allocate(ctx, ci, warp, instr, now)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        self.knobs.capture(ctx, ev, reg, near, port_free)
    }
}
