//! BOW (§VI-B): private per-warp bypassing operand collectors. Each BOC
//! keeps a sliding window of the last N instructions' registers; sources
//! found in the window bypass the banks, and every in-window destination
//! is captured at writeback (no write port contention, no filter).

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{AllocResult, CollectorArray};
use crate::sim::exec::WbEvent;

use super::{CachePolicy, CollectorChoice, PolicyCtx};

/// BOW with its per-warp sliding window.
pub struct BowPolicy {
    window: usize,
}

impl BowPolicy {
    /// Capture the window length from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        BowPolicy { window: cfg.bow_window }
    }
}

impl CachePolicy for BowPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        (self.window * 8) as f64 // 6 src + 2 dst per windowed instruction
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, warp: u8) -> CollectorChoice {
        let ci = warp as usize % ctx.collectors.len();
        if ctx.collectors.occupied(ci) {
            CollectorChoice::SkipWarp // private unit busy: this warp cannot issue
        } else {
            CollectorChoice::Unit(ci)
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        ctx.collectors.alloc_boc(ci, warp, instr, now, self.window)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        _near: bool,
        _port_free: bool,
    ) -> bool {
        // BOW writes every in-window destination
        let ci = ev.collector as usize;
        if ci < ctx.collectors.len() {
            ctx.collectors.boc_writeback(ci, ev.boc_seq, reg)
        } else {
            false
        }
    }

    fn operand_arrived(&mut self, collectors: &mut CollectorArray, ci: usize, slot: u8, reg: u8) {
        // a fetched value also becomes present in the sliding window
        collectors.bank_operand_arrived(ci, slot, reg, true);
    }

    fn uses_window(&self) -> bool {
        true // the only scheme whose collectors carry the sliding window
    }
}
