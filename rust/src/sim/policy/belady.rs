//! Registry-only comparison policy: CCU hardware under GTO issue with
//! **Belady (oracle) replacement** — the victim is the entry whose next
//! use by the owning warp lies farthest in the future, computed from the
//! warp's own instruction stream (the same exact reuse distances the
//! compiler pass profiles, §III-A, read forward from the warp's pc
//! instead of collapsed into a near/far bit). Brackets the paper's
//! reuse-guided replacement from above in the Fig 17 sweep.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{AllocResult, CacheTable};
use crate::sim::exec::WbEvent;
use crate::util::Rng;

use super::{
    ccu_allocate, ccu_capture, free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx,
};

/// How far ahead the oracle scans. Reuse past this window is far beyond
/// RTHLD anyway (§III-A), so the bounded scan decides identically to an
/// unbounded one for every realistic table size.
const ORACLE_WINDOW: usize = 256;

/// Forward distance (in instructions) from `pc` to the next *read* of
/// `reg`; a write before any read kills the cached value (`u64::MAX`, the
/// ideal victim), and no appearance within the window ranks just below.
fn next_use_distance(reg: u8, stream: &[Instruction], pc: usize) -> u64 {
    for (d, instr) in stream.iter().skip(pc).take(ORACLE_WINDOW).enumerate() {
        if instr.sources().contains(&reg) {
            return d as u64;
        }
        if instr.dests().contains(&reg) {
            return u64::MAX; // overwritten before any read: dead value
        }
    }
    u64::MAX - 1
}

/// Belady victim: the unlocked entry with the farthest next use (first
/// such entry on ties, for determinism).
pub fn belady_victim(ct: &CacheTable, stream: &[Instruction], pc: usize) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, e) in ct.entries().iter().enumerate() {
        if e.locked {
            continue;
        }
        let d = next_use_distance(e.reg, stream, pc);
        if best.map_or(true, |(_, bd)| d > bd) {
            best = Some((i, d));
        }
    }
    best.map(|(i, _)| i)
}

/// CCU hardware + GTO + Belady oracle replacement.
pub struct BeladyPolicy {
    ct_entries: usize,
}

impl BeladyPolicy {
    /// Capture the table size from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        BeladyPolicy { ct_entries: cfg.ct_entries }
    }
}

impl CachePolicy for BeladyPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        self.ct_entries as f64
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        // copy the (Copy) stream-slice reference out of the ctx so the
        // oracle closure does not hold a borrow of `ctx` across the call
        let streams = ctx.streams;
        let stream: &[Instruction] = &streams[warp as usize];
        let pc = ctx.warps[warp as usize].pc;
        let mut victim = |ct: &CacheTable, _r: &mut Rng| belady_victim(ct, stream, pc);
        ccu_allocate(ctx, ci, warp, instr, now, &mut victim)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        let streams = ctx.streams;
        let stream: &[Instruction] = &streams[ev.warp as usize];
        let pc = ctx.warps[ev.warp as usize].pc;
        let mut victim = |ct: &CacheTable, _r: &mut Rng| belady_victim(ct, stream, pc);
        // unfiltered, like the traditional comparison point
        ccu_capture(ctx, ev, reg, near, port_free, &mut victim, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::OpClass;

    fn alu(srcs: &[u8], dsts: &[u8]) -> Instruction {
        Instruction::new(OpClass::Alu, srcs, dsts)
    }

    #[test]
    fn oracle_prefers_farthest_next_use() {
        let stream = vec![
            alu(&[2], &[10]), // r2 read at distance 0
            alu(&[1], &[11]), // r1 read at distance 1
            alu(&[3], &[12]), // r3 read at distance 2
        ];
        let mut ct = CacheTable::new(3);
        let mut r = Rng::new(1);
        let mut v = |ct: &CacheTable, _r: &mut Rng| belady_victim(ct, &stream, 0);
        ct.allocate(1, false, false, &mut r, &mut v);
        ct.allocate(2, false, false, &mut r, &mut v);
        ct.allocate(3, false, false, &mut r, &mut v);
        // full: the victim must be r3 (farthest next read)
        ct.allocate(4, false, false, &mut r, &mut v);
        assert!(ct.lookup(3).is_none(), "farthest next use must be evicted");
        assert!(ct.lookup(1).is_some() && ct.lookup(2).is_some());
    }

    #[test]
    fn oracle_treats_overwritten_values_as_dead() {
        let stream = vec![
            alu(&[9], &[1]),  // r1 overwritten before any read: dead in cache
            alu(&[1], &[13]), // (reads the NEW r1, not the cached value)
            alu(&[2], &[14]), // r2 read at distance 2
        ];
        let mut ct = CacheTable::new(2);
        let mut r = Rng::new(1);
        let mut v = |ct: &CacheTable, _r: &mut Rng| belady_victim(ct, &stream, 0);
        ct.allocate(1, false, false, &mut r, &mut v);
        ct.allocate(2, false, false, &mut r, &mut v);
        ct.allocate(5, false, false, &mut r, &mut v);
        assert!(ct.lookup(1).is_none(), "dead value is the ideal victim");
        assert!(ct.lookup(2).is_some());
    }

    #[test]
    fn oracle_scan_is_bounded() {
        // a reg used only past the window ranks as far-but-alive
        let mut stream = vec![alu(&[], &[]); ORACLE_WINDOW + 10];
        stream[ORACLE_WINDOW + 5] = alu(&[7], &[15]);
        assert_eq!(next_use_distance(7, &stream, 0), u64::MAX - 1);
        assert_eq!(next_use_distance(7, &stream, ORACLE_WINDOW), 5);
    }
}
