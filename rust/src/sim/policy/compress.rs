//! Static data-compression RF cache (Angerd et al., PAPERS.md): values the
//! compiler proves compressible are stored compressed, so the same SRAM
//! budget caches more of them — modelled here as a cache-*admission*
//! signal. The trace carries no values, so compressibility is approximated
//! statically from the register id: low ids hold kernel parameters, loop
//! counters, and address bases — the narrow-value population the paper
//! compresses best — while high ids hold wide accumulators and vector
//! temporaries. Ids below `compress_regs` are admitted; everything else is
//! fetched from the banks but never occupies a table entry
//! ([`Collector::alloc_ccu_admit`]'s predicate).
//!
//! Because only compressed (half-width) values are stored, the physical
//! table is half the CCU's size for the same entry count:
//! [`CachePolicy::cache_entries_per_collector`] reports `ct_entries / 2`.
//! Replacement is plain LRU — the admission filter, not the victim
//! chooser, is this scheme's contribution.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{plain_lru_victim, AllocResult};
use crate::sim::exec::WbEvent;

use super::{ccu_capture, free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// Compression-admission CCU under GTO.
pub struct CompressPolicy {
    ct_entries: usize,
    compress_regs: u8,
}

impl CompressPolicy {
    /// Capture table geometry and the compressibility cutoff from the
    /// resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        CompressPolicy {
            ct_entries: cfg.ct_entries,
            compress_regs: cfg.compress_regs,
        }
    }

    /// The static compressibility approximation: is `reg` admissible?
    fn compressible(&self, reg: u8) -> bool {
        reg < self.compress_regs
    }
}

impl CachePolicy for CompressPolicy {
    /// CCU semantics: the table survives dispatch.
    fn caching(&self) -> bool {
        true
    }

    /// Compressed entries are half-width, so the same entry count costs
    /// half the storage.
    fn cache_entries_per_collector(&self) -> f64 {
        self.ct_entries as f64 / 2.0
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        let cut = self.compress_regs;
        ctx.collectors.alloc_ccu_admit(
            ci,
            warp,
            instr,
            now,
            ctx.rng,
            &mut plain_lru_victim,
            &mut |_, reg| reg < cut,
        )
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        // admission replaces the near/far write filter: a compressible
        // result is worth caching regardless of its reuse class (it is
        // cheap to hold), an incompressible one never enters
        if self.compressible(reg) {
            ccu_capture(ctx, ev, reg, near, port_free, &mut plain_lru_victim, true)
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    #[test]
    fn compressed_table_is_half_storage() {
        let cfg = GpuConfig::table1_baseline();
        let p = CompressPolicy::from_config(&cfg);
        // Table I: 8-entry CCU stored compressed = 4 entry-equivalents
        assert!((p.cache_entries_per_collector() - 4.0).abs() < 1e-12);
        assert!(p.caching());
    }

    #[test]
    fn admission_follows_the_static_cutoff() {
        let mut cfg = GpuConfig::table1_baseline();
        cfg.compress_regs = 16;
        let p = CompressPolicy::from_config(&cfg);
        assert!(p.compressible(0));
        assert!(p.compressible(15));
        assert!(!p.compressible(16));
        assert!(!p.compressible(200));
    }
}
