//! Registry-only comparison policy: CCU hardware under GTO issue with
//! **FIFO replacement** and no write filter — the classic
//! oldest-insertion-first victim, blind to reuse distance. Exists to
//! bracket the paper's reuse-guided replacement from below (Fig 17 sweep).

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::{AllocResult, CacheTable};
use crate::sim::exec::WbEvent;
use crate::util::Rng;

use super::{
    ccu_allocate, ccu_capture, free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx,
};

/// FIFO victim: the oldest-inserted unlocked entry (insertion order is
/// tracked by [`crate::sim::collector::CtEntry::inserted`] and survives
/// tag-hit updates, so a refreshed entry keeps its queue position).
pub fn fifo_victim(ct: &CacheTable, _rng: &mut Rng) -> Option<usize> {
    ct.entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| !e.locked)
        .min_by_key(|(_, e)| e.inserted)
        .map(|(i, _)| i)
}

/// CCU hardware + GTO + FIFO replacement.
pub struct FifoPolicy {
    ct_entries: usize,
}

impl FifoPolicy {
    /// Capture the table size from the resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        FifoPolicy { ct_entries: cfg.ct_entries }
    }
}

impl CachePolicy for FifoPolicy {
    fn caching(&self) -> bool {
        true
    }

    fn cache_entries_per_collector(&self) -> f64 {
        self.ct_entries as f64
    }

    fn select_collector(&mut self, ctx: &mut PolicyCtx, _warp: u8) -> CollectorChoice {
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        ccu_allocate(ctx, ci, warp, instr, now, &mut fifo_victim)
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        ev: &WbEvent,
        reg: u8,
        near: bool,
        port_free: bool,
    ) -> bool {
        // unfiltered, like the traditional comparison point
        ccu_capture(ctx, ev, reg, near, port_free, &mut fifo_victim, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_evicts_oldest_insertion_not_lru() {
        let mut ct = CacheTable::new(2);
        let mut r = Rng::new(1);
        ct.allocate(1, false, false, &mut r, &mut fifo_victim); // inserted first
        ct.allocate(2, false, false, &mut r, &mut fifo_victim);
        // touching reg 1 makes it MRU, but FIFO still evicts it (oldest
        // insertion)
        ct.touch(ct.lookup(1).unwrap());
        ct.allocate(3, false, false, &mut r, &mut fifo_victim);
        assert!(ct.lookup(1).is_none(), "FIFO must evict the oldest insertion");
        assert!(ct.lookup(2).is_some() && ct.lookup(3).is_some());
    }

    #[test]
    fn fifo_tag_hit_keeps_queue_position() {
        let mut ct = CacheTable::new(2);
        let mut r = Rng::new(1);
        ct.allocate(1, false, false, &mut r, &mut fifo_victim);
        ct.allocate(2, false, false, &mut r, &mut fifo_victim);
        // re-installing reg 1 must not move it to the back of the queue
        ct.allocate(1, true, false, &mut r, &mut fifo_victim);
        ct.allocate(3, false, false, &mut r, &mut fifo_victim);
        assert!(ct.lookup(1).is_none(), "refreshed entry keeps FIFO position");
    }

    #[test]
    fn fifo_skips_locked_entries() {
        let mut ct = CacheTable::new(2);
        let mut r = Rng::new(1);
        ct.allocate(1, false, true, &mut r, &mut fifo_victim); // locked, oldest
        ct.allocate(2, false, false, &mut r, &mut fifo_victim);
        ct.allocate(3, false, false, &mut r, &mut fifo_victim);
        assert!(ct.lookup(1).is_some(), "locked entries are never victims");
        assert!(ct.lookup(2).is_none());
    }
}
