//! RegDem-style register demotion (Sakdhnagool et al., PAPERS.md): the
//! compiler demotes the coldest architectural registers to shared-memory
//! slots, shrinking the physical RF so more thread blocks fit. There is no
//! operand cache at all — the price is *spill traffic*: every access to a
//! demoted register is a shared-memory transaction instead of an RF bank
//! read/write.
//!
//! Mapping onto this simulator: registers at or above `regdem_cutoff` are
//! the demoted set (the allocator assigns hot ids first, so high ids are
//! the cold tail). Demoted source operands never touch the RF banks — they
//! are delivered through [`crate::sim::memory::SpillModel`], which charges
//! bank-read + crossbar energy per transaction, and demoted destinations
//! likewise spill on writeback. Shared memory is slower than the RF, so an
//! instruction with demoted sources pays `regdem_penalty` scheduler passes
//! per demoted operand before it may claim a collector
//! ([`CachePolicy::select_collector`] returns `SkipWarp` while the spill
//! loads are in flight).
//!
//! Reports zero cache entries: the energy model sees no CCU storage, and
//! the Fig 15-style cost table for this scheme is all zeros — the spill
//! traffic shows up in the `BankRead`/`BankWrite`/`XbarTransfer` rows
//! instead.

use crate::config::GpuConfig;
use crate::isa::Instruction;
use crate::sim::collector::AllocResult;
use crate::sim::exec::WbEvent;
use crate::sim::memory::SpillModel;

use super::{free_unit_reservoir, CachePolicy, CollectorChoice, PolicyCtx};

/// Shared-memory register demotion under GTO; no operand cache.
pub struct RegdemPolicy {
    cutoff: u8,
    penalty: u32,
    spill: SpillModel,
    /// Per-warp countdown of scheduler passes spent waiting on in-flight
    /// spill loads (sized lazily at the first selection).
    spill_wait: Vec<u32>,
}

impl RegdemPolicy {
    /// Capture the demotion cutoff and shared-memory penalty from the
    /// resolved config.
    pub fn from_config(cfg: &GpuConfig) -> Self {
        RegdemPolicy {
            cutoff: cfg.regdem_cutoff,
            penalty: cfg.regdem_penalty,
            spill: SpillModel::new(),
            spill_wait: Vec::new(),
        }
    }

    /// Is `reg` in the demoted (shared-memory-backed) set?
    fn demoted(&self, reg: u8) -> bool {
        reg >= self.cutoff
    }

    /// How many of `instr`'s sources live in shared memory?
    fn demoted_sources(&self, instr: &Instruction) -> u32 {
        instr.sources().iter().filter(|&&r| self.demoted(r)).count() as u32
    }

    /// Total spill transactions issued so far (test hook).
    #[cfg(test)]
    fn spill_accesses(&self) -> u64 {
        self.spill.accesses()
    }
}

impl CachePolicy for RegdemPolicy {
    fn select_collector(&mut self, ctx: &mut PolicyCtx, warp: u8) -> CollectorChoice {
        if self.spill_wait.len() < ctx.warps.len() {
            self.spill_wait.resize(ctx.warps.len(), 0);
        }
        let wi = warp as usize;
        // shared memory is slower than the RF: an instruction with demoted
        // sources waits `penalty` passes per spilled operand before it may
        // claim a collector (Exit/Ctrl bypass the policy, so `pc` always
        // points at an operand-collecting instruction here)
        let instr = &ctx.streams[wi][ctx.warps[wi].pc];
        let need = self.demoted_sources(instr).saturating_mul(self.penalty);
        if need > 0 && self.spill_wait[wi] < need {
            self.spill_wait[wi] += 1;
            return CollectorChoice::SkipWarp;
        }
        self.spill_wait[wi] = 0;
        match free_unit_reservoir(ctx.collectors, ctx.rng) {
            Some(ci) => CollectorChoice::Unit(ci),
            None => {
                ctx.stats.collector_full_stalls += 1;
                CollectorChoice::StallCycle { waiting: false }
            }
        }
    }

    fn allocate(
        &mut self,
        ctx: &mut PolicyCtx,
        ci: usize,
        warp: u8,
        instr: &Instruction,
        now: u64,
    ) -> AllocResult {
        let mut res = ctx.collectors.alloc_ocu(ci, warp, instr, now);
        // demoted sources never reach the RF banks: deliver them through
        // the spill path (the penalty was already paid in selection) and
        // charge the shared-memory traffic to the energy model
        let col = &mut *ctx.collectors;
        let spill = &mut self.spill;
        let cutoff = self.cutoff;
        let energy = &mut ctx.stats.energy;
        let mut spilled = 0u32;
        res.misses.retain(|slot, reg| {
            if reg >= cutoff {
                spill.spill_read(energy);
                col.deliver(ci, slot);
                spilled += 1;
                false
            } else {
                true
            }
        });
        res.hits += spilled;
        res
    }

    fn capture_writeback(
        &mut self,
        ctx: &mut PolicyCtx,
        _ev: &WbEvent,
        reg: u8,
        _near: bool,
        _port_free: bool,
    ) -> bool {
        // demoted destinations spill to shared memory; claiming the event
        // keeps the result out of the (shrunk) RF write path, and with
        // zero cache entries the CcuWrite the sub-core charges costs 0 pJ
        if self.demoted(reg) {
            self.spill.spill_write(&mut ctx.stats.energy);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;
    use crate::isa::OpClass;

    #[test]
    fn no_cache_storage_is_reported() {
        let cfg = GpuConfig::table1_baseline();
        let p = RegdemPolicy::from_config(&cfg);
        assert!(!p.caching());
        assert_eq!(p.cache_entries_per_collector(), 0.0);
        assert_eq!(p.spill_accesses(), 0);
    }

    #[test]
    fn demotion_set_is_the_cold_tail() {
        let mut cfg = GpuConfig::table1_baseline();
        cfg.regdem_cutoff = 40;
        let p = RegdemPolicy::from_config(&cfg);
        assert!(!p.demoted(0));
        assert!(!p.demoted(39));
        assert!(p.demoted(40));
        assert!(p.demoted(255));
        let instr = Instruction::new(OpClass::Alu, &[10, 40, 50], &[2]);
        assert_eq!(p.demoted_sources(&instr), 2);
    }

    #[test]
    fn penalty_scales_with_demoted_operand_count() {
        let mut cfg = GpuConfig::table1_baseline();
        cfg.regdem_cutoff = 32;
        cfg.regdem_penalty = 3;
        let p = RegdemPolicy::from_config(&cfg);
        let hot = Instruction::new(OpClass::Alu, &[1, 2], &[3]);
        let cold = Instruction::new(OpClass::Alu, &[40, 50], &[3]);
        assert_eq!(p.demoted_sources(&hot) * cfg.regdem_penalty, 0);
        assert_eq!(p.demoted_sources(&cold) * cfg.regdem_penalty, 6);
    }
}
