//! Execution pipes + writeback event queue.
//!
//! Each sub-core has one pipe per EU class (ALU/SFU/MMA/LSU) with an
//! initiation interval and a result latency; completed instructions are
//! delivered as writeback events in cycle order.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::GpuConfig;
use crate::isa::{Instruction, OpClass, MAX_DST};

/// Execution pipe classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pipe {
    /// Integer/FP ALU.
    Alu = 0,
    /// Special function unit.
    Sfu,
    /// Tensor core.
    Mma,
    /// Load/store unit.
    Lsu,
}

/// Number of pipes.
pub const NPIPES: usize = 4;

/// Map an opcode to its pipe.
pub fn pipe_of(op: OpClass) -> Option<Pipe> {
    match op {
        OpClass::Alu => Some(Pipe::Alu),
        OpClass::Sfu => Some(Pipe::Sfu),
        OpClass::Mma => Some(Pipe::Mma),
        OpClass::LdGlobal | OpClass::StGlobal | OpClass::LdShared => Some(Pipe::Lsu),
        OpClass::Ctrl | OpClass::Exit => None,
    }
}

/// A completed instruction ready to write back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WbEvent {
    /// Completion cycle.
    pub cycle: u64,
    /// Local warp index within the sub-core.
    pub warp: u8,
    /// Destination registers.
    pub dsts: [u8; MAX_DST],
    /// Valid destinations.
    pub ndst: u8,
    /// Near bit per destination (compiler annotation).
    pub dst_near: u8,
    /// Collector the instruction was collected in (CCU writeback target).
    pub collector: u8,
    /// BOW window sequence number of the producing instruction.
    pub boc_seq: u64,
}

impl PartialOrd for WbEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for WbEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.cycle
            .cmp(&other.cycle)
            .then(self.warp.cmp(&other.warp))
            .then(self.collector.cmp(&other.collector))
            .then(self.boc_seq.cmp(&other.boc_seq))
            .then(self.ndst.cmp(&other.ndst))
            .then(self.dst_near.cmp(&other.dst_near))
            .then(self.dsts.cmp(&other.dsts))
    }
}

/// One instruction picked for dispatch this cycle (at most one per pipe).
/// The sub-core batches the cycle's picks and hands them to
/// [`ExecUnits::dispatch_batch`] in a single call.
#[derive(Debug, Clone, Copy)]
pub struct DispatchReq {
    /// The instruction leaving its collector.
    pub instr: Instruction,
    /// Local warp index within the sub-core.
    pub warp: u8,
    /// Collector the instruction was collected in.
    pub collector: u8,
    /// BOW window sequence number of the instruction.
    pub boc_seq: u64,
    /// Memory-system completion cycle for LSU ops (ignored otherwise).
    pub mem_done: u64,
}

/// The sub-core's execution back-end.
#[derive(Debug)]
pub struct ExecUnits {
    /// Next cycle each pipe can accept an instruction.
    next_accept: [u64; NPIPES],
    /// Pending writebacks, ordered by completion cycle.
    events: BinaryHeap<Reverse<WbEvent>>,
    /// Fixed latencies per pipe (LSU latency comes from the memory system).
    timing: [(u32, u32); NPIPES], // (initiation, latency)
    lds_latency: u32,
}

impl ExecUnits {
    /// Build from config.
    pub fn new(cfg: &GpuConfig) -> Self {
        ExecUnits {
            next_accept: [0; NPIPES],
            events: BinaryHeap::new(),
            timing: [
                (cfg.alu.initiation, cfg.alu.latency),
                (cfg.sfu.initiation, cfg.sfu.latency),
                (cfg.mma.initiation, cfg.mma.latency),
                (1, 0), // LSU: latency supplied per-access
            ],
            lds_latency: cfg.lds_latency,
        }
    }

    // simlint: hot
    /// Can `pipe` accept an instruction at `now`?
    #[inline]
    pub fn can_accept(&self, pipe: Pipe, now: u64) -> bool {
        self.next_accept[pipe as usize] <= now
    }

    // simlint: hot
    /// Dispatch `instr` at `now`. `mem_done` is the memory-system
    /// completion cycle for LSU ops (ignored otherwise). `collector` and
    /// `boc_seq` identify the producing collector for cache writeback.
    /// Returns the writeback cycle (== now for stores with no dests).
    #[allow(clippy::too_many_arguments)]
    pub fn dispatch(
        &mut self,
        instr: &Instruction,
        warp: u8,
        collector: u8,
        boc_seq: u64,
        now: u64,
        mem_done: u64,
    ) -> u64 {
        let pipe = pipe_of(instr.op).expect("ctrl/exit never dispatch");
        let (init, lat) = self.timing[pipe as usize];
        debug_assert!(self.can_accept(pipe, now));
        self.next_accept[pipe as usize] = now + init as u64;
        let done = match instr.op {
            OpClass::LdGlobal => mem_done,
            OpClass::LdShared => now + self.lds_latency as u64,
            OpClass::StGlobal => now + 1, // no register result
            _ => now + lat as u64,
        };
        if instr.ndst > 0 {
            self.events.push(Reverse(WbEvent {
                cycle: done,
                warp,
                dsts: instr.dsts,
                ndst: instr.ndst,
                dst_near: instr.dst_near,
                collector,
                boc_seq,
            }));
        }
        done
    }

    // simlint: hot
    /// Dispatch one cycle's picks in a single call. The requests target
    /// distinct pipes (at most one pick per pipe per cycle), so the
    /// per-request effects commute: each dispatch advances only its own
    /// pipe's accept cursor, and the event heap's total order makes the
    /// drain sequence a function of the event *set*, not insertion order —
    /// batching is bit-identical to the per-pipe calls it replaces.
    pub fn dispatch_batch(&mut self, reqs: &[DispatchReq], now: u64) {
        for r in reqs {
            self.dispatch(&r.instr, r.warp, r.collector, r.boc_seq, now, r.mem_done);
        }
    }

    // simlint: hot
    /// Pop all writebacks due at or before `now`.
    pub fn drain_due(&mut self, now: u64, out: &mut Vec<WbEvent>) {
        while let Some(Reverse(ev)) = self.events.peek() {
            if ev.cycle <= now {
                out.push(self.events.pop().unwrap().0);
            } else {
                break;
            }
        }
    }

    // simlint: hot
    /// Any instructions still in flight?
    pub fn busy(&self) -> bool {
        !self.events.is_empty()
    }

    // simlint: hot
    /// Cycle of the next completion (for idle fast-forward).
    pub fn next_event_cycle(&self) -> Option<u64> {
        self.events.peek().map(|Reverse(e)| e.cycle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GpuConfig {
        GpuConfig::table1_baseline()
    }

    #[test]
    fn pipes_map_correctly() {
        assert_eq!(pipe_of(OpClass::Alu), Some(Pipe::Alu));
        assert_eq!(pipe_of(OpClass::Mma), Some(Pipe::Mma));
        assert_eq!(pipe_of(OpClass::LdGlobal), Some(Pipe::Lsu));
        assert_eq!(pipe_of(OpClass::StGlobal), Some(Pipe::Lsu));
        assert_eq!(pipe_of(OpClass::Ctrl), None);
    }

    #[test]
    fn initiation_interval_enforced() {
        let mut eu = ExecUnits::new(&cfg());
        let i = Instruction::new(OpClass::Mma, &[1], &[2]);
        assert!(eu.can_accept(Pipe::Mma, 0));
        eu.dispatch(&i, 0, 0, 0, 0, 0);
        assert!(!eu.can_accept(Pipe::Mma, 1), "mma initiation is 2");
        assert!(eu.can_accept(Pipe::Mma, 2));
        assert!(eu.can_accept(Pipe::Alu, 1), "other pipes unaffected");
    }

    #[test]
    fn writeback_at_latency() {
        let mut eu = ExecUnits::new(&cfg());
        let i = Instruction::new(OpClass::Alu, &[1], &[2]);
        let done = eu.dispatch(&i, 3, 1, 0, 10, 0);
        assert_eq!(done, 14); // alu latency 4
        let mut out = Vec::new();
        eu.drain_due(13, &mut out);
        assert!(out.is_empty());
        eu.drain_due(14, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].warp, 3);
        assert_eq!(out[0].dsts[0], 2);
        assert!(!eu.busy());
    }

    #[test]
    fn events_drain_in_cycle_order() {
        let mut eu = ExecUnits::new(&cfg());
        let slow = Instruction::new(OpClass::Sfu, &[1], &[2]); // lat 16
        let fast = Instruction::new(OpClass::Alu, &[1], &[3]); // lat 4
        eu.dispatch(&slow, 0, 0, 0, 0, 0);
        eu.dispatch(&fast, 0, 1, 0, 0, 0);
        let mut out = Vec::new();
        eu.drain_due(100, &mut out);
        assert_eq!(out.len(), 2);
        assert!(out[0].cycle <= out[1].cycle);
        assert_eq!(out[0].dsts[0], 3, "alu completes first");
    }

    #[test]
    fn stores_produce_no_writeback() {
        let mut eu = ExecUnits::new(&cfg());
        let st = Instruction::mem(OpClass::StGlobal, &[1, 2], &[], 7);
        eu.dispatch(&st, 0, 0, 0, 5, 0);
        assert!(!eu.busy());
    }

    #[test]
    fn loads_use_memory_completion() {
        let mut eu = ExecUnits::new(&cfg());
        let ld = Instruction::mem(OpClass::LdGlobal, &[1], &[2], 7);
        let done = eu.dispatch(&ld, 0, 0, 0, 5, 345);
        assert_eq!(done, 345);
    }
}
