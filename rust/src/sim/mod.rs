//! Cycle-level sub-core GPU simulator (the Accel-sim stand-in, DESIGN.md §6).
//!
//! # Structure
//!
//! One [`Simulator`] owns `num_sms` SMs; each SM owns `sub_cores_per_sm`
//! [`subcore::SubCore`]s (issue scheduler, collector/CCU array, RF banks,
//! EU pipes) plus a private [`memory::L1Cache`]; the
//! [`memory::SharedMemorySystem`] (L2 + DRAM) and the dynamic
//! [`SthldController`] are the only GPU-global state. Per-cycle sub-core
//! phase order is writeback → dispatch → operand collection → issue.
//!
//! # Run loop and determinism
//!
//! [`Simulator::run`] is an **epoch scheduler**, not a lock-step loop:
//! each SM advances independently up to the earlier of the STHLD interval
//! boundary and its first L2-bound event (L2 requests queue on a per-SM
//! [`memory::MemPort`]), then a serial phase services the merged queues
//! in fixed `(cycle, sm_id, seq)` order. With
//! `GpuConfig::sim_threads > 1` the per-SM phases run on a worker pool —
//! results are **bit-identical at any thread count** (the determinism
//! contract of the crate root; see `docs/ARCHITECTURE.md` for the
//! epoch/sync-boundary walk-through). Simulations are pure functions of
//! `(GpuConfig, trace)`: no wall clock, no thread identity, and every
//! policy tie-break draws from the seeded per-sub-core RNG.

pub mod collector;
pub mod exec;
pub mod gpu;
pub mod memory;
pub mod policy;
pub mod regfile;
pub mod sthld;
pub mod subcore;
pub mod warp;

pub use gpu::{run_benchmark, run_trace, run_workload, Simulator};
pub use policy::{CachePolicy, Scheme};
pub use sthld::{SthldController, SthldState};
