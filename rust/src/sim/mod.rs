//! Cycle-level sub-core GPU simulator (the Accel-sim stand-in, DESIGN.md §6).

pub mod collector;
pub mod exec;
pub mod gpu;
pub mod memory;
pub mod regfile;
pub mod sthld;
pub mod subcore;
pub mod warp;

pub use gpu::{run_benchmark, run_trace, run_workload, Simulator};
pub use sthld::{SthldController, SthldState};
