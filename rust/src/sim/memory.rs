//! Memory hierarchy: per-SM L1D (set-associative, MSHR-merged), shared L2,
//! fixed-latency bandwidth-bounded DRAM.
//!
//! Latency is resolved at access time ("latency-on-dispatch"): the lookup
//! updates cache state immediately and returns the completion delay; MSHRs
//! merge outstanding misses to the same line. This keeps the model simple
//! while preserving what the paper's results depend on: relative L1 hit
//! ratios (Fig 14) and a memory pipeline that can become the IPC
//! bottleneck (lud, particlefilter discussions in §VI-B).

use std::collections::HashMap;

/// Set-associative tag store with LRU replacement.
#[derive(Debug, Clone)]
pub struct TagStore {
    /// tags[set * ways + way]
    tags: Vec<u64>,
    valid: Vec<bool>,
    lru: Vec<u64>,
    sets: usize,
    ways: usize,
    tick: u64,
}

impl TagStore {
    /// Build from byte capacity / line size / associativity.
    pub fn new(bytes: usize, line_bytes: usize, ways: usize) -> Self {
        let lines = bytes / line_bytes;
        let sets = (lines / ways).max(1);
        TagStore {
            tags: vec![0; sets * ways],
            valid: vec![false; sets * ways],
            lru: vec![0; sets * ways],
            sets,
            ways,
            tick: 0,
        }
    }

    /// Lookup `line`; on hit refresh LRU and return true; on miss install
    /// it (LRU victim) and return false.
    pub fn access(&mut self, line: u64) -> bool {
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        self.tick += 1;
        for w in 0..self.ways {
            if self.valid[base + w] && self.tags[base + w] == line {
                self.lru[base + w] = self.tick;
                return true;
            }
        }
        // miss: fill LRU way
        let mut victim = 0;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            if !self.valid[base + w] {
                victim = w;
                break;
            }
            if self.lru[base + w] < best {
                best = self.lru[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.valid[base + victim] = true;
        self.lru[base + victim] = self.tick;
        false
    }

    /// Probe without modifying state.
    pub fn probe(&self, line: u64) -> bool {
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        (0..self.ways).any(|w| self.valid[base + w] && self.tags[base + w] == line)
    }
}

/// L2 + DRAM shared across SMs.
#[derive(Debug)]
pub struct SharedMemorySystem {
    l2: TagStore,
    l2_latency: u32,
    dram_latency: u32,
    /// Next cycle DRAM can accept a request (bandwidth token).
    dram_next_slot: f64,
    /// Cycles added per DRAM request (1 / requests-per-cycle).
    dram_interval: f64,
    /// L2 lookup counter.
    pub accesses: u64,
    /// L2 hit counter.
    pub hits: u64,
}

impl SharedMemorySystem {
    /// Build from config fields.
    pub fn new(
        l2_bytes: usize,
        line_bytes: usize,
        l2_ways: usize,
        l2_latency: u32,
        dram_latency: u32,
        dram_reqs_per_cycle: f64,
    ) -> Self {
        SharedMemorySystem {
            l2: TagStore::new(l2_bytes, line_bytes, l2_ways),
            l2_latency,
            dram_latency,
            dram_next_slot: 0.0,
            dram_interval: 1.0 / dram_reqs_per_cycle.max(1e-6),
            accesses: 0,
            hits: 0,
        }
    }

    /// An L1 miss arrives at cycle `now`; returns the extra delay beyond L1.
    pub fn miss_from_l1(&mut self, line: u64, now: u64) -> u32 {
        self.accesses += 1;
        if self.l2.access(line) {
            self.hits += 1;
            self.l2_latency
        } else {
            // DRAM bandwidth token bucket
            let slot = self.dram_next_slot.max(now as f64);
            self.dram_next_slot = slot + self.dram_interval;
            let queue_delay = (slot - now as f64) as u32;
            self.l2_latency + self.dram_latency + queue_delay
        }
    }
}

/// Per-SM L1 data cache with MSHR merging.
#[derive(Debug)]
pub struct L1Cache {
    tags: TagStore,
    latency: u32,
    mshrs: usize,
    /// line -> completion cycle of the outstanding fill.
    outstanding: HashMap<u64, u64>,
    /// L1 lookups.
    pub accesses: u64,
    /// L1 hits.
    pub hits: u64,
}

impl L1Cache {
    /// Build from config fields.
    pub fn new(bytes: usize, line_bytes: usize, ways: usize, latency: u32, mshrs: usize) -> Self {
        L1Cache {
            tags: TagStore::new(bytes, line_bytes, ways),
            latency,
            mshrs,
            outstanding: HashMap::new(),
            accesses: 0,
            hits: 0,
        }
    }

    /// Load from `line` at cycle `now`; returns the completion cycle.
    pub fn load(&mut self, line: u64, now: u64, shared: &mut SharedMemorySystem) -> u64 {
        self.accesses += 1;
        // retire completed fills lazily
        self.outstanding.retain(|_, &mut c| c > now);
        if let Some(&c) = self.outstanding.get(&line) {
            // MSHR merge: ride the outstanding fill
            self.hits += 1; // sector already inbound: counts as L1-level hit
            return c.max(now + self.latency as u64);
        }
        if self.tags.access(line) {
            self.hits += 1;
            now + self.latency as u64
        } else {
            let extra = shared.miss_from_l1(line, now);
            let mut done = now + (self.latency + extra) as u64;
            if self.outstanding.len() >= self.mshrs {
                // MSHRs full: structural back-pressure
                let max_out = self.outstanding.values().copied().max().unwrap_or(now);
                done = done.max(max_out + 1);
            }
            self.outstanding.insert(line, done);
            done
        }
    }

    /// Store to `line`: write-through, no allocate (Turing L1 behaviour for
    /// global stores); cheap fixed cost, returns completion cycle.
    pub fn store(&mut self, _line: u64, now: u64) -> u64 {
        now + self.latency as u64
    }

    /// L1 hit ratio so far.
    pub fn hit_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared() -> SharedMemorySystem {
        SharedMemorySystem::new(1 << 20, 128, 8, 90, 220, 0.5)
    }

    #[test]
    fn tagstore_hit_after_fill() {
        let mut t = TagStore::new(1024, 128, 4);
        assert!(!t.access(42));
        assert!(t.access(42));
        assert!(t.probe(42));
        assert!(!t.probe(43));
    }

    #[test]
    fn tagstore_lru_eviction() {
        // 2 sets x 2 ways; lines 0,2,4 map to set 0
        let mut t = TagStore::new(4 * 128, 128, 2);
        t.access(0);
        t.access(2);
        t.access(0); // refresh 0
        t.access(4); // evicts 2 (LRU)
        assert!(t.probe(0));
        assert!(!t.probe(2));
        assert!(t.probe(4));
    }

    #[test]
    fn l1_hit_is_fast_miss_is_slow() {
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 32);
        let t_miss = l1.load(7, 0, &mut s);
        assert!(t_miss >= 28 + 90, "miss must include L2/DRAM");
        let t_hit = l1.load(7, t_miss, &mut s);
        assert_eq!(t_hit, t_miss + 28);
        assert_eq!(l1.accesses, 2);
        assert_eq!(l1.hits, 1);
    }

    #[test]
    fn mshr_merges_same_line() {
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 32);
        let t1 = l1.load(9, 0, &mut s);
        let t2 = l1.load(9, 1, &mut s); // merged, no second L2 access
        assert!(t2 <= t1.max(1 + 28));
        assert_eq!(s.accesses, 1, "merged miss must not re-access L2");
    }

    #[test]
    fn l2_hit_cheaper_than_dram() {
        let mut s = shared();
        let d1 = s.miss_from_l1(5, 0); // L2 miss -> DRAM
        let d2 = s.miss_from_l1(5, 1000); // now L2 hit
        assert!(d1 >= 90 + 220);
        assert_eq!(d2, 90);
    }

    #[test]
    fn dram_bandwidth_queues() {
        let mut s = shared(); // 0.5 req/cycle -> 2 cycles apart
        let mut delays = Vec::new();
        for i in 0..8 {
            delays.push(s.miss_from_l1(1000 + i, 0));
        }
        // each subsequent request waits ~2 more cycles
        assert!(delays[7] > delays[0] + 10);
    }

    #[test]
    fn mshr_full_back_pressure() {
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 2);
        let a = l1.load(1, 0, &mut s);
        let b = l1.load(2, 0, &mut s);
        let c = l1.load(3, 0, &mut s); // MSHRs full
        assert!(c > a.min(b), "third miss must be delayed past an MSHR");
    }
}
