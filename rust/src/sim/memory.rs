//! Memory hierarchy: per-SM L1D (set-associative, MSHR-merged), shared L2,
//! fixed-latency bandwidth-bounded DRAM.
//!
//! Latency is resolved at access time ("latency-on-dispatch"): the lookup
//! updates cache state immediately and returns the completion delay; MSHRs
//! merge outstanding misses to the same line. This keeps the model simple
//! while preserving what the paper's results depend on: relative L1 hit
//! ratios (Fig 14) and a memory pipeline that can become the IPC
//! bottleneck (lud, particlefilter discussions in §VI-B).
//!
//! # Queued L2 interface (epoch engine)
//!
//! The shared L2/DRAM system is the only state multiple SMs touch, so it
//! is accessed through an explicit request/response message interface
//! rather than direct calls: an L1 miss that needs the L2 *defers* the
//! access ([`L1Cache::load_or_defer`] returns [`L1Fetch::Deferred`] and
//! queues an [`L2Request`] on the SM's [`MemPort`]), the SM stops at that
//! cycle (its synchronization boundary), and a **serial service phase**
//! ([`SharedMemorySystem::service`]) later drains the merged queues of all
//! SMs in the fixed order `(cycle, sm_id, seq)`. Within one service round
//! that is cycle-interleaved order; across rounds a fast SM's later miss
//! can be serviced after a slow SM's earlier one — a deterministic
//! reordering bounded by one epoch, identical at every thread count. The
//! responses are posted back into each L1 with [`L1Cache::resolve_fill`],
//! after which the deferred dispatch retries (one cycle later — the miss
//! replay latency). Because the service order is a pure function of the
//! request set, simulation results are bit-identical at any
//! `sim_threads` worker count (see `docs/ARCHITECTURE.md`).

use std::collections::{BTreeMap, BTreeSet};

use crate::energy::{EnergyCounts, EventKind};

/// Placeholder completion cycle for a fill whose L2 latency has not been
/// served yet (same-epoch loads to the line merge onto it and defer).
const PENDING_FILL: u64 = u64::MAX;

/// Set-associative tag store with LRU replacement.
#[derive(Debug, Clone)]
pub struct TagStore {
    /// tags[set * ways + way]
    tags: Vec<u64>,
    valid: Vec<bool>,
    lru: Vec<u64>,
    sets: usize,
    ways: usize,
    tick: u64,
}

impl TagStore {
    /// Build from byte capacity / line size / associativity.
    pub fn new(bytes: usize, line_bytes: usize, ways: usize) -> Self {
        let lines = bytes / line_bytes;
        let sets = (lines / ways).max(1);
        TagStore {
            tags: vec![0; sets * ways],
            valid: vec![false; sets * ways],
            lru: vec![0; sets * ways],
            sets,
            ways,
            tick: 0,
        }
    }

    /// Lookup `line`; on hit refresh LRU and return true; on miss install
    /// it (LRU victim) and return false.
    pub fn access(&mut self, line: u64) -> bool {
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        self.tick += 1;
        for w in 0..self.ways {
            if self.valid[base + w] && self.tags[base + w] == line {
                self.lru[base + w] = self.tick;
                return true;
            }
        }
        // miss: fill LRU way
        let mut victim = 0;
        let mut best = u64::MAX;
        for w in 0..self.ways {
            if !self.valid[base + w] {
                victim = w;
                break;
            }
            if self.lru[base + w] < best {
                best = self.lru[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = line;
        self.valid[base + victim] = true;
        self.lru[base + victim] = self.tick;
        false
    }

    /// Probe without modifying state.
    pub fn probe(&self, line: u64) -> bool {
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        (0..self.ways).any(|w| self.valid[base + w] && self.tags[base + w] == line)
    }
}

/// One L2-bound request, queued by an SM during its parallel phase and
/// serviced by the serial L2 phase in `(cycle, sm_id, seq)` order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct L2Request {
    /// Issuing SM.
    pub sm_id: u32,
    /// Cycle the L1 miss occurred.
    pub cycle: u64,
    /// Per-SM monotone sequence number (intra-cycle sub-core order).
    pub seq: u64,
    /// Cache line address.
    pub line: u64,
}

/// The serial L2 phase's answer to one [`L2Request`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct L2Response {
    /// SM the response is routed back to.
    pub sm_id: u32,
    /// Cache line address.
    pub line: u64,
    /// Cycle the original miss occurred (the fill's reference point).
    pub cycle: u64,
    /// Delay beyond the L1 latency (L2 hit, or L2+DRAM+queueing).
    pub extra: u32,
}

/// Per-SM staging queue for L2-bound requests: the SM-side half of the
/// epoch message interface. Queued requests mark the SM's synchronization
/// boundary; the GPU-level scheduler drains them into the serial L2 phase.
#[derive(Debug)]
pub struct MemPort {
    sm_id: u32,
    seq: u64,
    queued: Vec<L2Request>,
}

impl MemPort {
    /// New empty port for SM `sm_id`.
    pub fn new(sm_id: u32) -> Self {
        MemPort { sm_id, seq: 0, queued: Vec::new() }
    }

    /// Queue one L2-bound line fetch observed at `cycle`.
    pub fn push(&mut self, line: u64, cycle: u64) {
        self.queued.push(L2Request {
            sm_id: self.sm_id,
            cycle,
            seq: self.seq,
            line,
        });
        self.seq += 1;
    }

    /// Any requests awaiting the serial service phase?
    pub fn is_empty(&self) -> bool {
        self.queued.is_empty()
    }

    /// Move all queued requests into `out` (the merged service queue).
    pub fn drain_into(&mut self, out: &mut Vec<L2Request>) {
        out.append(&mut self.queued);
    }
}

/// L2 + DRAM shared across SMs.
#[derive(Debug)]
pub struct SharedMemorySystem {
    l2: TagStore,
    l2_latency: u32,
    dram_latency: u32,
    /// Next cycle DRAM can accept a request (bandwidth token).
    dram_next_slot: f64,
    /// Cycles added per DRAM request (1 / requests-per-cycle).
    dram_interval: f64,
    /// L2 lookup counter.
    pub accesses: u64,
    /// L2 hit counter.
    pub hits: u64,
}

impl SharedMemorySystem {
    /// Build from config fields.
    pub fn new(
        l2_bytes: usize,
        line_bytes: usize,
        l2_ways: usize,
        l2_latency: u32,
        dram_latency: u32,
        dram_reqs_per_cycle: f64,
    ) -> Self {
        SharedMemorySystem {
            l2: TagStore::new(l2_bytes, line_bytes, l2_ways),
            l2_latency,
            dram_latency,
            dram_next_slot: 0.0,
            dram_interval: 1.0 / dram_reqs_per_cycle.max(1e-6),
            accesses: 0,
            hits: 0,
        }
    }

    /// An L1 miss arrives at cycle `now`; returns the extra delay beyond L1.
    pub fn miss_from_l1(&mut self, line: u64, now: u64) -> u32 {
        self.accesses += 1;
        if self.l2.access(line) {
            self.hits += 1;
            self.l2_latency
        } else {
            // DRAM bandwidth token bucket
            let slot = self.dram_next_slot.max(now as f64);
            self.dram_next_slot = slot + self.dram_interval;
            let queue_delay = (slot - now as f64) as u32;
            self.l2_latency + self.dram_latency + queue_delay
        }
    }

    /// Serial L2 phase: service one epoch's merged request queue.
    ///
    /// The queue is first sorted into the canonical `(cycle, sm_id, seq)`
    /// order, so the L2 tag state, the DRAM token bucket, and the counters
    /// evolve identically **no matter in which order the parallel workers
    /// appended their SMs' requests** — the property the epoch engine's
    /// thread-count invariance rests on (unit-tested below, enforced
    /// end-to-end by `rust/tests/parallel_determinism.rs`).
    pub fn service(&mut self, reqs: &mut [L2Request]) -> Vec<L2Response> {
        let mut out = Vec::with_capacity(reqs.len());
        self.service_into(reqs, &mut out);
        out
    }

    /// Allocation-free variant of [`SharedMemorySystem::service`]:
    /// responses are appended to the caller-owned `out` (the epoch loop
    /// reuses one buffer across the whole run, so the serial L2 phase
    /// stops allocating once both buffers have warmed up).
    pub fn service_into(&mut self, reqs: &mut [L2Request], out: &mut Vec<L2Response>) {
        reqs.sort_unstable_by_key(|r| (r.cycle, r.sm_id, r.seq));
        out.extend(reqs.iter().map(|r| L2Response {
            sm_id: r.sm_id,
            line: r.line,
            cycle: r.cycle,
            extra: self.miss_from_l1(r.line, r.cycle),
        }));
    }
}

/// RegDem-style shared-memory spill accounting (Sakdhnagool et al.,
/// PAPERS.md): registers demoted out of the RF live in a reserved
/// shared-memory slab, and every access to one is extra on-chip traffic.
///
/// The spill slab is per-sub-core private state (no cross-SM ordering to
/// preserve), so unlike the L1/L2 path it needs no queued interface — the
/// model is pure counting: the policy calls [`SpillModel::spill_read`] /
/// [`SpillModel::spill_write`] as it reroutes demoted operands, and each
/// access is charged at RF-bank cost plus one interconnect traversal (a
/// shared-memory bank is the same SRAM-array class as an RF bank, and the
/// operand still crosses the operand network — conservative, matching the
/// paper's observation that spilling trades RF capacity for traffic, not
/// for free energy).
#[derive(Debug, Clone, Copy, Default)]
pub struct SpillModel {
    /// Demoted source operands served from the spill slab.
    pub reads: u64,
    /// Demoted destination writebacks routed to the spill slab.
    pub writes: u64,
}

impl SpillModel {
    /// Fresh model with zero traffic.
    pub fn new() -> Self {
        Self::default()
    }

    /// One demoted source operand read from shared memory.
    pub fn spill_read(&mut self, energy: &mut EnergyCounts) {
        self.reads += 1;
        energy.add(EventKind::BankRead, 1);
        energy.add(EventKind::XbarTransfer, 1);
    }

    /// One demoted destination written to shared memory.
    pub fn spill_write(&mut self, energy: &mut EnergyCounts) {
        self.writes += 1;
        energy.add(EventKind::BankWrite, 1);
        energy.add(EventKind::XbarTransfer, 1);
    }

    /// Total spill accesses (reads + writes).
    pub fn accesses(&self) -> u64 {
        self.reads + self.writes
    }
}

/// Outcome of one L1 lookup under the queued interface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum L1Fetch {
    /// Served locally (tag hit or MSHR merge): completion cycle.
    Hit(u64),
    /// A previously deferred miss completing (fill latency now known):
    /// completion cycle. Counts as the miss's single L1 access.
    Miss(u64),
    /// L2-bound: the request was queued on the [`MemPort`] (or merged onto
    /// a fill still awaiting service). The caller must not dispatch; the
    /// SM stops at this cycle and retries after [`L1Cache::resolve_fill`].
    Deferred,
}

/// Per-SM L1 data cache with MSHR merging.
#[derive(Debug)]
pub struct L1Cache {
    tags: TagStore,
    latency: u32,
    mshrs: usize,
    /// line -> completion cycle of the outstanding fill
    /// (`PENDING_FILL` while the L2 latency is still unserved).
    ///
    /// Ordered map by design: `retain`/`iter` below walk it, and the
    /// simlint `unordered-iteration` rule bans hash-order walks in
    /// `sim/` — every current use (per-entry retain, count, max) is
    /// order-insensitive, but BTreeMap keeps that true by construction
    /// instead of by audit (`mshr_bookkeeping_is_insertion_order_free`
    /// pins it).
    outstanding: BTreeMap<u64, u64>,
    /// Lines whose deferred primary miss has not retried yet (the retry is
    /// counted as the miss; later same-line loads count as MSHR merges).
    deferred_primary: BTreeSet<u64>,
    /// L1 lookups.
    pub accesses: u64,
    /// L1 hits.
    pub hits: u64,
}

impl L1Cache {
    /// Build from config fields.
    pub fn new(bytes: usize, line_bytes: usize, ways: usize, latency: u32, mshrs: usize) -> Self {
        L1Cache {
            tags: TagStore::new(bytes, line_bytes, ways),
            latency,
            mshrs,
            outstanding: BTreeMap::new(),
            deferred_primary: BTreeSet::new(),
            accesses: 0,
            hits: 0,
        }
    }

    /// Load from `line` at cycle `now`.
    ///
    /// Local outcomes (tag hit, MSHR merge onto a resolved fill) complete
    /// immediately; an L2-bound miss queues an [`L2Request`] on `port`,
    /// installs a pending fill, and returns [`L1Fetch::Deferred`] — the
    /// SM's synchronization boundary. After the serial phase posts the
    /// latency via [`L1Cache::resolve_fill`], the retried load returns
    /// [`L1Fetch::Miss`] with the real completion cycle.
    pub fn load_or_defer(&mut self, line: u64, now: u64, port: &mut MemPort) -> L1Fetch {
        // retire completed fills lazily (pending placeholders stay)
        self.outstanding.retain(|_, &mut c| c > now);
        if let Some(&c) = self.outstanding.get(&line) {
            if c == PENDING_FILL {
                // the line is already queued for this epoch's L2 phase:
                // ride that fill, retry together with it
                return L1Fetch::Deferred;
            }
            if self.deferred_primary.remove(&line) {
                // the deferred miss completing: THE one L1 miss access
                self.accesses += 1;
                return L1Fetch::Miss(c);
            }
            // MSHR merge: ride the outstanding fill
            self.accesses += 1;
            self.hits += 1; // sector already inbound: counts as L1-level hit
            return L1Fetch::Hit(c.max(now + self.latency as u64));
        }
        if self.tags.access(line) {
            self.accesses += 1;
            self.hits += 1;
            L1Fetch::Hit(now + self.latency as u64)
        } else {
            // L2-bound: queue for the serial service phase. The tag was
            // installed above (fill-on-miss, as the direct path did); the
            // access is counted when the deferred dispatch retries.
            self.outstanding.insert(line, PENDING_FILL);
            self.deferred_primary.insert(line);
            port.push(line, now);
            L1Fetch::Deferred
        }
    }

    /// Post the serial phase's answer for `line`: convert the pending fill
    /// into a concrete completion cycle, applying MSHR back-pressure when
    /// the fill exceeds capacity (mirrors the direct path's structural
    /// stall). `req_cycle`/`extra` come from the [`L2Response`].
    pub fn resolve_fill(&mut self, line: u64, req_cycle: u64, extra: u32) {
        let mut done = req_cycle + (self.latency + extra) as u64;
        // count only concrete fills: a PENDING placeholder belongs to a
        // later request of the same cycle, which the direct path would not
        // have issued yet at this miss's point in the cycle
        let others = self
            .outstanding
            .iter()
            .filter(|&(&l, &c)| l != line && c != PENDING_FILL)
            .count();
        if others >= self.mshrs {
            // MSHRs full: structural back-pressure
            let max_out = self
                .outstanding
                .iter()
                .filter(|&(&l, &c)| l != line && c != PENDING_FILL)
                .map(|(_, &c)| c)
                .max()
                .unwrap_or(req_cycle);
            done = done.max(max_out + 1);
        }
        self.outstanding.insert(line, done);
    }

    /// Store to `line`: write-through, no allocate (Turing L1 behaviour for
    /// global stores); cheap fixed cost, returns completion cycle.
    pub fn store(&mut self, _line: u64, now: u64) -> u64 {
        now + self.latency as u64
    }

    /// L1 hit ratio so far.
    pub fn hit_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared() -> SharedMemorySystem {
        SharedMemorySystem::new(1 << 20, 128, 8, 90, 220, 0.5)
    }

    /// Single-SM test driver: load, and on deferral immediately run the
    /// serial phase + resolve (what the epoch engine does after an SM
    /// blocks), then retry one cycle later — returning the completion
    /// cycle exactly as a sub-core's deferred dispatch would observe it.
    fn load_now(l1: &mut L1Cache, s: &mut SharedMemorySystem, line: u64, now: u64) -> u64 {
        let mut port = MemPort::new(0);
        match l1.load_or_defer(line, now, &mut port) {
            L1Fetch::Hit(done) | L1Fetch::Miss(done) => done,
            L1Fetch::Deferred => {
                let mut reqs = Vec::new();
                port.drain_into(&mut reqs);
                for r in s.service(&mut reqs) {
                    l1.resolve_fill(r.line, r.cycle, r.extra);
                }
                match l1.load_or_defer(line, now + 1, &mut port) {
                    L1Fetch::Miss(done) | L1Fetch::Hit(done) => done,
                    L1Fetch::Deferred => panic!("resolved fill must complete"),
                }
            }
        }
    }

    #[test]
    fn tagstore_hit_after_fill() {
        let mut t = TagStore::new(1024, 128, 4);
        assert!(!t.access(42));
        assert!(t.access(42));
        assert!(t.probe(42));
        assert!(!t.probe(43));
    }

    #[test]
    fn tagstore_lru_eviction() {
        // 2 sets x 2 ways; lines 0,2,4 map to set 0
        let mut t = TagStore::new(4 * 128, 128, 2);
        t.access(0);
        t.access(2);
        t.access(0); // refresh 0
        t.access(4); // evicts 2 (LRU)
        assert!(t.probe(0));
        assert!(!t.probe(2));
        assert!(t.probe(4));
    }

    #[test]
    fn l1_hit_is_fast_miss_is_slow() {
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 32);
        let t_miss = load_now(&mut l1, &mut s, 7, 0);
        assert!(t_miss >= 28 + 90, "miss must include L2/DRAM");
        let t_hit = load_now(&mut l1, &mut s, 7, t_miss);
        assert_eq!(t_hit, t_miss + 28);
        assert_eq!(l1.accesses, 2, "a deferred miss counts once");
        assert_eq!(l1.hits, 1);
    }

    #[test]
    fn mshr_merges_same_line() {
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 32);
        let t1 = load_now(&mut l1, &mut s, 9, 0);
        let t2 = load_now(&mut l1, &mut s, 9, 2); // merged, no second L2 access
        assert!(t2 <= t1.max(2 + 28));
        assert_eq!(s.accesses, 1, "merged miss must not re-access L2");
        assert_eq!(l1.accesses, 2);
        assert_eq!(l1.hits, 1, "the merge is an L1-level hit");
    }

    #[test]
    fn same_cycle_same_line_merges_onto_pending_fill() {
        // two sub-cores missing the same line in the same cycle queue ONE
        // L2 request; both retries complete off the single fill
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 32);
        let mut port = MemPort::new(0);
        assert_eq!(l1.load_or_defer(5, 0, &mut port), L1Fetch::Deferred);
        assert_eq!(l1.load_or_defer(5, 0, &mut port), L1Fetch::Deferred);
        let mut reqs = Vec::new();
        port.drain_into(&mut reqs);
        assert_eq!(reqs.len(), 1, "second load rides the pending fill");
        for r in s.service(&mut reqs) {
            l1.resolve_fill(r.line, r.cycle, r.extra);
        }
        let a = l1.load_or_defer(5, 1, &mut port);
        let b = l1.load_or_defer(5, 1, &mut port);
        match (a, b) {
            // both complete off the single fill: the merge's completion is
            // max(fill, now + latency) = the fill cycle itself here
            (L1Fetch::Miss(da), L1Fetch::Hit(db)) => {
                assert!(da >= 28 + 90, "fill must carry at least the L2 latency");
                assert_eq!(db, da, "merged load must ride the same fill");
            }
            other => panic!("want (Miss, Hit), got {other:?}"),
        }
        assert_eq!(l1.accesses, 2);
        assert_eq!(l1.hits, 1);
    }

    #[test]
    fn l2_hit_cheaper_than_dram() {
        let mut s = shared();
        let d1 = s.miss_from_l1(5, 0); // L2 miss -> DRAM
        let d2 = s.miss_from_l1(5, 1000); // now L2 hit
        assert!(d1 >= 90 + 220);
        assert_eq!(d2, 90);
    }

    #[test]
    fn dram_bandwidth_queues() {
        let mut s = shared(); // 0.5 req/cycle -> 2 cycles apart
        let mut delays = Vec::new();
        for i in 0..8 {
            delays.push(s.miss_from_l1(1000 + i, 0));
        }
        // each subsequent request waits ~2 more cycles
        assert!(delays[7] > delays[0] + 10);
    }

    #[test]
    fn mshr_full_back_pressure() {
        let mut s = shared();
        let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 2);
        let a = load_now(&mut l1, &mut s, 1, 0);
        let b = load_now(&mut l1, &mut s, 2, 0);
        let c = load_now(&mut l1, &mut s, 3, 0); // MSHRs full
        assert!(c > a.min(b), "third miss must be delayed past an MSHR");
    }

    #[test]
    fn spill_model_counts_and_charges_traffic() {
        let mut sp = SpillModel::new();
        let mut e = EnergyCounts::new();
        assert_eq!(sp.accesses(), 0);
        sp.spill_read(&mut e);
        sp.spill_read(&mut e);
        sp.spill_write(&mut e);
        assert_eq!(sp.reads, 2);
        assert_eq!(sp.writes, 1);
        assert_eq!(sp.accesses(), 3);
        // each access = one bank-class event + one interconnect traversal
        assert_eq!(e.get(EventKind::BankRead), 2);
        assert_eq!(e.get(EventKind::BankWrite), 1);
        assert_eq!(e.get(EventKind::XbarTransfer), 3);
        // spills never touch cache-event counters (zero-entry contract)
        assert_eq!(e.get(EventKind::CcuRead), 0);
        assert_eq!(e.get(EventKind::CcuWrite), 0);
    }

    #[test]
    fn mshr_bookkeeping_is_insertion_order_free() {
        // the MSHR map is walked by retain/count/max in load_or_defer and
        // resolve_fill; none of those may depend on the order the misses
        // were installed. Drive the same miss set through two caches in
        // permuted insertion orders (fills resolve in the canonical sorted
        // order either way, as the L2 serial phase guarantees) and require
        // identical completion cycles and counters. mshrs=2 so the
        // back-pressure count/max path is exercised, not just retain.
        let run = |order: &[u64]| {
            let mut l1 = L1Cache::new(64 * 1024, 128, 4, 28, 2);
            let mut port = MemPort::new(0);
            for &l in order {
                assert_eq!(l1.load_or_defer(l, 0, &mut port), L1Fetch::Deferred);
            }
            let mut fills: Vec<u64> = order.to_vec();
            fills.sort_unstable();
            for &l in &fills {
                l1.resolve_fill(l, 100 + l, 0);
            }
            let mut out = Vec::new();
            for &l in &fills {
                match l1.load_or_defer(l, 1, &mut port) {
                    L1Fetch::Miss(c) => out.push((l, c)),
                    other => panic!("want Miss for line {l}, got {other:?}"),
                }
            }
            (out, l1.accesses, l1.hits)
        };
        let a = run(&[3, 11, 7, 5, 2]);
        let b = run(&[7, 2, 3, 11, 5]);
        assert_eq!(a, b, "MSHR outcomes must not depend on miss insertion order");
    }

    #[test]
    fn l2_service_order_independent_of_arrival_order() {
        // the same multiset of requests, appended by workers in two very
        // different interleavings, must produce identical responses and
        // identical final L2/DRAM state
        let base = vec![
            L2Request { sm_id: 2, cycle: 40, seq: 0, line: 7 },
            L2Request { sm_id: 0, cycle: 41, seq: 4, line: 9 },
            L2Request { sm_id: 1, cycle: 40, seq: 3, line: 7 },
            L2Request { sm_id: 0, cycle: 12, seq: 3, line: 3 },
            L2Request { sm_id: 3, cycle: 12, seq: 0, line: 3 },
            L2Request { sm_id: 1, cycle: 40, seq: 2, line: 11 },
            L2Request { sm_id: 3, cycle: 90, seq: 1, line: 1024 },
        ];
        let mut a = base.clone();
        let mut b = base.clone();
        b.reverse();
        b.swap(0, 3);
        let mut sa = shared();
        let mut sb = shared();
        let ra = sa.service(&mut a);
        let rb = sb.service(&mut b);
        assert_eq!(ra, rb, "responses depend on arrival order");
        assert_eq!(sa.accesses, sb.accesses);
        assert_eq!(sa.hits, sb.hits);
        // and the canonical order is (cycle, sm_id, seq)
        let keys: Vec<_> = a.iter().map(|r| (r.cycle, r.sm_id, r.seq)).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }
}
