//! Typed GPU configuration + presets (Table I) + key=value overrides.
//!
//! `serde`/`toml` are unavailable in this offline build, so the config file
//! format is a plain `key = value` / `# comment` subset parsed here; every
//! field is also overridable from the CLI (`-s key=value`), which is how the
//! bench harness builds its sweeps.
//!
//! The scheme identifier is re-exported from the policy registry
//! ([`crate::sim::policy::registry`]) — the single source of scheme names;
//! `scheme = <name>` overrides resolve through [`Scheme::parse`], so an
//! unknown name errors with the list of valid ones (including any policy
//! registered at runtime).

mod parse;
pub use parse::{parse_kv_file, parse_kv_str};

/// Which collector-unit organisation (and therefore which policy) a
/// simulation runs — a handle into the scheme registry. See DESIGN.md §4
/// and `docs/ARCHITECTURE.md` §Policy layer.
pub use crate::sim::policy::Scheme;

/// How STHLD (the waiting-mechanism threshold, §IV-B3) is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SthldMode {
    /// Fixed value for the whole run (Fig 7 sweeps this).
    Static(u32),
    /// The paper's 6-state dynamic FSM, re-evaluated every interval.
    Dynamic,
}

/// Execution-unit timing (initiation interval, result latency in cycles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EuTiming {
    /// Cycles between accepted instructions.
    pub initiation: u32,
    /// Cycles from dispatch to writeback.
    pub latency: u32,
}

/// Full simulator configuration. Defaults = Table I baseline (RTX 2060
/// scaled to 10 SMs) + Turing sub-core parameters from §II.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    // ---- topology (Table I) ----
    /// Streaming multiprocessors.
    pub num_sms: usize,
    /// Sub-cores per SM (each with private banks, collectors, scheduler).
    pub sub_cores_per_sm: usize,
    /// Warps per SM (divided evenly across sub-cores).
    pub warps_per_sm: usize,
    // ---- register file (§II) ----
    /// RF banks per sub-core (Turing: 2).
    pub banks_per_sub_core: usize,
    /// Collector units per sub-core (ignored by private-per-warp schemes).
    pub collectors_per_sub_core: usize,
    /// Crossbar output width: operands deliverable per collector per cycle
    /// (Turing's 128b dual-bank crossbar: 2).
    pub collector_ports: usize,
    /// Cache-table entries per CCU (paper sweet spot: 8; baseline OCU: 6).
    pub ct_entries: usize,
    /// BOW sliding-window length in instructions.
    pub bow_window: usize,
    /// RFC per-warp cache entries.
    pub rfc_entries: usize,
    /// Active warps per sub-core for two-level schedulers (§VI-A: 2).
    pub active_warps_per_sub_core: usize,
    /// Software-RFC strand length (instructions between swap points).
    pub swrfc_strand_len: usize,
    // ---- related-work scheme knobs (PAPERS.md policies) ----
    /// GREENER power-gate wake-up latency in cycles (slice re-activation;
    /// Jatala et al.) — replaces the plain two-level activation delay.
    pub greener_wakeup: u64,
    /// Compression policy: register ids below this are treated as
    /// compressible (narrow values) and admitted to the cache (Angerd et
    /// al.; the trace has no values, so low ids — parameters, counters —
    /// proxy for compressibility).
    pub compress_regs: u8,
    /// LTRF software-prefetch latency in cycles: the activation delay the
    /// prefetch engine needs to stage a register interval (Sadrosadati et
    /// al.).
    pub ltrf_prefetch: u64,
    /// RegDem: register ids at or above this cutoff are demoted to
    /// shared-memory spill space (Sakdhnagool et al.).
    pub regdem_cutoff: u8,
    /// RegDem: issue-throttle cycles charged per demoted source operand
    /// (the shared-memory access latency the spill path adds).
    pub regdem_penalty: u32,
    // ---- Malekeh policies (§IV) ----
    /// Scheme under test.
    pub scheme: Scheme,
    /// STHLD selection.
    pub sthld: SthldMode,
    /// Dynamic-algorithm interval in cycles (§IV-B3: 10_000).
    pub sthld_interval: u64,
    /// Relative IPC delta separating Small from Large (§IV-B3: 0.02).
    pub sthld_epsilon: f64,
    /// Max STHLD the dynamic algorithm may reach.
    pub sthld_max: u32,
    /// Compiler near/far threshold in dynamic instructions (§III-A: 12).
    pub rthld: u32,
    /// Disable the reuse-guided replacement (plain LRU) — Fig 17 ablation.
    pub traditional_replacement: bool,
    /// Disable the write filter (cache all writebacks) — Fig 17 ablation.
    pub no_write_filter: bool,
    // ---- execution units ----
    /// ALU pipe timing.
    pub alu: EuTiming,
    /// SFU pipe timing.
    pub sfu: EuTiming,
    /// Tensor-core pipe timing.
    pub mma: EuTiming,
    /// Shared-memory load latency.
    pub lds_latency: u32,
    // ---- memory hierarchy ----
    /// L1D size in bytes per SM.
    pub l1_bytes: usize,
    /// L1D associativity.
    pub l1_ways: usize,
    /// L1D line size in bytes.
    pub line_bytes: usize,
    /// L1D hit latency.
    pub l1_latency: u32,
    /// L1D MSHR entries per SM.
    pub l1_mshrs: usize,
    /// L2 size in bytes (whole GPU).
    pub l2_bytes: usize,
    /// L2 associativity.
    pub l2_ways: usize,
    /// L2 hit latency.
    pub l2_latency: u32,
    /// DRAM latency.
    pub dram_latency: u32,
    /// DRAM: max requests accepted per cycle *per SM* (bandwidth proxy;
    /// total = this x num_sms, mirroring the paper's proportional scaling
    /// of memory channels with SM count).
    pub dram_reqs_per_cycle: f64,
    // ---- run control ----
    /// Stop after this many cycles (0 = run to completion).
    pub max_cycles: u64,
    /// PRNG seed for policy tie-breaking.
    pub seed: u64,
    /// Worker threads stepping SMs *inside one simulation* (epoch engine):
    /// 1 = serial, 0 = one per available core, clamped to `num_sms`.
    /// Results are bit-identical at any value — this knob is wall-clock
    /// only (enforced by `rust/tests/parallel_determinism.rs`).
    pub sim_threads: usize,
}

/// Profile-warp count of the golden parity configuration (the
/// `profile_warps` argument every fixture point passes to
/// `sim::run_benchmark`; see [`GpuConfig::golden_parity`]).
pub const GOLDEN_PROFILE_WARPS: usize = 2;

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig::table1_baseline()
    }
}

impl GpuConfig {
    /// Table I baseline: RTX-2060-like scaled to 10 SMs, Turing sub-cores.
    pub fn table1_baseline() -> Self {
        GpuConfig {
            num_sms: 10,
            sub_cores_per_sm: 4,
            warps_per_sm: 32,
            banks_per_sub_core: 2,
            collectors_per_sub_core: 2,
            collector_ports: 2,
            ct_entries: 8,
            bow_window: 3,
            rfc_entries: 6,
            active_warps_per_sub_core: 2,
            swrfc_strand_len: 10,
            greener_wakeup: 6,
            compress_regs: 32,
            ltrf_prefetch: 8,
            regdem_cutoff: 32,
            regdem_penalty: 2,
            scheme: Scheme::BASELINE,
            sthld: SthldMode::Dynamic,
            sthld_interval: 10_000,
            sthld_epsilon: 0.02,
            sthld_max: 64,
            rthld: 12,
            traditional_replacement: false,
            no_write_filter: false,
            alu: EuTiming { initiation: 1, latency: 4 },
            sfu: EuTiming { initiation: 4, latency: 16 },
            mma: EuTiming { initiation: 2, latency: 16 },
            lds_latency: 24,
            l1_bytes: 64 * 1024,
            l1_ways: 4,
            line_bytes: 128,
            l1_latency: 28,
            l1_mshrs: 32,
            l2_bytes: 1024 * 1024,
            l2_ways: 8,
            l2_latency: 90,
            dram_latency: 220,
            dram_reqs_per_cycle: 0.5,
            max_cycles: 0,
            seed: 0xC0FFEE,
            sim_threads: 1,
        }
    }

    /// The golden-fixture parity configuration
    /// (`rust/tests/golden/fingerprints.txt` header): Table I baseline on
    /// 1 SM, serial reference engine, 40k-cycle cap; run with
    /// [`GOLDEN_PROFILE_WARPS`] profile warps. The single source of truth
    /// for the pinned config — the policy-parity suite and the
    /// `perf_hotpath` `golden_check` block both build from here, so they
    /// can never drift apart.
    pub fn golden_parity(scheme: Scheme) -> Self {
        let mut c = Self::table1_baseline().with_scheme(scheme);
        c.num_sms = 1;
        c.sim_threads = 1;
        c.max_cycles = 40_000;
        c
    }

    /// Early-Tesla-like monolithic SM for the Fig 2 comparison: one
    /// scheduler and one pool of banks/collectors for all warps.
    pub fn monolithic() -> Self {
        let mut c = Self::table1_baseline();
        c.sub_cores_per_sm = 1;
        // keep per-SM totals equal: 4 sub-cores x 2 = 8 banks/collectors
        c.banks_per_sub_core = 8;
        c.collectors_per_sub_core = 8;
        c.active_warps_per_sub_core = 8;
        c
    }

    /// Set the scheme, applying the Fig 17 ablation knobs for the
    /// traditional comparison point (plain LRU, no write filter, no
    /// waiting mechanism).
    pub fn with_scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        if scheme == Scheme::MALEKEH_TRADITIONAL {
            self.traditional_replacement = true;
            self.no_write_filter = true;
            self.sthld = SthldMode::Static(0);
        }
        self
    }

    /// Warps per sub-core scheduler.
    pub fn warps_per_sub_core(&self) -> usize {
        self.warps_per_sm / self.sub_cores_per_sm
    }

    /// Effective number of collector units in one sub-core for `scheme`.
    pub fn effective_collectors(&self) -> usize {
        if self.scheme.private_per_warp() {
            self.warps_per_sub_core()
        } else {
            self.collectors_per_sub_core
        }
    }

    /// Apply one `key=value` override; error string on unknown key/bad value.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T, String> {
            v.trim()
                .parse::<T>()
                .map_err(|_| format!("bad value for {k}: {v:?}"))
        }
        match key.trim() {
            "num_sms" => self.num_sms = p(key, value)?,
            "sub_cores_per_sm" => self.sub_cores_per_sm = p(key, value)?,
            "warps_per_sm" => self.warps_per_sm = p(key, value)?,
            "banks_per_sub_core" => self.banks_per_sub_core = p(key, value)?,
            "collectors_per_sub_core" => {
                self.collectors_per_sub_core = p(key, value)?
            }
            "collector_ports" => self.collector_ports = p(key, value)?,
            "ct_entries" => self.ct_entries = p(key, value)?,
            "bow_window" => self.bow_window = p(key, value)?,
            "rfc_entries" => self.rfc_entries = p(key, value)?,
            "active_warps_per_sub_core" => {
                self.active_warps_per_sub_core = p(key, value)?
            }
            "swrfc_strand_len" => self.swrfc_strand_len = p(key, value)?,
            "greener_wakeup" => self.greener_wakeup = p(key, value)?,
            "compress_regs" => self.compress_regs = p(key, value)?,
            "ltrf_prefetch" => self.ltrf_prefetch = p(key, value)?,
            "regdem_cutoff" => self.regdem_cutoff = p(key, value)?,
            "regdem_penalty" => self.regdem_penalty = p(key, value)?,
            "scheme" => self.scheme = Scheme::parse(value.trim())?,
            "sthld" => {
                self.sthld = if value.trim() == "dynamic" {
                    SthldMode::Dynamic
                } else {
                    SthldMode::Static(p(key, value)?)
                }
            }
            "sthld_interval" => self.sthld_interval = p(key, value)?,
            "sthld_epsilon" => self.sthld_epsilon = p(key, value)?,
            "sthld_max" => self.sthld_max = p(key, value)?,
            "rthld" => self.rthld = p(key, value)?,
            "traditional_replacement" => {
                self.traditional_replacement = p(key, value)?
            }
            "no_write_filter" => self.no_write_filter = p(key, value)?,
            "alu_latency" => self.alu.latency = p(key, value)?,
            "sfu_latency" => self.sfu.latency = p(key, value)?,
            "mma_latency" => self.mma.latency = p(key, value)?,
            "mma_initiation" => self.mma.initiation = p(key, value)?,
            "lds_latency" => self.lds_latency = p(key, value)?,
            "l1_bytes" => self.l1_bytes = p(key, value)?,
            "l1_ways" => self.l1_ways = p(key, value)?,
            "line_bytes" => self.line_bytes = p(key, value)?,
            "l1_latency" => self.l1_latency = p(key, value)?,
            "l1_mshrs" => self.l1_mshrs = p(key, value)?,
            "l2_bytes" => self.l2_bytes = p(key, value)?,
            "l2_ways" => self.l2_ways = p(key, value)?,
            "l2_latency" => self.l2_latency = p(key, value)?,
            "dram_latency" => self.dram_latency = p(key, value)?,
            "dram_reqs_per_cycle" => self.dram_reqs_per_cycle = p(key, value)?,
            "max_cycles" => self.max_cycles = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "sim_threads" => self.sim_threads = p(key, value)?,
            other => return Err(format!("unknown config key {other:?}")),
        }
        Ok(())
    }

    /// Apply many overrides from parsed key=value pairs.
    pub fn apply(&mut self, pairs: &[(String, String)]) -> Result<(), String> {
        for (k, v) in pairs {
            self.set(k, v)?;
        }
        Ok(())
    }

    /// Canonical `key = value` serialisation of every **behaviour-bearing**
    /// field, in declaration order — the preimage of
    /// [`GpuConfig::fingerprint`].
    ///
    /// `sim_threads` is deliberately excluded: it is a wall-clock-only
    /// knob (results are bit-identical at any thread count — the crate's
    /// determinism contract), so a result computed at `--sim-threads 4`
    /// must content-address identically to the `--sim-threads 1`
    /// reference run. Every key here parses back through
    /// [`GpuConfig::set`] (enforced by a unit test), so the canonical
    /// form doubles as a loadable config file.
    pub fn canonical_string(&self) -> String {
        let mut s = String::with_capacity(1024);
        let mut kv = |k: &str, v: String| {
            s.push_str(k);
            s.push_str(" = ");
            s.push_str(&v);
            s.push('\n');
        };
        kv("num_sms", self.num_sms.to_string());
        kv("sub_cores_per_sm", self.sub_cores_per_sm.to_string());
        kv("warps_per_sm", self.warps_per_sm.to_string());
        kv("banks_per_sub_core", self.banks_per_sub_core.to_string());
        kv("collectors_per_sub_core", self.collectors_per_sub_core.to_string());
        kv("collector_ports", self.collector_ports.to_string());
        kv("ct_entries", self.ct_entries.to_string());
        kv("bow_window", self.bow_window.to_string());
        kv("rfc_entries", self.rfc_entries.to_string());
        kv(
            "active_warps_per_sub_core",
            self.active_warps_per_sub_core.to_string(),
        );
        kv("swrfc_strand_len", self.swrfc_strand_len.to_string());
        kv("greener_wakeup", self.greener_wakeup.to_string());
        kv("compress_regs", self.compress_regs.to_string());
        kv("ltrf_prefetch", self.ltrf_prefetch.to_string());
        kv("regdem_cutoff", self.regdem_cutoff.to_string());
        kv("regdem_penalty", self.regdem_penalty.to_string());
        kv("scheme", self.scheme.name().to_string());
        kv(
            "sthld",
            match self.sthld {
                SthldMode::Dynamic => "dynamic".to_string(),
                SthldMode::Static(v) => v.to_string(),
            },
        );
        kv("sthld_interval", self.sthld_interval.to_string());
        // f64 Display prints the shortest round-tripping decimal, so the
        // canonical text is both readable and bit-exact
        kv("sthld_epsilon", self.sthld_epsilon.to_string());
        kv("sthld_max", self.sthld_max.to_string());
        kv("rthld", self.rthld.to_string());
        kv(
            "traditional_replacement",
            self.traditional_replacement.to_string(),
        );
        kv("no_write_filter", self.no_write_filter.to_string());
        kv("alu_latency", self.alu.latency.to_string());
        kv("sfu_latency", self.sfu.latency.to_string());
        kv("mma_latency", self.mma.latency.to_string());
        kv("mma_initiation", self.mma.initiation.to_string());
        kv("lds_latency", self.lds_latency.to_string());
        kv("l1_bytes", self.l1_bytes.to_string());
        kv("l1_ways", self.l1_ways.to_string());
        kv("line_bytes", self.line_bytes.to_string());
        kv("l1_latency", self.l1_latency.to_string());
        kv("l1_mshrs", self.l1_mshrs.to_string());
        kv("l2_bytes", self.l2_bytes.to_string());
        kv("l2_ways", self.l2_ways.to_string());
        kv("l2_latency", self.l2_latency.to_string());
        kv("dram_latency", self.dram_latency.to_string());
        kv("dram_reqs_per_cycle", self.dram_reqs_per_cycle.to_string());
        kv("max_cycles", self.max_cycles.to_string());
        kv("seed", self.seed.to_string());
        s
    }

    /// FNV-1a digest of [`GpuConfig::canonical_string`] — one third of the
    /// persistent result store's content address
    /// (`config x workload x policy`, see [`crate::serve::store`]). Two
    /// configs fingerprint equal iff every behaviour-bearing field is
    /// equal; `sim_threads` never participates.
    pub fn fingerprint(&self) -> u64 {
        crate::util::fnv1a_bytes(self.canonical_string().as_bytes())
    }

    /// Sanity-check invariants; returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_sms == 0 {
            return Err("num_sms must be > 0".into());
        }
        if self.sub_cores_per_sm == 0 {
            return Err("sub_cores_per_sm must be > 0".into());
        }
        if self.warps_per_sm % self.sub_cores_per_sm != 0 {
            return Err(format!(
                "warps_per_sm ({}) must divide evenly across sub-cores ({})",
                self.warps_per_sm, self.sub_cores_per_sm
            ));
        }
        if self.banks_per_sub_core == 0 {
            return Err("banks_per_sub_core must be > 0".into());
        }
        if self.ct_entries < crate::isa::MAX_SRC {
            return Err(format!(
                "ct_entries ({}) must fit {} sources of one instruction",
                self.ct_entries,
                crate::isa::MAX_SRC
            ));
        }
        if self.scheme.two_level()
            && self.active_warps_per_sub_core > self.warps_per_sub_core()
        {
            return Err("active set larger than the warp pool".into());
        }
        if !self.line_bytes.is_power_of_two() {
            return Err("line_bytes must be a power of two".into());
        }
        if self.l1_bytes % (self.line_bytes * self.l1_ways) != 0 {
            return Err("l1_bytes must be divisible by ways*line".into());
        }
        if self.l2_bytes % (self.line_bytes * self.l2_ways) != 0 {
            return Err("l2_bytes must be divisible by ways*line".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let c = GpuConfig::table1_baseline();
        assert_eq!(c.num_sms, 10);
        assert_eq!(c.warps_per_sm, 32);
        assert_eq!(c.sub_cores_per_sm, 4);
        assert_eq!(c.warps_per_sub_core(), 8);
        assert_eq!(c.banks_per_sub_core, 2);
        assert_eq!(c.ct_entries, 8);
        assert_eq!(c.rthld, 12);
        assert_eq!(c.sthld_interval, 10_000);
        assert_eq!(c.l2_bytes, 1024 * 1024);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn monolithic_keeps_per_sm_totals() {
        let m = GpuConfig::monolithic();
        let b = GpuConfig::table1_baseline();
        assert_eq!(
            m.banks_per_sub_core * m.sub_cores_per_sm,
            b.banks_per_sub_core * b.sub_cores_per_sm
        );
        assert!(m.validate().is_ok());
    }

    #[test]
    fn with_scheme_traditional_sets_ablation_flags() {
        let c = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH_TRADITIONAL);
        assert!(c.traditional_replacement);
        assert!(c.no_write_filter);
        assert_eq!(c.sthld, SthldMode::Static(0));
    }

    #[test]
    fn effective_collectors_private_schemes() {
        let c = GpuConfig::table1_baseline().with_scheme(Scheme::BOW);
        assert_eq!(c.effective_collectors(), 8); // one per warp
        let c = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
        assert_eq!(c.effective_collectors(), 2);
    }

    #[test]
    fn set_roundtrips_keys() {
        let mut c = GpuConfig::table1_baseline();
        c.set("scheme", "malekeh").unwrap();
        assert_eq!(c.scheme, Scheme::MALEKEH);
        c.set("sthld", "dynamic").unwrap();
        assert_eq!(c.sthld, SthldMode::Dynamic);
        c.set("sthld", "4").unwrap();
        assert_eq!(c.sthld, SthldMode::Static(4));
        c.set("rthld", "7").unwrap();
        assert_eq!(c.rthld, 7);
        c.set("sim_threads", "4").unwrap();
        assert_eq!(c.sim_threads, 4);
        assert!(c.set("nonsense_key", "1").is_err());
        assert!(c.set("rthld", "xyz").is_err());
    }

    #[test]
    fn related_work_knobs_default_and_roundtrip() {
        let mut c = GpuConfig::table1_baseline();
        assert_eq!(c.greener_wakeup, 6);
        assert_eq!(c.compress_regs, 32);
        assert_eq!(c.ltrf_prefetch, 8);
        assert_eq!(c.regdem_cutoff, 32);
        assert_eq!(c.regdem_penalty, 2);
        c.set("greener_wakeup", "12").unwrap();
        c.set("compress_regs", "48").unwrap();
        c.set("ltrf_prefetch", "16").unwrap();
        c.set("regdem_cutoff", "40").unwrap();
        c.set("regdem_penalty", "5").unwrap();
        assert_eq!(c.greener_wakeup, 12);
        assert_eq!(c.compress_regs, 48);
        assert_eq!(c.ltrf_prefetch, 16);
        assert_eq!(c.regdem_cutoff, 40);
        assert_eq!(c.regdem_penalty, 5);
        assert!(c.set("compress_regs", "300").is_err(), "u8 range enforced");
    }

    #[test]
    fn validate_catches_bad_configs() {
        let mut c = GpuConfig::table1_baseline();
        c.warps_per_sm = 30; // not divisible by 4 sub-cores
        assert!(c.validate().is_err());

        let mut c = GpuConfig::table1_baseline();
        c.ct_entries = 4; // cannot hold 6 sources
        assert!(c.validate().is_err());

        let mut c = GpuConfig::table1_baseline().with_scheme(Scheme::RFC);
        c.active_warps_per_sub_core = 100;
        assert!(c.validate().is_err());
    }

    #[test]
    fn canonical_string_parses_back_through_set() {
        // the canonical form doubles as a loadable config file: every
        // line must round-trip through the override parser and reproduce
        // the same fingerprint
        let mut c = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
        c.sthld = SthldMode::Static(4);
        c.sthld_epsilon = 0.125;
        let pairs = parse_kv_str(&c.canonical_string()).unwrap();
        let mut rebuilt = GpuConfig::table1_baseline();
        rebuilt.apply(&pairs).unwrap();
        rebuilt.sim_threads = c.sim_threads;
        assert_eq!(rebuilt, c);
        assert_eq!(rebuilt.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fingerprint_tracks_behaviour_fields_only() {
        let base = GpuConfig::table1_baseline();
        let fp = base.fingerprint();
        assert_eq!(fp, base.clone().fingerprint(), "pure function of fields");

        // every behaviour-bearing change must show
        let mut c = base.clone();
        c.seed = 1;
        assert_ne!(fp, c.fingerprint(), "seed must show");
        let mut c = base.clone();
        c.rthld += 1;
        assert_ne!(fp, c.fingerprint(), "rthld must show");
        let c = base.clone().with_scheme(Scheme::MALEKEH);
        assert_ne!(fp, c.fingerprint(), "scheme must show");
        let mut c = base.clone();
        c.sthld = SthldMode::Static(0);
        assert_ne!(fp, c.fingerprint(), "sthld mode must show");
        let mut c = base.clone();
        c.max_cycles = 40_000;
        assert_ne!(fp, c.fingerprint(), "max_cycles must show");

        // sim_threads is wall-clock only: results are bit-identical at
        // any thread count, so the content address must not split on it
        let mut c = base.clone();
        c.sim_threads = 4;
        assert_eq!(fp, c.fingerprint(), "sim_threads must NOT show");
    }

    #[test]
    fn scheme_names_roundtrip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::from_name(s.name()), Some(s));
        }
        assert_eq!(Scheme::from_name("bogus"), None);
    }
}
