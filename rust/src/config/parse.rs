//! Minimal `key = value` config-file parser (serde/toml are unavailable in
//! this offline build). Supports `#`/`;` comments, blank lines, optional
//! `[section]` headers (flattened as `section.key`), and quoted values.

/// Parse a config string into ordered `(key, value)` pairs.
pub fn parse_kv_str(text: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section", ln + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", ln + 1));
        }
        let mut value = line[eq + 1..].trim();
        if value.len() >= 2
            && ((value.starts_with('"') && value.ends_with('"'))
                || (value.starts_with('\'') && value.ends_with('\'')))
        {
            value = &value[1..value.len() - 1];
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((full_key, value.to_string()));
    }
    Ok(out)
}

/// Parse a config file from disk.
pub fn parse_kv_file(path: &str) -> Result<Vec<(String, String)>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    parse_kv_str(&text)
}

fn strip_comment(line: &str) -> &str {
    // respect quotes so "#" inside a quoted value survives
    let mut in_quote: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (c, in_quote) {
            ('"' | '\'', None) => in_quote = Some(c),
            (q, Some(open)) if q == open => in_quote = None,
            ('#' | ';', None) => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_simple_pairs() {
        let kv = parse_kv_str("a = 1\nb=two\n").unwrap();
        assert_eq!(
            kv,
            vec![("a".into(), "1".into()), ("b".into(), "two".into())]
        );
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let kv = parse_kv_str("# header\n\na = 1  # trailing\n; note\n").unwrap();
        assert_eq!(kv, vec![("a".into(), "1".into())]);
    }

    #[test]
    fn sections_flatten() {
        let kv = parse_kv_str("[sim]\nscheme = malekeh\n[mem]\nl1 = 64\n").unwrap();
        assert_eq!(
            kv,
            vec![
                ("sim.scheme".into(), "malekeh".into()),
                ("mem.l1".into(), "64".into())
            ]
        );
    }

    #[test]
    fn quoted_values_keep_hash() {
        let kv = parse_kv_str("name = \"a # b\"\n").unwrap();
        assert_eq!(kv, vec![("name".into(), "a # b".into())]);
    }

    #[test]
    fn errors_are_located() {
        assert!(parse_kv_str("just words\n").unwrap_err().contains("line 1"));
        assert!(parse_kv_str("[open\n").unwrap_err().contains("line 1"));
        assert!(parse_kv_str("= v\n").unwrap_err().contains("empty key"));
    }
}
