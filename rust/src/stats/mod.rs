//! Simulation counters and derived metrics.
//!
//! One `Stats` per simulation run (merged across SMs/sub-cores). Everything
//! the paper's figures report is derived from these fields; benches read
//! them directly, so the naming follows the paper: "RF cache hit ratio" =
//! cache-served reads / total operand reads (§VI-B2), scheduler state
//! distribution (Fig 10), interval IPC (Fig 7/9), etc.

use crate::energy::EnergyCounts;

/// Per-cycle state of an issue scheduler, as classified in §VI-A.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedState {
    /// State 1: an instruction was issued.
    Issued,
    /// State 2: nothing issued although a ready warp exists somewhere in
    /// the pool (two-level: in the pending set; Malekeh: blocked by the
    /// waiting mechanism or collectors).
    StallReady,
    /// State 3: nothing issued and no warp was ready.
    StallEmpty,
}

/// Counter set for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    // ---- progress ----
    /// Simulated cycles.
    pub cycles: u64,
    /// Warp instructions committed.
    pub instructions: u64,
    /// Warps that reached their Exit marker.
    pub warps_retired: u64,

    // ---- register file traffic ----
    /// Source-operand reads requested by issued instructions (cache +
    /// banks; Ctrl/Exit read nothing).
    pub rf_reads: u64,
    /// Reads served by the RF banks.
    pub rf_bank_reads: u64,
    /// Reads served by a collector cache (CCU/BOC/RFC hit).
    pub rf_cache_reads: u64,
    /// Destination writes (RF banks are always written, §IV-A2).
    pub rf_writes: u64,
    /// Writes also captured by a collector cache.
    pub rf_cache_writes: u64,
    /// Cache-resident values that were later actually read (reuse proof,
    /// Fig 16 discussion).
    pub cache_write_reused: u64,
    /// Cycles read requests spent queued behind a busy bank (conflict
    /// pressure; not a paper figure, used for analysis).
    pub bank_conflict_wait: u64,

    // ---- issue scheduler ----
    /// Cycles (per sub-core scheduler, summed) in each state.
    pub sched_issued: u64,
    /// State 2 cycles (ready warp existed but nothing issued).
    pub sched_stall_ready: u64,
    /// State 3 cycles (no ready warp).
    pub sched_stall_empty: u64,
    /// Subset of state-2 cycles caused by Malekeh's waiting mechanism.
    pub waiting_stalls: u64,
    /// Issue attempts rejected because every collector was occupied.
    pub collector_full_stalls: u64,
    /// CCU flushes triggered by warp-ownership change (§III-C1).
    pub ccu_flushes: u64,

    // ---- memory ----
    /// L1D lookups.
    pub l1_accesses: u64,
    /// L1D hits.
    pub l1_hits: u64,
    /// L2 lookups.
    pub l2_accesses: u64,
    /// L2 hits.
    pub l2_hits: u64,

    // ---- energy events ----
    /// RF energy event counts (consumed by `energy::EnergyModel`).
    pub energy: EnergyCounts,

    // ---- interval traces (dynamic algorithm, Figs 7/9) ----
    /// IPC of each STHLD interval.
    pub interval_ipc: Vec<f64>,
    /// STHLD value used during each interval.
    pub sthld_trace: Vec<u32>,
}

impl Stats {
    /// New empty counter set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Instructions per cycle over the whole run (0 if no cycles).
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles as f64
        }
    }

    /// RF cache hit ratio: cache-served reads / all operand reads (§VI-B2).
    pub fn rf_hit_ratio(&self) -> f64 {
        if self.rf_reads == 0 {
            0.0
        } else {
            self.rf_cache_reads as f64 / self.rf_reads as f64
        }
    }

    /// Fraction of RF bank reads eliminated relative to `baseline`.
    pub fn bank_read_reduction_vs(&self, baseline: &Stats) -> f64 {
        if baseline.rf_bank_reads == 0 {
            0.0
        } else {
            1.0 - self.rf_bank_reads as f64 / baseline.rf_bank_reads as f64
        }
    }

    /// L1 data-cache hit ratio (Fig 14).
    pub fn l1_hit_ratio(&self) -> f64 {
        if self.l1_accesses == 0 {
            0.0
        } else {
            self.l1_hits as f64 / self.l1_accesses as f64
        }
    }

    /// Cache writes / total RF writes (Fig 16).
    pub fn cache_write_fraction(&self) -> f64 {
        if self.rf_writes == 0 {
            0.0
        } else {
            self.rf_cache_writes as f64 / self.rf_writes as f64
        }
    }

    /// Scheduler state distribution (issued, state2, state3) as fractions
    /// of scheduler-cycles (Fig 10).
    pub fn sched_state_distribution(&self) -> (f64, f64, f64) {
        let total =
            (self.sched_issued + self.sched_stall_ready + self.sched_stall_empty) as f64;
        if total == 0.0 {
            return (0.0, 0.0, 0.0);
        }
        (
            self.sched_issued as f64 / total,
            self.sched_stall_ready as f64 / total,
            self.sched_stall_empty as f64 / total,
        )
    }

    /// Record one scheduler-cycle state.
    #[inline]
    pub fn record_sched(&mut self, s: SchedState) {
        match s {
            SchedState::Issued => self.sched_issued += 1,
            SchedState::StallReady => self.sched_stall_ready += 1,
            SchedState::StallEmpty => self.sched_stall_empty += 1,
        }
    }

    /// Order-stable FNV-1a digest over every deterministic counter,
    /// including the energy event matrix and the interval traces: two runs
    /// are bit-identical iff their fingerprints match. Used by the trace
    /// round-trip tests, the CI record/replay check, and the
    /// parallel-scaling bench.
    pub fn fingerprint(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x0100_0000_01B3)
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for v in [
            self.cycles,
            self.instructions,
            self.warps_retired,
            self.rf_reads,
            self.rf_bank_reads,
            self.rf_cache_reads,
            self.rf_writes,
            self.rf_cache_writes,
            self.cache_write_reused,
            self.bank_conflict_wait,
            self.sched_issued,
            self.sched_stall_ready,
            self.sched_stall_empty,
            self.waiting_stalls,
            self.collector_full_stalls,
            self.ccu_flushes,
            self.l1_accesses,
            self.l1_hits,
            self.l2_accesses,
            self.l2_hits,
        ] {
            h = mix(h, v);
        }
        for v in self.energy.raw() {
            h = mix(h, v);
        }
        for &v in &self.interval_ipc {
            h = mix(h, v.to_bits());
        }
        for &v in &self.sthld_trace {
            h = mix(h, u64::from(v));
        }
        h
    }

    /// One-line JSON object carrying every counter, the derived figure
    /// metrics, the energy event row (by [`crate::energy::EVENT_NAMES`]),
    /// the interval traces, and the [`Stats::fingerprint`] as zero-padded
    /// hex — the machine-readable form consumed by `malekeh simulate
    /// --json`, the serve protocol's `RESULT` line, and CI fingerprint
    /// diffs. Hand-rolled (serde is unavailable offline); every number is
    /// a plain JSON number (`f64` Display prints the shortest
    /// round-tripping decimal and all derived ratios are finite by
    /// construction).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push('{');
        let mut first = true;
        let mut field = |s: &mut String, k: &str, v: String| {
            if !first {
                s.push(',');
            }
            first = false;
            s.push('"');
            s.push_str(k);
            s.push_str("\":");
            s.push_str(&v);
        };
        field(&mut s, "cycles", self.cycles.to_string());
        field(&mut s, "instructions", self.instructions.to_string());
        field(&mut s, "warps_retired", self.warps_retired.to_string());
        field(&mut s, "rf_reads", self.rf_reads.to_string());
        field(&mut s, "rf_bank_reads", self.rf_bank_reads.to_string());
        field(&mut s, "rf_cache_reads", self.rf_cache_reads.to_string());
        field(&mut s, "rf_writes", self.rf_writes.to_string());
        field(&mut s, "rf_cache_writes", self.rf_cache_writes.to_string());
        field(&mut s, "cache_write_reused", self.cache_write_reused.to_string());
        field(&mut s, "bank_conflict_wait", self.bank_conflict_wait.to_string());
        field(&mut s, "sched_issued", self.sched_issued.to_string());
        field(&mut s, "sched_stall_ready", self.sched_stall_ready.to_string());
        field(&mut s, "sched_stall_empty", self.sched_stall_empty.to_string());
        field(&mut s, "waiting_stalls", self.waiting_stalls.to_string());
        field(
            &mut s,
            "collector_full_stalls",
            self.collector_full_stalls.to_string(),
        );
        field(&mut s, "ccu_flushes", self.ccu_flushes.to_string());
        field(&mut s, "l1_accesses", self.l1_accesses.to_string());
        field(&mut s, "l1_hits", self.l1_hits.to_string());
        field(&mut s, "l2_accesses", self.l2_accesses.to_string());
        field(&mut s, "l2_hits", self.l2_hits.to_string());
        field(&mut s, "ipc", self.ipc().to_string());
        field(&mut s, "rf_hit_ratio", self.rf_hit_ratio().to_string());
        field(&mut s, "l1_hit_ratio", self.l1_hit_ratio().to_string());
        field(
            &mut s,
            "cache_write_fraction",
            self.cache_write_fraction().to_string(),
        );
        let energy: Vec<String> = crate::energy::EVENT_NAMES
            .iter()
            .zip(self.energy.raw())
            .map(|(name, n)| format!("\"{name}\":{n}"))
            .collect();
        field(&mut s, "energy", format!("{{{}}}", energy.join(",")));
        let ipc_row: Vec<String> =
            self.interval_ipc.iter().map(|v| v.to_string()).collect();
        field(&mut s, "interval_ipc", format!("[{}]", ipc_row.join(",")));
        let sthld_row: Vec<String> =
            self.sthld_trace.iter().map(|v| v.to_string()).collect();
        field(&mut s, "sthld_trace", format!("[{}]", sthld_row.join(",")));
        field(
            &mut s,
            "fingerprint",
            format!("\"{:016x}\"", self.fingerprint()),
        );
        s.push('}');
        s
    }

    /// Merge another counter set into this one (SM/sub-core aggregation).
    /// `cycles` takes the max (SMs share the wall clock), scalar counters
    /// add.
    ///
    /// Interval traces (`interval_ipc`/`sthld_trace`) are **not** merged:
    /// they are GPU-wide series sampled at interval boundaries, owned
    /// exclusively by the GPU-level controller
    /// (`sim::Simulator::collect_stats` attaches them once per run).
    /// Per-SM inputs must therefore carry none — debug builds assert this
    /// instead of silently keeping whichever copy arrived first, which is
    /// what the old "concatenate"-documented behavior actually did.
    pub fn merge(&mut self, other: &Stats) {
        debug_assert!(
            other.interval_ipc.is_empty() && other.sthld_trace.is_empty(),
            "Stats::merge: interval traces are owned by the GPU-level \
             controller; per-SM/sub-core stats must not carry them"
        );
        self.cycles = self.cycles.max(other.cycles);
        self.instructions += other.instructions;
        self.warps_retired += other.warps_retired;
        self.rf_reads += other.rf_reads;
        self.rf_bank_reads += other.rf_bank_reads;
        self.rf_cache_reads += other.rf_cache_reads;
        self.rf_writes += other.rf_writes;
        self.rf_cache_writes += other.rf_cache_writes;
        self.cache_write_reused += other.cache_write_reused;
        self.bank_conflict_wait += other.bank_conflict_wait;
        self.sched_issued += other.sched_issued;
        self.sched_stall_ready += other.sched_stall_ready;
        self.sched_stall_empty += other.sched_stall_empty;
        self.waiting_stalls += other.waiting_stalls;
        self.collector_full_stalls += other.collector_full_stalls;
        self.ccu_flushes += other.ccu_flushes;
        self.l1_accesses += other.l1_accesses;
        self.l1_hits += other.l1_hits;
        self.l2_accesses += other.l2_accesses;
        self.l2_hits += other.l2_hits;
        self.energy.merge(&other.energy);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipc_and_ratios() {
        let mut s = Stats::new();
        assert_eq!(s.ipc(), 0.0);
        assert_eq!(s.rf_hit_ratio(), 0.0);
        s.cycles = 100;
        s.instructions = 250;
        s.rf_reads = 10;
        s.rf_cache_reads = 4;
        s.rf_bank_reads = 6;
        assert!((s.ipc() - 2.5).abs() < 1e-12);
        assert!((s.rf_hit_ratio() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn bank_read_reduction() {
        let mut base = Stats::new();
        base.rf_bank_reads = 100;
        let mut m = Stats::new();
        m.rf_bank_reads = 54;
        assert!((m.bank_read_reduction_vs(&base) - 0.46).abs() < 1e-12);
        let empty = Stats::new();
        assert_eq!(m.bank_read_reduction_vs(&empty), 0.0);
    }

    #[test]
    fn sched_distribution_sums_to_one() {
        let mut s = Stats::new();
        for _ in 0..50 {
            s.record_sched(SchedState::Issued);
        }
        for _ in 0..30 {
            s.record_sched(SchedState::StallReady);
        }
        for _ in 0..20 {
            s.record_sched(SchedState::StallEmpty);
        }
        let (a, b, c) = s.sched_state_distribution();
        assert!((a + b + c - 1.0).abs() < 1e-12);
        assert!((a - 0.5).abs() < 1e-12);
        assert!((b - 0.3).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_counters_and_maxes_cycles() {
        let mut a = Stats::new();
        a.cycles = 100;
        a.instructions = 10;
        a.rf_reads = 5;
        let mut b = Stats::new();
        b.cycles = 80;
        b.instructions = 20;
        b.rf_reads = 7;
        a.merge(&b);
        assert_eq!(a.cycles, 100);
        assert_eq!(a.instructions, 30);
        assert_eq!(a.rf_reads, 12);
    }

    #[test]
    fn merge_leaves_interval_traces_to_the_gpu_owner() {
        // the GPU-level controller attaches the interval series once per
        // run; merging per-SM counter sets must never touch them
        let mut total = Stats::new();
        total.interval_ipc = vec![1.0, 2.0];
        total.sthld_trace = vec![3, 4];
        let mut sm = Stats::new();
        sm.instructions = 7;
        total.merge(&sm);
        assert_eq!(total.interval_ipc, vec![1.0, 2.0]);
        assert_eq!(total.sthld_trace, vec![3, 4]);
        assert_eq!(total.instructions, 7);
    }

    #[test]
    fn fingerprint_tracks_every_counter() {
        let mut s = Stats::new();
        s.cycles = 100;
        s.instructions = 250;
        let base = s.fingerprint();
        assert_eq!(base, s.clone().fingerprint(), "pure function of counters");
        s.rf_cache_reads += 1;
        assert_ne!(base, s.fingerprint(), "counter change must show");
        s.rf_cache_reads -= 1;
        s.interval_ipc.push(1.25);
        assert_ne!(base, s.fingerprint(), "interval trace change must show");
    }

    #[test]
    fn to_json_is_one_line_and_carries_the_fingerprint() {
        let mut s = Stats::new();
        s.cycles = 100;
        s.instructions = 250;
        s.rf_reads = 10;
        s.rf_cache_reads = 4;
        s.interval_ipc.push(2.5);
        s.sthld_trace.push(3);
        s.energy.add(crate::energy::EventKind::BankRead, 7);
        let j = s.to_json();
        assert!(!j.contains('\n'), "must be line-delimited-protocol safe");
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"cycles\":100"));
        assert!(j.contains("\"ipc\":2.5"));
        assert!(j.contains("\"bank_read\":7"));
        assert!(j.contains("\"interval_ipc\":[2.5]"));
        assert!(j.contains("\"sthld_trace\":[3]"));
        assert!(j.contains(&format!("\"fingerprint\":\"{:016x}\"", s.fingerprint())));
        // stable under clone (pure function of the counters)
        assert_eq!(j, s.clone().to_json());
    }

    #[test]
    fn cache_write_fraction_guard() {
        let mut s = Stats::new();
        assert_eq!(s.cache_write_fraction(), 0.0);
        s.rf_writes = 10;
        s.rf_cache_writes = 3;
        assert!((s.cache_write_fraction() - 0.3).abs() < 1e-12);
    }
}
