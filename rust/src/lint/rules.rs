//! The six simlint rules.
//!
//! Each rule is a token-window matcher scoped by repo-relative path
//! (relative to `rust/src`, `/`-separated). Tokens inside `#[cfg(test)]`
//! items are exempt everywhere — the contracts govern shipped simulator
//! code, not its tests. The contract each rule encodes, with the fix
//! guidance, is catalogued in `docs/LINTS.md`.

use std::collections::BTreeSet;

use super::lexer::{Kind, LexedFile, Tok};
use super::Finding;

/// `scheme-dispatch`: sub-core and collector decide nothing by scheme.
pub const SCHEME_DISPATCH: &str = "scheme-dispatch";
/// `hot-path-alloc`: no heap allocation in `hot`-marked functions.
pub const HOT_PATH_ALLOC: &str = "hot-path-alloc";
/// `unordered-iteration`: no HashMap/HashSet iteration where order can
/// leak into fingerprints or on-disk bytes.
pub const UNORDERED_ITERATION: &str = "unordered-iteration";
/// `rng-discipline`: RNG draws only at policy decision points or in the
/// allowlisted workload generators.
pub const RNG_DISCIPLINE: &str = "rng-discipline";
/// `wallclock`: no wall-clock or process-environment reads in the
/// deterministic core.
pub const WALLCLOCK: &str = "wallclock";
/// `serve-panic`: the daemon degrades, it never dies.
pub const SERVE_PANIC: &str = "serve-panic";

/// Run every rule over one lexed file, appending findings.
pub fn check_file(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    scheme_dispatch(rel, lexed, out);
    hot_path_alloc(rel, lexed, out);
    unordered_iteration(rel, lexed, out);
    rng_discipline(rel, lexed, out);
    wallclock(rel, lexed, out);
    serve_panic(rel, lexed, out);
}

fn finding(rule: &str, rel: &str, line: u32, message: String) -> Finding {
    Finding { rule: rule.to_string(), file: rel.to_string(), line, message, allowed: None }
}

/// Live (non-test) token at `i`, if any.
fn live(toks: &[Tok], i: usize) -> Option<&Tok> {
    toks.get(i).filter(|t| !t.in_test)
}

/// `toks[i..]` starts the path `first::second` (identifier-exact).
fn is_path2(toks: &[Tok], i: usize, first: &str, second: &str) -> bool {
    toks[i].is_ident(first)
        && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
        && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        && toks.get(i + 3).is_some_and(|t| t.is_ident(second))
}

/// `toks[i..]` is the method call `.name(` (identifier-exact).
fn is_method_call(toks: &[Tok], i: usize, names: &[&str]) -> Option<&'static str> {
    if !toks[i].is_punct('.') {
        return None;
    }
    let m = toks.get(i + 1)?;
    if m.kind != Kind::Ident || !toks.get(i + 2).is_some_and(|t| t.is_punct('(')) {
        return None;
    }
    names.iter().find(|&&n| m.text == n).copied()
}

// --------------------------- scheme-dispatch --------------------------------

/// The PR 4 registry contract: every scheme-varying decision lives in
/// `sim/policy/`. A `Scheme::` reference or a `match` on a scheme field
/// in the sub-core/collector hot paths means a decision leaked out.
fn scheme_dispatch(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    if rel != "sim/subcore.rs" && rel != "sim/collector.rs" {
        return;
    }
    let toks = &lexed.toks;
    for i in 0..toks.len() {
        let Some(t) = live(toks, i) else { continue };
        if t.is_ident("Scheme")
            && toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        {
            out.push(finding(
                SCHEME_DISPATCH,
                rel,
                t.line,
                "`Scheme::` reference outside the policy layer".to_string(),
            ));
        }
        if t.is_ident("match") {
            // scan the scrutinee (everything before the arm block)
            for j in i + 1..(i + 40).min(toks.len()) {
                if toks[j].is_punct('{') {
                    break;
                }
                if toks[j].is_ident("scheme") {
                    out.push(finding(
                        SCHEME_DISPATCH,
                        rel,
                        t.line,
                        "match on a scheme field — dispatch belongs in sim/policy".to_string(),
                    ));
                    break;
                }
            }
        }
    }
}

// --------------------------- hot-path-alloc ---------------------------------

const ALLOC_TYPES: &[&str] =
    &["Vec", "VecDeque", "Box", "String", "HashMap", "HashSet", "BTreeMap", "BTreeSet"];
const ALLOC_CTORS: &[&str] = &["new", "with_capacity"];
const ALLOC_METHODS: &[&str] = &["collect", "to_vec", "to_string", "to_owned"];
const ALLOC_MACROS: &[&str] = &["vec", "format"];

/// The PR 5 steady-state contract: functions marked `hot` run every
/// cycle and must not touch the heap — capacity is pre-allocated in
/// constructors and reused via caller-owned scratch buffers.
fn hot_path_alloc(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    let toks = &lexed.toks;
    for f in lexed.fns.iter().filter(|f| f.hot) {
        for i in f.body.clone() {
            let Some(t) = live(toks, i) else { continue };
            if let Some(m) = is_method_call(toks, i, ALLOC_METHODS) {
                out.push(finding(
                    HOT_PATH_ALLOC,
                    rel,
                    t.line,
                    format!("`.{m}()` allocates inside hot fn `{}`", f.name),
                ));
            }
            if t.kind == Kind::Ident
                && ALLOC_TYPES.contains(&t.text.as_str())
                && toks.get(i + 1).is_some_and(|x| x.is_punct(':'))
                && toks.get(i + 2).is_some_and(|x| x.is_punct(':'))
                && toks
                    .get(i + 3)
                    .is_some_and(|x| x.kind == Kind::Ident && ALLOC_CTORS.contains(&x.text.as_str()))
            {
                let ctor = toks[i + 3].text.as_str();
                let msg = format!("`{}::{ctor}` allocates inside hot fn `{}`", t.text, f.name);
                out.push(finding(HOT_PATH_ALLOC, rel, t.line, msg));
            }
            if t.kind == Kind::Ident
                && ALLOC_MACROS.contains(&t.text.as_str())
                && toks.get(i + 1).is_some_and(|x| x.is_punct('!'))
            {
                out.push(finding(
                    HOT_PATH_ALLOC,
                    rel,
                    t.line,
                    format!("`{}!` allocates inside hot fn `{}`", t.text, f.name),
                ));
            }
        }
    }
}

// ------------------------- unordered-iteration ------------------------------

const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "into_iter", "keys", "values", "values_mut", "drain", "retain"];

/// Iteration order over `HashMap`/`HashSet` is randomized per process;
/// in `sim/`, `harness/`, and the store's on-disk path it can leak into
/// fingerprints or bytes. Names are collected from `name: HashMap<..>`
/// annotations (fields, params, struct literals) and `= HashMap::new()`
/// initializers within the same file — a deliberate, documented
/// heuristic (docs/LINTS.md).
fn unordered_iteration(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    if !(rel.starts_with("sim/") || rel.starts_with("harness/") || rel == "serve/store.rs") {
        return;
    }
    let toks = &lexed.toks;
    let mut names: BTreeSet<&str> = BTreeSet::new();
    for i in 0..toks.len() {
        if toks[i].kind != Kind::Ident {
            continue;
        }
        // `name: [&][mut] [std::collections::]Hash{Map,Set}`
        if toks.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && !toks.get(i + 2).is_some_and(|t| t.is_punct(':'))
        {
            let mut j = i + 2;
            let mut hops = 0;
            while let Some(t) = toks.get(j) {
                if hops > 8 {
                    break;
                }
                if t.is_ident("HashMap") || t.is_ident("HashSet") {
                    names.insert(toks[i].text.as_str());
                    break;
                }
                let skip = t.is_punct('&')
                    || t.is_punct(':')
                    || t.kind == Kind::Lifetime
                    || t.is_ident("mut")
                    || t.is_ident("std")
                    || t.is_ident("collections");
                if !skip {
                    break;
                }
                j += 1;
                hops += 1;
            }
        }
        // `name = Hash{Map,Set}::...` (untyped let bindings)
        if toks.get(i + 1).is_some_and(|t| t.is_punct('='))
            && toks
                .get(i + 2)
                .is_some_and(|t| t.is_ident("HashMap") || t.is_ident("HashSet"))
        {
            names.insert(toks[i].text.as_str());
        }
    }
    if names.is_empty() {
        return;
    }
    for i in 0..toks.len() {
        let Some(t) = live(toks, i) else { continue };
        // `name.iter()` and friends
        if t.kind == Kind::Ident && names.contains(t.text.as_str()) {
            if let Some(m) = is_method_call(toks, i + 1, ITER_METHODS) {
                out.push(finding(
                    UNORDERED_ITERATION,
                    rel,
                    t.line,
                    format!(
                        "`.{m}()` iterates unordered container `{}` — use BTreeMap/BTreeSet \
                         or a sorted drain",
                        t.text
                    ),
                ));
            }
        }
        // `for x in [&][mut] name`
        if t.is_ident("in") {
            let mut j = i + 1;
            while toks.get(j).is_some_and(|x| x.is_punct('&') || x.is_ident("mut")) {
                j += 1;
            }
            if let Some(x) = toks.get(j) {
                if x.kind == Kind::Ident
                    && names.contains(x.text.as_str())
                    && toks.get(j + 1).is_some_and(|n| n.is_punct('{') || n.is_punct('.'))
                {
                    // `in map {` (whole-map loop) or `in map.xxx` handled
                    // above; only flag the brace form here to avoid
                    // double-reporting
                    if toks[j + 1].is_punct('{') {
                        out.push(finding(
                            UNORDERED_ITERATION,
                            rel,
                            t.line,
                            format!("for-loop over unordered container `{}`", x.text),
                        ));
                    }
                }
            }
        }
    }
}

// --------------------------- rng-discipline ---------------------------------

/// Draw methods whose names are unique to `util::rng::Rng` in this tree.
const DRAWS: &[&str] = &["next_u64", "next_u32", "below", "chance", "pick", "shuffle", "geometric"];
/// Draw methods with common names: flagged only on an rng-ish receiver.
const DRAWS_AMBIGUOUS: &[&str] = &["range", "f64"];

/// RNG-draw-order preservation (the PR 4 parity contract): every policy
/// must see the same draw sequence, so draw sites live in `sim/policy/`
/// decision points or the allowlisted seeded workload generators.
fn rng_discipline(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    let allowlisted = rel.starts_with("sim/policy/")
        || rel == "util/rng.rs"
        || rel == "trace/program.rs"
        || rel == "trace/workloads.rs"
        || rel == "trace/corpus.rs";
    if allowlisted {
        return;
    }
    let toks = &lexed.toks;
    for i in 0..toks.len() {
        let Some(t) = live(toks, i) else { continue };
        if let Some(m) = is_method_call(toks, i, DRAWS) {
            out.push(finding(
                RNG_DISCIPLINE,
                rel,
                t.line,
                format!("RNG draw `.{m}()` outside sim/policy/ and the generator allowlist"),
            ));
        } else if let Some(m) = is_method_call(toks, i, DRAWS_AMBIGUOUS) {
            // `.range(`/`.f64(` collide with std names; require an
            // rng-named receiver to fire
            let rng_receiver =
                i > 0 && toks[i - 1].kind == Kind::Ident && toks[i - 1].text.contains("rng");
            if rng_receiver {
                out.push(finding(
                    RNG_DISCIPLINE,
                    rel,
                    t.line,
                    format!("RNG draw `.{m}()` outside sim/policy/ and the generator allowlist"),
                ));
            }
        }
    }
}

// ------------------------------ wallclock -----------------------------------

const ENV_READS: &[&str] = &["var", "vars", "var_os", "args", "temp_dir", "current_dir"];

/// A simulation is a pure function of `(GpuConfig, workload, seed)`:
/// wall-clock and process-environment reads in the deterministic core
/// would make results machine- or invocation-dependent. The CLI shell
/// (`main.rs`, `cli.rs`), the daemon (`serve/`), the artifact loader
/// (`runtime/`), and this linter are exempt by path.
fn wallclock(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    let exempt = rel == "main.rs"
        || rel == "cli.rs"
        || rel.starts_with("serve/")
        || rel.starts_with("runtime/")
        || rel.starts_with("lint/");
    if exempt {
        return;
    }
    let toks = &lexed.toks;
    for i in 0..toks.len() {
        let Some(t) = live(toks, i) else { continue };
        if is_path2(toks, i, "Instant", "now") || is_path2(toks, i, "SystemTime", "now") {
            out.push(finding(
                WALLCLOCK,
                rel,
                t.line,
                format!("`{}::now()` in the deterministic core", t.text),
            ));
        }
        if is_path2(toks, i, "std", "env") {
            out.push(finding(
                WALLCLOCK,
                rel,
                t.line,
                "`std::env` read in the deterministic core".to_string(),
            ));
        } else if t.is_ident("env")
            && toks.get(i + 1).is_some_and(|x| x.is_punct(':'))
            && toks.get(i + 2).is_some_and(|x| x.is_punct(':'))
            && toks
                .get(i + 3)
                .is_some_and(|x| x.kind == Kind::Ident && ENV_READS.contains(&x.text.as_str()))
        {
            out.push(finding(
                WALLCLOCK,
                rel,
                t.line,
                format!("`env::{}` read in the deterministic core", toks[i + 3].text),
            ));
        }
    }
}

// ------------------------------ serve-panic ---------------------------------

const PANIC_MACROS: &[&str] =
    &["panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne"];

/// The serving contract: a hostile or malformed request produces a
/// protocol-level `ERR` reply or a logged connection drop — never a
/// daemon death. `unwrap`/`expect`/panicking macros/slice-indexing in
/// `serve/` request handling are all one bad input away from an abort.
fn serve_panic(rel: &str, lexed: &LexedFile, out: &mut Vec<Finding>) {
    if !rel.starts_with("serve/") {
        return;
    }
    let toks = &lexed.toks;
    for i in 0..toks.len() {
        let Some(t) = live(toks, i) else { continue };
        if let Some(m) = is_method_call(toks, i, &["unwrap", "expect"]) {
            out.push(finding(
                SERVE_PANIC,
                rel,
                t.line,
                format!("`.{m}()` can panic the daemon — return a protocol error instead"),
            ));
        }
        if t.kind == Kind::Ident
            && PANIC_MACROS.contains(&t.text.as_str())
            && toks.get(i + 1).is_some_and(|x| x.is_punct('!'))
        {
            out.push(finding(
                SERVE_PANIC,
                rel,
                t.line,
                format!("`{}!` in request handling — the daemon must degrade, not die", t.text),
            ));
        }
        // index/slice expressions: `expr[...]` panics on out-of-bounds.
        // An expression position is a `[` directly after an ident, `)`,
        // or `]` (attributes `#[...]` and type/array syntax never are).
        if t.is_punct('[') && i > 0 {
            // a `[` after a keyword opens an array literal or slice
            // pattern, not an index expression
            const KEYWORDS: &[&str] =
                &["let", "mut", "in", "return", "if", "else", "match", "ref", "box"];
            let p = &toks[i - 1];
            let indexes = (p.kind == Kind::Ident && !KEYWORDS.contains(&p.text.as_str()))
                || p.is_punct(')')
                || p.is_punct(']');
            if indexes {
                out.push(finding(
                    SERVE_PANIC,
                    rel,
                    t.line,
                    "slice/array index can panic — use `.get()` and handle the miss".to_string(),
                ));
            }
        }
    }
}
