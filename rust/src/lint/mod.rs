//! simlint: the determinism & hot-path contract checker.
//!
//! The repo's correctness story rests on contracts that runtime tests
//! can only check after the fact — bit-identical fingerprints at any
//! thread count, RNG-draw-order preservation across policies, the PR 5
//! allocation-free hot path, zero scheme dispatch in the sub-core. This
//! pass checks them *statically*, at review time: a small comment- and
//! string-aware tokenizer ([`lexer`]) feeds six token-window rules
//! ([`rules`]) scoped by path. `malekeh lint` runs it over `rust/src`;
//! `rust/tests/simlint_self.rs` pins every rule with firing and
//! non-firing fixtures. The full rule catalog lives in `docs/LINTS.md`.
//!
//! # Directives
//!
//! Plain `//` comments (doc comments are inert):
//!
//! - `simlint: hot` — the next `fn` item is on the per-cycle hot path
//!   and must not allocate.
//! - `simlint: allow(<rule>) reason="<why>"` — suppress `<rule>` on the
//!   same line or the next one. The reason is mandatory, an allow that
//!   suppresses nothing is itself reported, and every suppression is
//!   counted against the committed baseline
//!   (`rust/tests/golden/simlint_baseline.json`) so the total can only
//!   ratchet down.

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::path::Path;

use lexer::Directive;

/// Rule registry: `(name, one-line contract)`. The names are the only
/// valid arguments to `allow(...)`.
pub const RULES: &[(&str, &str)] = &[
    (rules::SCHEME_DISPATCH, "no Scheme:: or scheme matching in the sim hot path"),
    (rules::HOT_PATH_ALLOC, "no heap allocation inside `simlint: hot` functions"),
    (rules::UNORDERED_ITERATION, "no HashMap/HashSet iteration in sim/, harness/, serve/store.rs"),
    (rules::RNG_DISCIPLINE, "RNG draws only in sim/policy/ or the generator allowlist"),
    (rules::WALLCLOCK, "no Instant/SystemTime/std::env in the deterministic core"),
    (rules::SERVE_PANIC, "no unwrap/expect/panic!/indexing in serve/ request handling"),
];

/// Pseudo-rule for malformed/unused directives. Not suppressible — a
/// broken suppression must never silence itself.
pub const DIRECTIVE_RULE: &str = "directive";

/// One finding: a rule firing at a source line, possibly suppressed.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule name (or [`DIRECTIVE_RULE`]).
    pub rule: String,
    /// Path relative to the linted root, `/`-separated.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// What fired and why it matters.
    pub message: String,
    /// `Some(reason)` when an `allow` directive suppressed it.
    pub allowed: Option<String>,
}

impl Finding {
    /// Suppressed by a justified allow?
    pub fn is_allowed(&self) -> bool {
        self.allowed.is_some()
    }
}

/// Every finding from one run, in (file, line, rule) order.
#[derive(Debug, Default)]
pub struct Report {
    /// All findings, suppressed ones included.
    pub findings: Vec<Finding>,
}

impl Report {
    /// Findings no allow covers — these fail the run.
    pub fn unsuppressed(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| !f.is_allowed()).collect()
    }

    /// Suppression count per rule (every rule present, zeros included),
    /// the quantity the committed baseline ratchets.
    pub fn allow_counts(&self) -> BTreeMap<String, u64> {
        let mut counts: BTreeMap<String, u64> =
            RULES.iter().map(|(r, _)| (r.to_string(), 0)).collect();
        for f in self.findings.iter().filter(|f| f.is_allowed()) {
            *counts.entry(f.rule.clone()).or_insert(0) += 1;
        }
        counts
    }

    /// Human-readable listing plus a one-line summary.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message));
            if let Some(reason) = &f.allowed {
                out.push_str(&format!(" (allowed: {reason})"));
            }
            out.push('\n');
        }
        let allows: Vec<String> = self
            .allow_counts()
            .iter()
            .filter(|(_, &n)| n > 0)
            .map(|(r, n)| format!("{r}={n}"))
            .collect();
        out.push_str(&format!(
            "simlint: {} finding(s), {} unsuppressed, allows: {}\n",
            self.findings.len(),
            self.unsuppressed().len(),
            if allows.is_empty() { "none".to_string() } else { allows.join(" ") }
        ));
        out
    }

    /// Machine-readable report (the CI artifact).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"version\": 1,\n");
        out.push_str(&format!("  \"total\": {},\n", self.findings.len()));
        out.push_str(&format!("  \"unsuppressed\": {},\n", self.unsuppressed().len()));
        out.push_str("  \"allows\": {");
        let counts = self.allow_counts();
        let body: Vec<String> =
            counts.iter().map(|(r, n)| format!("\"{}\": {n}", json_escape(r))).collect();
        out.push_str(&body.join(", "));
        out.push_str("},\n");
        out.push_str("  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"allowed\": {}, \
                 \"message\": \"{}\"{}}}{}\n",
                json_escape(&f.rule),
                json_escape(&f.file),
                f.line,
                f.is_allowed(),
                json_escape(&f.message),
                match &f.allowed {
                    Some(r) => format!(", \"reason\": \"{}\"", json_escape(r)),
                    None => String::new(),
                },
                if i + 1 < self.findings.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]\n}");
        out
    }
}

/// Minimal JSON string escaping (the only JSON writer dependency-free
/// crates get).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Lint one file's source. `rel` is its path relative to the linted
/// root (`/`-separated) — rule scoping keys off it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let mut findings = Vec::new();
    rules::check_file(rel, &lexed, &mut findings);

    // apply suppressions: a justified allow covers matching findings on
    // its own line or the next one
    let mut used = vec![false; lexed.directives.len()];
    for f in &mut findings {
        for (di, d) in lexed.directives.iter().enumerate() {
            if let Directive::Allow { line, rule, reason: Some(reason) } = d {
                if *rule == f.rule && (*line == f.line || *line + 1 == f.line) {
                    f.allowed = Some(reason.clone());
                    used[di] = true;
                    break;
                }
            }
        }
    }

    // directive hygiene: malformed, reasonless, unknown-rule, or unused
    // suppressions are findings themselves, and can't be suppressed
    for (di, d) in lexed.directives.iter().enumerate() {
        match d {
            Directive::Bad { line, what } => {
                findings.push(directive_finding(rel, *line, what.clone()));
            }
            Directive::Allow { line, rule, reason } => {
                if !RULES.iter().any(|(r, _)| rule.as_str() == *r) {
                    let msg = format!("allow({rule}) names no rule");
                    findings.push(directive_finding(rel, *line, msg));
                } else if reason.is_none() {
                    let msg = format!("allow({rule}) missing mandatory reason=\"...\"");
                    findings.push(directive_finding(rel, *line, msg));
                } else if !used[di] {
                    let msg = format!("unused allow({rule}) — nothing it covers fires here");
                    findings.push(directive_finding(rel, *line, msg));
                }
            }
            Directive::Hot { .. } => {}
        }
    }
    for line in &lexed.hot_dangling {
        findings.push(directive_finding(rel, *line, "hot marker attaches to no fn".to_string()));
    }

    findings.sort_by(|a, b| (a.line, &a.rule, &a.message).cmp(&(b.line, &b.rule, &b.message)));
    findings
}

fn directive_finding(rel: &str, line: u32, message: String) -> Finding {
    Finding {
        rule: DIRECTIVE_RULE.to_string(),
        file: rel.to_string(),
        line,
        message,
        allowed: None,
    }
}

/// Lint every `.rs` file under `src_root` (normally `rust/src`), in
/// sorted path order so reports are byte-stable.
pub fn run_tree(src_root: &Path) -> Result<Report, String> {
    let mut files = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        let path = src_root.join(rel);
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        findings.extend(lint_source(rel, &src));
    }
    Ok(Report { findings })
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| format!("{}: {e}", path.display()))?
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

// ------------------------------- baseline -----------------------------------

/// The committed suppression budget
/// (`rust/tests/golden/simlint_baseline.json`). CI compares every run
/// against it: new findings or new allows fail; a cleaner tree fails
/// too, with instructions to re-bless smaller — the ratchet only goes
/// down.
pub mod baseline {
    use std::collections::BTreeMap;

    use super::{json_escape, Report, RULES};

    /// Parsed baseline.
    #[derive(Debug, Default, PartialEq, Eq)]
    pub struct Baseline {
        /// Unsuppressed findings the baseline tolerates (always 0 —
        /// bless refuses anything else; kept explicit in the file so a
        /// hand edit that raises it is visible in review).
        pub unsuppressed: u64,
        /// Allow count per rule.
        pub allows: BTreeMap<String, u64>,
    }

    /// Render the baseline a report would bless.
    pub fn render(report: &Report) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"version\": 1,\n");
        out.push_str(&format!("  \"unsuppressed\": {},\n", report.unsuppressed().len()));
        out.push_str("  \"allows\": {\n");
        let counts = report.allow_counts();
        let body: Vec<String> = counts
            .iter()
            .map(|(r, n)| format!("    \"{}\": {n}", json_escape(r)))
            .collect();
        out.push_str(&body.join(",\n"));
        out.push_str("\n  }\n}\n");
        out
    }

    /// Parse a baseline file. Tolerant scanner for the fixed shape
    /// [`render`] emits (std has no JSON parser and the crate stays
    /// dependency-free).
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let unsuppressed = field_u64(text, "unsuppressed")
            .ok_or_else(|| "baseline: missing \"unsuppressed\"".to_string())?;
        let mut allows = BTreeMap::new();
        let allows_at = text
            .find("\"allows\"")
            .ok_or_else(|| "baseline: missing \"allows\"".to_string())?;
        let open = text[allows_at..]
            .find('{')
            .ok_or_else(|| "baseline: allows is not an object".to_string())?;
        let body_start = allows_at + open + 1;
        let close = text[body_start..]
            .find('}')
            .ok_or_else(|| "baseline: unclosed allows object".to_string())?;
        for pair in text[body_start..body_start + close].split(',') {
            let pair = pair.trim();
            if pair.is_empty() {
                continue;
            }
            let (key, value) = pair
                .split_once(':')
                .ok_or_else(|| format!("baseline: bad allows entry {pair:?}"))?;
            let key = key.trim().trim_matches('"').to_string();
            let value: u64 = value
                .trim()
                .parse()
                .map_err(|_| format!("baseline: bad count in {pair:?}"))?;
            allows.insert(key, value);
        }
        Ok(Baseline { unsuppressed, allows })
    }

    /// `"name": <u64>` scan for top-level scalar fields.
    fn field_u64(text: &str, name: &str) -> Option<u64> {
        let at = text.find(&format!("\"{name}\""))?;
        let rest = &text[at..];
        let colon = rest.find(':')?;
        let digits: String = rest[colon + 1..]
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        digits.parse().ok()
    }

    /// Enforce the ratchet. Any unsuppressed finding fails; per-rule
    /// allow counts must match the baseline exactly — higher means new
    /// suppressions slipped in, lower means the tree got cleaner and
    /// the baseline must be re-blessed smaller.
    pub fn check(report: &Report, base: &Baseline) -> Result<(), String> {
        let bad = report.unsuppressed();
        if !bad.is_empty() {
            let mut msg = format!("{} unsuppressed finding(s):\n", bad.len());
            for f in bad.iter().take(20) {
                msg.push_str(&format!("  {}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
            }
            if bad.len() > 20 {
                msg.push_str(&format!("  ... and {} more\n", bad.len() - 20));
            }
            return Err(msg);
        }
        let counts = report.allow_counts();
        for (rule, _) in RULES {
            let got = counts.get(*rule).copied().unwrap_or(0);
            let want = base.allows.get(*rule).copied().unwrap_or(0);
            if got > want {
                return Err(format!(
                    "rule {rule}: {got} allow(s) vs baseline {want} — a new suppression \
                     needs review; fix the finding or re-bless deliberately"
                ));
            }
            if got < want {
                return Err(format!(
                    "rule {rule}: {got} allow(s) vs baseline {want} — the tree got cleaner; \
                     ratchet down with `malekeh lint --baseline <file> --bless`"
                ));
            }
        }
        Ok(())
    }
}
