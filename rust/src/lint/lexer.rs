//! Comment- and string-aware Rust tokenizer for the simlint pass.
//!
//! Deliberately tiny and std-only: just enough lexical structure for
//! identifier-exact pattern matching (`unwrap_or_else` never matches
//! `unwrap`), directive extraction from plain `//` comments, the
//! `#[cfg(test)]` region exemption, and fn-item segmentation with brace
//! tracking. This is not a parser — the rules in [`super::rules`] match
//! short token windows, and anything inside string/char literals or
//! comments is invisible to them by construction.

/// Lexical class of one token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`fn`, `match`, `unwrap`, ...).
    Ident,
    /// Numeric literal (value not kept — rules never need it).
    Num,
    /// String/byte-string literal, raw or not (contents dropped).
    Str,
    /// Character or byte literal.
    Char,
    /// Lifetime (`'a`) — kept distinct so it never looks like a char.
    Lifetime,
    /// Any single punctuation byte; multi-byte operators such as `::`
    /// appear as consecutive tokens.
    Punct,
}

/// One token with its source line.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Lexical class.
    pub kind: Kind,
    /// Identifier text, or the single punctuation character; literals
    /// keep an empty string.
    pub text: String,
    /// 1-based source line.
    pub line: u32,
    /// Inside a `#[cfg(test)]` item — exempt from every rule.
    pub in_test: bool,
}

impl Tok {
    /// Exact identifier match.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == Kind::Ident && self.text == s
    }

    /// Exact punctuation match.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == Kind::Punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }
}

/// One `// simlint: ...` comment. Doc comments (`///`, `//!`) are never
/// parsed as directives, so grammar examples in rustdoc stay inert.
#[derive(Debug, Clone)]
pub enum Directive {
    /// `hot` — the next `fn` item is on the allocation-free hot path.
    Hot {
        /// Directive line.
        line: u32,
    },
    /// `allow(<rule>) reason="..."` — suppress findings of `rule` on
    /// this line or the next one. A missing reason is itself reported.
    Allow {
        /// Directive line.
        line: u32,
        /// Rule name inside the parentheses.
        rule: String,
        /// The mandatory justification, if present and non-empty.
        reason: Option<String>,
    },
    /// Anything else after `simlint:` — reported, never ignored.
    Bad {
        /// Directive line.
        line: u32,
        /// What was malformed about it.
        what: String,
    },
}

impl Directive {
    /// Source line of the directive.
    pub fn line(&self) -> u32 {
        match self {
            Directive::Hot { line }
            | Directive::Allow { line, .. }
            | Directive::Bad { line, .. } => *line,
        }
    }
}

/// One `fn` item: name, declaration line, and the token range of its
/// body (between, not including, the braces).
#[derive(Debug, Clone)]
pub struct FnSpan {
    /// Function name.
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Token-index range of the body.
    pub body: std::ops::Range<usize>,
    /// Declared hot via a `hot` directive directly above it.
    pub hot: bool,
}

/// A fully lexed source file.
#[derive(Debug)]
pub struct LexedFile {
    /// Token stream (comments/whitespace dropped, literals opaque).
    pub toks: Vec<Tok>,
    /// All simlint directives, in source order.
    pub directives: Vec<Directive>,
    /// All fn items, in source order (nested fns included).
    pub fns: Vec<FnSpan>,
    /// Lines of `hot` directives with no following `fn` to attach to.
    pub hot_dangling: Vec<u32>,
}

/// Lex a file: scan, mark `#[cfg(test)]` regions, segment fn items, and
/// attach `hot` markers to the first fn at or below each one.
pub fn lex(src: &str) -> LexedFile {
    let (mut toks, directives) = scan(src);
    mark_test_regions(&mut toks);
    let mut fns = segment_fns(&toks);
    let mut hot_dangling = Vec::new();
    for d in &directives {
        if let Directive::Hot { line } = d {
            match fns.iter_mut().find(|f| f.line >= *line) {
                Some(f) => f.hot = true,
                None => hot_dangling.push(*line),
            }
        }
    }
    LexedFile { toks, directives, fns, hot_dangling }
}

/// Character-level scan: tokens plus directive comments.
fn scan(src: &str) -> (Vec<Tok>, Vec<Directive>) {
    let b = src.as_bytes();
    let mut toks = Vec::new();
    let mut directives = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i + 2;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            if let Some(d) = parse_directive(&src[start..i], line) {
                directives.push(d);
            }
        } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
            // rust block comments nest
            let mut depth = 1u32;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if let Some(next) = raw_string_end(b, i, &mut line) {
            toks.push(Tok { kind: Kind::Str, text: String::new(), line, in_test: false });
            i = next;
        } else if c == b'"' || (c == b'b' && b.get(i + 1) == Some(&b'"')) {
            let at = line;
            i += if c == b'b' { 2 } else { 1 };
            while i < b.len() && b[i] != b'"' {
                if b[i] == b'\\' {
                    i += 1; // the escaped byte is consumed below
                }
                if i < b.len() {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            i += 1; // closing quote
            toks.push(Tok { kind: Kind::Str, text: String::new(), line: at, in_test: false });
        } else if c == b'\'' || (c == b'b' && b.get(i + 1) == Some(&b'\'')) {
            let start = if c == b'b' { i + 1 } else { i };
            let (kind, next) = char_or_lifetime(b, start);
            toks.push(Tok { kind, text: lifetime_text(b, start, next, kind), line, in_test: false });
            i = next;
        } else if c == b'_' || c.is_ascii_alphabetic() {
            let start = i;
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: src[start..i].to_string(),
                line,
                in_test: false,
            });
        } else if c.is_ascii_digit() {
            // greedy alphanumeric run covers hex and suffixes; a `.` is
            // only part of the number when a digit follows (so `0..n`
            // stays a range)
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
            }
            toks.push(Tok { kind: Kind::Num, text: String::new(), line, in_test: false });
        } else {
            toks.push(Tok {
                kind: Kind::Punct,
                text: (c as char).to_string(),
                line,
                in_test: false,
            });
            i += 1;
        }
    }
    (toks, directives)
}

/// If position `i` starts a raw (byte) string (`r"`, `r#..#"`, `br"`),
/// consume it and return the index just past it; `line` is advanced over
/// embedded newlines. Raw *identifiers* (`r#match`) are left alone.
fn raw_string_end(b: &[u8], i: usize, line: &mut u32) -> Option<usize> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) != Some(&b'"') {
        return None; // `r#ident` or plain ident starting with r/br
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
        } else if b[j] == b'"' && closes_raw(b, j + 1, hashes) {
            return Some(j + 1 + hashes);
        } else {
            j += 1;
        }
    }
    Some(j)
}

/// `hashes` consecutive `#` bytes at `at` (the raw-string terminator).
fn closes_raw(b: &[u8], at: usize, hashes: usize) -> bool {
    at + hashes <= b.len() && b[at..at + hashes].iter().all(|&h| h == b'#')
}

/// Disambiguate `'a` (lifetime) from `'x'` / `'\n'` (char literal),
/// starting at the `'`. Returns the kind and the index just past it.
fn char_or_lifetime(b: &[u8], i: usize) -> (Kind, usize) {
    match b.get(i + 1) {
        Some(&b'\\') => {
            // escaped char literal: scan to the closing quote
            let mut j = i + 2;
            while j < b.len() && b[j] != b'\'' {
                j += 1;
            }
            (Kind::Char, j + 1)
        }
        Some(&c) if c == b'_' || c.is_ascii_alphabetic() => {
            let mut j = i + 1;
            while j < b.len() && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                j += 1;
            }
            if b.get(j) == Some(&b'\'') {
                (Kind::Char, j + 1) // 'x'
            } else {
                (Kind::Lifetime, j) // 'a in a generic position
            }
        }
        Some(_) if b.get(i + 2) == Some(&b'\'') => (Kind::Char, i + 3), // '{' etc.
        _ => (Kind::Punct, i + 1), // stray quote; valid rust never gets here
    }
}

/// Lifetime tokens keep their name; other quote-introduced tokens don't
/// need text.
fn lifetime_text(b: &[u8], start: usize, end: usize, kind: Kind) -> String {
    if kind == Kind::Lifetime {
        String::from_utf8_lossy(&b[start + 1..end]).into_owned()
    } else {
        String::new()
    }
}

/// Parse one line comment body (text after `//`) as a directive.
fn parse_directive(comment: &str, line: u32) -> Option<Directive> {
    let t = comment.trim();
    // `///` and `//!` bodies start with '/' or '!' here: doc comments
    if t.starts_with('/') || t.starts_with('!') {
        return None;
    }
    let rest = t.strip_prefix("simlint:")?.trim();
    if rest == "hot" {
        return Some(Directive::Hot { line });
    }
    if let Some(r) = rest.strip_prefix("allow(") {
        let Some(close) = r.find(')') else {
            return Some(Directive::Bad { line, what: format!("unclosed allow( in {t:?}") });
        };
        let rule = r[..close].trim().to_string();
        let tail = r[close + 1..].trim();
        let reason = tail
            .strip_prefix("reason=\"")
            .and_then(|x| x.find('"').map(|q| x[..q].to_string()))
            .filter(|s| !s.is_empty());
        return Some(Directive::Allow { line, rule, reason });
    }
    Some(Directive::Bad { line, what: format!("unrecognised simlint directive {rest:?}") })
}

/// Mark every token belonging to a `#[cfg(test)]` item as test-only.
fn mark_test_regions(toks: &mut [Tok]) {
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let is_attr = toks[i].is_punct('#')
            && toks[i + 1].is_punct('[')
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is_punct('(')
            && toks[i + 4].is_ident("test")
            && toks[i + 5].is_punct(')')
            && toks[i + 6].is_punct(']');
        if !is_attr {
            i += 1;
            continue;
        }
        // the attribute governs the next item: up to its `;`, or the
        // matching close of its `{` body
        let mut j = i + 7;
        let mut depth = 0usize;
        while j < toks.len() {
            if toks[j].is_punct('{') {
                depth += 1;
            } else if toks[j].is_punct('}') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if toks[j].is_punct(';') && depth == 0 {
                break;
            }
            j += 1;
        }
        let end = j.min(toks.len() - 1);
        for t in &mut toks[i..=end] {
            t.in_test = true;
        }
        i = end + 1;
    }
}

/// Find every `fn` item and its brace-matched body range.
fn segment_fns(toks: &[Tok]) -> Vec<FnSpan> {
    let mut fns = Vec::new();
    for i in 0..toks.len() {
        if !toks[i].is_ident("fn") {
            continue;
        }
        // `fn` in a function-pointer type has no name ident after it
        let Some(name) = toks.get(i + 1).filter(|t| t.kind == Kind::Ident) else {
            continue;
        };
        // body = first `{` before any `;` (a `;` means a bodyless trait
        // method declaration)
        let mut j = i + 2;
        while j < toks.len() && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
            j += 1;
        }
        if j >= toks.len() || toks[j].is_punct(';') {
            continue;
        }
        let mut depth = 0usize;
        let mut k = j;
        while k < toks.len() {
            if toks[k].is_punct('{') {
                depth += 1;
            } else if toks[k].is_punct('}') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        fns.push(FnSpan {
            name: name.text.clone(),
            line: toks[i].line,
            body: j + 1..k.min(toks.len()),
            hot: false,
        });
    }
    fns
}
