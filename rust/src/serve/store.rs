//! Persistent on-disk content-addressed result store.
//!
//! One record file per simulated point, named by its [`StoreKey`]
//! (`<config_fp>-<workload_fp>-<policy>.rec`), in a flat directory
//! (default `.malekeh-store/`). The format is versioned, textual, and
//! self-verifying:
//!
//! ```text
//! MALEKEH-STORE/1
//! config_fp = 0123456789abcdef
//! workload_fp = fedcba9876543210
//! policy = malekeh
//! stats_fp = 00c0ffee00c0ffee
//! cycles = 40000
//! ...one line per Stats counter...
//! energy = 8 space-separated u64s (EVENT_NAMES order)
//! interval_ipc = f64-to_bits hex words
//! sthld_trace = u32s
//! END
//! ```
//!
//! Reads are **corruption-tolerant**: a missing file, truncated record
//! (no `END`), unparseable line, key mismatch (file renamed or moved
//! between stores), or a `stats_fp` that does not match the fingerprint
//! recomputed from the parsed counters all surface as a cache *miss* —
//! the caller re-simulates and overwrites. Writes go to a temp file in
//! the same directory and are published with an atomic rename, so
//! concurrent writers of the same key (shard workers, racing daemons)
//! each publish a complete record and the last rename wins — which is
//! harmless, because any two writers of one key computed bit-identical
//! stats (the determinism contract).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::GpuConfig;
use crate::energy::{EnergyCounts, NEVENTS};
use crate::stats::Stats;
use crate::trace::Workload;
use crate::util::Fnv1a;

/// First line of every record; bump the suffix on format changes —
/// readers treat any other first line as a miss, so old stores degrade
/// to cold caches instead of crashing new binaries (and vice versa).
pub const RECORD_VERSION: &str = "MALEKEH-STORE/1";

/// Default store directory (relative to the working directory).
pub const DEFAULT_STORE_DIR: &str = ".malekeh-store";

/// The scalar `Stats` counters a record carries, in record order. One
/// macro feeds both the serialiser and the parser, so the two can never
/// drift apart (a field added to `Stats` but not here still changes
/// `stats_fp`, which the round-trip test catches).
macro_rules! with_stats_scalars {
    ($m:ident!($($extra:tt)*)) => {
        $m!(($($extra)*)
            cycles, instructions, warps_retired, rf_reads, rf_bank_reads,
            rf_cache_reads, rf_writes, rf_cache_writes, cache_write_reused,
            bank_conflict_wait, sched_issued, sched_stall_ready,
            sched_stall_empty, waiting_stalls, collector_full_stalls,
            ccu_flushes, l1_accesses, l1_hits, l2_accesses, l2_hits)
    };
}

/// Content address of one simulated point:
/// `config fingerprint x workload fingerprint x policy name`.
///
/// The config half is [`GpuConfig::fingerprint`] (canonical
/// serialisation; `sim_threads` excluded) extended with the harness-level
/// `profile_warps` knob, which also shapes results (it bounds the
/// compiler's reuse profiling pass). The workload half is
/// [`Workload::content_fingerprint`] — generated or on-disk trace
/// *content*, never a file path or its byte encoding: a `.mtrace` and
/// its `trace convert`ed v2 twin decode to the same instructions and
/// therefore share one record. The policy name is carried redundantly
/// (it is already inside the config fingerprint via `scheme = <name>`)
/// to keep store filenames and `store info` listings human-readable.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StoreKey {
    /// FNV-1a over the canonical config serialisation + `profile_warps`.
    pub config_fp: u64,
    /// FNV-1a over the workload content.
    pub workload_fp: u64,
    /// Registry policy name (`GpuConfig::scheme`).
    pub policy: String,
}

impl StoreKey {
    /// Address of the simulation `run_workload(cfg, workload,
    /// profile_warps)` would perform. Errs when the workload content
    /// cannot be resolved (unknown benchmark, unreadable trace file).
    pub fn for_run(
        cfg: &GpuConfig,
        workload: &Workload,
        profile_warps: usize,
    ) -> Result<StoreKey, String> {
        let nwarps = cfg.num_sms * cfg.warps_per_sm;
        let workload_fp = workload.content_fingerprint(nwarps, cfg.seed)?;
        let mut h = Fnv1a::new();
        h.bytes(cfg.canonical_string().as_bytes());
        h.bytes(format!("profile_warps = {profile_warps}\n").as_bytes());
        Ok(StoreKey {
            config_fp: h.finish(),
            workload_fp,
            policy: cfg.scheme.name().to_string(),
        })
    }

    /// Record filename for this key. Policy names are sanitised to a
    /// conservative character set; a collision between two sanitised
    /// names cannot serve a wrong result because the record carries the
    /// full key and [`Store::get`] verifies it.
    pub fn file_name(&self) -> String {
        let safe: String = self
            .policy
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '-' { c } else { '_' })
            .collect();
        format!("{:016x}-{:016x}-{safe}.rec", self.config_fp, self.workload_fp)
    }
}

/// Aggregate store statistics (`malekeh store info`, server health).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StoreInfo {
    /// Record files present.
    pub records: usize,
    /// Total record bytes.
    pub bytes: u64,
}

/// What `Store::gc` did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GcReport {
    /// Records deleted (oldest first).
    pub deleted: usize,
    /// Bytes reclaimed.
    pub reclaimed: u64,
    /// Store size after collection.
    pub after: StoreInfo,
}

/// Handle to one store directory. Cheap to clone conceptually (it is just
/// the root path); all methods take `&self` and are safe to call from
/// many threads — the filesystem provides the synchronisation
/// (atomic-rename publishes, unlinked reads).
#[derive(Debug, Clone)]
pub struct Store {
    root: PathBuf,
}

/// Per-process tie-breaker for temp-file names: two threads of one
/// process writing the same key must not share a temp file.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

impl Store {
    /// Open (creating if needed) the store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<Store> {
        let root = dir.into();
        std::fs::create_dir_all(&root)?;
        Ok(Store { root })
    }

    /// Store directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Look up a key. `None` covers every kind of absence: no record,
    /// version mismatch, truncation, parse failure, key mismatch, or an
    /// integrity failure (recomputed stats fingerprint != recorded one).
    pub fn get(&self, key: &StoreKey) -> Option<Stats> {
        let text = std::fs::read_to_string(self.root.join(key.file_name())).ok()?;
        parse_record(&text, key).ok()
    }

    /// Persist `stats` under `key` (write-temp-then-rename; overwrites
    /// any existing record — safe, see the module docs on racing
    /// writers). Returns the record path.
    pub fn put(&self, key: &StoreKey, stats: &Stats) -> std::io::Result<PathBuf> {
        let final_path = self.root.join(key.file_name());
        let tmp_path = self.root.join(format!(
            ".tmp-{}-{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
            key.file_name()
        ));
        let mut f = std::fs::File::create(&tmp_path)?;
        f.write_all(format_record(key, stats).as_bytes())?;
        f.sync_all()?;
        drop(f);
        match std::fs::rename(&tmp_path, &final_path) {
            Ok(()) => Ok(final_path),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp_path);
                Err(e)
            }
        }
    }

    /// Record files with size and modification time, oldest first (ties
    /// broken by name so iteration order is deterministic). Temp files
    /// and foreign files are ignored.
    fn entries(&self) -> std::io::Result<Vec<(PathBuf, u64, std::time::SystemTime)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if !name.ends_with(".rec") || name.starts_with(".tmp-") {
                continue;
            }
            let meta = match entry.metadata() {
                Ok(m) if m.is_file() => m,
                _ => continue,
            };
            let mtime = meta.modified().unwrap_or(std::time::UNIX_EPOCH);
            out.push((entry.path(), meta.len(), mtime));
        }
        out.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        Ok(out)
    }

    /// Aggregate size.
    pub fn info(&self) -> std::io::Result<StoreInfo> {
        let entries = self.entries()?;
        Ok(StoreInfo {
            records: entries.len(),
            bytes: entries.iter().map(|e| e.1).sum(),
        })
    }

    /// Delete oldest records until total size fits `budget_bytes`.
    /// `budget_bytes = 0` empties the store.
    pub fn gc(&self, budget_bytes: u64) -> std::io::Result<GcReport> {
        let entries = self.entries()?;
        let mut total: u64 = entries.iter().map(|e| e.1).sum();
        let mut report = GcReport::default();
        for (path, size, _) in entries {
            if total <= budget_bytes {
                break;
            }
            std::fs::remove_file(&path)?;
            total -= size;
            report.deleted += 1;
            report.reclaimed += size;
        }
        report.after = self.info()?;
        Ok(report)
    }
}

/// Serialise one record (the format in the module docs).
fn format_record(key: &StoreKey, stats: &Stats) -> String {
    let mut out = String::with_capacity(1024);
    out.push_str(RECORD_VERSION);
    out.push('\n');
    out.push_str(&format!("config_fp = {:016x}\n", key.config_fp));
    out.push_str(&format!("workload_fp = {:016x}\n", key.workload_fp));
    out.push_str(&format!("policy = {}\n", key.policy));
    out.push_str(&format!("stats_fp = {:016x}\n", stats.fingerprint()));
    macro_rules! emit {
        (($out:ident, $stats:ident) $($f:ident),*) => {
            $( $out.push_str(&format!("{} = {}\n", stringify!($f), $stats.$f)); )*
        };
    }
    with_stats_scalars!(emit!(out, stats));
    let energy: Vec<String> =
        stats.energy.raw().iter().map(|v| v.to_string()).collect();
    out.push_str(&format!("energy = {}\n", energy.join(" ")));
    // f64 as to_bits hex: bit-exact, no decimal round-trip to trust
    let ipc: Vec<String> = stats
        .interval_ipc
        .iter()
        .map(|v| format!("{:016x}", v.to_bits()))
        .collect();
    out.push_str(&format!("interval_ipc = {}\n", ipc.join(" ")));
    let sthld: Vec<String> =
        stats.sthld_trace.iter().map(|v| v.to_string()).collect();
    out.push_str(&format!("sthld_trace = {}\n", sthld.join(" ")));
    out.push_str("END\n");
    out
}

/// Parse + verify one record against the key that addressed it. Any
/// error string means "treat as miss".
fn parse_record(text: &str, key: &StoreKey) -> Result<Stats, String> {
    let mut lines = text.lines();
    if lines.next() != Some(RECORD_VERSION) {
        return Err("bad or missing version line".into());
    }
    let mut fields: Vec<(&str, &str)> = Vec::with_capacity(32);
    let mut terminated = false;
    for line in lines {
        if line == "END" {
            terminated = true;
            break;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("bad record line {line:?}"))?;
        fields.push((k.trim(), v.trim()));
    }
    if !terminated {
        return Err("truncated record (no END)".into());
    }
    let take = |k: &str| -> Result<&str, String> {
        fields
            .iter()
            .find(|(fk, _)| *fk == k)
            .map(|(_, v)| *v)
            .ok_or_else(|| format!("missing field {k}"))
    };
    let hex64 = |k: &str| -> Result<u64, String> {
        u64::from_str_radix(take(k)?, 16).map_err(|e| format!("bad {k}: {e}"))
    };
    // the record must be the one this key addresses — a renamed/moved
    // file or a sanitised-name collision is a miss, not a wrong answer
    if hex64("config_fp")? != key.config_fp
        || hex64("workload_fp")? != key.workload_fp
        || take("policy")? != key.policy
    {
        return Err("record key mismatch".into());
    }
    let mut stats = Stats::new();
    macro_rules! absorb {
        (($stats:ident, $take:ident) $($f:ident),*) => {
            $( $stats.$f = $take(stringify!($f))?
                .parse::<u64>()
                .map_err(|e| format!("bad {}: {e}", stringify!($f)))?; )*
        };
    }
    with_stats_scalars!(absorb!(stats, take));
    let energy_row: Vec<u64> = take("energy")?
        .split_whitespace()
        .map(|t| t.parse::<u64>().map_err(|e| format!("bad energy: {e}")))
        .collect::<Result<_, _>>()?;
    let energy: [u64; NEVENTS] = energy_row
        .try_into()
        .map_err(|_| format!("energy row must have {NEVENTS} entries"))?;
    stats.energy = EnergyCounts::from_raw(energy);
    stats.interval_ipc = take("interval_ipc")?
        .split_whitespace()
        .map(|t| {
            u64::from_str_radix(t, 16)
                .map(f64::from_bits)
                .map_err(|e| format!("bad interval_ipc: {e}"))
        })
        .collect::<Result<_, _>>()?;
    stats.sthld_trace = take("sthld_trace")?
        .split_whitespace()
        .map(|t| t.parse::<u32>().map_err(|e| format!("bad sthld_trace: {e}")))
        .collect::<Result<_, _>>()?;
    // integrity: the record's fingerprint must match what the parsed
    // counters actually hash to — a flipped digit anywhere is a miss
    let recorded = hex64("stats_fp")?;
    let recomputed = stats.fingerprint();
    if recorded != recomputed {
        return Err(format!(
            "integrity failure: recorded {recorded:016x} != recomputed {recomputed:016x}"
        ));
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    fn tmp_store(name: &str) -> Store {
        let dir = std::env::temp_dir()
            .join(format!("malekeh_store_unit_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        Store::open(dir).unwrap()
    }

    fn sample_stats() -> Stats {
        let mut s = Stats::new();
        s.cycles = 40_000;
        s.instructions = 123_456;
        s.rf_reads = 999;
        s.rf_cache_reads = 400;
        s.energy.add(crate::energy::EventKind::BankRead, 77);
        s.energy.add(crate::energy::EventKind::LeakProxy, 40_000);
        s.interval_ipc = vec![1.5, 2.25, 0.125];
        s.sthld_trace = vec![0, 2, 4];
        s
    }

    fn sample_key() -> StoreKey {
        StoreKey {
            config_fp: 0x0123_4567_89ab_cdef,
            workload_fp: 0xfedc_ba98_7654_3210,
            policy: "malekeh".into(),
        }
    }

    #[test]
    fn record_roundtrips_bit_exactly() {
        let key = sample_key();
        let stats = sample_stats();
        let text = format_record(&key, &stats);
        let back = parse_record(&text, &key).unwrap();
        assert_eq!(back.fingerprint(), stats.fingerprint());
        assert_eq!(back.interval_ipc, stats.interval_ipc);
        assert_eq!(back.sthld_trace, stats.sthld_trace);
        assert_eq!(back.energy, stats.energy);
    }

    #[test]
    fn parse_rejects_version_truncation_and_key_mismatch() {
        let key = sample_key();
        let text = format_record(&key, &sample_stats());
        // wrong version line
        let wrong = text.replacen("MALEKEH-STORE/1", "MALEKEH-STORE/9", 1);
        assert!(parse_record(&wrong, &key).is_err());
        // truncation: drop END (and anything after it)
        let cut = &text[..text.len() - "END\n".len()];
        assert!(parse_record(cut, &key).unwrap_err().contains("truncated"));
        // key mismatch: same record addressed by a different key
        let mut other = key.clone();
        other.workload_fp ^= 1;
        assert!(parse_record(&text, &other).unwrap_err().contains("mismatch"));
    }

    #[test]
    fn parse_rejects_integrity_failures() {
        let key = sample_key();
        let text = format_record(&key, &sample_stats());
        // flip one counter digit: the recomputed fingerprint must not match
        let corrupted = text.replacen("instructions = 123456", "instructions = 123457", 1);
        assert_ne!(corrupted, text, "corruption edit must apply");
        let err = parse_record(&corrupted, &key).unwrap_err();
        assert!(err.contains("integrity"), "got: {err}");
    }

    #[test]
    fn store_get_put_and_miss_semantics() {
        let store = tmp_store("getput");
        let key = sample_key();
        assert!(store.get(&key).is_none(), "empty store is a miss");
        let stats = sample_stats();
        let path = store.put(&key, &stats).unwrap();
        assert!(path.ends_with(key.file_name()));
        let back = store.get(&key).unwrap();
        assert_eq!(back.fingerprint(), stats.fingerprint());
        // corrupt on disk -> miss, not a crash
        std::fs::write(&path, "MALEKEH-STORE/1\ngarbage\n").unwrap();
        assert!(store.get(&key).is_none());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn store_key_for_run_tracks_inputs() {
        let cfg = GpuConfig::golden_parity(Scheme::MALEKEH);
        let w = Workload::builtin("kmeans");
        let k1 = StoreKey::for_run(&cfg, &w, 2).unwrap();
        let k2 = StoreKey::for_run(&cfg, &w, 2).unwrap();
        assert_eq!(k1, k2, "pure function of the run inputs");
        assert_eq!(k1.policy, "malekeh");

        // profile_warps shapes the compiler pass -> must split the address
        let k3 = StoreKey::for_run(&cfg, &w, 3).unwrap();
        assert_ne!(k1.config_fp, k3.config_fp);
        assert_eq!(k1.workload_fp, k3.workload_fp);

        // sim_threads is wall-clock only -> must NOT split the address
        let mut threaded = cfg.clone();
        threaded.sim_threads = 4;
        assert_eq!(StoreKey::for_run(&threaded, &w, 2).unwrap(), k1);

        // the workload half tracks content: another benchmark differs
        let k4 = StoreKey::for_run(&cfg, &Workload::builtin("hotspot"), 2).unwrap();
        assert_ne!(k1.workload_fp, k4.workload_fp);

        // and a behaviour knob splits the config half
        let mut capped = cfg.clone();
        capped.max_cycles = 1_000;
        assert_ne!(StoreKey::for_run(&capped, &w, 2).unwrap().config_fp, k1.config_fp);
    }

    #[test]
    fn file_names_are_sanitised_but_keys_stay_exact() {
        let key = StoreKey {
            config_fp: 1,
            workload_fp: 2,
            policy: "weird/policy name".into(),
        };
        let name = key.file_name();
        assert!(!name.contains('/') && !name.contains(' '), "{name}");
        // a sanitised-name collision still cannot serve a wrong result:
        // the record carries the exact policy string and get() verifies it
        let store = tmp_store("sanitise");
        store.put(&key, &sample_stats()).unwrap();
        let imposter = StoreKey { policy: "weird_policy_name".into(), ..key.clone() };
        assert_eq!(imposter.file_name(), key.file_name());
        assert!(store.get(&imposter).is_none(), "exact-policy check must gate");
        assert!(store.get(&key).is_some());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn info_and_gc_honour_the_budget() {
        let store = tmp_store("gc");
        let stats = sample_stats();
        let mut keys = Vec::new();
        for i in 0..4u64 {
            let key = StoreKey { config_fp: i, workload_fp: i, policy: "baseline".into() };
            store.put(&key, &stats).unwrap();
            keys.push(key);
        }
        let info = store.info().unwrap();
        assert_eq!(info.records, 4);
        assert!(info.bytes > 0);
        let per_record = info.bytes / 4;
        // budget for ~2 records: the oldest must go first
        let report = store.gc(per_record * 2).unwrap();
        assert!(report.deleted >= 2, "deleted {}", report.deleted);
        assert_eq!(report.after.records, 4 - report.deleted);
        assert!(report.after.bytes <= per_record * 2);
        // budget 0 empties the store
        let report = store.gc(0).unwrap();
        assert_eq!(report.after.records, 0);
        assert!(store.get(&keys[0]).is_none());
        let _ = std::fs::remove_dir_all(store.root());
    }
}
