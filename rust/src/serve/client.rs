//! Client side of the serve protocol (`malekeh submit` / `serve-ctl`).
//!
//! One [`Client`] = one TCP connection. Every method is a synchronous
//! request/response round-trip; [`Client::wait`] blocks server-side (the
//! daemon parks the connection handler until the job settles), so a
//! submit-and-wait needs no client polling loop.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use super::protocol::{JobSpec, JobState, Request, Response, PROTOCOL_VERSION};

/// A connected protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a daemon and verify its greeting speaks our protocol
    /// version.
    pub fn connect(addr: &str) -> Result<Client, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let writer = stream.try_clone().map_err(|e| format!("connect {addr}: {e}"))?;
        let mut client = Client { reader: BufReader::new(stream), writer };
        let greeting = client.read_line()?;
        match greeting.split_ascii_whitespace().next() {
            Some(v) if v == PROTOCOL_VERSION => Ok(client),
            _ => Err(format!(
                "{addr} is not a {PROTOCOL_VERSION} server (greeting {greeting:?})"
            )),
        }
    }

    fn read_line(&mut self) -> Result<String, String> {
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .map_err(|e| format!("read: {e}"))?;
        if n == 0 {
            return Err("server closed the connection".to_string());
        }
        Ok(line.trim_end_matches(['\r', '\n']).to_string())
    }

    /// One request/response round-trip; `ERR` responses surface as `Err`.
    fn call(&mut self, req: &Request) -> Result<String, String> {
        self.writer
            .write_all(format!("{}\n", req.encode()).as_bytes())
            .and_then(|()| self.writer.flush())
            .map_err(|e| format!("write: {e}"))?;
        match Response::parse(&self.read_line()?)? {
            Response::Ok(payload) => Ok(payload),
            Response::Err(msg) => Err(msg),
        }
    }

    /// Strip the expected payload tag (`job`, `result <id>`, ...).
    fn expect_tag<'a>(payload: &'a str, tag: &str) -> Result<&'a str, String> {
        payload
            .strip_prefix(tag)
            .map(str::trim_start)
            .ok_or_else(|| format!("unexpected payload {payload:?} (want {tag} ...)"))
    }

    /// PING; returns the pong payload (carries the server's version).
    pub fn ping(&mut self) -> Result<String, String> {
        self.call(&Request::Ping)
    }

    /// SUBMIT; returns the job id and its state at submission time
    /// (`done` means a dedupe or store hit served it instantly).
    pub fn submit(&mut self, spec: &JobSpec) -> Result<(u64, JobState), String> {
        let payload = self.call(&Request::Submit(spec.clone()))?;
        Response::parse_job_payload(&payload)
    }

    /// STATUS; non-blocking state query.
    pub fn status(&mut self, id: u64) -> Result<JobState, String> {
        let payload = self.call(&Request::Status(id))?;
        Ok(Response::parse_job_payload(&payload)?.1)
    }

    /// WAIT; blocks until the job settles, returns `done` or `failed`.
    pub fn wait(&mut self, id: u64) -> Result<JobState, String> {
        let payload = self.call(&Request::Wait(id))?;
        Ok(Response::parse_job_payload(&payload)?.1)
    }

    /// RESULT; the finished job's stats as one-line JSON.
    pub fn result_json(&mut self, id: u64) -> Result<String, String> {
        let payload = self.call(&Request::Result(id))?;
        let rest = Self::expect_tag(&payload, "result")?;
        match rest.split_once(' ') {
            Some((got_id, json)) if got_id == id.to_string() => Ok(json.to_string()),
            _ => Err(format!("unexpected RESULT payload {payload:?}")),
        }
    }

    /// STATS; server health as one-line JSON.
    pub fn stats_json(&mut self) -> Result<String, String> {
        let payload = self.call(&Request::Stats)?;
        Ok(Self::expect_tag(&payload, "stats")?.to_string())
    }

    /// SHUTDOWN the daemon.
    pub fn shutdown(&mut self) -> Result<(), String> {
        self.call(&Request::Shutdown).map(|_| ())
    }

    /// Convenience: submit, wait, and fetch the result JSON in one call.
    pub fn run_to_completion(&mut self, spec: &JobSpec) -> Result<(u64, String), String> {
        let (id, state) = self.submit(spec)?;
        if state != JobState::Done {
            let settled = self.wait(id)?;
            if settled != JobState::Done {
                // surface the failure reason RESULT carries
                return match self.result_json(id) {
                    Err(e) => Err(e),
                    Ok(_) => Err(format!("job {id} settled as {}", settled.as_str())),
                };
            }
        }
        Ok((id, self.result_json(id)?))
    }
}
