//! The `malekeh serve` daemon.
//!
//! One TCP listener, N simulation workers, one shared job table. A
//! SUBMIT is resolved in three tiers, cheapest first:
//!
//! 1. **job-table dedupe** — the same [`super::store::StoreKey`] already
//!    has a job in this process (queued, running, or finished): the
//!    submission attaches to that job's id instead of creating work;
//! 2. **persistent store** — the key has a verified record on disk: the
//!    job is born `done` with the stored stats, no simulation runs;
//! 3. **simulate** — the job queues for a worker, which runs
//!    [`crate::sim::run_workload`] and writes the result to the store
//!    *before* publishing `done` (so a client that observed `done` can
//!    rely on the record surviving a daemon restart).
//!
//! Connection handling is one thread per client (blocking reads; WAIT
//! parks the handler on the job condvar, not the worker pool), mirroring
//! how `Runner::execute` shards figure points across scoped workers.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::config::{GpuConfig, Scheme};
use crate::sim::run_workload;
use crate::stats::Stats;
use crate::trace::Workload;

use super::protocol::{self, JobSpec, JobState, Request, Response, WorkloadSpec};
use super::store::{Store, StoreKey};

/// Daemon configuration (`malekeh serve`).
#[derive(Debug, Clone)]
pub struct ServerOpts {
    /// Listen address, e.g. `127.0.0.1:7757` (port 0 = ephemeral).
    pub addr: String,
    /// Simulation workers; 0 = one per core.
    pub workers: usize,
    /// Persistent store directory; `None` disables tiers 2/3 persistence
    /// (the in-process job table still dedupes).
    pub store_dir: Option<PathBuf>,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts { addr: "127.0.0.1:7757".to_string(), workers: 0, store_dir: None }
    }
}

/// One submitted simulation.
struct Job {
    cfg: GpuConfig,
    workload: Workload,
    profile_warps: usize,
    state: JobState,
    stats: Option<Stats>,
    error: Option<String>,
}

/// Everything behind the job-table lock.
#[derive(Default)]
struct Table {
    jobs: Vec<Job>,
    queue: VecDeque<usize>,
    index: HashMap<StoreKey, usize>,
    // health counters (reported by STATS)
    submitted: u64,
    dedup_hits: u64,
    store_hits: u64,
    sims_completed: u64,
    sims_failed: u64,
}

/// State shared by the accept loop, workers, and connection handlers.
struct Shared {
    table: Mutex<Table>,
    cv: Condvar,
    shutdown: AtomicBool,
    store: Option<Store>,
    addr: SocketAddr,
}

/// A bound (but not yet serving) daemon. `bind` then [`Server::run`];
/// the split lets tests bind port 0 and read [`Server::local_addr`]
/// before serving starts.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    workers: usize,
}

impl Server {
    /// Bind the listen socket and open the store.
    pub fn bind(opts: ServerOpts) -> Result<Server, String> {
        let listener = TcpListener::bind(&opts.addr)
            .map_err(|e| format!("bind {}: {e}", opts.addr))?;
        let addr = listener
            .local_addr()
            .map_err(|e| format!("local_addr: {e}"))?;
        let store = match &opts.store_dir {
            Some(dir) => Some(
                Store::open(dir).map_err(|e| format!("store {}: {e}", dir.display()))?,
            ),
            None => None,
        };
        Ok(Server {
            listener,
            shared: Arc::new(Shared {
                table: Mutex::new(Table::default()),
                cv: Condvar::new(),
                shutdown: AtomicBool::new(false),
                store,
                addr,
            }),
            workers: opts.workers,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Serve until a client sends SHUTDOWN. Joins the worker pool before
    /// returning, so every completed simulation's store record is on
    /// disk when this returns.
    pub fn run(self) -> Result<(), String> {
        let nworkers = if self.workers == 0 {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        } else {
            self.workers
        };
        let mut pool = Vec::with_capacity(nworkers);
        for _ in 0..nworkers {
            let shared = Arc::clone(&self.shared);
            pool.push(std::thread::spawn(move || worker_loop(&shared)));
        }
        for conn in self.listener.incoming() {
            if self.shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let shared = Arc::clone(&self.shared);
                    // one handler thread per client; WAIT blocks here,
                    // never a simulation worker
                    std::thread::spawn(move || {
                        let _ = handle_connection(&shared, stream);
                    });
                }
                Err(e) => {
                    if self.shared.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("serve: accept failed: {e}");
                }
            }
        }
        self.shared.cv.notify_all();
        for w in pool {
            let _ = w.join();
        }
        Ok(())
    }
}

/// Job-table lock that recovers from poisoning: a thread that panicked
/// while holding the lock must not take every future request down with
/// it (the serve-panic contract — degrade, don't die; the table is a
/// plain state record, valid after any partial update).
fn lock_table(shared: &Shared) -> MutexGuard<'_, Table> {
    shared.table.lock().unwrap_or_else(|p| p.into_inner())
}

/// Condvar wait with the same poisoning recovery as [`lock_table`].
fn wait_table<'a>(shared: &'a Shared, t: MutexGuard<'a, Table>) -> MutexGuard<'a, Table> {
    shared.cv.wait(t).unwrap_or_else(|p| p.into_inner())
}

/// Worker: pop queued jobs, simulate, persist, publish.
fn worker_loop(shared: &Shared) {
    loop {
        // claim one queued job (or exit on shutdown)
        let (id, cfg, workload, profile_warps) = {
            let mut t = lock_table(shared);
            loop {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = t.queue.pop_front() {
                    // a queue entry without a job would be a table bug;
                    // drop it rather than index and abort the worker
                    let Some(j) = t.jobs.get_mut(id) else {
                        eprintln!("serve: queued job {id} missing from the table");
                        continue;
                    };
                    j.state = JobState::Running;
                    shared.cv.notify_all();
                    break (id, j.cfg.clone(), j.workload.clone(), j.profile_warps);
                }
                t = wait_table(shared, t);
            }
        };
        let outcome = run_workload(&cfg, &workload, profile_warps);
        // persist BEFORE publishing `done`: a client that saw `done` may
        // immediately restart the daemon and expect the record to exist
        if let (Ok(stats), Some(store)) = (&outcome, &shared.store) {
            if let Ok(key) = StoreKey::for_run(&cfg, &workload, profile_warps) {
                if let Err(e) = store.put(&key, stats) {
                    eprintln!("serve: store write for job {id} failed: {e}");
                }
            }
        }
        let mut t = lock_table(shared);
        match outcome {
            Ok(stats) => {
                if let Some(j) = t.jobs.get_mut(id) {
                    j.stats = Some(stats);
                    j.state = JobState::Done;
                }
                t.sims_completed += 1;
            }
            Err(e) => {
                if let Some(j) = t.jobs.get_mut(id) {
                    j.error = Some(e);
                    j.state = JobState::Failed;
                }
                t.sims_failed += 1;
            }
        }
        shared.cv.notify_all();
    }
}

/// Build the `GpuConfig` a [`JobSpec`] describes. Mirrors the binary's
/// `simulate` config construction (Table-1 baseline + scheme + SM count
/// + overrides), so a daemon result is bit-identical to the same point
/// run through `malekeh simulate`.
fn build_job(spec: &JobSpec) -> Result<(GpuConfig, Workload, usize), String> {
    let scheme = Scheme::parse(&spec.scheme)?;
    let mut cfg = GpuConfig::table1_baseline().with_scheme(scheme);
    cfg.num_sms = spec.sms;
    cfg.apply(&spec.overrides)?;
    cfg.validate()?;
    let workload = match &spec.workload {
        WorkloadSpec::Bench(name) => Workload::builtin(name),
        WorkloadSpec::Trace(path) => Workload::trace_file(path),
    };
    Ok((cfg, workload, spec.profile_warps))
}

/// SUBMIT: resolve through the three tiers; returns the job id + state.
fn submit(shared: &Shared, spec: &JobSpec) -> Result<(u64, JobState), String> {
    let (cfg, workload, profile_warps) = build_job(spec)?;
    // the content address also validates the workload (unknown benchmark
    // or unreadable trace file fails here, before a job exists)
    let key = StoreKey::for_run(&cfg, &workload, profile_warps)?;
    let mut t = lock_table(shared);
    t.submitted += 1;
    if let Some(&id) = t.index.get(&key) {
        t.dedup_hits += 1;
        // the index only maps to pushed job ids; report rather than
        // index if the table is ever inconsistent
        let state = t.jobs.get(id).map(|j| j.state);
        return match state {
            Some(state) => Ok((id as u64, state)),
            None => Err(format!("job table inconsistent for id {id}")),
        };
    }
    let mut job = Job {
        cfg,
        workload,
        profile_warps,
        state: JobState::Queued,
        stats: None,
        error: None,
    };
    if let Some(store) = &shared.store {
        if let Some(stats) = store.get(&key) {
            job.stats = Some(stats);
            job.state = JobState::Done;
            t.store_hits += 1;
        }
    }
    let id = t.jobs.len();
    let state = job.state;
    t.index.insert(key, id);
    t.jobs.push(job);
    if state == JobState::Queued {
        t.queue.push_back(id);
        shared.cv.notify_all();
    }
    Ok((id as u64, state))
}

/// Server-health JSON (the STATS payload body).
fn stats_json(shared: &Shared) -> String {
    let (records, bytes) = match &shared.store {
        Some(store) => match store.info() {
            Ok(i) => (i.records as u64, i.bytes),
            Err(_) => (0, 0),
        },
        None => (0, 0),
    };
    let t = lock_table(shared);
    format!(
        "{{\"jobs\":{},\"submitted\":{},\"dedup_hits\":{},\"store_hits\":{},\
         \"sims_completed\":{},\"sims_failed\":{},\"store_records\":{records},\
         \"store_bytes\":{bytes}}}",
        t.jobs.len(),
        t.submitted,
        t.dedup_hits,
        t.store_hits,
        t.sims_completed,
        t.sims_failed,
    )
}

/// Execute one request. Blocking verbs (WAIT) park on the condvar here,
/// in the connection handler's thread.
fn dispatch(shared: &Shared, req: Request) -> Response {
    let job_state = |id: u64| -> Result<JobState, String> {
        let t = lock_table(shared);
        t.jobs
            .get(id as usize)
            .map(|j| j.state)
            .ok_or_else(|| format!("no such job {id}"))
    };
    match req {
        Request::Ping => Response::Ok(format!("pong {}", protocol::PROTOCOL_VERSION)),
        Request::Submit(spec) => match submit(shared, &spec) {
            Ok((id, state)) => Response::Ok(Response::job_payload(id, state)),
            Err(e) => Response::Err(e),
        },
        Request::Status(id) => match job_state(id) {
            Ok(state) => Response::Ok(Response::job_payload(id, state)),
            Err(e) => Response::Err(e),
        },
        Request::Wait(id) => {
            let mut t = lock_table(shared);
            loop {
                let Some(state) = t.jobs.get(id as usize).map(|j| j.state) else {
                    return Response::Err(format!("no such job {id}"));
                };
                if !matches!(state, JobState::Queued | JobState::Running) {
                    return Response::Ok(Response::job_payload(id, state));
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return Response::Err("server shutting down".to_string());
                }
                t = wait_table(shared, t);
            }
        }
        Request::Result(id) => {
            let t = lock_table(shared);
            match t.jobs.get(id as usize) {
                None => Response::Err(format!("no such job {id}")),
                Some(j) => match (j.state, &j.stats, &j.error) {
                    (JobState::Done, Some(stats), _) => {
                        Response::Ok(format!("result {id} {}", stats.to_json()))
                    }
                    (JobState::Failed, _, Some(e)) => {
                        Response::Err(format!("job {id} failed: {e}"))
                    }
                    _ => Response::Err(format!("job {id} not finished (try WAIT)")),
                },
            }
        }
        Request::Stats => Response::Ok(format!("stats {}", stats_json(shared))),
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            // unblock the accept loop so it observes the flag
            let _ = TcpStream::connect(shared.addr);
            Response::Ok("bye".to_string())
        }
    }
}

/// One client connection: greeting, then request/response lines until
/// EOF (or the client stops after SHUTDOWN).
fn handle_connection(shared: &Shared, stream: TcpStream) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    writer.write_all(format!("{}\n", protocol::greeting()).as_bytes())?;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client hung up
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = match Request::parse(&line) {
            Ok(req) => dispatch(shared, req),
            Err(e) => Response::Err(e),
        };
        writer.write_all(format!("{}\n", response.encode()).as_bytes())?;
        writer.flush()?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Client;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("malekeh_server_unit_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn spawn(store_dir: Option<PathBuf>) -> (SocketAddr, std::thread::JoinHandle<()>) {
        let server = Server::bind(ServerOpts {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            store_dir,
        })
        .unwrap();
        let addr = server.local_addr();
        let handle = std::thread::spawn(move || server.run().unwrap());
        (addr, handle)
    }

    /// A spec small enough to simulate in well under a second.
    fn quick_spec(scheme: &str) -> JobSpec {
        let mut spec = JobSpec::bench("hotspot");
        spec.scheme = scheme.to_string();
        spec.overrides.push(("max_cycles".to_string(), "2000".to_string()));
        spec
    }

    #[test]
    fn ping_submit_wait_result_shutdown() {
        let dir = tmp_dir("e2e");
        let (addr, handle) = spawn(Some(dir.clone()));
        let mut c = Client::connect(&addr.to_string()).unwrap();
        assert!(c.ping().unwrap().contains(protocol::PROTOCOL_VERSION));

        let (id, state) = c.submit(&quick_spec("malekeh")).unwrap();
        assert!(matches!(state, JobState::Queued | JobState::Running | JobState::Done));
        assert_eq!(c.wait(id).unwrap(), JobState::Done);
        let json = c.result_json(id).unwrap();
        assert!(json.contains("\"fingerprint\":\""), "{json}");

        // identical resubmission attaches to the same job, no new sim
        let (id2, state2) = c.submit(&quick_spec("malekeh")).unwrap();
        assert_eq!(id2, id);
        assert_eq!(state2, JobState::Done);
        // a different scheme is a different job
        let (id3, _) = c.submit(&quick_spec("baseline")).unwrap();
        assert_ne!(id3, id);
        assert_eq!(c.wait(id3).unwrap(), JobState::Done);

        let health = c.stats_json().unwrap();
        assert!(health.contains("\"dedup_hits\":1"), "{health}");
        assert!(health.contains("\"sims_completed\":2"), "{health}");

        c.shutdown().unwrap();
        handle.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_submissions_are_errors_not_jobs() {
        let (addr, handle) = spawn(None);
        let mut c = Client::connect(&addr.to_string()).unwrap();
        let mut bogus = JobSpec::bench("no_such_benchmark");
        assert!(c.submit(&bogus).is_err(), "unknown benchmark");
        bogus = quick_spec("no_such_scheme");
        assert!(c.submit(&bogus).is_err(), "unknown scheme");
        bogus = quick_spec("malekeh");
        bogus.overrides.push(("no_such_key".to_string(), "1".to_string()));
        assert!(c.submit(&bogus).is_err(), "unknown config key");
        assert!(c.result_json(99).is_err(), "no such job");
        // the connection survives errors
        assert!(c.ping().is_ok());
        c.shutdown().unwrap();
        handle.join().unwrap();
    }
}
