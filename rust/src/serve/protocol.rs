//! Versioned line-delimited request/response wire format.
//!
//! One connection = one greeting line from the server
//! (`MALEKEH-SERVE/1 ready`), then any number of request/response pairs.
//! Every message is exactly one `\n`-terminated line of ASCII; values
//! that could contain whitespace (trace paths, policy names) are
//! percent-escaped. The full grammar with a worked example lives in
//! `docs/SERVING.md`; this module is the single source of truth for
//! encode/parse on both sides, so client and server cannot drift.
//!
//! Requests:
//!
//! ```text
//! PING
//! SUBMIT bench=<name>|trace=<path> [scheme=<s>] [sms=<n>]
//!        [profile_warps=<n>] [set:<key>=<value>]...
//! STATUS <job-id>
//! WAIT <job-id>
//! RESULT <job-id>
//! STATS
//! SHUTDOWN
//! ```
//!
//! Responses are `OK <payload>` or `ERR <message>`; SUBMIT/STATUS/WAIT
//! answer with the payload `job <id> <queued|running|done|failed>`,
//! RESULT with `result <id> <one-line stats JSON>`, STATS with
//! `stats <one-line server-health JSON>`.

/// Protocol identifier; also the first token of the server greeting.
/// Bump the suffix on any incompatible grammar change — a client checks
/// it before speaking.
pub const PROTOCOL_VERSION: &str = "MALEKEH-SERVE/1";

/// Full greeting line the server sends on accept.
pub fn greeting() -> String {
    format!("{PROTOCOL_VERSION} ready")
}

/// Percent-escape a token value so it survives space-delimited framing.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'%' | b' ' | b'\t' | b'\n' | b'\r' | b'=' => {
                out.push_str(&format!("%{b:02X}"));
            }
            _ => out.push(b as char),
        }
    }
    out
}

/// Inverse of [`escape`]. Unknown or truncated `%xx` sequences error
/// rather than passing through silently. Byte-iterator based: hostile
/// input must not be able to panic the daemon via an out-of-bounds
/// index (the serve-panic contract).
pub fn unescape(s: &str) -> Result<String, String> {
    let mut out = Vec::with_capacity(s.len());
    let mut it = s.bytes();
    while let Some(b) = it.next() {
        if b == b'%' {
            let (hi, lo) = match (it.next(), it.next()) {
                (Some(hi), Some(lo)) => (hi, lo),
                _ => return Err(format!("truncated escape in {s:?}")),
            };
            match ((hi as char).to_digit(16), (lo as char).to_digit(16)) {
                (Some(h), Some(l)) => out.push((h * 16 + l) as u8),
                _ => {
                    return Err(format!("bad escape %{}{} in {s:?}", hi as char, lo as char));
                }
            }
        } else {
            out.push(b);
        }
    }
    String::from_utf8(out).map_err(|_| format!("escape decoded to non-UTF8 in {s:?}"))
}

/// What to simulate: a registry benchmark or a `.mtrace` file (resolved
/// against the *server's* working directory).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkloadSpec {
    /// Registry benchmark by name.
    Bench(String),
    /// Recorded trace file path.
    Trace(String),
}

/// One simulation request, mirroring the `malekeh simulate` surface:
/// the Table-1 baseline config with a scheme, an SM count, the
/// compiler's `profile_warps`, and arbitrary `-s key=value` overrides.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// What to simulate.
    pub workload: WorkloadSpec,
    /// Policy name (registry); default `baseline`.
    pub scheme: String,
    /// SM count; default 2 (same as `simulate`).
    pub sms: usize,
    /// Compiler profiling warps; default 2 (same as `simulate`).
    pub profile_warps: usize,
    /// `GpuConfig` key overrides, applied in order.
    pub overrides: Vec<(String, String)>,
}

impl JobSpec {
    /// Spec with `simulate`'s defaults.
    pub fn bench(name: &str) -> JobSpec {
        JobSpec {
            workload: WorkloadSpec::Bench(name.to_string()),
            scheme: "baseline".to_string(),
            sms: 2,
            profile_warps: 2,
            overrides: Vec::new(),
        }
    }

    /// Spec replaying a trace file (server-side path).
    pub fn trace(path: &str) -> JobSpec {
        JobSpec {
            workload: WorkloadSpec::Trace(path.to_string()),
            ..JobSpec::bench("")
        }
    }

    /// The SUBMIT argument string (everything after the verb).
    pub fn encode(&self) -> String {
        let mut out = match &self.workload {
            WorkloadSpec::Bench(name) => format!("bench={}", escape(name)),
            WorkloadSpec::Trace(path) => format!("trace={}", escape(path)),
        };
        out.push_str(&format!(
            " scheme={} sms={} profile_warps={}",
            escape(&self.scheme),
            self.sms,
            self.profile_warps
        ));
        for (k, v) in &self.overrides {
            out.push_str(&format!(" set:{}={}", escape(k), escape(v)));
        }
        out
    }

    /// Parse the SUBMIT argument string.
    pub fn parse(args: &str) -> Result<JobSpec, String> {
        let mut workload: Option<WorkloadSpec> = None;
        let mut spec = JobSpec::bench("");
        for tok in args.split_ascii_whitespace() {
            let (key, value) = tok
                .split_once('=')
                .ok_or_else(|| format!("bad SUBMIT token {tok:?}, want key=value"))?;
            let value = unescape(value)?;
            match key {
                "bench" => workload = Some(WorkloadSpec::Bench(value)),
                "trace" => workload = Some(WorkloadSpec::Trace(value)),
                "scheme" => spec.scheme = value,
                "sms" => {
                    spec.sms = value.parse().map_err(|_| format!("bad sms={value:?}"))?;
                }
                "profile_warps" => {
                    spec.profile_warps = value
                        .parse()
                        .map_err(|_| format!("bad profile_warps={value:?}"))?;
                }
                _ => match key.strip_prefix("set:") {
                    Some(cfg_key) => {
                        spec.overrides.push((unescape(cfg_key)?, value));
                    }
                    None => return Err(format!("unknown SUBMIT key {key:?}")),
                },
            }
        }
        spec.workload =
            workload.ok_or("SUBMIT needs bench=<name> or trace=<path>")?;
        Ok(spec)
    }
}

/// Client-to-server message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Liveness + version check.
    Ping,
    /// Schedule (or dedupe) a simulation.
    Submit(JobSpec),
    /// Non-blocking job state query.
    Status(u64),
    /// Block until the job leaves queued/running.
    Wait(u64),
    /// Fetch a finished job's stats as one-line JSON.
    Result(u64),
    /// Server health + store size, as one-line JSON.
    Stats,
    /// Stop accepting connections and exit the daemon.
    Shutdown,
}

impl Request {
    /// Wire line (without the trailing newline).
    pub fn encode(&self) -> String {
        match self {
            Request::Ping => "PING".to_string(),
            Request::Submit(spec) => format!("SUBMIT {}", spec.encode()),
            Request::Status(id) => format!("STATUS {id}"),
            Request::Wait(id) => format!("WAIT {id}"),
            Request::Result(id) => format!("RESULT {id}"),
            Request::Stats => "STATS".to_string(),
            Request::Shutdown => "SHUTDOWN".to_string(),
        }
    }

    /// Parse one request line (tolerates trailing `\r\n`).
    pub fn parse(line: &str) -> Result<Request, String> {
        let line = line.trim_end_matches(['\r', '\n']);
        let (verb, rest) = match line.split_once(' ') {
            Some((v, r)) => (v, r.trim()),
            None => (line, ""),
        };
        let id = |rest: &str| -> Result<u64, String> {
            rest.parse().map_err(|_| format!("bad job id {rest:?}"))
        };
        match verb {
            "PING" => Ok(Request::Ping),
            "SUBMIT" => Ok(Request::Submit(JobSpec::parse(rest)?)),
            "STATUS" => Ok(Request::Status(id(rest)?)),
            "WAIT" => Ok(Request::Wait(id(rest)?)),
            "RESULT" => Ok(Request::Result(id(rest)?)),
            "STATS" => Ok(Request::Stats),
            "SHUTDOWN" => Ok(Request::Shutdown),
            other => Err(format!("unknown request {other:?}")),
        }
    }
}

/// Lifecycle of a submitted job, as reported on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is simulating it.
    Running,
    /// Finished; RESULT will serve it.
    Done,
    /// Simulation errored; STATUS/WAIT report it.
    Failed,
}

impl JobState {
    /// Wire token.
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    /// Parse a wire token.
    pub fn parse(s: &str) -> Result<JobState, String> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "done" => Ok(JobState::Done),
            "failed" => Ok(JobState::Failed),
            other => Err(format!("unknown job state {other:?}")),
        }
    }
}

/// Server-to-client message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// Success; the payload grammar depends on the request verb.
    Ok(String),
    /// Failure, with a human-readable reason.
    Err(String),
}

impl Response {
    /// Wire line (without the trailing newline).
    pub fn encode(&self) -> String {
        match self {
            Response::Ok(payload) if payload.is_empty() => "OK".to_string(),
            Response::Ok(payload) => format!("OK {payload}"),
            Response::Err(msg) => {
                // an error reason must stay one line on the wire
                format!("ERR {}", msg.replace(['\n', '\r'], " "))
            }
        }
    }

    /// Parse one response line (tolerates trailing `\r\n`).
    pub fn parse(line: &str) -> Result<Response, String> {
        let line = line.trim_end_matches(['\r', '\n']);
        if let Some(rest) = line.strip_prefix("OK") {
            return Ok(Response::Ok(rest.trim_start().to_string()));
        }
        if let Some(rest) = line.strip_prefix("ERR") {
            return Ok(Response::Err(rest.trim_start().to_string()));
        }
        Err(format!("unparseable response line {line:?}"))
    }

    /// Payload for SUBMIT/STATUS/WAIT.
    pub fn job_payload(id: u64, state: JobState) -> String {
        format!("job {id} {}", state.as_str())
    }

    /// Parse a `job <id> <state>` payload.
    pub fn parse_job_payload(payload: &str) -> Result<(u64, JobState), String> {
        let mut it = payload.split_ascii_whitespace();
        match (it.next(), it.next(), it.next(), it.next()) {
            (Some("job"), Some(id), Some(state), None) => Ok((
                id.parse().map_err(|_| format!("bad job id {id:?}"))?,
                JobState::parse(state)?,
            )),
            _ => Err(format!("bad job payload {payload:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_roundtrips_awkward_values() {
        for s in ["plain", "with space", "a=b", "100%", "tab\there", "nl\nthere", ""] {
            assert_eq!(unescape(&escape(s)).unwrap(), s, "{s:?}");
        }
        assert!(unescape("%").is_err(), "truncated escape");
        assert!(unescape("%zz").is_err(), "non-hex escape");
    }

    #[test]
    fn requests_roundtrip_through_the_wire_form() {
        let mut spec = JobSpec::bench("hotspot");
        spec.scheme = "malekeh".into();
        spec.overrides.push(("rthld".into(), "7".into()));
        spec.overrides.push(("max_cycles".into(), "5000".into()));
        let reqs = [
            Request::Ping,
            Request::Submit(spec),
            Request::Submit(JobSpec::trace("runs/my trace.mtrace")),
            Request::Status(7),
            Request::Wait(0),
            Request::Result(42),
            Request::Stats,
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.encode();
            assert!(!line.contains('\n'));
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
            // tolerate CRLF clients (telnet-style probing)
            assert_eq!(Request::parse(&format!("{line}\r\n")).unwrap(), r);
        }
    }

    #[test]
    fn submit_defaults_mirror_simulate() {
        let spec = JobSpec::parse("bench=kmeans").unwrap();
        assert_eq!(spec.workload, WorkloadSpec::Bench("kmeans".into()));
        assert_eq!(spec.scheme, "baseline");
        assert_eq!(spec.sms, 2);
        assert_eq!(spec.profile_warps, 2);
        assert!(spec.overrides.is_empty());
        // override order is preserved (later overrides win in GpuConfig)
        let spec = JobSpec::parse("bench=x set:rthld=3 set:rthld=9").unwrap();
        assert_eq!(spec.overrides, vec![
            ("rthld".to_string(), "3".to_string()),
            ("rthld".to_string(), "9".to_string()),
        ]);
    }

    #[test]
    fn submit_rejects_malformed_input() {
        assert!(JobSpec::parse("").is_err(), "workload is mandatory");
        assert!(JobSpec::parse("scheme=malekeh").is_err(), "still no workload");
        assert!(JobSpec::parse("bench=x spurious").is_err(), "token without =");
        assert!(JobSpec::parse("bench=x sms=abc").is_err());
        assert!(JobSpec::parse("bench=x unknown=1").is_err());
        assert!(Request::parse("FROBNICATE 1").is_err());
        assert!(Request::parse("STATUS notanid").is_err());
    }

    #[test]
    fn responses_and_job_payloads_roundtrip() {
        for r in [
            Response::Ok(String::new()),
            Response::Ok("pong MALEKEH-SERVE/1".into()),
            Response::Err("no such job".into()),
        ] {
            assert_eq!(Response::parse(&r.encode()).unwrap(), r);
        }
        // multi-line error reasons are flattened, not smuggled
        let r = Response::Err("line1\nline2".into());
        assert!(!r.encode().contains('\n'));

        for st in [JobState::Queued, JobState::Running, JobState::Done, JobState::Failed] {
            let payload = Response::job_payload(9, st);
            assert_eq!(Response::parse_job_payload(&payload).unwrap(), (9, st));
        }
        assert!(Response::parse_job_payload("job x done").is_err());
        assert!(Response::parse_job_payload("nope").is_err());
    }
}
