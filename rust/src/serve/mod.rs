//! Simulation-as-a-service: the `malekeh serve` daemon and its persistent
//! content-addressed result store.
//!
//! The paper's evaluation grid (Table II benchmarks x 13 registry schemes
//! x config sweeps) is heavily duplicate-dominated: every figure suite
//! re-declares mostly the same `(config, workload, policy)` points, and
//! the [`crate::harness::Runner`] memo cache that absorbs the duplicates
//! dies with the process. This subsystem makes result reuse survive the
//! process — and the machine boundary:
//!
//! - [`store`] — a persistent on-disk **content-addressed result store**
//!   (default `.malekeh-store/`). Keys are
//!   `config fingerprint x workload fingerprint x policy name`
//!   ([`store::StoreKey`]); records carry the full [`crate::stats::Stats`]
//!   plus its [`crate::stats::Stats::fingerprint`] and are verified on
//!   read, so a truncated, corrupted, or hand-edited record is a *miss*,
//!   never a wrong answer. Writes are write-temp-then-rename atomic, so
//!   concurrent writers (shard workers, multiple daemons on one
//!   filesystem) can race safely.
//! - [`protocol`] — the versioned line-delimited request/response wire
//!   format (submit / status / wait / result / stats / shutdown) spoken
//!   over TCP. Grammar in `docs/SERVING.md`.
//! - [`server`] — the `malekeh serve --addr <host:port> --workers N`
//!   daemon: checks the store before scheduling, **dedupes identical
//!   in-flight jobs** (a second identical submission attaches to the
//!   first's result instead of re-simulating), and fans misses over a
//!   worker pool (each worker runs one simulation exactly like a
//!   `--jobs` shard worker; `--sim-threads` applies inside it).
//! - [`client`] — the client library behind the `malekeh submit` /
//!   `malekeh serve-ctl` CLI verbs.
//!
//! The harness uses the store directly, without the daemon:
//! `--store <dir>` ([`crate::harness::ExpOpts::store_dir`]) backs the
//! `Runner` memo cache with the persistent store, so re-running a figure
//! suite across process restarts is warm-cache reads.
//!
//! # Identity and determinism
//!
//! Every simulation is a pure function of `(GpuConfig, workload)` — the
//! crate's determinism contract — so the store address is built from
//! exactly those two inputs plus the policy name:
//! [`crate::config::GpuConfig::fingerprint`] (canonical serialisation,
//! `sim_threads` excluded — it is wall-clock-only) and
//! [`crate::trace::Workload::content_fingerprint`] (generated or on-disk
//! trace *content*, never a file path). A stored result is therefore
//! bit-identical to what a fresh `--sim-threads 1` run of the same point
//! would produce, and the record's embedded `Stats::fingerprint` lets
//! every reader prove it.

pub mod client;
pub mod protocol;
pub mod server;
pub mod store;

pub use client::Client;
pub use protocol::{Request, Response, PROTOCOL_VERSION};
pub use server::{Server, ServerOpts};
pub use store::{Store, StoreInfo, StoreKey};
