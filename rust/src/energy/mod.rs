//! AccelWattch-style RF dynamic-energy model (paper §V).
//!
//! The paper extends AccelWattch with CCU models and reports *relative*
//! dynamic energy (Fig 15), so this model works in relative energy units:
//! per-event costs are normalised to one RF-bank read = 1.0. The cost of
//! cache structures scales with their storage, and the crossbar with its
//! port count, which is what makes BOW's 8-collector crossbar more
//! expensive than the baseline's 2 — the effect behind BOW's worse-than-
//! baseline energy in Fig 15.
//!
//! Event *counts* are produced by the simulator (`stats::Stats::energy`);
//! the same count matrix can be evaluated through the AOT `rf_energy`
//! artifact (L1 Pallas kernel) via `runtime::EnergyModelExe`, and the two
//! paths are cross-checked by an integration test.

use crate::config::GpuConfig;

/// RF energy event kinds. Order must match `python/compile/constants.py`
/// `ENERGY_EVENTS` (the AOT artifact's column order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum EventKind {
    /// One 128B operand read from an RF bank.
    BankRead = 0,
    /// One 128B operand write to an RF bank.
    BankWrite,
    /// Operand served from a collector cache entry.
    CcuRead,
    /// Operand written into a collector cache entry.
    CcuWrite,
    /// Crossbar traversal bank -> collector.
    XbarTransfer,
    /// Arbiter decision.
    ArbiterOp,
    /// Collector bookkeeping (tag check / OCT update).
    OctOp,
    /// Per-cycle structure-size proxy (captures bigger-buffer overheads).
    LeakProxy,
}

/// Number of event kinds.
pub const NEVENTS: usize = 8;

/// Names, in artifact column order.
pub const EVENT_NAMES: [&str; NEVENTS] = [
    "bank_read",
    "bank_write",
    "ccu_read",
    "ccu_write",
    "xbar_transfer",
    "arbiter_op",
    "oct_op",
    "leak_proxy",
];

/// Event counters (one u64 per kind).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EnergyCounts {
    counts: [u64; NEVENTS],
}

impl EnergyCounts {
    /// All-zero counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bump one event kind by `n`.
    #[inline]
    pub fn add(&mut self, kind: EventKind, n: u64) {
        self.counts[kind as usize] += n;
    }

    /// Read one counter.
    #[inline]
    pub fn get(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    /// Raw counters in artifact column order (lossless, for fingerprints).
    pub fn raw(&self) -> [u64; NEVENTS] {
        self.counts
    }

    /// Rebuild from a raw column-order row — the inverse of
    /// [`EnergyCounts::raw`], used by the persistent result store to
    /// deserialise records losslessly.
    pub fn from_raw(counts: [u64; NEVENTS]) -> Self {
        EnergyCounts { counts }
    }

    /// Raw row in artifact column order (f32 for the AOT path).
    pub fn as_f32_row(&self) -> [f32; NEVENTS] {
        let mut r = [0f32; NEVENTS];
        for (i, c) in self.counts.iter().enumerate() {
            r[i] = *c as f32;
        }
        r
    }

    /// Add another counter set.
    pub fn merge(&mut self, other: &EnergyCounts) {
        for i in 0..NEVENTS {
            self.counts[i] += other.counts[i];
        }
    }
}

/// Per-event relative costs for one scheme/config.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    costs: [f64; NEVENTS],
}

impl EnergyModel {
    /// Build the cost vector for `cfg`. Cost rationale (relative units,
    /// bank read = 1.0, CACTI-style scaling):
    ///
    /// - bank read/write: 1.0 — the large single-ported 32KB-class bank.
    /// - cache read/write: grows ~linearly with per-collector cache bytes
    ///   (8-entry CCU ≈ 1KB → 0.12; BOW 3KB BOC ≈ 0.30); writes slightly
    ///   above reads (bitline drive). A scheme reporting **zero** cache
    ///   entries has no cache structure at all, so its cache-event and
    ///   cache-leakage costs are exactly zero — the floor below must never
    ///   charge a cacheless scheme (the baseline) a phantom CCU cost.
    /// - crossbar: per-transfer cost grows with the number of collector
    ///   ports it must span (≈ sqrt scaling of wire length per CACTI),
    ///   baseline 2-port = 0.22.
    /// - arbiter / OCT bookkeeping: small constants.
    /// - leak proxy: per-cycle, proportional to total collector cache
    ///   storage (zero when there is none).
    pub fn for_config(cfg: &GpuConfig) -> Self {
        let ncol = cfg.effective_collectors() as f64;
        // the policy knows its own cache geometry (BOW window slots, RFC
        // entries, CCU cache-table entries; 0 = no cache)
        let entries_per_col = cfg.scheme.build_policy(cfg).cache_entries_per_collector();
        // 128B per entry; normalise to the 8-entry CCU = 1KB baseline point.
        let cache_kb = entries_per_col * 128.0 / 1024.0;
        // the 0.25KB floor models tag/control overhead of *small* caches;
        // no cache means no cost at all (Fig 15 baseline point)
        let (cache_read, cache_write) = if entries_per_col > 0.0 {
            let read = 0.12 * cache_kb.max(0.25);
            (read, read * 1.15)
        } else {
            (0.0, 0.0)
        };
        // crossbar wire/port scaling vs the 2-collector baseline
        let xbar = 0.22 * (ncol / 2.0).sqrt();
        let leak = 0.0008 * ncol * cache_kb;
        EnergyModel {
            costs: [
                1.0,         // BankRead
                1.0,         // BankWrite
                cache_read,  // CcuRead
                cache_write, // CcuWrite
                xbar,        // XbarTransfer
                0.02,        // ArbiterOp
                0.015,       // OctOp
                leak,        // LeakProxy
            ],
        }
    }

    /// Cost vector (artifact column order).
    pub fn costs(&self) -> &[f64; NEVENTS] {
        &self.costs
    }

    /// Cost vector as f32 (for the AOT artifact).
    pub fn costs_f32(&self) -> [f32; NEVENTS] {
        let mut r = [0f32; NEVENTS];
        for (i, c) in self.costs.iter().enumerate() {
            r[i] = *c as f32;
        }
        r
    }

    /// Total relative dynamic energy for a counter set.
    pub fn total(&self, counts: &EnergyCounts) -> f64 {
        self.costs
            .iter()
            .zip(counts.counts.iter())
            .map(|(c, n)| c * *n as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;

    #[test]
    fn raw_roundtrips_through_from_raw() {
        let mut a = EnergyCounts::new();
        a.add(EventKind::BankRead, 7);
        a.add(EventKind::LeakProxy, 123_456);
        assert_eq!(EnergyCounts::from_raw(a.raw()), a);
    }

    #[test]
    fn counts_add_and_merge() {
        let mut a = EnergyCounts::new();
        a.add(EventKind::BankRead, 5);
        a.add(EventKind::CcuRead, 2);
        let mut b = EnergyCounts::new();
        b.add(EventKind::BankRead, 3);
        a.merge(&b);
        assert_eq!(a.get(EventKind::BankRead), 8);
        assert_eq!(a.get(EventKind::CcuRead), 2);
        assert_eq!(a.get(EventKind::BankWrite), 0);
    }

    #[test]
    fn cacheless_scheme_has_zero_cache_event_cost() {
        // Fig 15 baseline point: the baseline policy reports zero cache
        // entries, so CCU-read/-write and cache-leakage costs must be
        // exactly zero — the 0.25KB tag floor must never charge a
        // cacheless scheme a phantom CCU cost
        let cfg = crate::config::GpuConfig::table1_baseline()
            .with_scheme(Scheme::BASELINE);
        let m = EnergyModel::for_config(&cfg);
        assert_eq!(m.costs()[EventKind::CcuRead as usize], 0.0);
        assert_eq!(m.costs()[EventKind::CcuWrite as usize], 0.0);
        assert_eq!(m.costs()[EventKind::LeakProxy as usize], 0.0);
        // bank / crossbar / arbiter structure is real hardware and still
        // costs what it did
        assert_eq!(m.costs()[EventKind::BankRead as usize], 1.0);
        assert!(m.costs()[EventKind::XbarTransfer as usize] > 0.0);
        // pin the point: a count matrix carrying (impossible for the
        // baseline, but defensive) CCU events contributes nothing
        let mut c = EnergyCounts::new();
        c.add(EventKind::BankRead, 100);
        c.add(EventKind::CcuRead, 40);
        c.add(EventKind::CcuWrite, 40);
        assert!((m.total(&c) - 100.0).abs() < 1e-12);
    }

    #[test]
    fn cached_scheme_costs_are_unchanged_by_the_zero_entry_fix() {
        // malekeh: 8-entry CCU = 1KB -> cache read 0.12, write 0.138 — the
        // pre-fix values, pinned so the zero-entry special case can never
        // leak into cached schemes
        let cfg = crate::config::GpuConfig::table1_baseline()
            .with_scheme(Scheme::MALEKEH);
        let m = EnergyModel::for_config(&cfg);
        assert!((m.costs()[EventKind::CcuRead as usize] - 0.12).abs() < 1e-12);
        assert!((m.costs()[EventKind::CcuWrite as usize] - 0.12 * 1.15).abs() < 1e-12);
        assert!(m.costs()[EventKind::LeakProxy as usize] > 0.0);
    }

    #[test]
    fn cache_read_cheaper_than_bank_read() {
        let cfg = crate::config::GpuConfig::table1_baseline()
            .with_scheme(Scheme::MALEKEH);
        let m = EnergyModel::for_config(&cfg);
        assert!(m.costs()[EventKind::CcuRead as usize] < 0.5);
        assert!(m.costs()[EventKind::BankRead as usize] == 1.0);
    }

    #[test]
    fn bow_structures_cost_more_than_malekeh() {
        let base = crate::config::GpuConfig::table1_baseline();
        let mal = EnergyModel::for_config(&base.clone().with_scheme(Scheme::MALEKEH));
        let bow = EnergyModel::for_config(&base.clone().with_scheme(Scheme::BOW));
        // BOW: bigger buffers and an 8-port crossbar
        assert!(
            bow.costs()[EventKind::CcuRead as usize]
                > mal.costs()[EventKind::CcuRead as usize]
        );
        assert!(
            bow.costs()[EventKind::XbarTransfer as usize]
                > mal.costs()[EventKind::XbarTransfer as usize]
        );
    }

    #[test]
    fn related_work_scheme_cost_rows_are_pinned() {
        // the Fig 15-style cost rows of the related-work frontier
        // (docs/CONFIG.md table): entries/collector -> (ccu_read,
        // ccu_write, leak_proxy). Greener powers 1.5 of 6 entries
        // (2 active / 8 warps), compress stores 8 entries half-width,
        // ltrf keeps the full 6-entry per-warp RFC, regdem has no cache.
        let base = crate::config::GpuConfig::table1_baseline();
        for (scheme, read, write, leak) in [
            (Scheme::GREENER, 0.03, 0.0345, 0.0003),
            (Scheme::COMPRESS, 0.06, 0.069, 0.0008),
            (Scheme::LTRF, 0.09, 0.1035, 0.0012),
            (Scheme::REGDEM, 0.0, 0.0, 0.0),
        ] {
            let m = EnergyModel::for_config(&base.clone().with_scheme(scheme));
            let c = m.costs();
            assert!(
                (c[EventKind::CcuRead as usize] - read).abs() < 1e-12,
                "{scheme}: ccu_read {} != {read}",
                c[EventKind::CcuRead as usize]
            );
            assert!(
                (c[EventKind::CcuWrite as usize] - write).abs() < 1e-12,
                "{scheme}: ccu_write {} != {write}",
                c[EventKind::CcuWrite as usize]
            );
            assert!(
                (c[EventKind::LeakProxy as usize] - leak).abs() < 1e-12,
                "{scheme}: leak_proxy {} != {leak}",
                c[EventKind::LeakProxy as usize]
            );
        }
    }

    #[test]
    fn zero_entry_policies_incur_zero_cache_event_energy() {
        // regdem routes all spill traffic through bank/xbar events; like
        // the baseline it reports zero cache entries, so any CcuRead /
        // CcuWrite counts it produces must evaluate to exactly 0 energy
        for scheme in [Scheme::BASELINE, Scheme::REGDEM] {
            let cfg = crate::config::GpuConfig::table1_baseline().with_scheme(scheme);
            let m = EnergyModel::for_config(&cfg);
            let mut c = EnergyCounts::new();
            c.add(EventKind::CcuRead, 1_000);
            c.add(EventKind::CcuWrite, 1_000);
            c.add(EventKind::LeakProxy, 1_000);
            assert_eq!(m.total(&c), 0.0, "{scheme} charged phantom cache energy");
        }
    }

    #[test]
    fn total_is_dot_product() {
        let cfg = crate::config::GpuConfig::table1_baseline();
        let m = EnergyModel::for_config(&cfg);
        let mut c = EnergyCounts::new();
        c.add(EventKind::BankRead, 10);
        c.add(EventKind::ArbiterOp, 100);
        let want = 10.0 * m.costs()[0] + 100.0 * m.costs()[EventKind::ArbiterOp as usize];
        assert!((m.total(&c) - want).abs() < 1e-9);
    }

    #[test]
    fn event_names_match_python_constants_order() {
        // guard against silent reordering vs python/compile/constants.py
        assert_eq!(EVENT_NAMES[0], "bank_read");
        assert_eq!(EVENT_NAMES[EventKind::XbarTransfer as usize], "xbar_transfer");
        assert_eq!(EVENT_NAMES[NEVENTS - 1], "leak_proxy");
    }

    #[test]
    fn f32_row_roundtrip() {
        let mut c = EnergyCounts::new();
        c.add(EventKind::BankWrite, 42);
        let row = c.as_f32_row();
        assert_eq!(row[EventKind::BankWrite as usize], 42.0);
        assert_eq!(row[0], 0.0);
    }
}
