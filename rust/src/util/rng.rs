//! Deterministic, seedable PRNG (xoshiro256**).
//!
//! The crates.io `rand` ecosystem is not available in this offline build, and
//! a cycle-level simulator needs *reproducible* randomness anyway (the paper's
//! policies use random selection among far-reuse candidates). xoshiro256** is
//! small, fast, and passes BigCrush; good enough for workload synthesis and
//! policy tie-breaking.

/// A deterministic xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a new PRNG from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's method; `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    #[inline]
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample a geometric-ish discrete value: number of failures before the
    /// first success with success probability `p` (clamped to `max`). Used by
    /// workload generators for reuse-distance tails.
    pub fn geometric(&mut self, p: f64, max: usize) -> usize {
        let p = p.clamp(1e-9, 1.0);
        let mut k = 0;
        while k < max && !self.chance(p) {
            k += 1;
        }
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
        // degenerate range
        assert_eq!(r.range(4, 4), 4);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn chance_probability_roughly_respected() {
        let mut r = Rng::new(13);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        let frac = hits as f64 / 10_000.0;
        assert!((frac - 0.25).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn geometric_bounded() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            assert!(r.geometric(0.3, 16) <= 16);
        }
        // p = 1 always 0 failures
        assert_eq!(r.geometric(1.0, 16), 0);
    }
}
