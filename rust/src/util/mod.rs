//! Small shared utilities (deterministic PRNG, etc.).
pub mod rng;
pub use rng::Rng;
