//! Small shared utilities (deterministic PRNG, content hashing, etc.).
pub mod fnv;
pub mod rng;
pub use fnv::{fnv1a_bytes, Fnv1a};
pub use rng::Rng;
