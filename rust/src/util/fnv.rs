//! Streaming FNV-1a — the repo-wide content-identity hash.
//!
//! [`crate::stats::Stats::fingerprint`] established FNV-1a as the
//! bit-identity check for simulation *results*; the serving layer
//! ([`crate::serve`]) extends the same construction to the *inputs*:
//! [`crate::config::GpuConfig::fingerprint`] digests the canonical config
//! serialisation and [`crate::trace::KernelTrace::content_fingerprint`]
//! digests workload content, and together they form the persistent
//! store's content address. This module is the one implementation all
//! three share, so the mixing constants can never drift apart.

/// Incremental 64-bit FNV-1a hasher.
///
/// Two feeding granularities are exposed — raw bytes ([`Fnv1a::bytes`])
/// and whole `u64` words ([`Fnv1a::word`], the `Stats::fingerprint`
/// construction). They advance the same state, so a caller picks
/// whichever matches its data; mixing the two within one digest is fine
/// as long as the feed order is deterministic.
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0100_0000_01B3;

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    /// Fresh hasher at the FNV offset basis.
    pub fn new() -> Self {
        Fnv1a(FNV_OFFSET)
    }

    /// Absorb raw bytes (classic byte-wise FNV-1a).
    #[inline]
    pub fn bytes(&mut self, data: &[u8]) -> &mut Self {
        for &b in data {
            self.0 = (self.0 ^ u64::from(b)).wrapping_mul(FNV_PRIME);
        }
        self
    }

    /// Absorb one 64-bit word (the `Stats::fingerprint` word-wise mix).
    #[inline]
    pub fn word(&mut self, v: u64) -> &mut Self {
        self.0 = (self.0 ^ v).wrapping_mul(FNV_PRIME);
        self
    }

    /// Current digest.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot byte-wise FNV-1a (file contents, canonical strings).
pub fn fnv1a_bytes(data: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.bytes(data);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // published FNV-1a/64 test vectors
        assert_eq!(fnv1a_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_bytes(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_bytes(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.bytes(b"foo").bytes(b"bar");
        assert_eq!(h.finish(), fnv1a_bytes(b"foobar"));
    }

    #[test]
    fn word_feed_matches_stats_fingerprint_construction() {
        // the exact fold Stats::fingerprint has always used
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(0x0100_0000_01B3)
        }
        let want = [3u64, 1, 4, 1, 5]
            .iter()
            .fold(0xcbf2_9ce4_8422_2325u64, |h, &v| mix(h, v));
        let mut h = Fnv1a::new();
        for v in [3u64, 1, 4, 1, 5] {
            h.word(v);
        }
        assert_eq!(h.finish(), want);
    }

    #[test]
    fn byte_and_word_feeds_differ() {
        // feeding a u64 as a word is not the same as feeding its bytes —
        // callers must pick one granularity per field and stick to it
        assert_ne!(
            Fnv1a::new().word(0x61).finish(),
            fnv1a_bytes(b"a"),
            "word(0x61) must not alias bytes(\"a\")"
        );
    }
}
