//! The paper's compiler pass (§III-A): reuse-distance profiling and binary
//! near/far annotation.
//!
//! The paper profiles a small fraction of warps offline, votes per static
//! operand on whether its reuse is most often near or far (vs RTHLD), and
//! encodes one bit per operand in the binary. Here the "static operand"
//! signature is `(opcode, operand slot, is_dst, register)` — a key that
//! transfers across warps even when divergence makes their dynamic streams
//! differ (DESIGN.md §2 documents this substitution for synthetic traces).
//!
//! Two interchangeable distance engines exist:
//! - [`windowed_reuse_distances`] — pure rust, O(n);
//! - the AOT `reuse_annotate` artifact (L1 Pallas kernel) executed through
//!   [`crate::runtime`].
//! Both implement identical windowed semantics and are cross-checked by a
//! parity test. The profiler below uses the rust engine; the end-to-end
//! example routes through the artifact.

use std::collections::HashMap;

use crate::isa::{Instruction, MAX_DST, MAX_SRC, NUM_REGS};
use crate::trace::KernelTrace;

/// Window (in accesses) of the forward scan; must match
/// `python/compile/constants.py::WINDOW`.
pub const WINDOW: usize = 96;
/// "No reuse found within the window" marker; must match python `CAP`.
pub const CAP: i32 = 255;
/// Value redefined before any read — dead, never cached; must match
/// python `DEAD`.
pub const DEAD: i32 = -2;
/// Default binary threshold (§III-A; Table I text: 12).
pub const RTHLD: u32 = 12;
/// Fig-1 histogram buckets (d<=1, ==2, ==3, 4..=10, >10).
pub const HIST_BUCKETS: usize = 5;

/// Forward reuse distance per access over a flattened `(ids, pos, rw)`
/// stream row — semantics identical to the Pallas kernel: the first
/// re-occurrence of the same id within `window` accesses decides the
/// outcome. If it is a read, the distance in instructions (`pos` delta,
/// clipped to `[0, cap]`); if it is a write, the value is dead (`DEAD`).
/// `cap` when no occurrence in the window; `-1` on padding.
pub fn windowed_reuse_distances(
    ids: &[i32],
    pos: &[i32],
    rw: &[i32],
    window: usize,
    cap: i32,
) -> Vec<i32> {
    assert_eq!(ids.len(), pos.len());
    assert_eq!(ids.len(), rw.len());
    let n = ids.len();
    let mut out = vec![-1i32; n];
    // last unresolved access index per register id
    let mut last: HashMap<i32, usize> = HashMap::new();
    for i in 0..n {
        let id = ids[i];
        if id < 0 {
            continue;
        }
        if let Some(&j) = last.get(&id) {
            // the kernel reports the FIRST occurrence within `window`
            out[j] = if i - j > window {
                cap
            } else if rw[i] == 1 {
                (pos[i] - pos[j]).clamp(0, cap)
            } else {
                DEAD
            };
        }
        last.insert(id, i);
        out[i] = cap; // provisional: resolved by the next occurrence
    }
    out
}

/// Per-access exact reuse distances for one warp stream, flattened in the
/// same operand order as [`KernelTrace::access_streams`] (sources = reads,
/// destinations = writes). Convenience for the profiler and Fig 1.
pub fn stream_distances(stream: &[Instruction], window: usize, cap: i32) -> Vec<i32> {
    let mut ids = Vec::with_capacity(stream.len() * 3);
    let mut pos = Vec::with_capacity(stream.len() * 3);
    let mut rw = Vec::with_capacity(stream.len() * 3);
    for (ii, instr) in stream.iter().enumerate() {
        for &r in instr.sources() {
            ids.push(r as i32);
            pos.push(ii as i32);
            rw.push(1);
        }
        for &r in instr.dests() {
            ids.push(r as i32);
            pos.push(ii as i32);
            rw.push(0);
        }
    }
    windowed_reuse_distances(&ids, &pos, &rw, window, cap)
}

/// Fig-1 histogram buckets over all warps of a trace:
/// `[d<=1, d==2, d==3, 4<=d<=10, d>10]` (cap counts as >10).
pub fn reuse_histogram(trace: &KernelTrace) -> [u64; HIST_BUCKETS] {
    let mut h = [0u64; HIST_BUCKETS];
    for w in &trace.warps {
        for d in stream_distances(w, WINDOW, CAP) {
            if d < 0 {
                continue;
            }
            let b = match d {
                0 | 1 => 0,
                2 => 1,
                3 => 2,
                4..=10 => 3,
                _ => 4,
            };
            h[b] += 1;
        }
    }
    h
}

/// LTRF-style register-interval partition (Sadrosadati et al., PAPERS.md):
/// greedily split `stream` into maximal contiguous intervals whose distinct
/// register working set fits `max_working_set`, and return the interval
/// index of every instruction. The LTRF policy prefetches an interval's
/// registers into the per-warp RFC when the warp enters it, so a run's
/// interval sequence is the software half of the software/hardware
/// cooperative scheme.
///
/// An instruction whose own operand set exceeds `max_working_set` still
/// gets an interval (an instruction cannot be split) — the hardware simply
/// cannot hold all of it at once. Interval indices are non-decreasing and
/// start at 0; an empty stream yields an empty table.
pub fn register_intervals(stream: &[Instruction], max_working_set: usize) -> Vec<u32> {
    let cap = max_working_set.max(1);
    let mut out = Vec::with_capacity(stream.len());
    let mut interval = 0u32;
    let mut in_set = [false; NUM_REGS];
    let mut set_size = 0usize;
    for instr in stream {
        // distinct operand registers this instruction would add to the set
        let mut fresh = [0u8; MAX_SRC + MAX_DST];
        let mut nfresh = 0usize;
        for &r in instr.sources().iter().chain(instr.dests().iter()) {
            if !in_set[r as usize] && !fresh[..nfresh].contains(&r) {
                fresh[nfresh] = r;
                nfresh += 1;
            }
        }
        if set_size + nfresh > cap && set_size > 0 {
            // working set would overflow: start a new interval here
            interval += 1;
            in_set = [false; NUM_REGS];
            set_size = 0;
            nfresh = 0;
            for &r in instr.sources().iter().chain(instr.dests().iter()) {
                if !fresh[..nfresh].contains(&r) {
                    fresh[nfresh] = r;
                    nfresh += 1;
                }
            }
        }
        for &r in &fresh[..nfresh] {
            in_set[r as usize] = true;
        }
        set_size += nfresh;
        out.push(interval);
    }
    out
}

/// Static-operand signature the votes are keyed by.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct SigKey {
    op: u8,
    slot: u8,
    is_dst: bool,
    reg: u8,
}

fn sig(instr: &Instruction, slot: usize, is_dst: bool) -> SigKey {
    SigKey {
        op: instr.op as u8,
        slot: slot as u8,
        is_dst,
        reg: if is_dst {
            instr.dests()[slot]
        } else {
            instr.sources()[slot]
        },
    }
}

/// Profiling result: per static operand, how often its reuse was near/far.
#[derive(Debug, Default, Clone)]
pub struct ReuseProfile {
    votes: HashMap<SigKey, (u32, u32)>, // (near, far)
    /// Warps profiled.
    pub warps_profiled: usize,
    /// Accesses observed.
    pub accesses: u64,
}

impl ReuseProfile {
    /// Majority vote for a signature; `None` if never observed.
    fn is_near(&self, key: &SigKey) -> Option<bool> {
        self.votes.get(key).map(|(n, f)| n >= f)
    }

    /// Number of distinct static operands observed.
    pub fn static_operands(&self) -> usize {
        self.votes.len()
    }
}

/// Profile the first `profile_warps` warps of `trace` (partial profiling,
/// §III-A: "profiling only a few warps produces accurate results").
pub fn profile(trace: &KernelTrace, profile_warps: usize, rthld: u32) -> ReuseProfile {
    let mut p = ReuseProfile::default();
    let n = profile_warps.min(trace.warps.len());
    p.warps_profiled = n;
    for w in 0..n {
        let stream = &trace.warps[w];
        let dists = stream_distances(stream, WINDOW, CAP);
        let mut k = 0usize;
        for instr in stream.iter() {
            for (slot, _r) in instr.sources().iter().enumerate() {
                vote(&mut p, sig(instr, slot, false), dists[k], rthld);
                k += 1;
            }
            for (slot, _r) in instr.dests().iter().enumerate() {
                vote(&mut p, sig(instr, slot, true), dists[k], rthld);
                k += 1;
            }
        }
    }
    p
}

fn vote(p: &mut ReuseProfile, key: SigKey, dist: i32, rthld: u32) {
    if dist == -1 {
        return; // padding
    }
    p.accesses += 1;
    let e = p.votes.entry(key).or_insert((0, 0));
    if dist >= 0 && dist as u32 <= rthld {
        e.0 += 1;
    } else {
        e.1 += 1; // far or dead
    }
}

/// Annotate every instruction of every warp with the profiled binary
/// reuse-distance bits. Unobserved operands default to *far* (conservative:
/// never pollutes the cache with unknown values).
pub fn annotate(trace: &mut KernelTrace, profile: &ReuseProfile) {
    for w in &mut trace.warps {
        for instr in w.iter_mut() {
            for slot in 0..instr.nsrc as usize {
                let near = profile.is_near(&sig(instr, slot, false)).unwrap_or(false);
                instr.set_src_near(slot, near);
            }
            for slot in 0..instr.ndst as usize {
                let near = profile.is_near(&sig(instr, slot, true)).unwrap_or(false);
                instr.set_dst_near(slot, near);
            }
        }
    }
}

/// Oracle annotation: every warp gets its own exact (windowed) distances
/// binarised — the upper bound the binary approximation is measured
/// against (§III-A's claim that the approximation is near-lossless). Dead
/// values are far.
pub fn annotate_precise(trace: &mut KernelTrace, rthld: u32) {
    for w in &mut trace.warps {
        let dists = stream_distances(w, WINDOW, CAP);
        let mut k = 0usize;
        for instr in w.iter_mut() {
            for slot in 0..instr.nsrc as usize {
                instr.set_src_near(slot, dists[k] >= 0 && dists[k] as u32 <= rthld);
                k += 1;
            }
            for slot in 0..instr.ndst as usize {
                instr.set_dst_near(slot, dists[k] >= 0 && dists[k] as u32 <= rthld);
                k += 1;
            }
        }
    }
}

/// Convenience: profile the first `profile_warps` warps and annotate the
/// whole trace in place.
pub fn profile_and_annotate(trace: &mut KernelTrace, profile_warps: usize, rthld: u32) {
    let p = profile(trace, profile_warps, rthld);
    annotate(trace, &p);
}

/// The standard annotation dispatch shared by simulation and trace
/// recording: `profile_warps == 0` selects the precise oracle pass,
/// anything else the partial-profiling vote.
pub fn annotate_trace(trace: &mut KernelTrace, profile_warps: usize, rthld: u32) {
    if profile_warps == 0 {
        annotate_precise(trace, rthld);
    } else {
        profile_and_annotate(trace, profile_warps, rthld);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, OpClass};
    use crate::trace::{find, KernelTrace};

    #[test]
    fn windowed_distances_basic() {
        //          r5    r7    r5    pad
        let ids = [5, 7, 5, -1];
        let pos = [0, 1, 2, 3];
        let rw = [1, 1, 1, 1];
        let d = windowed_reuse_distances(&ids, &pos, &rw, 96, 255);
        assert_eq!(d, vec![2, 255, 255, -1]);
    }

    #[test]
    fn windowed_distances_window_cap() {
        // same id at gap of 3 accesses but window=2 -> cap
        let ids = [9, 1, 2, 9];
        let pos = [0, 1, 2, 3];
        let rw = [1, 1, 1, 1];
        let d = windowed_reuse_distances(&ids, &pos, &rw, 2, 255);
        assert_eq!(d[0], 255);
        // window=3 -> resolved
        let d = windowed_reuse_distances(&ids, &pos, &rw, 3, 255);
        assert_eq!(d[0], 3);
    }

    #[test]
    fn write_after_write_is_dead() {
        // two writes to the same register with no read in between: the
        // first value is dead; the last stays unresolved (cap)
        let ids = [4, 4];
        let pos = [0, 1];
        let rw = [0, 0];
        let d = windowed_reuse_distances(&ids, &pos, &rw, 96, 255);
        assert_eq!(d, vec![DEAD, 255]);
    }

    #[test]
    fn cap_exactly_at_window_boundary() {
        // a re-occurrence exactly `window` accesses later still resolves
        // (the scan is inclusive) ...
        let window = 4;
        let ids = [7, 1, 2, 3, 7];
        let pos = [0, 1, 2, 3, 9];
        let rw = [1; 5];
        let d = windowed_reuse_distances(&ids, &pos, &rw, window, 255);
        assert_eq!(d[0], 9, "gap == window must resolve to the pos delta");
        // ... one access further does not
        let ids = [7, 1, 2, 3, 4, 7];
        let pos = [0, 1, 2, 3, 4, 9];
        let rw = [1; 6];
        let d = windowed_reuse_distances(&ids, &pos, &rw, window, 255);
        assert_eq!(d[0], 255, "gap == window + 1 must cap");
    }

    #[test]
    fn all_padding_row_stays_padding() {
        let ids = [-1; 8];
        let pos = [0; 8];
        let rw = [1; 8];
        let d = windowed_reuse_distances(&ids, &pos, &rw, 96, 255);
        assert!(d.iter().all(|&x| x == -1));
        // and an empty stream is fine too
        assert!(windowed_reuse_distances(&[], &[], &[], 96, 255).is_empty());
    }

    #[test]
    fn same_instruction_distance_zero() {
        let ids = [3, 3];
        let pos = [7, 7];
        let rw = [1, 1];
        let d = windowed_reuse_distances(&ids, &pos, &rw, 96, 255);
        assert_eq!(d[0], 0);
    }

    #[test]
    fn redefinition_kills_value() {
        // read r4, then write r4, then read r4
        let ids = [4, 4, 4];
        let pos = [0, 3, 5];
        let rw = [1, 0, 1];
        let d = windowed_reuse_distances(&ids, &pos, &rw, 96, 255);
        assert_eq!(d[0], DEAD, "value killed by the write");
        assert_eq!(d[1], 2, "the written value is read 2 instrs later");
    }

    #[test]
    fn stream_distances_flatten_order_matches_access_streams() {
        let b = find("kmeans").unwrap();
        let t = KernelTrace::generate(b, 1, 3);
        let by_stream = stream_distances(&t.warps[0], WINDOW, CAP);
        let naccesses: usize = t.warps[0].iter().map(|i| i.noperands()).sum();
        let (ids, pos, rw) = t.access_streams(1, naccesses);
        let by_flat = windowed_reuse_distances(&ids, &pos, &rw, WINDOW, CAP);
        assert_eq!(by_stream, by_flat);
    }

    #[test]
    fn histogram_deepbench_longer_than_rodinia() {
        // the paper's Fig 1: Deepbench has clearly more >10 mass
        let far_frac = |name: &str| {
            let t = KernelTrace::generate(find(name).unwrap(), 4, 11);
            let h = reuse_histogram(&t);
            let total: u64 = h.iter().sum();
            h[4] as f64 / total as f64
        };
        let deep = (far_frac("gemm_t1") + far_frac("conv_t1")) / 2.0;
        let rod = (far_frac("hotspot") + far_frac("kmeans")) / 2.0;
        assert!(
            deep > rod,
            "deepbench >10 frac {deep:.3} should exceed rodinia {rod:.3}"
        );
    }

    #[test]
    fn register_intervals_partition_basics() {
        let alu = |s: &[u8], d: &[u8]| Instruction::new(OpClass::Alu, s, d);
        // working set per instruction: {1,2},{1,2},{3,4},{3,4}
        let stream =
            vec![alu(&[1], &[2]), alu(&[2], &[1]), alu(&[3], &[4]), alu(&[4], &[3])];
        // cap 2: the first pair fits one interval, the second pair the next
        assert_eq!(register_intervals(&stream, 2), vec![0, 0, 1, 1]);
        // cap 4 (>= total distinct): everything is one interval
        assert_eq!(register_intervals(&stream, 4), vec![0, 0, 0, 0]);
        // cap 1: every register introduction overflows the set
        assert_eq!(register_intervals(&stream, 1), vec![0, 1, 2, 3]);
        assert!(register_intervals(&[], 4).is_empty());
    }

    #[test]
    fn register_intervals_are_nondecreasing_and_bounded() {
        let b = find("gemm_t1").unwrap();
        let t = KernelTrace::generate(b, 2, 13);
        for w in &t.warps {
            let cap = 6usize;
            let table = register_intervals(w, cap);
            assert_eq!(table.len(), w.len());
            assert!(table.windows(2).all(|p| p[0] <= p[1] && p[1] - p[0] <= 1));
            // replay the partition: each interval's distinct register set
            // fits the cap unless a single instruction alone exceeds it
            let mut seen: Vec<u8> = Vec::new();
            for (i, instr) in w.iter().enumerate() {
                if i > 0 && table[i] != table[i - 1] {
                    seen.clear();
                }
                let start = seen.len();
                for &r in instr.sources().iter().chain(instr.dests().iter()) {
                    if !seen.contains(&r) {
                        seen.push(r);
                    }
                }
                let solo = seen.len() - start;
                assert!(
                    seen.len() <= cap || seen.len() == solo,
                    "interval working set {} exceeds cap {cap}",
                    seen.len()
                );
            }
        }
    }

    #[test]
    fn register_intervals_oversized_instruction_gets_own_interval() {
        // an 8-operand MMA cannot fit a 4-entry set but must still be placed
        let wide = Instruction::new(OpClass::Mma, &[1, 2, 3, 4, 5, 6], &[7, 8]);
        let narrow = Instruction::new(OpClass::Alu, &[9], &[10]);
        let table = register_intervals(&[narrow, wide, narrow], 4);
        assert_eq!(table.len(), 3);
        assert_eq!(table[0], 0);
        assert!(table[1] > table[0], "overflowing instr opens a new interval");
    }

    #[test]
    fn profile_votes_majority() {
        // two warps: same static op reused near in both -> near bit set
        let mk = || {
            vec![
                Instruction::new(OpClass::Alu, &[1], &[2]),
                Instruction::new(OpClass::Alu, &[2], &[3]), // r2 reused, d=1
                Instruction::new(OpClass::Alu, &[3], &[4]),
            ]
        };
        let mut t =
            KernelTrace { name: "t".into(), kernel_id: 0, warps: vec![mk(), mk()] };
        let p = profile(&t, 2, 12);
        assert_eq!(p.warps_profiled, 2);
        assert!(p.accesses > 0);
        annotate(&mut t, &p);
        // dst r2 of instr 0 is reused at distance 1 -> near
        assert!(t.warps[0][0].dst_is_near(0));
        assert!(t.warps[1][0].dst_is_near(0));
        // dst r4 of last instr never reused -> far
        assert!(!t.warps[0][2].dst_is_near(0));
    }

    #[test]
    fn unobserved_operands_default_far() {
        let mut t = KernelTrace {
            name: "t".into(),
            kernel_id: 0,
            warps: vec![vec![Instruction::new(OpClass::Alu, &[1, 2], &[3])]],
        };
        let empty = ReuseProfile::default();
        annotate(&mut t, &empty);
        assert!(!t.warps[0][0].src_is_near(0));
        assert!(!t.warps[0][0].dst_is_near(0));
    }

    #[test]
    fn partial_profiling_close_to_full() {
        // §III-A: profiling a few warps ≈ profiling all warps
        let b = find("srad_v1").unwrap();
        let t = KernelTrace::generate(b, 32, 5);
        let few = profile(&t, 2, RTHLD);
        let all = profile(&t, 32, RTHLD);
        // compare the annotation decisions on a fresh copy
        let mut ta = t.clone();
        let mut tb = t.clone();
        annotate(&mut ta, &few);
        annotate(&mut tb, &all);
        let mut same = 0u64;
        let mut total = 0u64;
        for (wa, wb) in ta.warps.iter().zip(tb.warps.iter()) {
            for (ia, ib) in wa.iter().zip(wb.iter()) {
                total += (ia.nsrc + ia.ndst) as u64;
                let mut s = 0;
                for k in 0..ia.nsrc as usize {
                    if ia.src_is_near(k) == ib.src_is_near(k) {
                        s += 1;
                    }
                }
                for k in 0..ia.ndst as usize {
                    if ia.dst_is_near(k) == ib.dst_is_near(k) {
                        s += 1;
                    }
                }
                same += s;
            }
        }
        let agreement = same as f64 / total as f64;
        assert!(
            agreement > 0.9,
            "partial profiling agreement too low: {agreement:.3}"
        );
    }

    #[test]
    fn precise_annotation_marks_accumulators_near() {
        let b = find("rnn_i2").unwrap();
        let mut t = KernelTrace::generate(b, 1, 9);
        annotate_precise(&mut t, RTHLD);
        // at least some MMA accumulator sources must be near
        let near_mma_srcs = t.warps[0]
            .iter()
            .filter(|i| i.op == OpClass::Mma)
            .filter(|i| (0..i.nsrc as usize).any(|k| i.src_is_near(k)))
            .count();
        assert!(near_mma_srcs > 0);
    }
}
