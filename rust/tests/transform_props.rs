//! Property-style tests for the trace transform pipeline
//! (`trace/io/transform.rs`): hand-rolled input sweeps (benches x seeds x
//! transform parameters) instead of a property-testing crate, asserting
//! the invariants that matter to scenario scaling:
//!
//! - any composed `subsample ∘ window ∘ remap` result survives the
//!   `.mtrace` writer -> reader round trip **bit-identically**,
//! - `apply_all` composes left to right (order is observable),
//! - degenerate parameters (empty window, identity/full-permutation
//!   remap, subsample factor past the warp count) degrade gracefully
//!   instead of panicking.

use malekeh::trace::io::{apply_all, read_str, write_string};
use malekeh::trace::{find, KernelTrace, Transform};

fn sample(bench: &str, nwarps: usize, seed: u64) -> KernelTrace {
    KernelTrace::generate(find(bench).unwrap(), nwarps, seed)
}

/// The composed pipeline under test, parameterised by the sweep.
fn composed(k: usize, start: usize, len: usize, pairs: Vec<(u8, u8)>) -> Vec<Transform> {
    vec![
        Transform::WarpSubsample { keep_one_in: k },
        Transform::InstructionWindow { start, len },
        Transform::RegisterRemap { pairs },
    ]
}

#[test]
fn composed_transforms_round_trip_bit_identically() {
    for bench in ["hotspot", "kmeans", "gemm_t1"] {
        for seed in [1u64, 7, 1234] {
            for (k, start, len) in [(1, 0, 1000), (2, 5, 10), (3, 0, 1), (8, 2, 4)] {
                let t = sample(bench, 8, seed);
                let out = apply_all(&t, &composed(k, start, len, vec![(2, 200), (7, 3)]));
                let s1 = write_string(&out).expect("serialize transformed trace");
                let back = read_str(&s1).expect("parse own writer output");
                let s2 = write_string(&back).expect("re-serialize");
                assert_eq!(
                    s1, s2,
                    "{bench} seed={seed} k={k} window=[{start},{start}+{len}): \
                     writer->reader->writer is not bit-identical"
                );
                assert_eq!(back.warps, out.warps, "instruction streams drifted");
                assert_eq!(back.kernel_id, out.kernel_id);
            }
        }
    }
}

#[test]
fn apply_all_order_is_observable() {
    // window-then-subsample == subsample-then-window only for the warp
    // axis vs instruction axis — but remap-then-window differs from
    // window-then-remap when the remap collides with sliced-off operands?
    // No: remap is per-instruction, so those commute. The observable
    // non-commutation is subsample ∘ window vs window ∘ subsample on a
    // *warp-varying* trace... which also commutes (different axes). What
    // cannot commute is two windows: [5,15) then [0,5) picks instructions
    // 5..10, while [0,5) then [5,15) leaves only the EXIT. Pin that.
    let t = sample("hotspot", 8, 7);
    let a = apply_all(
        &t,
        &[
            Transform::InstructionWindow { start: 5, len: 10 },
            Transform::InstructionWindow { start: 0, len: 5 },
        ],
    );
    let b = apply_all(
        &t,
        &[
            Transform::InstructionWindow { start: 0, len: 5 },
            Transform::InstructionWindow { start: 5, len: 10 },
        ],
    );
    // a: instructions 5..10 of the original (+EXIT); b: nothing survives
    for (w, orig) in a.warps.iter().zip(t.warps.iter()) {
        assert_eq!(w.len(), 6);
        assert_eq!(&w[..5], &orig[5..10]);
    }
    assert!(
        b.warps.iter().all(|w| w.len() == 1),
        "second window past the first's end must leave only EXIT"
    );
    // and chained remaps apply left to right: r->a then a->b moves the
    // original r *through* to b, while the reverse order parks it at a.
    // Probe registers come from the trace itself (the workload generators
    // only use part of the id space)
    let used = |reg: u8, tr: &KernelTrace| {
        tr.warps
            .iter()
            .flatten()
            .any(|i| i.sources().contains(&reg) || i.dests().contains(&reg))
    };
    let r = *t.warps[0]
        .iter()
        .flat_map(|i| i.sources())
        .next()
        .expect("probe trace has a source operand");
    let mut free = (0..=255u8).filter(|&x| !used(x, &t));
    let a = free.next().expect("an unused register id exists");
    let b = free.next().expect("a second unused register id exists");
    let c = apply_all(
        &t,
        &[
            Transform::RegisterRemap { pairs: vec![(r, a)] },
            Transform::RegisterRemap { pairs: vec![(a, b)] },
        ],
    );
    let d = apply_all(
        &t,
        &[
            Transform::RegisterRemap { pairs: vec![(a, b)] },
            Transform::RegisterRemap { pairs: vec![(r, a)] },
        ],
    );
    assert!(!used(r, &c) && !used(a, &c) && used(b, &c), "r must chain through to b");
    assert!(
        used(a, &d) && !used(b, &d),
        "reverse remap order must park r at a (the a->b hop ran first, on nothing)"
    );
}

#[test]
fn degenerate_parameters_do_not_panic() {
    let t = sample("hotspot", 8, 7);
    // empty window: every warp degrades to a bare EXIT and still
    // serializes/parses
    let empty = apply_all(&t, &[Transform::InstructionWindow { start: 0, len: 0 }]);
    assert!(empty.warps.iter().all(|w| w.len() == 1));
    let s = write_string(&empty).expect("empty-window trace serializes");
    assert_eq!(read_str(&s).expect("and parses").warps, empty.warps);
    // window entirely past the end (saturating arithmetic territory)
    let past = apply_all(
        &t,
        &[Transform::InstructionWindow { start: usize::MAX, len: usize::MAX }],
    );
    assert!(past.warps.iter().all(|w| w.len() == 1));
    // full-permutation remap (every id named, including fixpoints) is a
    // bijection: applying it then its inverse restores the trace
    let fwd: Vec<(u8, u8)> = (0..=255u8).map(|r| (r, r.wrapping_add(1))).collect();
    let inv: Vec<(u8, u8)> = (0..=255u8).map(|r| (r, r.wrapping_sub(1))).collect();
    let there = apply_all(&t, &[Transform::RegisterRemap { pairs: fwd }]);
    let back = apply_all(&there, &[Transform::RegisterRemap { pairs: inv }]);
    assert_eq!(back.warps, t.warps, "permutation remap must invert cleanly");
    // subsample factor beyond the warp count keeps exactly warp 0
    let one = apply_all(&t, &[Transform::WarpSubsample { keep_one_in: 1000 }]);
    assert_eq!(one.warps.len(), 1);
    assert_eq!(one.warps[0], t.warps[0]);
}
