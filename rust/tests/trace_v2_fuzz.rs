//! Adversarial battery for the binary v2 parser: truncations, trailing
//! garbage, version skew, non-canonical varints, absurd declared lengths
//! and a seeded single-byte mutation sweep must all surface as clean
//! `Err`s — never a panic, never an unbounded allocation. The content
//! digest makes this total: any byte flip that survives the structural
//! checks changes the decoded content and fails the digest instead.
//!
//! The second half is the transform property from `transform_props.rs`
//! lifted onto the v2 container: subsample ∘ window ∘ remap composed on
//! a trace round-trips through `write_v2_bytes`/`read_v2_slice`
//! losslessly and re-encodes bit-identically (the writer is canonical).

use malekeh::compiler;
use malekeh::trace::io::{self, Transform, MAGIC2};
use malekeh::trace::{find, KernelTrace};

/// Minimal xorshift64 so the mutation sweep is seeded and reproducible
/// without pulling in a dependency.
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

/// Canonical LEB128, mirroring the writer — used to handcraft headers
/// around hostile field values the real writer refuses to emit.
fn uv(mut v: u64) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
    out
}

fn sample(nwarps: usize) -> KernelTrace {
    let mut t = KernelTrace::generate(find("hotspot").unwrap(), nwarps, 0xFEED);
    compiler::profile_and_annotate(&mut t, 2, 12);
    t
}

fn valid_bytes() -> Vec<u8> {
    io::write_v2_bytes(&sample(5)).unwrap()
}

#[test]
fn every_strict_prefix_is_rejected() {
    let bytes = valid_bytes();
    io::read_v2_slice(&bytes).expect("the unmutated file must parse");
    for len in 0..bytes.len() {
        assert!(
            io::read_v2_slice(&bytes[..len]).is_err(),
            "prefix of {len}/{} bytes parsed as a complete trace",
            bytes.len()
        );
    }
}

#[test]
fn trailing_garbage_and_version_skew_are_rejected() {
    let bytes = valid_bytes();
    for tail in [&b"\x00"[..], b"\xc1", b"mtrace v2\n"] {
        let mut padded = bytes.clone();
        padded.extend_from_slice(tail);
        assert!(io::read_v2_slice(&padded).is_err(), "trailing {tail:?} accepted");
    }
    // a future-versioned magic must be refused, not best-effort parsed
    let mut skewed = bytes;
    skewed[..MAGIC2.len()].copy_from_slice(b"mtrace v3\n");
    assert!(io::read_v2_slice(&skewed).is_err(), "unknown version accepted");
}

#[test]
fn hostile_declared_lengths_fail_without_allocating() {
    // name_len = u64::MAX straight after the magic
    let mut f = MAGIC2.to_vec();
    f.extend(uv(u64::MAX));
    assert!(io::read_v2_slice(&f).is_err(), "absurd name_len accepted");
    // well-formed header, then a chunk declaring u64::MAX records
    let mut g = MAGIC2.to_vec();
    g.extend(uv(1)); // name_len
    g.push(b'k');
    g.extend(uv(3)); // kernel_id
    g.extend(uv(1)); // nwarps
    g.push(0xC1); // chunk tag
    g.extend(uv(0)); // warp
    g.extend(uv(u64::MAX)); // count
    assert!(io::read_v2_slice(&g).is_err(), "absurd chunk count accepted");
    // same header, sane count, but a payload length past the cap
    let mut h = MAGIC2.to_vec();
    h.extend(uv(1));
    h.push(b'k');
    h.extend(uv(3));
    h.extend(uv(1));
    h.push(0xC1);
    h.extend(uv(0));
    h.extend(uv(1)); // count
    h.push(0); // ENC_RAW
    h.extend(uv(u64::MAX)); // payload_len
    assert!(io::read_v2_slice(&h).is_err(), "absurd payload_len accepted");
}

#[test]
fn non_canonical_varints_are_rejected() {
    // 0x81 0x00 decodes to 1 in plain LEB128 but is non-minimal; the
    // format demands the canonical encoding so every file has exactly
    // one byte representation
    let mut f = MAGIC2.to_vec();
    f.extend_from_slice(&[0x81, 0x00]); // name_len = 1, padded
    f.push(b'k');
    f.extend(uv(3));
    f.extend(uv(1));
    assert!(io::read_v2_slice(&f).is_err(), "non-canonical varint accepted");
}

#[test]
fn seeded_single_byte_mutations_never_parse_and_never_panic() {
    let bytes = valid_bytes();
    let mut rng = XorShift(0x9E3779B97F4A7C15);
    for round in 0..200u32 {
        let mut mutated = bytes.clone();
        let idx = (rng.next() % bytes.len() as u64) as usize;
        let mask = (rng.next() % 255) as u8 + 1; // never a no-op flip
        mutated[idx] ^= mask;
        assert!(
            io::read_v2_slice(&mutated).is_err(),
            "round {round}: flipping byte {idx} with {mask:#04x} still parsed \
             ({} bytes) — structure or digest check has a hole",
            bytes.len()
        );
    }
}

#[test]
fn transformed_traces_roundtrip_v2_bit_identically() {
    // the transform_props property on the binary container: for a grid of
    // subsample ∘ window ∘ remap pipelines, encode → decode is lossless
    // and decode → encode reproduces the exact bytes (canonical writer)
    let base = sample(8);
    for keep_one_in in [1usize, 2, 3] {
        for (start, len) in [(0usize, 40usize), (7, 25), (100, 10_000)] {
            let out = io::apply_all(
                &base,
                &[
                    Transform::WarpSubsample { keep_one_in },
                    Transform::InstructionWindow { start, len },
                    Transform::RegisterRemap { pairs: vec![(2, 200), (5, 90)] },
                ],
            );
            let bytes = io::write_v2_bytes(&out).unwrap();
            let back = io::read_v2_slice(&bytes)
                .unwrap_or_else(|e| panic!("keep {keep_one_in} window {start}+{len}: {e}"));
            assert_eq!(back.name, out.name);
            assert_eq!(back.kernel_id, out.kernel_id);
            assert_eq!(back.warps, out.warps, "keep {keep_one_in} window {start}+{len}");
            assert_eq!(
                io::write_v2_bytes(&back).unwrap(),
                bytes,
                "re-encode is not bit-identical (keep {keep_one_in} window {start}+{len})"
            );
        }
    }
}
