//! Randomized lockstep equivalence of the SoA hot path against the AoS
//! reference.
//!
//! [`CollectorArray`] (flat arrays + packed bitmasks, the simulator's hot
//! path) and the retained [`Collector`] struct (the obviously-correct
//! array-of-structs form) are driven through identical randomized
//! operation streams with twin same-seeded RNGs. After every operation the
//! complete observable state must match — per-unit flags, the packed
//! ready/occupancy masks against a per-unit recompute, the value-bit
//! mirrors against the cache tables — and the scan helpers
//! (`free_unit_reservoir`, the Malekeh dual reservoir, the owns-values
//! priority order) must match the AoS per-struct scans **draw-for-draw**:
//! same picks AND same number of RNG draws, verified by comparing the next
//! raw output of both streams.
//!
//! Over 550 seeded runs (OCU, CCU, CCU-with-admission, and BOW-window
//! variants) this pins the bit-identity contract the SoA rework rests on.

use malekeh::isa::{Instruction, OpClass};
use malekeh::sim::collector::{
    plain_lru_victim, reuse_guided_victim, AllocResult, Collector, CollectorArray,
};
use malekeh::sim::policy::free_unit_reservoir;
use malekeh::util::Rng;

const CT_ENTRIES: usize = 8;
const BOW_WINDOW: usize = 4;
const NREGS: u8 = 16; // small register space => frequent hits/evictions
const NWARPS: u8 = 6;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Ocu,
    Ccu,
    CcuAdmit,
    Boc,
}

/// Random instruction over a small register window; near bits are set
/// randomly in CCU modes so the near/far paths (write filter, far
/// reservoir, victim choice) are all exercised.
fn rand_instr(d: &mut Rng, near_bits: bool) -> Instruction {
    let ops = [
        OpClass::Alu,
        OpClass::Sfu,
        OpClass::Mma,
        OpClass::LdGlobal,
        OpClass::StGlobal,
        OpClass::LdShared,
    ];
    let op = ops[d.below(ops.len())];
    let nsrc = 1 + d.below(3);
    let srcs: Vec<u8> = (0..nsrc).map(|_| d.below(NREGS as usize) as u8).collect();
    let dsts: Vec<u8> = if d.below(4) == 0 {
        Vec::new()
    } else {
        vec![d.below(NREGS as usize) as u8]
    };
    let mut i = Instruction::new(op, &srcs, &dsts);
    if near_bits {
        for s in 0..nsrc {
            if d.below(2) == 0 {
                i.set_src_near(s, true);
            }
        }
    }
    i
}

/// AoS reference of `free_unit_reservoir`: the old per-struct scan.
fn free_unit_reservoir_aos(cols: &[Collector], rng: &mut Rng) -> Option<usize> {
    let mut seen = 0usize;
    let mut pick = None;
    for (i, c) in cols.iter().enumerate() {
        if c.occupied {
            continue;
        }
        seen += 1;
        if rng.below(seen) == 0 {
            pick = Some(i);
        }
    }
    pick
}

/// AoS reference of the Malekeh dual reservoir (§IV-B2): per free unit in
/// ascending order, one `free` draw always, then one `far` draw iff the
/// unit holds no near value — the exact interleaving the SoA bitmask loop
/// must reproduce.
fn dual_reservoir_aos(cols: &[Collector], rng: &mut Rng) -> (Option<usize>, Option<usize>) {
    let mut nfree = 0usize;
    let mut free_pick = None;
    let mut nfar = 0usize;
    let mut far_pick = None;
    for (i, c) in cols.iter().enumerate() {
        if c.occupied {
            continue;
        }
        nfree += 1;
        if rng.below(nfree) == 0 {
            free_pick = Some(i);
        }
        if !c.ct.has_near_value() {
            nfar += 1;
            if rng.below(nfar) == 0 {
                far_pick = Some(i);
            }
        }
    }
    (free_pick, far_pick)
}

/// SoA port of the dual reservoir, written the way `MalekehPolicy`
/// iterates the packed free bitmask.
fn dual_reservoir_soa(arr: &CollectorArray, rng: &mut Rng) -> (Option<usize>, Option<usize>) {
    let mut nfree = 0usize;
    let mut free_pick = None;
    let mut nfar = 0usize;
    let mut far_pick = None;
    let mut free = arr.free_mask();
    while free != 0 {
        let i = free.trailing_zeros() as usize;
        free &= free - 1;
        nfree += 1;
        if rng.below(nfree) == 0 {
            free_pick = Some(i);
        }
        if !arr.has_near_value(i) {
            nfar += 1;
            if rng.below(nfar) == 0 {
                far_pick = Some(i);
            }
        }
    }
    (free_pick, far_pick)
}

/// AoS reference of `CollectorArray::warp_owns_values`.
fn warp_owns_values_aos(cols: &[Collector], w: u8) -> bool {
    cols.iter().any(|c| c.ct.has_values() && c.owner == Some(w))
}

fn assert_alloc_eq(a: &AllocResult, b: &AllocResult, seed: u64, step: usize) {
    assert_eq!(a.hits, b.hits, "hits: seed {seed} step {step}");
    assert_eq!(a.wb_reuse, b.wb_reuse, "wb_reuse: seed {seed} step {step}");
    assert_eq!(a.flushed, b.flushed, "flushed: seed {seed} step {step}");
    assert_eq!(
        a.misses.as_slice(),
        b.misses.as_slice(),
        "miss list: seed {seed} step {step}"
    );
}

/// Full observable-state comparison after each operation.
fn assert_state_eq(cols: &[Collector], arr: &CollectorArray, seed: u64, step: usize) {
    assert_eq!(cols.len(), arr.len());
    let mut occ = 0u64;
    let mut rdy = 0u64;
    for (ci, c) in cols.iter().enumerate() {
        let tag = format!("seed {seed} step {step} unit {ci}");
        assert_eq!(c.occupied, arr.occupied(ci), "occupied: {tag}");
        assert_eq!(c.ready(), arr.ready(ci), "ready: {tag}");
        assert_eq!(c.owner, arr.owner(ci), "owner: {tag}");
        if c.occupied {
            assert_eq!(c.instr, *arr.instr(ci), "instr: {tag}");
            assert_eq!(c.issue_cycle, arr.issue_cycle(ci), "issue_cycle: {tag}");
        }
        assert_eq!(c.cur_seq, arr.cur_seq(ci), "cur_seq: {tag}");
        // value-bit mirrors vs the reference tables
        assert_eq!(c.ct.has_values(), arr.has_values(ci), "hasv mirror: {tag}");
        assert_eq!(
            c.ct.has_near_value(),
            arr.has_near_value(ci),
            "nearv mirror: {tag}"
        );
        // and the SoA cold table itself must track the reference table
        assert_eq!(c.ct.valid_count(), arr.ct(ci).valid_count(), "valid_count: {tag}");
        for reg in 0..NREGS {
            assert_eq!(c.ct.lookup(reg), arr.ct(ci).lookup(reg), "lookup({reg}): {tag}");
        }
        if c.occupied {
            occ |= 1 << ci;
        }
        if c.ready() {
            rdy |= 1 << ci;
        }
    }
    assert_eq!(occ, arr.occ_mask(), "occ mask: seed {seed} step {step}");
    assert_eq!(rdy, arr.ready_mask(), "ready mask: seed {seed} step {step}");
    assert_eq!(
        !occ & ((1u64 << cols.len()) - 1),
        arr.free_mask(),
        "free mask: seed {seed} step {step}"
    );

    // scan helpers, draw-for-draw: same pick AND same draw count (the
    // trailing next_u64 comparison fails if either side drew a different
    // number of times)
    let mut ra = Rng::new(seed ^ 0x5ca1ab1e ^ step as u64);
    let mut rb = ra.clone();
    assert_eq!(
        free_unit_reservoir_aos(cols, &mut ra),
        free_unit_reservoir(arr, &mut rb),
        "reservoir pick: seed {seed} step {step}"
    );
    assert_eq!(ra.next_u64(), rb.next_u64(), "reservoir draws: seed {seed} step {step}");

    let mut ra = Rng::new(seed ^ 0xdeadbea7 ^ step as u64);
    let mut rb = ra.clone();
    assert_eq!(
        dual_reservoir_aos(cols, &mut ra),
        dual_reservoir_soa(arr, &mut rb),
        "dual reservoir: seed {seed} step {step}"
    );
    assert_eq!(ra.next_u64(), rb.next_u64(), "dual draws: seed {seed} step {step}");

    // Malekeh §IV-B1 priority order from the bitmask walk vs the AoS scan
    for w in 0..NWARPS {
        assert_eq!(
            warp_owns_values_aos(cols, w),
            arr.warp_owns_values(w),
            "owns-values: seed {seed} step {step} warp {w}"
        );
    }
    for w in 0..NWARPS {
        assert_eq!(
            cols.iter().position(|c| c.owner == Some(w)),
            arr.position_owned_by(w),
            "position_owned_by: seed {seed} step {step} warp {w}"
        );
    }
}

/// Drive both layouts through one randomized operation stream.
fn lockstep(seed: u64, mode: Mode, steps: usize) {
    let mut driver = Rng::new(seed);
    let nunits = 1 + driver.below(8);
    let mut cols: Vec<Collector> = (0..nunits).map(|_| Collector::new(CT_ENTRIES)).collect();
    let mut arr = CollectorArray::new(nunits, CT_ENTRIES);
    if mode == Mode::Boc {
        arr.enable_windows();
    }
    // twin op-RNG streams: every RNG-consuming operation draws from both
    let mut rng_a = Rng::new(seed ^ 0xabcd_1234);
    let mut rng_b = rng_a.clone();
    let near_bits = matches!(mode, Mode::Ccu | Mode::CcuAdmit);

    for step in 0..steps {
        match driver.below(10) {
            // ---- allocate on a random free unit
            0..=3 => {
                let Some(ci) = (0..nunits).find(|&i| !cols[i].occupied) else {
                    continue;
                };
                let warp = driver.below(NWARPS as usize) as u8;
                let instr = rand_instr(&mut driver, near_bits);
                let now = step as u64;
                let (ra, rb) = match mode {
                    Mode::Ocu => (
                        cols[ci].alloc_ocu(warp, &instr, now),
                        arr.alloc_ocu(ci, warp, &instr, now),
                    ),
                    Mode::Ccu => (
                        cols[ci].alloc_ccu(warp, &instr, now, &mut rng_a, &mut reuse_guided_victim),
                        arr.alloc_ccu(ci, warp, &instr, now, &mut rng_b, &mut reuse_guided_victim),
                    ),
                    Mode::CcuAdmit => (
                        cols[ci].alloc_ccu_admit(
                            warp,
                            &instr,
                            now,
                            &mut rng_a,
                            &mut plain_lru_victim,
                            &mut |_, reg| reg < NREGS / 2,
                        ),
                        arr.alloc_ccu_admit(
                            ci,
                            warp,
                            &instr,
                            now,
                            &mut rng_b,
                            &mut plain_lru_victim,
                            &mut |_, reg| reg < NREGS / 2,
                        ),
                    ),
                    Mode::Boc => (
                        cols[ci].alloc_boc(warp, &instr, now, BOW_WINDOW),
                        arr.alloc_boc(ci, warp, &instr, now, BOW_WINDOW),
                    ),
                };
                assert_alloc_eq(&ra, &rb, seed, step);
            }
            // ---- a bank operand arrives for a pending source slot
            4..=5 => {
                let mut pending: Vec<(usize, u8)> = Vec::new();
                for (i, c) in cols.iter().enumerate() {
                    if !c.occupied || c.ready() {
                        continue;
                    }
                    for s in 0..c.instr.nsrc {
                        if c.src_ready & (1 << s) == 0 {
                            pending.push((i, s));
                        }
                    }
                }
                if pending.is_empty() {
                    continue;
                }
                let (ci, slot) = pending[driver.below(pending.len())];
                let reg = cols[ci].instr.srcs[slot as usize];
                let bow = mode == Mode::Boc;
                cols[ci].bank_operand_arrived(slot, reg, bow);
                arr.bank_operand_arrived(ci, slot, reg, bow);
            }
            // ---- dispatch a ready unit
            6..=7 => {
                let ready: Vec<usize> = (0..nunits).filter(|&i| cols[i].ready()).collect();
                if ready.is_empty() {
                    continue;
                }
                let ci = ready[driver.below(ready.len())];
                let caching = matches!(mode, Mode::Ccu | Mode::CcuAdmit);
                cols[ci].dispatched(caching);
                arr.dispatched(ci, caching);
            }
            // ---- a writeback targets a random unit
            8 => {
                let ci = driver.below(nunits);
                let reg = driver.below(NREGS as usize) as u8;
                if mode == Mode::Boc {
                    let seq = 1 + driver.below((cols[ci].cur_seq as usize).max(1)) as u64;
                    assert_eq!(
                        cols[ci].boc_writeback(seq, reg),
                        arr.boc_writeback(ci, seq, reg),
                        "boc_writeback: seed {seed} step {step}"
                    );
                } else {
                    let warp = driver.below(NWARPS as usize) as u8;
                    let near = driver.below(2) == 0;
                    let no_filter = driver.below(4) == 0;
                    assert_eq!(
                        cols[ci].ccu_writeback(
                            warp,
                            reg,
                            near,
                            &mut rng_a,
                            &mut reuse_guided_victim,
                            no_filter,
                        ),
                        arr.ccu_writeback(
                            ci,
                            warp,
                            reg,
                            near,
                            &mut rng_b,
                            &mut reuse_guided_victim,
                            no_filter,
                        ),
                        "ccu_writeback: seed {seed} step {step}"
                    );
                }
            }
            // ---- operand delivered over the collector port (policy hit)
            _ => {
                let occupied: Vec<usize> =
                    (0..nunits).filter(|&i| cols[i].occupied && !cols[i].ready()).collect();
                if occupied.is_empty() {
                    continue;
                }
                let ci = occupied[driver.below(occupied.len())];
                let c = &cols[ci];
                let slots: Vec<u8> =
                    (0..c.instr.nsrc).filter(|&s| c.src_ready & (1 << s) == 0).collect();
                let slot = slots[driver.below(slots.len())];
                cols[ci].deliver(slot);
                arr.deliver(ci, slot);
            }
        }
        assert_state_eq(&cols, &arr, seed, step);
    }
    // twin op-RNG streams consumed the same number of draws end to end
    assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "op rng streams: seed {seed}");
}

#[test]
fn ocu_lockstep_matches_aos() {
    for seed in 0..150u64 {
        lockstep(seed, Mode::Ocu, 60);
    }
}

#[test]
fn ccu_lockstep_matches_aos_draw_for_draw() {
    for seed in 0..125u64 {
        lockstep(seed, Mode::Ccu, 80);
    }
    for seed in 0..125u64 {
        lockstep(1000 + seed, Mode::CcuAdmit, 80);
    }
}

#[test]
fn bow_window_lockstep_matches_aos() {
    for seed in 0..150u64 {
        lockstep(2000 + seed, Mode::Boc, 80);
    }
}

#[test]
fn empty_and_full_banks_are_degenerate_but_consistent() {
    // 0 units: every mask empty, every scan returns nothing
    let arr = CollectorArray::new(0, CT_ENTRIES);
    assert!(arr.is_empty());
    assert_eq!(arr.free_mask(), 0);
    assert_eq!(arr.ready_mask(), 0);
    let mut r = Rng::new(3);
    assert_eq!(free_unit_reservoir(&arr, &mut r), None);
    // full bank: reservoir returns None and draws nothing
    let mut arr = CollectorArray::new(3, CT_ENTRIES);
    let i = Instruction::new(OpClass::Alu, &[1], &[2]);
    for ci in 0..3 {
        arr.alloc_ocu(ci, ci as u8, &i, 0);
    }
    let mut ra = Rng::new(5);
    let mut rb = ra.clone();
    assert_eq!(free_unit_reservoir(&arr, &mut ra), None);
    assert_eq!(ra.next_u64(), rb.next_u64(), "no draws on a full bank");
}
