//! Property-based tests (hand-rolled generators — proptest is unavailable
//! offline). Each property runs many seeded random cases; on failure the
//! seed is printed so the case reproduces exactly.

use malekeh::compiler::{windowed_reuse_distances, CAP, DEAD};
use malekeh::config::{GpuConfig, Scheme, SthldMode};
use malekeh::sim::collector::{plain_lru_victim, reuse_guided_victim, CacheTable, VictimFn};
use malekeh::sim::SthldController;
use malekeh::util::Rng;

const CASES: u64 = 60;

/// Random access stream generator.
fn random_stream(rng: &mut Rng, len: usize, nregs: usize) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let mut ids = Vec::with_capacity(len);
    let mut pos = Vec::with_capacity(len);
    let mut rw = Vec::with_capacity(len);
    let mut p = 0i32;
    for _ in 0..len {
        ids.push(if rng.chance(0.05) { -1 } else { rng.below(nregs) as i32 });
        p += rng.below(2) as i32;
        pos.push(p);
        rw.push(if rng.chance(0.65) { 1 } else { 0 });
    }
    (ids, pos, rw)
}

/// O(n²) oracle with the same semantics as the kernel.
fn oracle(ids: &[i32], pos: &[i32], rw: &[i32], window: usize, cap: i32) -> Vec<i32> {
    let n = ids.len();
    let mut out = vec![-1i32; n];
    for i in 0..n {
        if ids[i] < 0 {
            continue;
        }
        let mut d = cap;
        for j in i + 1..(i + window + 1).min(n) {
            if ids[j] == ids[i] {
                d = if rw[j] == 1 {
                    (pos[j] - pos[i]).clamp(0, cap)
                } else {
                    DEAD
                };
                break;
            }
        }
        out[i] = d;
    }
    out
}

#[test]
fn prop_windowed_distances_match_quadratic_oracle() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let len = rng.range(1, 300);
        let nregs = rng.range(1, 40);
        let window = rng.range(1, 120);
        let (ids, pos, rw) = random_stream(&mut rng, len, nregs);
        let fast = windowed_reuse_distances(&ids, &pos, &rw, window, CAP);
        let slow = oracle(&ids, &pos, &rw, window, CAP);
        assert_eq!(fast, slow, "seed {seed} len {len} window {window}");
    }
}

#[test]
fn prop_distances_well_formed() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let (ids, pos, rw) = random_stream(&mut rng, 200, 16);
        let d = windowed_reuse_distances(&ids, &pos, &rw, 96, CAP);
        for (i, &x) in d.iter().enumerate() {
            if ids[i] < 0 {
                assert_eq!(x, -1, "padding lane must be -1");
            } else {
                assert!(
                    (0..=CAP).contains(&x) || x == DEAD,
                    "bad distance {x} at {i}"
                );
            }
        }
    }
}

#[test]
fn prop_cache_table_invariants() {
    // after any operation sequence: at most one valid entry per tag, and
    // locked entries survive any allocation storm
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x51E);
        let entries = rng.range(6, 16);
        let mut ct = CacheTable::new(entries);
        let mut locked_regs = Vec::new();
        for step in 0..300 {
            match rng.below(10) {
                0..=5 => {
                    let reg = rng.below(32) as u8;
                    let lock = rng.chance(0.2) && locked_regs.len() < 5;
                    let near = rng.chance(0.5);
                    // alternate between the two built-in victim choosers
                    // (named bindings: a `&mut fn_item` temporary would not
                    // outlive the `let` through the if/else arms)
                    let (mut lru, mut guided) = (plain_lru_victim, reuse_guided_victim);
                    let victim: VictimFn = if rng.chance(0.3) { &mut lru } else { &mut guided };
                    if ct.allocate(reg, near, lock, &mut rng, victim).is_some() && lock {
                        locked_regs.push(reg);
                    }
                }
                6..=7 => {
                    if let Some(i) = ct.lookup(rng.below(32) as u8) {
                        ct.touch(i);
                    }
                }
                8 => {
                    ct.unlock_all();
                    locked_regs.clear();
                }
                _ => {
                    ct.flush();
                    locked_regs.clear();
                }
            }
            // no duplicate tags among valid entries
            let mut seen = std::collections::HashSet::new();
            for i in 0..entries {
                let e = ct.entry(i);
                if e.valid {
                    assert!(seen.insert(e.reg), "dup tag {} seed {seed} step {step}", e.reg);
                }
            }
            // locked entries still present
            for &r in &locked_regs {
                assert!(ct.lookup(r).is_some(), "locked reg {r} evicted, seed {seed}");
            }
        }
    }
}

#[test]
fn prop_sthld_controller_bounded_and_live() {
    // random IPC sequences: STHLD stays within [0, max]; controller never
    // panics; with a perfectly flat curve it eventually moves upward
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x6A5);
        let max = rng.range(2, 64) as u32;
        let mut c = SthldController::new(max, 0.02);
        for _ in 0..200 {
            let ipc = rng.f64() * 4.0;
            let s = c.interval_end(ipc);
            assert!(s <= max, "sthld {s} > max {max} seed {seed}");
        }
    }
}

#[test]
fn prop_simulation_conservation_random_configs() {
    // random (small) configs: instructions conserved, reads conserved,
    // all warps retire
    let benches = ["nn", "kmeans", "bfs", "rnn_i1"];
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0xC0DE);
        let mut cfg = GpuConfig::table1_baseline()
            .with_scheme(*rng.pick(&Scheme::all()));
        cfg.num_sms = 1;
        cfg.warps_per_sm = [8, 16, 32][rng.below(3)];
        cfg.banks_per_sub_core = rng.range(1, 4);
        cfg.collectors_per_sub_core = rng.range(2, 4);
        cfg.ct_entries = rng.range(6, 12);
        cfg.sthld = if rng.chance(0.5) {
            SthldMode::Dynamic
        } else {
            SthldMode::Static(rng.below(16) as u32)
        };
        cfg.seed = seed;
        if cfg.validate().is_err() {
            continue;
        }
        let bench = *rng.pick(&benches);
        let stats = malekeh::sim::run_benchmark(&cfg, bench, 2);
        assert_eq!(
            stats.warps_retired as usize, cfg.warps_per_sm,
            "seed {seed} {bench} {}: warps lost",
            cfg.scheme
        );
        assert_eq!(
            stats.rf_reads,
            stats.rf_cache_reads + stats.rf_bank_reads,
            "seed {seed}: conservation"
        );
        assert!(stats.cycles > 0 && stats.instructions > 0);
    }
}
