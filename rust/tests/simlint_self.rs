//! simlint self-test: every rule pinned with firing and non-firing
//! fixtures, the directive grammar exercised end-to-end (suppression
//! placement, mandatory reasons, unused allows, dangling hot markers),
//! the baseline ratchet, and the shipped tree held to exactly the allow
//! counts the committed `rust/tests/golden/simlint_baseline.json`
//! records. Fixtures are lexed, not compiled — they only need to look
//! like the Rust the rules match.

use std::path::Path;

use malekeh::lint::{baseline, DIRECTIVE_RULE, Finding, lint_source, Report, rules};

/// Findings that survive suppression for one fixture file.
fn unsup(rel: &str, src: &str) -> Vec<Finding> {
    lint_source(rel, src).into_iter().filter(|f| !f.is_allowed()).collect()
}

/// How many findings of `rule` are in `fs`.
fn fired(fs: &[Finding], rule: &str) -> usize {
    fs.iter().filter(|f| f.rule == rule).count()
}

// ---------------------------- scheme-dispatch --------------------------------

#[test]
fn scheme_dispatch_fires_on_scheme_refs_in_hot_files() {
    let fs = unsup("sim/subcore.rs", "fn f() -> u32 { Scheme::MALEKEH as u32 }\n");
    assert_eq!(fired(&fs, rules::SCHEME_DISPATCH), 1, "{fs:?}");
    let fs = unsup("sim/collector.rs", "fn f(&self) { match self.scheme { _ => {} } }\n");
    assert_eq!(fired(&fs, rules::SCHEME_DISPATCH), 1, "{fs:?}");
}

#[test]
fn scheme_dispatch_ignores_the_policy_layer_and_tests() {
    let src = "fn f() -> u32 { Scheme::MALEKEH as u32 }\n";
    assert!(unsup("sim/policy/registry.rs", src).is_empty());
    assert!(unsup("sim/gpu.rs", src).is_empty(), "only subcore/collector are in scope");
    let src = "#[cfg(test)]\nmod tests {\n    fn f() { let s = Scheme::MALEKEH; }\n}\n";
    assert!(unsup("sim/subcore.rs", src).is_empty(), "cfg(test) items are exempt");
}

// ---------------------------- hot-path-alloc ---------------------------------

#[test]
fn hot_path_alloc_fires_inside_hot_fns() {
    let src = r#"
// simlint: hot
fn step(xs: &[u8]) {
    let v: Vec<u8> = Vec::new();
    let s = format!("{}", v.len());
    let w: Vec<u8> = xs.iter().copied().collect();
    let _ = (s, w);
}
"#;
    let fs = unsup("sim/subcore.rs", src);
    assert_eq!(fired(&fs, rules::HOT_PATH_ALLOC), 3, "Vec::new + format! + collect: {fs:?}");
}

#[test]
fn hot_path_alloc_ignores_unmarked_fns_and_reuse() {
    let src = r#"
fn cold() -> Vec<u8> {
    Vec::with_capacity(8)
}
// simlint: hot
fn step(buf: &mut Vec<u8>) {
    buf.clear();
    buf.push(1);
}
"#;
    assert!(unsup("sim/subcore.rs", src).is_empty(), "scratch reuse in a hot fn is fine");
}

#[test]
fn hot_marker_attaches_to_the_next_fn_only() {
    let src = r#"
fn before() -> Vec<u8> { Vec::new() }
// simlint: hot
fn marked(n: u64) -> u64 { n + 1 }
fn after() -> Vec<u8> { Vec::new() }
"#;
    assert!(unsup("sim/subcore.rs", src).is_empty());
}

// -------------------------- unordered-iteration ------------------------------

#[test]
fn unordered_iteration_fires_on_hash_walks_in_scope() {
    let src = r#"
fn f(m: &HashMap<u64, u64>, s: HashSet<u32>) -> usize {
    let n = m.values().count();
    for x in s {
        let _ = x;
    }
    n
}
"#;
    let fs = unsup("harness/mod.rs", src);
    assert_eq!(fired(&fs, rules::UNORDERED_ITERATION), 2, "{fs:?}");
}

#[test]
fn unordered_iteration_allows_lookups_ordered_maps_and_other_layers() {
    let src = r#"
fn f(m: &std::collections::HashMap<u64, u64>, b: &BTreeMap<u64, u64>) -> u64 {
    let hit = m.get(&3).copied().unwrap_or(0);
    let walked: u64 = b.keys().sum();
    hit + walked
}
"#;
    assert!(unsup("sim/memory.rs", src).is_empty(), "point lookups and BTree walks are fine");
    let src = "fn f(m: &HashMap<u64, u64>) { for k in m.keys() { let _ = k; } }\n";
    assert!(unsup("stats.rs", src).is_empty(), "outside sim/, harness/, serve/store.rs");
}

// ---------------------------- rng-discipline ---------------------------------

#[test]
fn rng_discipline_fires_outside_the_allowlist() {
    let fs = unsup("sim/memory.rs", "fn f(rng: &mut Rng) -> usize { rng.below(4) }\n");
    assert_eq!(fired(&fs, rules::RNG_DISCIPLINE), 1, "{fs:?}");
    // ambiguous draw names fire only with an rng-named receiver
    let fs = unsup("sim/memory.rs", "fn f(rng: &mut Rng) -> u64 { rng.range(1, 5) }\n");
    assert_eq!(fired(&fs, rules::RNG_DISCIPLINE), 1, "{fs:?}");
}

#[test]
fn rng_discipline_ignores_the_policy_layer_and_non_rng_receivers() {
    let src = "fn f(rng: &mut Rng) -> usize { rng.below(4) }\n";
    assert!(unsup("sim/policy/malekeh.rs", src).is_empty());
    assert!(unsup("trace/workloads.rs", src).is_empty(), "seeded generators are allowlisted");
    let src = "fn f(axis: &Axis) -> (f64, f64) { axis.range(0, 4) }\n";
    assert!(unsup("sim/memory.rs", src).is_empty(), "`.range()` on a non-rng receiver");
}

// ------------------------------- wallclock -----------------------------------

#[test]
fn wallclock_fires_in_the_deterministic_core() {
    let fs = unsup("sim/gpu.rs", "fn f() -> u64 { let t = Instant::now(); t.as_secs() }\n");
    assert_eq!(fired(&fs, rules::WALLCLOCK), 1, "{fs:?}");
    let fs = unsup("harness/mod.rs", "fn f() -> bool { env::var(\"MALEKEH_X\").is_ok() }\n");
    assert_eq!(fired(&fs, rules::WALLCLOCK), 1, "{fs:?}");
}

#[test]
fn wallclock_exempts_the_cli_shell_daemon_and_linter() {
    let src = "fn f() -> Instant { Instant::now() }\n";
    for rel in ["main.rs", "cli.rs", "serve/server.rs", "runtime/mod.rs", "lint/mod.rs"] {
        assert!(unsup(rel, src).is_empty(), "{rel} is exempt by path");
    }
}

// ------------------------------ serve-panic ----------------------------------

#[test]
fn serve_panic_fires_on_panicky_request_handling() {
    let src = r#"
fn handle(line: &str, buf: &[u8]) -> u8 {
    let n: u64 = line.parse().unwrap();
    if n > 9 {
        panic!("bad request");
    }
    buf[0]
}
"#;
    let fs = unsup("serve/server.rs", src);
    assert_eq!(fired(&fs, rules::SERVE_PANIC), 3, "unwrap + panic! + index: {fs:?}");
}

#[test]
fn serve_panic_ignores_recovery_idioms_and_other_layers() {
    let src = r#"
fn lock(m: &Mutex<u64>) -> u64 {
    let g = m.lock().unwrap_or_else(|p| p.into_inner());
    let [a, b] = [1u64, 2u64];
    *g + a + b
}
"#;
    assert!(unsup("serve/server.rs", src).is_empty(), "poison recovery and patterns are fine");
    let src = "fn f(v: &[u8]) -> u8 { v[0] }\n";
    assert!(unsup("sim/memory.rs", src).is_empty(), "indexing is fine outside serve/");
}

// ------------------------------ directives -----------------------------------

#[test]
fn allow_suppresses_on_its_own_line_and_the_next() {
    let above = r#"
fn f(rng: &mut Rng) -> usize {
    // simlint: allow(rng-discipline) reason="fixture"
    rng.below(4)
}
"#;
    let report = Report { findings: lint_source("sim/memory.rs", above) };
    assert!(report.unsuppressed().is_empty(), "{:?}", report.findings);
    assert_eq!(report.allow_counts()["rng-discipline"], 1);

    let same = concat!(
        "fn f(rng: &mut Rng) -> usize { rng.below(4) }",
        " // simlint: allow(rng-discipline) reason=\"fixture\"\n"
    );
    let report = Report { findings: lint_source("sim/memory.rs", same) };
    assert!(report.unsuppressed().is_empty(), "{:?}", report.findings);
    assert_eq!(report.allow_counts()["rng-discipline"], 1);
}

#[test]
fn broken_directives_are_findings_themselves() {
    // reasonless: the draw stays unsuppressed AND the allow is reported
    let src = r#"
fn f(rng: &mut Rng) -> usize {
    // simlint: allow(rng-discipline)
    rng.below(4)
}
"#;
    let fs = lint_source("sim/memory.rs", src);
    assert_eq!(fired(&fs, rules::RNG_DISCIPLINE), 1, "{fs:?}");
    assert_eq!(fired(&fs, DIRECTIVE_RULE), 1, "{fs:?}");
    assert!(fs.iter().all(|f| !f.is_allowed()), "a reasonless allow suppresses nothing");

    // unknown rule name
    let fs = lint_source("sim/memory.rs", "// simlint: allow(bogus) reason=\"x\"\nfn f() {}\n");
    assert_eq!(fired(&fs, DIRECTIVE_RULE), 1, "{fs:?}");

    // allow that covers nothing
    let src = "// simlint: allow(wallclock) reason=\"stale\"\nfn f() -> u64 { 3 }\n";
    let fs = lint_source("sim/memory.rs", src);
    assert_eq!(fired(&fs, DIRECTIVE_RULE), 1, "{fs:?}");

    // hot marker with no fn below it
    let fs = lint_source("sim/memory.rs", "struct S;\n// simlint: hot\n");
    assert_eq!(fired(&fs, DIRECTIVE_RULE), 1, "{fs:?}");

    // unrecognised directive body
    let fs = lint_source("sim/memory.rs", "// simlint: allo(rng-discipline)\nfn f() {}\n");
    assert_eq!(fired(&fs, DIRECTIVE_RULE), 1, "{fs:?}");
}

#[test]
fn doc_comments_never_parse_as_directives() {
    let src = "/// `// simlint: allow(wallclock) reason=\"x\"` is the grammar\nfn f() {}\n";
    assert!(lint_source("sim/memory.rs", src).is_empty());
}

// -------------------------- baseline & the tree ------------------------------

#[test]
fn baseline_round_trips_and_ratchets_both_directions() {
    let allowed = Finding {
        rule: "wallclock".to_string(),
        file: "harness/mod.rs".to_string(),
        line: 1,
        message: "fixture".to_string(),
        allowed: Some("fixture".to_string()),
    };
    let report = Report { findings: vec![allowed.clone()] };
    let base = baseline::parse(&baseline::render(&report)).expect("round-trip");
    assert_eq!(base.unsuppressed, 0);
    assert_eq!(base.allows["wallclock"], 1);
    baseline::check(&report, &base).expect("exact counts pass");

    // a new suppression fails against a cleaner baseline...
    let empty = Report { findings: Vec::new() };
    let base0 = baseline::parse(&baseline::render(&empty)).expect("round-trip");
    assert!(baseline::check(&report, &base0).is_err(), "new allow must fail");
    // ...and a cleaner tree fails a stale baseline until re-blessed
    assert!(baseline::check(&empty, &base).is_err(), "stale baseline must fail");

    // any unsuppressed finding fails regardless of allow counts
    let mut live = allowed;
    live.allowed = None;
    let report = Report { findings: vec![live] };
    assert!(baseline::check(&report, &base0).is_err(), "unsuppressed finding must fail");
}

#[test]
fn shipped_tree_is_clean_with_the_committed_allows() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = malekeh::lint::run_tree(&root).expect("lint rust/src");
    let bad: Vec<String> = report
        .unsuppressed()
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(bad.is_empty(), "unsuppressed findings:\n{}", bad.join("\n"));
    let counts = report.allow_counts();
    assert_eq!(counts["rng-discipline"], 1, "{counts:?}");
    assert_eq!(counts["wallclock"], 2, "{counts:?}");
    let silent: u64 = counts
        .iter()
        .filter(|(r, _)| r.as_str() != "rng-discipline" && r.as_str() != "wallclock")
        .map(|(_, n)| *n)
        .sum();
    assert_eq!(silent, 0, "every other rule runs allow-free: {counts:?}");
}

#[test]
fn committed_baseline_matches_the_tree_byte_for_byte() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = malekeh::lint::run_tree(&manifest.join("rust/src")).expect("lint rust/src");
    let path = manifest.join("rust/tests/golden/simlint_baseline.json");
    let text = std::fs::read_to_string(&path).expect("committed baseline");
    let base = baseline::parse(&text).expect("parse baseline");
    baseline::check(&report, &base).expect("tree must match the committed baseline");
    assert_eq!(text, baseline::render(&report), "baseline drifted from --bless output");
}
