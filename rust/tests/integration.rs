//! Cross-module integration tests: full simulations over generated +
//! compiler-annotated traces, scheme-vs-scheme invariants, and paper-shape
//! checks on small configs (the benches verify the full-size shapes).

use malekeh::compiler;
use malekeh::config::{GpuConfig, Scheme, SthldMode};
use malekeh::energy::EnergyModel;
use malekeh::sim::{run_benchmark, Simulator};
use malekeh::trace::{find, KernelTrace};

fn cfg(scheme: Scheme) -> GpuConfig {
    let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
    c.num_sms = 1;
    c
}

#[test]
fn all_schemes_complete_all_suites() {
    for bench in ["hotspot", "bfs", "gemm_t1", "rnn_i1"] {
        for scheme in Scheme::all() {
            let stats = run_benchmark(&cfg(scheme), bench, 2);
            assert_eq!(
                stats.warps_retired, 32,
                "{bench}/{scheme}: warps lost"
            );
            assert!(stats.ipc() > 0.01, "{bench}/{scheme}: ipc collapsed");
        }
    }
}

#[test]
fn read_conservation_invariant() {
    // every operand read is served exactly once, by cache or banks
    for scheme in Scheme::all() {
        let s = run_benchmark(&cfg(scheme), "kmeans", 2);
        assert_eq!(
            s.rf_reads,
            s.rf_cache_reads + s.rf_bank_reads,
            "{scheme}: read conservation"
        );
    }
}

#[test]
fn same_workload_same_read_demand() {
    // schemes change WHERE reads are served, not HOW MANY are requested
    let base = run_benchmark(&cfg(Scheme::BASELINE), "srad_v1", 2);
    for scheme in [Scheme::MALEKEH, Scheme::BOW, Scheme::MALEKEH_PR] {
        let s = run_benchmark(&cfg(scheme), "srad_v1", 2);
        assert_eq!(s.rf_reads, base.rf_reads, "{scheme}");
        assert_eq!(s.instructions, base.instructions, "{scheme}");
        assert_eq!(s.rf_writes, base.rf_writes, "{scheme}");
    }
}

#[test]
fn baseline_never_hits_cache() {
    let s = run_benchmark(&cfg(Scheme::BASELINE), "gemm_i1", 2);
    assert_eq!(s.rf_cache_reads, 0);
    assert_eq!(s.rf_cache_writes, 0);
}

#[test]
fn malekeh_headline_direction_small_config() {
    // the paper's three headline directions on a 1-SM config
    let mut hit = Vec::new();
    let mut ipc_rel = Vec::new();
    let mut energy_rel = Vec::new();
    for bench in ["kmeans", "gemm_t1", "rnn_i2", "srad_v1", "hotspot"] {
        let b = run_benchmark(&cfg(Scheme::BASELINE), bench, 2);
        let m = run_benchmark(&cfg(Scheme::MALEKEH), bench, 2);
        hit.push(m.rf_hit_ratio());
        ipc_rel.push(m.ipc() / b.ipc());
        let be = EnergyModel::for_config(&cfg(Scheme::BASELINE)).total(&b.energy);
        let me = EnergyModel::for_config(&cfg(Scheme::MALEKEH)).total(&m.energy);
        energy_rel.push(me / be);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(mean(&hit) > 0.25, "hit ratio too low: {:?}", hit);
    assert!(mean(&ipc_rel) > 1.0, "no IPC win: {:?}", ipc_rel);
    assert!(mean(&energy_rel) < 0.9, "no energy win: {:?}", energy_rel);
}

#[test]
fn bow_energy_above_baseline() {
    // Fig 15's qualitative claim: BOW's big crossbar + buffers cost more
    // dynamic energy than the baseline despite its hits
    let mut rel = Vec::new();
    for bench in ["kmeans", "b+tree", "hotspot"] {
        let b = run_benchmark(&cfg(Scheme::BASELINE), bench, 2);
        let w = run_benchmark(&cfg(Scheme::BOW), bench, 2);
        let be = EnergyModel::for_config(&cfg(Scheme::BASELINE)).total(&b.energy);
        let we = EnergyModel::for_config(&cfg(Scheme::BOW)).total(&w.energy);
        rel.push(we / be);
    }
    let mean = rel.iter().sum::<f64>() / rel.len() as f64;
    assert!(mean > 0.95, "BOW should not save much energy: {rel:?}");
}

#[test]
fn traditional_policies_collapse_hit_ratio() {
    // Fig 17: GTO + plain LRU + no write filter loses most of the hits
    let mut drop = Vec::new();
    for bench in ["kmeans", "nn", "rnn_i2"] {
        let m = run_benchmark(&cfg(Scheme::MALEKEH), bench, 2);
        let t = run_benchmark(&cfg(Scheme::MALEKEH_TRADITIONAL), bench, 2);
        drop.push(t.rf_hit_ratio() / m.rf_hit_ratio().max(1e-9));
    }
    let mean = drop.iter().sum::<f64>() / drop.len() as f64;
    assert!(mean < 0.6, "traditional policies should collapse hits: {drop:?}");
}

#[test]
fn two_level_slower_than_one_level_on_subcores() {
    // Fig 2's core claim for the software-managed variant (the hardware
    // RFC's cache gains can offset its scheduler loss in this model — a
    // documented deviation, docs/EXPERIMENTS.md §Fig 2)
    let mut rel = Vec::new();
    for bench in ["hotspot", "srad_v1", "kmeans"] {
        let b = run_benchmark(&cfg(Scheme::BASELINE), bench, 2);
        let s = run_benchmark(&cfg(Scheme::SOFTWARE_RFC), bench, 2);
        rel.push(s.ipc() / b.ipc());
    }
    assert!(
        rel.iter().all(|&x| x < 1.0),
        "software RFC must lose IPC on sub-cores: {rel:?}"
    );
}

#[test]
fn sub_core_partitioning_hurts_two_level_more_than_monolithic() {
    // Fig 2: the sub-core drop exceeds the monolithic drop (swRFC), and
    // the two-level scheduler shows substantial ready-but-stalled cycles
    let bench = "hotspot";
    let sub_base = run_benchmark(&cfg(Scheme::BASELINE), bench, 2);
    let sub_sw = run_benchmark(&cfg(Scheme::SOFTWARE_RFC), bench, 2);
    let mut mono = GpuConfig::monolithic();
    mono.num_sms = 1;
    let mono_base = run_benchmark(&mono, bench, 2);
    let mono_sw = run_benchmark(&mono.clone().with_scheme(Scheme::SOFTWARE_RFC), bench, 2);
    let sub_drop = 1.0 - sub_sw.ipc() / sub_base.ipc();
    let mono_drop = 1.0 - mono_sw.ipc() / mono_base.ipc();
    assert!(
        sub_drop > mono_drop,
        "sub-core drop {sub_drop:.3} must exceed monolithic {mono_drop:.3}"
    );
    // Fig 10: state-2 fraction is significant for both two-level schemes
    let (_, s2_rfc, _) = run_benchmark(&cfg(Scheme::RFC), bench, 2).sched_state_distribution();
    let (_, s2_sw, _) = sub_sw.sched_state_distribution();
    assert!(s2_rfc > 0.1, "rfc state2 {s2_rfc:.3}");
    assert!(s2_sw > 0.1, "swrfc state2 {s2_sw:.3}");
}

#[test]
fn precise_vs_partial_profiling_close() {
    // §III-A: binary + partial profiling ~ oracle
    for bench in ["kmeans", "rnn_i2"] {
        let c = cfg(Scheme::MALEKEH);
        let partial = run_benchmark(&c, bench, 2);
        let oracle = run_benchmark(&c, bench, 0); // 0 = precise annotation
        let rel = partial.rf_hit_ratio() / oracle.rf_hit_ratio().max(1e-9);
        assert!(
            rel > 0.8,
            "{bench}: partial profiling hit {:.3} too far from oracle {:.3}",
            partial.rf_hit_ratio(),
            oracle.rf_hit_ratio()
        );
    }
}

#[test]
fn write_filter_reduces_cache_writes() {
    let c = cfg(Scheme::MALEKEH);
    let mut nof = cfg(Scheme::MALEKEH);
    nof.no_write_filter = true;
    let filtered = run_benchmark(&c, "conv_t1", 2);
    let unfiltered = run_benchmark(&nof, "conv_t1", 2);
    assert!(
        filtered.rf_cache_writes < unfiltered.rf_cache_writes,
        "filter {} !< unfiltered {}",
        filtered.rf_cache_writes,
        unfiltered.rf_cache_writes
    );
}

#[test]
fn sthld_zero_means_no_waiting() {
    let mut c = cfg(Scheme::MALEKEH);
    c.sthld = SthldMode::Static(0);
    let s = run_benchmark(&c, "kmeans", 2);
    assert_eq!(s.waiting_stalls, 0);
}

#[test]
fn higher_static_sthld_does_not_reduce_hits() {
    // Fig 7: hit ratio vs STHLD is (weakly) monotone up
    let mut prev = -1.0f64;
    for sthld in [0u32, 4, 16] {
        let mut c = cfg(Scheme::MALEKEH);
        c.sthld = SthldMode::Static(sthld);
        let s = run_benchmark(&c, "gaussian", 2);
        assert!(
            s.rf_hit_ratio() >= prev - 0.02,
            "hit ratio dropped at sthld={sthld}"
        );
        prev = s.rf_hit_ratio();
    }
}

#[test]
fn simulator_reuses_annotated_trace() {
    // Simulator::new is pure wrt the trace: two sims over the same
    // annotated trace give identical results
    let bench = find("pathfinder").unwrap();
    let c = cfg(Scheme::MALEKEH);
    let mut trace = KernelTrace::generate(bench, 32, 1);
    compiler::profile_and_annotate(&mut trace, 2, c.rthld);
    let a = Simulator::new(&c, &trace).run();
    let b = Simulator::new(&c, &trace).run();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.rf_cache_reads, b.rf_cache_reads);
    assert_eq!(a.energy, b.energy);
}

#[test]
fn dynamic_sthld_tracks_interval_count() {
    let mut c = cfg(Scheme::MALEKEH);
    c.sthld_interval = 1000;
    let s = run_benchmark(&c, "srad_v1", 2);
    assert_eq!(s.interval_ipc.len(), s.sthld_trace.len());
    assert_eq!(s.interval_ipc.len() as u64, s.cycles / 1000);
}
