//! Differential battery for the v2 container and streaming ingestion:
//! every registered benchmark (Table II + generated corpus) is recorded
//! to both `.mtrace` encodings and replayed through both ingestion paths
//! (whole-file parse and [`TraceStream`] windows). All four combinations
//! must reproduce the directly generated trace bit for bit — same IR,
//! same [`Stats::fingerprint`](malekeh::stats::Stats::fingerprint) — at
//! `--sim-threads 1` and 4. A final check pins the store contract: a
//! `trace convert`ed file addresses the *same* persistent-store record
//! as its source, so conversion never invalidates cached results.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use malekeh::compiler;
use malekeh::config::{GpuConfig, Scheme};
use malekeh::serve::{Store, StoreKey};
use malekeh::sim::{run_trace, run_workload};
use malekeh::trace::io::{self, TraceStream};
use malekeh::trace::{corpus, find, table2, KernelTrace, Workload};

/// Differential configuration: 4 SMs so `sim_threads` actually shards
/// work, with a cycle cap that keeps 28 benchmarks tractable in debug CI
/// runs (a capped run's fingerprint is just as discriminating).
fn cfg(sim_threads: usize) -> GpuConfig {
    let mut c = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    c.num_sms = 4;
    c.sim_threads = sim_threads;
    c.max_cycles = 15_000;
    c
}

/// Unique temp path per test process so parallel binaries never collide.
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("malekeh_parity_{}_{name}", std::process::id()))
}

/// Record `bench` to v1 and v2, ingest each through both paths, and
/// demand IR + replay parity. Returns a description of the first
/// divergence instead of panicking so the sweep can report all failures.
fn check_bench(bench: &str) -> Result<(), String> {
    let mut t = KernelTrace::generate(find(bench).unwrap(), 8, 0xC0FFEE);
    compiler::profile_and_annotate(&mut t, 2, 12);
    let p1 = tmp(&format!("{bench}.v1.mtrace"));
    let p2 = tmp(&format!("{bench}.v2.mtrace"));
    io::write_path(&p1, &t).map_err(|e| format!("{bench}: write v1: {e}"))?;
    io::write_v2_path(&p2, &t).map_err(|e| format!("{bench}: write v2: {e}"))?;
    let stream = |p: &PathBuf| -> Result<KernelTrace, String> {
        TraceStream::open(p)
            .and_then(TraceStream::into_trace)
            .map_err(|e| format!("{bench}: stream {}: {e}", p.display()))
    };
    let ingested: [(&str, KernelTrace); 4] = [
        ("v1/in-memory", io::read_path(&p1).map_err(|e| format!("{bench}: read v1: {e}"))?),
        ("v2/in-memory", io::read_path(&p2).map_err(|e| format!("{bench}: read v2: {e}"))?),
        ("v1/streamed", stream(&p1)?),
        ("v2/streamed", stream(&p2)?),
    ];
    for (label, back) in &ingested {
        if back.name != t.name || back.kernel_id != t.kernel_id || back.warps != t.warps {
            return Err(format!("{bench}: {label} ingestion altered the IR"));
        }
    }
    // replay parity: the directly generated trace is the reference; the
    // annotation bits are baked into the files, so no re-annotation
    let reference = run_trace(&cfg(1), t, 2, false).fingerprint();
    for threads in [1usize, 4] {
        for (label, back) in &ingested {
            let fp = run_trace(&cfg(threads), back.clone(), 2, false).fingerprint();
            if fp != reference {
                return Err(format!(
                    "{bench}: {label} at sim-threads {threads}: \
                     {fp:016x} != reference {reference:016x}"
                ));
            }
        }
    }
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
    Ok(())
}

#[test]
fn every_benchmark_replays_identically_across_encoding_ingestion_and_threads() {
    let benches: Vec<&'static str> = table2().chain(corpus()).map(|b| b.name).collect();
    assert_eq!(benches.len(), 28, "registry drifted; update this sweep");
    let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= benches.len() {
                    break;
                }
                if let Err(e) = check_bench(benches[i]) {
                    failures.lock().unwrap().push(e);
                }
            });
        }
    });
    let failures = failures.into_inner().unwrap();
    assert!(
        failures.is_empty(),
        "encoding/ingestion parity failures:\n{}",
        failures.join("\n")
    );
}

#[test]
fn converted_trace_hits_the_same_store_record() {
    // store-key regression for the decoded-content fingerprint: a raw
    // recording, its v2 conversion, and the builtin workload it records
    // all address one record, so `trace convert` output is a store HIT
    let mut c = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    c.num_sms = 1;
    let t = KernelTrace::generate(
        find("kmeans").unwrap(),
        c.num_sms * c.warps_per_sm,
        c.seed,
    );
    let p1 = tmp("store_kmeans.v1.mtrace");
    let p2 = tmp("store_kmeans.v2.mtrace");
    io::write_path(&p1, &t).unwrap();
    // conversion exactly as `malekeh trace convert` performs it
    io::write_v2_path(&p2, &io::read_path(&p1).unwrap()).unwrap();
    let w1 = Workload::trace_file(&p1);
    let w2 = Workload::trace_file(&p2);
    let k1 = StoreKey::for_run(&c, &w1, 2).unwrap();
    let k2 = StoreKey::for_run(&c, &w2, 2).unwrap();
    assert_eq!(k1, k2, "conversion changed the store address");
    let kb = StoreKey::for_run(&c, &Workload::builtin("kmeans"), 2).unwrap();
    assert_eq!(k1, kb, "a raw recording must address its builtin's record");
    // and an actual round-trip: simulate the v1 file, then the v2 file
    // must find the result already in the store
    let dir = tmp("store_convert_dir");
    let store = Store::open(&dir).unwrap();
    let stats = run_workload(&c, &w1, 2).unwrap();
    store.put(&k1, &stats).unwrap();
    let hit = store
        .get(&k2)
        .expect("converted trace missed the store record");
    assert_eq!(hit.fingerprint(), stats.fingerprint());
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}
