//! Parallel determinism: the sharded experiment engine must be a pure
//! wall-clock optimisation. The same seed + the same plan has to produce
//! **bit-identical** `Table` output (and identical raw `Stats`) whether it
//! runs on one worker (`--jobs 1`) or many (`--jobs 8`), because each
//! `SimPoint` carries its own fully-resolved config/seed and results are
//! merged in fixed plan order.

use malekeh::config::Scheme;
use malekeh::harness::{geomean, ExpOpts, Runner, Table};

fn opts(jobs: usize) -> ExpOpts {
    ExpOpts {
        num_sms: 1,
        seed: 0xC0FFEE,
        profile_warps: 2,
        quick: true,
        jobs,
    }
}

const BENCHES: [&str; 3] = ["kmeans", "hotspot", "nn"];
const SCHEMES: [Scheme; 2] = [Scheme::Baseline, Scheme::Malekeh];

/// Shard the probe plan, then assemble a figure-style table serially.
fn build_table(runner: &Runner) -> Table {
    let mut plan = runner.plan();
    for b in BENCHES {
        for s in SCHEMES {
            plan.add(b, s);
        }
    }
    runner.execute(&plan);

    let mut t = Table::new(
        "determinism probe: IPC (norm) + RF cache hit ratio",
        &["bench", "ipc_rel", "hit"],
    );
    let mut rel = Vec::new();
    for b in BENCHES {
        let base = runner.run(b, Scheme::Baseline);
        let m = runner.run(b, Scheme::Malekeh);
        let r = m.ipc() / base.ipc().max(1e-9);
        rel.push(r);
        // 9 decimals: any cross-shard nondeterminism would show here
        t.row_f(b, &[r, m.rf_hit_ratio()], 9);
    }
    t.row_f("GEOMEAN", &[geomean(&rel), 0.0], 9);
    t
}

#[test]
fn jobs1_and_jobs8_render_bit_identical_tables() {
    let serial = build_table(&Runner::new(opts(1)));
    let sharded = build_table(&Runner::new(opts(8)));
    assert_eq!(
        serial.render(),
        sharded.render(),
        "sharded table output diverged from serial"
    );
}

#[test]
fn sharded_stats_identical_to_serial() {
    let r1 = Runner::new(opts(1));
    let r4 = Runner::new(opts(4));
    for r in [&r1, &r4] {
        let mut plan = r.plan();
        for b in ["srad_v1", "b+tree"] {
            for s in SCHEMES {
                plan.add(b, s);
            }
        }
        r.execute(&plan);
    }
    assert_eq!(r1.cached(), 4);
    assert_eq!(r4.cached(), 4);
    for b in ["srad_v1", "b+tree"] {
        for s in SCHEMES {
            let a = r1.run(b, s);
            let c = r4.run(b, s);
            assert_eq!(a.cycles, c.cycles, "{b}/{s} cycles");
            assert_eq!(a.instructions, c.instructions, "{b}/{s} instructions");
            assert_eq!(a.rf_reads, c.rf_reads, "{b}/{s} rf_reads");
            assert_eq!(a.rf_cache_reads, c.rf_cache_reads, "{b}/{s} cache reads");
            assert_eq!(a.rf_cache_writes, c.rf_cache_writes, "{b}/{s} cache writes");
            assert_eq!(a.energy, c.energy, "{b}/{s} energy events");
        }
    }
}

#[test]
fn runner_is_shareable_across_threads() {
    // the memoising Runner is Sync: shards (and callers) may share one
    let runner = Runner::new(opts(2));
    std::thread::scope(|scope| {
        let r = &runner;
        scope.spawn(move || r.run("kmeans", Scheme::Baseline));
        scope.spawn(move || r.run("kmeans", Scheme::Malekeh));
    });
    assert_eq!(runner.cached(), 2);
    // a post-join read is a cache hit and matches a fresh serial run
    let serial = Runner::new(opts(1));
    assert_eq!(
        runner.run("kmeans", Scheme::Malekeh).cycles,
        serial.run("kmeans", Scheme::Malekeh).cycles
    );
}
