//! Parallel determinism: both parallelism layers must be pure wall-clock
//! optimisations.
//!
//! 1. **Across experiment points** (`--jobs N`, the sharded harness): the
//!    same seed + the same plan has to produce **bit-identical** `Table`
//!    output (and identical raw `Stats`) whether it runs on one worker or
//!    many, because each `SimPoint` carries its own fully-resolved
//!    config/seed and results are merged in fixed plan order.
//! 2. **Within one simulation** (`--sim-threads N`, the epoch engine):
//!    `Stats::fingerprint()` must be identical at 1/2/4 SM workers for
//!    every Table II benchmark, because SMs advance independently between
//!    synchronization boundaries and the serial L2 phase services the
//!    merged request queues in fixed `(cycle, sm_id, seq)` order.

use malekeh::config::{GpuConfig, Scheme};
use malekeh::harness::{geomean, ExpOpts, Runner, Table};
use malekeh::sim::run_benchmark;
use malekeh::trace::table2;

fn opts(jobs: usize) -> ExpOpts {
    ExpOpts {
        num_sms: 1,
        seed: 0xC0FFEE,
        profile_warps: 2,
        quick: true,
        jobs,
        sim_threads: 1,
            store_dir: None,
    }
}

const BENCHES: [&str; 3] = ["kmeans", "hotspot", "nn"];
const SCHEMES: [Scheme; 2] = [Scheme::BASELINE, Scheme::MALEKEH];

/// Shard the probe plan, then assemble a figure-style table serially.
fn build_table(runner: &Runner) -> Table {
    let mut plan = runner.plan();
    for b in BENCHES {
        for s in SCHEMES {
            plan.add(b, s);
        }
    }
    runner.execute(&plan);

    let mut t = Table::new(
        "determinism probe: IPC (norm) + RF cache hit ratio",
        &["bench", "ipc_rel", "hit"],
    );
    let mut rel = Vec::new();
    for b in BENCHES {
        let base = runner.run(b, Scheme::BASELINE);
        let m = runner.run(b, Scheme::MALEKEH);
        let r = m.ipc() / base.ipc().max(1e-9);
        rel.push(r);
        // 9 decimals: any cross-shard nondeterminism would show here
        t.row_f(b, &[r, m.rf_hit_ratio()], 9);
    }
    t.row_f("GEOMEAN", &[geomean(&rel), 0.0], 9);
    t
}

#[test]
fn jobs1_and_jobs8_render_bit_identical_tables() {
    let serial = build_table(&Runner::new(opts(1)));
    let sharded = build_table(&Runner::new(opts(8)));
    assert_eq!(
        serial.render(),
        sharded.render(),
        "sharded table output diverged from serial"
    );
}

#[test]
fn sharded_stats_identical_to_serial() {
    let r1 = Runner::new(opts(1));
    let r4 = Runner::new(opts(4));
    for r in [&r1, &r4] {
        let mut plan = r.plan();
        for b in ["srad_v1", "b+tree"] {
            for s in SCHEMES {
                plan.add(b, s);
            }
        }
        r.execute(&plan);
    }
    assert_eq!(r1.cached(), 4);
    assert_eq!(r4.cached(), 4);
    for b in ["srad_v1", "b+tree"] {
        for s in SCHEMES {
            let a = r1.run(b, s);
            let c = r4.run(b, s);
            assert_eq!(a.cycles, c.cycles, "{b}/{s} cycles");
            assert_eq!(a.instructions, c.instructions, "{b}/{s} instructions");
            assert_eq!(a.rf_reads, c.rf_reads, "{b}/{s} rf_reads");
            assert_eq!(a.rf_cache_reads, c.rf_cache_reads, "{b}/{s} cache reads");
            assert_eq!(a.rf_cache_writes, c.rf_cache_writes, "{b}/{s} cache writes");
            assert_eq!(a.energy, c.energy, "{b}/{s} energy events");
        }
    }
}

// ---------------- intra-run SM parallelism (--sim-threads) -----------------

/// Config for the epoch-engine sweeps: `threads` SM workers. The cycle cap
/// keeps the debug-build sweep fast while still crossing several dynamic
/// STHLD interval boundaries (10k cycles each).
fn threaded_cfg(scheme: Scheme, num_sms: usize, threads: usize) -> GpuConfig {
    let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
    c.num_sms = num_sms;
    c.max_cycles = 60_000;
    c.sim_threads = threads;
    c
}

#[test]
fn sim_threads_fingerprints_identical_across_table2() {
    // every Table II benchmark, --sim-threads {1, 2, 4}: the stats
    // fingerprint (every deterministic counter, energy matrix, interval
    // traces) must be bit-identical
    for bench in table2() {
        let serial = run_benchmark(&threaded_cfg(Scheme::MALEKEH, 2, 1), bench.name, 2);
        for threads in [2usize, 4] {
            let par =
                run_benchmark(&threaded_cfg(Scheme::MALEKEH, 2, threads), bench.name, 2);
            assert_eq!(
                serial.fingerprint(),
                par.fingerprint(),
                "{}: --sim-threads {threads} diverged from serial",
                bench.name
            );
        }
    }
}

#[test]
fn sim_threads_match_uncapped_on_wider_gpu() {
    // uncapped runs on a 4-SM machine: exercises the drain path, the
    // stall-empty tail accounting, and genuinely concurrent 4-worker
    // epochs (plus the auto/over-provisioned clamp)
    for (bench, scheme) in [
        ("kmeans", Scheme::MALEKEH),
        ("gemm_t1", Scheme::BASELINE),
        ("srad_v1", Scheme::RFC),
    ] {
        let fps: Vec<u64> = [1usize, 2, 4, 0]
            .into_iter()
            .map(|threads| {
                let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
                c.num_sms = 4;
                c.sim_threads = threads; // 0 = auto (one per core, clamped)
                run_benchmark(&c, bench, 2).fingerprint()
            })
            .collect();
        assert!(
            fps.iter().all(|&f| f == fps[0]),
            "{bench}/{scheme:?}: fingerprints diverged across sim-thread counts: {fps:x?}"
        );
    }
}

#[test]
fn runner_is_shareable_across_threads() {
    // the memoising Runner is Sync: shards (and callers) may share one
    let runner = Runner::new(opts(2));
    std::thread::scope(|scope| {
        let r = &runner;
        scope.spawn(move || r.run("kmeans", Scheme::BASELINE));
        scope.spawn(move || r.run("kmeans", Scheme::MALEKEH));
    });
    assert_eq!(runner.cached(), 2);
    // a post-join read is a cache hit and matches a fresh serial run
    let serial = Runner::new(opts(1));
    assert_eq!(
        runner.run("kmeans", Scheme::MALEKEH).cycles,
        serial.run("kmeans", Scheme::MALEKEH).cycles
    );
}
