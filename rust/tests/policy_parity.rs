//! Policy-parity suite: every registered benchmark (Table II plus the
//! generated-kernel corpus) replayed under every registered policy at
//! `--sim-threads 1` (the engine's reference configuration),
//! fingerprints pinned against the committed golden fixture
//! `rust/tests/golden/fingerprints.txt`.
//!
//! - A behavior change in any policy shows up as a fingerprint mismatch
//!   and fails until the fixture is deliberately re-blessed:
//!   `MALEKEH_BLESS_GOLDEN=1 cargo test --test policy_parity`.
//! - While the fixture carries the `STATE: bootstrap` marker (no entries
//!   yet — the authoring environment had no toolchain), the suite
//!   verifies recomputation stability on a deterministic sample and then
//!   **self-blesses**: it writes the computed table over the bootstrap
//!   fixture in the source tree, so the very first toolchain run pins
//!   every policy's behavior and each run after that enforces it. Commit
//!   the rewritten file; CI re-runs the suite against it in the same job
//!   to prove enforcement engages.
//! - A source-level check asserts the sub-core/collector hot paths carry
//!   zero `Scheme::` dispatch — all scheme variation must flow through
//!   the policy trait.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use malekeh::config::{GOLDEN_PROFILE_WARPS, GpuConfig, Scheme};
use malekeh::sim::run_benchmark;
use malekeh::trace::{corpus, table2};

const GOLDEN_REL: &str = "rust/tests/golden/fingerprints.txt";

fn golden_path() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join(GOLDEN_REL)
}

/// The fixture's pinned configuration lives in the library
/// ([`GpuConfig::golden_parity`]: Table I baseline on 1 SM, serial
/// reference engine, 40k-cycle cap — tractable in debug CI runs, and a
/// capped run's fingerprint is just as pinned as a full one) so the
/// `perf_hotpath` `golden_check` block can never drift from it.
fn fingerprint(bench: &str, scheme: Scheme) -> u64 {
    run_benchmark(&GpuConfig::golden_parity(scheme), bench, GOLDEN_PROFILE_WARPS).fingerprint()
}

/// Compute the full bench x policy fingerprint grid, sharded over a small
/// worker pool (each point is an independent, deterministic simulation).
fn compute_grid() -> BTreeMap<(String, String), u64> {
    let points: Vec<(&'static str, Scheme)> = table2()
        .chain(corpus())
        .flat_map(|b| Scheme::all().into_iter().map(move |s| (b.name, s)))
        .collect();
    let results: Mutex<Vec<Option<u64>>> = Mutex::new(vec![None; points.len()]);
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let (bench, scheme) = points[i];
                let fp = fingerprint(bench, scheme);
                results.lock().unwrap()[i] = Some(fp);
            });
        }
    });
    let results = results.into_inner().unwrap();
    points
        .iter()
        .zip(results)
        .map(|(&(bench, scheme), fp)| {
            ((bench.to_string(), scheme.name().to_string()), fp.expect("point computed"))
        })
        .collect()
}

fn render_fixture(grid: &BTreeMap<(String, String), u64>) -> String {
    let mut out = String::from(
        "# Golden stats fingerprints: one `<bench> <policy> <fingerprint>` per line.\n\
         # Grid: Table II + the generated-kernel corpus x all registered policies.\n\
         # Config: Table I baseline, num_sms=1, sim_threads=1, max_cycles=40000,\n\
         # profile_warps=2, scheme applied via GpuConfig::with_scheme.\n\
         # Bless/update: MALEKEH_BLESS_GOLDEN=1 cargo test --test policy_parity\n\
         # STATE: blessed\n",
    );
    for ((bench, scheme), fp) in grid {
        let _ = writeln!(out, "{bench} {scheme} {fp:016x}");
    }
    out
}

fn parse_fixture(text: &str) -> (bool, BTreeMap<(String, String), u64>) {
    let bootstrap = text.contains("STATE: bootstrap");
    let mut map = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(bench), Some(scheme), Some(fp)) = (it.next(), it.next(), it.next()) else {
            panic!("malformed golden line: {line:?}");
        };
        let fp = u64::from_str_radix(fp, 16)
            .unwrap_or_else(|_| panic!("bad fingerprint in golden line: {line:?}"));
        map.insert((bench.to_string(), scheme.to_string()), fp);
    }
    (bootstrap, map)
}

#[test]
fn golden_fingerprints_match() {
    let grid = compute_grid();
    let path = golden_path();
    // always leave the rendered table where CI can diff it against the
    // committed fixture without a second full sweep
    let computed = Path::new(env!("CARGO_TARGET_TMPDIR")).join("fingerprints.computed.txt");
    std::fs::write(&computed, render_fixture(&grid)).expect("write computed table");
    if std::env::var("MALEKEH_BLESS_GOLDEN").is_ok() {
        std::fs::write(&path, render_fixture(&grid)).expect("write golden fixture");
        eprintln!("blessed {} ({} points)", path.display(), grid.len());
        return;
    }
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let (bootstrap, golden) = parse_fixture(&text);
    let mut missing = Vec::new();
    for ((bench, scheme), fp) in &grid {
        match golden.get(&(bench.clone(), scheme.clone())) {
            Some(g) => assert_eq!(
                g,
                fp,
                "{bench}/{scheme}: fingerprint changed vs the golden fixture — a \
                 policy edit altered behavior. If intended, re-bless with \
                 MALEKEH_BLESS_GOLDEN=1 cargo test --test policy_parity"
            ),
            None => missing.push(format!("{bench} {scheme}")),
        }
    }
    // entries for points that no longer exist are stale
    let stale: Vec<String> = golden
        .keys()
        .filter(|k| !grid.contains_key(*k))
        .map(|(b, s)| format!("{b} {s}"))
        .collect();
    if bootstrap {
        // fixture not yet pinned (the authoring environment had no
        // toolchain): check recomputation stability on a deterministic
        // sample, then SELF-BLESS — write the computed table over the
        // bootstrap fixture so this run's behavior is pinned and every
        // later run (including a re-run in the same CI job) enforces it
        for (i, ((bench, scheme), fp)) in grid.iter().enumerate() {
            if i % 7 != 0 {
                continue;
            }
            let s = Scheme::from_name(scheme).expect("computed points are registered");
            assert_eq!(
                *fp,
                fingerprint(bench, s),
                "{bench}/{scheme}: fingerprint not stable across recomputation"
            );
        }
        std::fs::write(&path, render_fixture(&grid)).expect("self-bless golden fixture");
        eprintln!(
            "golden fixture was in bootstrap state; self-blessed {} ({} points) — \
             commit the rewritten file to pin policy behavior from here on",
            path.display(),
            grid.len()
        );
        return;
    }
    assert!(missing.is_empty(), "points missing from the golden fixture: {missing:?}");
    assert!(stale.is_empty(), "stale golden entries (re-bless): {stale:?}");
}

/// Differential configuration: 4 SMs (so `sim_threads` actually shards
/// work) with a tighter cycle cap than the golden config — the grid is
/// 364 points x 2 engines, and a capped run's fingerprint is just as
/// discriminating.
fn differential_config(scheme: Scheme, sim_threads: usize) -> GpuConfig {
    let mut c = GpuConfig::table1_baseline().with_scheme(scheme);
    c.num_sms = 4;
    c.sim_threads = sim_threads;
    c.max_cycles = 15_000;
    c
}

fn differential_fingerprint(bench: &str, scheme: Scheme, sim_threads: usize) -> u64 {
    run_benchmark(&differential_config(scheme, sim_threads), bench, GOLDEN_PROFILE_WARPS)
        .fingerprint()
}

#[test]
fn differential_grid_is_thread_count_invariant() {
    // every registered policy x every registered bench (Table II +
    // corpus) on 4 SMs: the epoch engine must produce bit-identical
    // stats at sim-threads 1 and 4 — the hardened form of the
    // determinism contract (a policy that reads thread identity, wall
    // clock, or unordered containers fails here)
    let points: Vec<(&'static str, Scheme)> = table2()
        .chain(corpus())
        .flat_map(|b| Scheme::all().into_iter().map(move |s| (b.name, s)))
        .collect();
    let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let next = AtomicUsize::new(0);
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                let (bench, scheme) = points[i];
                let serial = differential_fingerprint(bench, scheme, 1);
                let sharded = differential_fingerprint(bench, scheme, 4);
                if serial != sharded {
                    failures.lock().unwrap().push(format!(
                        "{bench}/{scheme}: {serial:016x} (threads=1) != {sharded:016x} (threads=4)"
                    ));
                }
            });
        }
    });
    let failures = failures.into_inner().unwrap();
    assert!(
        failures.is_empty(),
        "sim-threads changed simulation results:\n{}",
        failures.join("\n")
    );
}

#[test]
fn related_work_schemes_are_stable_and_diverge() {
    // the four related-work policies (greener / compress / ltrf / regdem)
    // must be deterministic AND actually wired: each must differ from the
    // baseline and from malekeh on at least one cache-pressured Table II
    // bench, at both engine shardings
    let benches = ["kmeans", "gemm_t1", "srad_v1"];
    for threads in [1usize, 4] {
        let refs: Vec<(u64, u64)> = benches
            .iter()
            .map(|b| {
                (
                    differential_fingerprint(b, Scheme::BASELINE, threads),
                    differential_fingerprint(b, Scheme::MALEKEH, threads),
                )
            })
            .collect();
        for scheme in [Scheme::GREENER, Scheme::COMPRESS, Scheme::LTRF, Scheme::REGDEM] {
            let mut vs_baseline = false;
            let mut vs_malekeh = false;
            for (bench, &(base_fp, mal_fp)) in benches.iter().zip(&refs) {
                let a = differential_fingerprint(bench, scheme, threads);
                let b = differential_fingerprint(bench, scheme, threads);
                assert_eq!(
                    a, b,
                    "{bench}/{scheme} (threads={threads}): fingerprint not stable"
                );
                vs_baseline |= a != base_fp;
                vs_malekeh |= a != mal_fp;
            }
            assert!(
                vs_baseline,
                "{scheme} (threads={threads}) is indistinguishable from the baseline \
                 on every probe bench — the policy is not wired"
            );
            assert!(
                vs_malekeh,
                "{scheme} (threads={threads}) is indistinguishable from malekeh \
                 on every probe bench — the policy is not wired"
            );
        }
    }
}

#[test]
fn corpus_kernels_are_mutually_distinct_workloads() {
    // the generated corpus only earns its registry slots if each kernel
    // actually exercises the hierarchy differently: under the pinned
    // golden config every corpus fingerprint must differ from every
    // other corpus kernel and from the GEMM-shaped reference
    let mut fps: Vec<(&str, u64)> = corpus()
        .map(|b| (b.name, fingerprint(b.name, Scheme::MALEKEH)))
        .collect();
    fps.push(("gemm_t1", fingerprint("gemm_t1", Scheme::MALEKEH)));
    for i in 0..fps.len() {
        for j in i + 1..fps.len() {
            assert_ne!(
                fps[i].1, fps[j].1,
                "{} and {} simulate identically — a generator is degenerate",
                fps[i].0, fps[j].0
            );
        }
    }
}

#[test]
fn fifo_and_belady_fingerprints_are_stable_and_distinct() {
    // the two registry-only policies must be deterministic (same
    // fingerprint on recomputation) ...
    let mut fps = BTreeMap::new();
    for scheme in [Scheme::FIFO, Scheme::BELADY, Scheme::MALEKEH_TRADITIONAL] {
        for bench in ["kmeans", "gemm_t1", "srad_v1"] {
            let a = fingerprint(bench, scheme);
            let b = fingerprint(bench, scheme);
            assert_eq!(a, b, "{bench}/{scheme}: fingerprint not stable");
            fps.insert((scheme.name(), bench), a);
        }
    }
    // ... and actually wired: FIFO and Belady replacement must diverge
    // from each other somewhere on these cache-pressured benchmarks
    let diverges = ["kmeans", "gemm_t1", "srad_v1"]
        .iter()
        .any(|b| fps[&("fifo", *b)] != fps[&("belady", *b)]);
    assert!(diverges, "fifo and belady produced identical runs everywhere");
}

#[test]
fn hot_paths_carry_no_scheme_dispatch() {
    // the refactor's acceptance gate: sub-core and collector decide
    // nothing by scheme — a Scheme:: reference or a match on a scheme
    // field in those files means a decision leaked out of the policy
    // layer. Enforced through the simlint engine (token-level, comment-
    // and string-aware), which replaced this test's original literal
    // grep; `malekeh lint` runs the same rule tree-wide.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let report = malekeh::lint::run_tree(&root).expect("lint run over rust/src");
    let leaks: Vec<String> = report
        .findings
        .iter()
        .filter(|f| f.rule == malekeh::lint::rules::SCHEME_DISPATCH && !f.is_allowed())
        .map(|f| format!("{}:{}: {}", f.file, f.line, f.message))
        .collect();
    assert!(leaks.is_empty(), "scheme dispatch leaked into the hot path:\n{}", leaks.join("\n"));
}

#[test]
fn registry_is_reachable_from_config_layer() {
    // the config layer resolves names through the registry: unknown names
    // list the valid ones, and every registered name round-trips through
    // a `-s scheme=<name>` override
    let mut cfg = GpuConfig::table1_baseline();
    for s in Scheme::all() {
        cfg.set("scheme", s.name()).unwrap();
        assert_eq!(cfg.scheme, s);
    }
    let err = cfg.set("scheme", "not_a_policy").unwrap_err();
    assert!(
        err.contains("baseline") && err.contains("fifo") && err.contains("belady"),
        "{err}"
    );
}
