//! Integration tests for the serving subsystem (`serve::*`): persistent
//! store semantics across process "restarts" and writer races, the
//! content-based (not path-based) workload cache keying, harness
//! store-backing, and the end-to-end daemon dedupe + restart-persistence
//! contract over real localhost TCP.

use std::path::PathBuf;

use malekeh::config::{GpuConfig, Scheme};
use malekeh::harness::{ExpOpts, Runner};
use malekeh::serve::protocol::{JobSpec, JobState};
use malekeh::serve::{Client, Server, ServerOpts, Store, StoreKey};
use malekeh::sim::run_workload;
use malekeh::stats::Stats;
use malekeh::trace::{self, io as trace_io, KernelTrace, Workload};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("malekeh_serve_it_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Small options every test here shares: 1 SM, quick, serial, capped by
/// the benchmark size ("nn" is the smallest registry benchmark).
fn tiny_opts(store_dir: Option<PathBuf>) -> ExpOpts {
    ExpOpts {
        num_sms: 1,
        seed: 7,
        profile_warps: 2,
        quick: true,
        jobs: 1,
        sim_threads: 1,
        store_dir,
    }
}

/// A Stats value no simulation would produce, but internally consistent
/// (its fingerprint is computed from its own counters, so the store's
/// integrity check passes). Finding it in a Runner result proves the
/// store — not the simulator — served the point.
fn sentinel_stats() -> Stats {
    let mut s = Stats::new();
    s.cycles = 424_242;
    s.instructions = 999_999_999;
    s.warps_retired = 77;
    s.rf_reads = 5;
    s.interval_ipc = vec![3.25];
    s.sthld_trace = vec![9];
    s
}

#[test]
fn store_roundtrips_across_reopen() {
    let dir = tmp_dir("reopen");
    let cfg = tiny_opts(None).config(Scheme::MALEKEH);
    let w = Workload::builtin("nn");
    let key = StoreKey::for_run(&cfg, &w, 2).unwrap();
    let stats = run_workload(&cfg, &w, 2).unwrap();
    {
        let store = Store::open(&dir).unwrap();
        store.put(&key, &stats).unwrap();
    } // handle dropped: the record must live on disk, not in the handle
    let store = Store::open(&dir).unwrap();
    let back = store.get(&key).expect("record survives reopen");
    assert_eq!(back.fingerprint(), stats.fingerprint());
    assert_eq!(back.cycles, stats.cycles);
    assert_eq!(back.interval_ipc, stats.interval_ipc);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_writers_of_one_key_never_corrupt_it() {
    let dir = tmp_dir("race");
    let store = Store::open(&dir).unwrap();
    let key = StoreKey { config_fp: 1, workload_fp: 2, policy: "baseline".into() };
    let stats = sentinel_stats();
    // hammer the same key from many threads; atomic temp+rename means
    // every published record is complete, whichever rename lands last
    std::thread::scope(|scope| {
        for _ in 0..8 {
            scope.spawn(|| {
                for _ in 0..20 {
                    store.put(&key, &stats).unwrap();
                    if let Some(got) = store.get(&key) {
                        assert_eq!(got.fingerprint(), stats.fingerprint());
                    }
                }
            });
        }
    });
    let got = store.get(&key).expect("record present after the race");
    assert_eq!(got.fingerprint(), stats.fingerprint());
    // no temp droppings left behind
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().starts_with(".tmp-")
        })
        .collect();
    assert!(leftovers.is_empty(), "{leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn damaged_records_are_misses_and_the_runner_recovers() {
    let dir = tmp_dir("damage");
    let store = Store::open(&dir).unwrap();
    let cfg = tiny_opts(None).config(Scheme::BASELINE);
    let w = Workload::builtin("nn");
    let key = StoreKey::for_run(&cfg, &w, 2).unwrap();
    store.put(&key, &sentinel_stats()).unwrap();
    let path = dir.join(key.file_name());

    // truncation -> miss
    let full = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert!(store.get(&key).is_none(), "truncated record must miss");

    // counter tampering -> fingerprint mismatch -> miss
    std::fs::write(&path, full.replace("cycles = 424242", "cycles = 424243")).unwrap();
    assert!(store.get(&key).is_none(), "tampered record must miss");

    // a Runner over the damaged store recovers by simulating (and its
    // write-back heals the record)
    let runner = Runner::new(tiny_opts(Some(dir.clone())));
    let fresh = runner.run("nn", Scheme::BASELINE);
    assert_ne!(fresh.cycles, 424_242, "must have re-simulated, not trusted damage");
    let healed = store.get(&key).expect("write-back heals the record");
    assert_eq!(healed.fingerprint(), fresh.fingerprint());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn runner_is_store_backed_across_restarts() {
    let dir = tmp_dir("runner");
    // seed the store with a sentinel under the exact key the runner will
    // compute for ("nn", MALEKEH)
    let opts = tiny_opts(Some(dir.clone()));
    let cfg = opts.config(Scheme::MALEKEH);
    let key = StoreKey::for_run(&cfg, &Workload::builtin("nn"), opts.profile_warps).unwrap();
    Store::open(&dir).unwrap().put(&key, &sentinel_stats()).unwrap();

    // "restarted process": a fresh Runner with an empty memo cache must
    // serve the sentinel from the store instead of simulating
    let runner = Runner::new(opts.clone());
    let served = runner.run("nn", Scheme::MALEKEH);
    assert_eq!(served.cycles, 424_242, "store, not simulator, must serve this");
    assert_eq!(runner.cached(), 1, "store hit still lands in the memo cache");

    // the sharded Plan path consults the store too
    let runner2 = Runner::new(ExpOpts { jobs: 2, ..opts });
    let mut plan = runner2.plan();
    plan.add("nn", Scheme::MALEKEH);
    plan.add("nn", Scheme::BASELINE); // a genuine miss, to keep >1 point
    runner2.execute(&plan);
    assert_eq!(runner2.run("nn", Scheme::MALEKEH).cycles, 424_242);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_respects_the_byte_budget() {
    let dir = tmp_dir("gc");
    let store = Store::open(&dir).unwrap();
    let stats = sentinel_stats();
    for i in 0..6u64 {
        let key = StoreKey { config_fp: i, workload_fp: 0, policy: "baseline".into() };
        store.put(&key, &stats).unwrap();
    }
    let before = store.info().unwrap();
    assert_eq!(before.records, 6);
    let budget = before.bytes / 2;
    let report = store.gc(budget).unwrap();
    assert!(report.after.bytes <= budget, "{report:?}");
    assert_eq!(report.after.records, 6 - report.deleted);
    assert!(report.deleted >= 3, "oldest-first deletion until under budget");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite regression: the Runner used to key trace points by path
/// string, so editing a trace file in place served the OLD stats. Keys
/// are content fingerprints now — a rewrite is a miss, identical bytes
/// at another path are a hit.
#[test]
fn rewritten_trace_file_is_a_cache_miss_not_stale_stats() {
    let dir = tmp_dir("rekey");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("point.mtrace");
    let bench = trace::find("nn").unwrap();

    let runner = Runner::new(tiny_opts(None));
    trace_io::write_path(&path, &KernelTrace::generate(bench, 4, 11)).unwrap();
    let first = runner.run_trace(&path, Scheme::MALEKEH);
    assert_eq!(runner.cached(), 1);

    // rewrite the same path with different content: MUST re-simulate
    trace_io::write_path(&path, &KernelTrace::generate(bench, 4, 99)).unwrap();
    let second = runner.run_trace(&path, Scheme::MALEKEH);
    assert_eq!(runner.cached(), 2, "in-place rewrite must be a miss");
    assert_ne!(
        first.fingerprint(),
        second.fingerprint(),
        "different trace content must produce different stats"
    );

    // identical bytes under a different path: pure hit, no new entry
    let copy = dir.join("copy.mtrace");
    std::fs::copy(&path, &copy).unwrap();
    let third = runner.run_trace(&copy, Scheme::MALEKEH);
    assert_eq!(runner.cached(), 2, "same content at a new path must be a hit");
    assert_eq!(second.fingerprint(), third.fingerprint());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite regression for the serve-panic contract: hostile or
/// malformed input must produce a protocol-level `ERR` (or at worst a
/// dropped connection) — never a daemon death. Exercises every parse
/// path a client controls, then proves the daemon still serves real
/// work afterwards.
#[test]
fn daemon_survives_hostile_input() {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;

    let server = Server::bind(ServerOpts {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        store_dir: None,
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let daemon = std::thread::spawn(move || server.run().unwrap());

    // raw socket: read the greeting, then a volley of malformed requests
    // — every one must come back as a one-line ERR on a live connection
    let mut sock = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("MALEKEH-SERVE/1"), "{line:?}");
    for bad in [
        "SUBMIT bench=%zz",          // non-hex percent escape
        "SUBMIT bench=%",            // truncated escape
        "SUBMIT bench=x spurious",   // token without =
        "SUBMIT scheme=malekeh",     // no workload at all
        "SUBMIT bench=x sms=no",     // unparseable number
        "STATUS 99999",              // job that never existed
        "RESULT notanid",            // malformed job id
        "FROBNICATE all the things", // unknown verb
    ] {
        sock.write_all(format!("{bad}\n").as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR "), "{bad:?} must ERR, got {line:?}");
    }
    drop(reader);
    drop(sock);

    // truncated frame: binary junk with no terminating newline, then a
    // hard close; the handler may drop the connection, the daemon not
    let mut sock = TcpStream::connect(&addr).unwrap();
    let mut greeting = [0u8; 4];
    sock.read_exact(&mut greeting).unwrap();
    sock.write_all(&[0xff, 0xfe, 0x00, 0x80, b'S', b'U', b'B']).unwrap();
    drop(sock);

    // the daemon is still up and still does real work
    let mut client = Client::connect(&addr).unwrap();
    assert!(client.ping().unwrap().starts_with("pong"), "daemon must survive the volley");
    let spec = {
        let mut s = JobSpec::bench("nn");
        s.overrides.push(("max_cycles".to_string(), "5000".to_string()));
        s
    };
    let (id, _) = client.submit(&spec).unwrap();
    assert_eq!(client.wait(id).unwrap(), JobState::Done);
    client.shutdown().unwrap();
    daemon.join().unwrap();
}

/// Pull the 16-hex-digit `fingerprint` field out of a stats JSON line.
fn json_fingerprint(json: &str) -> u64 {
    let tag = "\"fingerprint\":\"";
    let at = json.find(tag).unwrap_or_else(|| panic!("no fingerprint in {json}"));
    u64::from_str_radix(&json[at + tag.len()..at + tag.len() + 16], 16).unwrap()
}

/// The acceptance criterion, end to end over real TCP: the same job
/// submitted twice to one daemon, and once more after a daemon restart,
/// performs exactly ONE simulation, and the served result is bit-identical
/// to a fresh storeless `--sim-threads 1` run of the same point.
#[test]
fn daemon_dedupes_in_flight_and_survives_restart() {
    let dir = tmp_dir("daemon");
    let spec = {
        let mut s = JobSpec::bench("nn");
        s.scheme = "malekeh".to_string();
        s.overrides.push(("max_cycles".to_string(), "20000".to_string()));
        s
    };

    let bind = |store: PathBuf| {
        Server::bind(ServerOpts {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            store_dir: Some(store),
        })
        .unwrap()
    };

    // ---- first daemon lifetime: miss, then in-process dedupe ----
    let server = bind(dir.clone());
    let addr = server.local_addr().to_string();
    let daemon = std::thread::spawn(move || server.run().unwrap());
    let mut client = Client::connect(&addr).unwrap();
    let (id, _) = client.submit(&spec).unwrap();
    assert_eq!(client.wait(id).unwrap(), JobState::Done);
    let fp_first = json_fingerprint(&client.result_json(id).unwrap());

    let (id2, state2) = client.submit(&spec).unwrap();
    assert_eq!(id2, id, "identical submission attaches to the same job");
    assert_eq!(state2, JobState::Done);
    let health = client.stats_json().unwrap();
    assert!(health.contains("\"sims_completed\":1"), "one sim only: {health}");
    assert!(health.contains("\"dedup_hits\":1"), "{health}");
    client.shutdown().unwrap();
    daemon.join().unwrap();

    // ---- second daemon lifetime: the store serves it, zero sims ----
    let server = bind(dir.clone());
    let addr = server.local_addr().to_string();
    let daemon = std::thread::spawn(move || server.run().unwrap());
    let mut client = Client::connect(&addr).unwrap();
    let (id3, state3) = client.submit(&spec).unwrap();
    assert_eq!(state3, JobState::Done, "store hit is done at submission time");
    let fp_restarted = json_fingerprint(&client.result_json(id3).unwrap());
    let health = client.stats_json().unwrap();
    assert!(health.contains("\"sims_completed\":0"), "no sim after restart: {health}");
    assert!(health.contains("\"store_hits\":1"), "{health}");
    client.shutdown().unwrap();
    daemon.join().unwrap();

    assert_eq!(fp_first, fp_restarted, "restart must not change a single bit");

    // ---- reference: fresh storeless run of the same point ----
    let mut cfg = GpuConfig::table1_baseline().with_scheme(Scheme::MALEKEH);
    cfg.num_sms = 2; // JobSpec::bench default, same as `malekeh simulate`
    cfg.apply(&spec.overrides).unwrap();
    cfg.validate().unwrap();
    assert_eq!(cfg.sim_threads, 1, "reference runs at --sim-threads 1");
    let reference = run_workload(&cfg, &Workload::builtin("nn"), 2).unwrap();
    assert_eq!(
        reference.fingerprint(),
        fp_first,
        "daemon result must be bit-identical to a direct storeless run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
